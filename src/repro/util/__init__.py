"""Shared runtime utilities used by both the train and serve stacks."""
from repro.util.faults import (FaultInjector, FaultSpec, InjectedFault,
                               StragglerMonitor, crash_at, delay_at)

__all__ = ["FaultInjector", "FaultSpec", "InjectedFault", "StragglerMonitor",
           "crash_at", "delay_at"]

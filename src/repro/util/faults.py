"""Shared fault runtime: deterministic injection + straggler detection.

Promoted out of ``repro.train.fault_tolerance`` (which re-exports these
names) so the *serve* stack can use the same discipline the train loop
already has: every failure mode is a named injection point that fires
deterministically, so crash recovery is testable instead of aspirational.

Injection points wired into the serving stack (``ServeEngine`` fires the
first two, the router's engine factory fires the third):

``"decode"``
    immediately before each jitted decode call (one fire per live group
    per tick) — an engine crash mid-decode.
``"prefill"``
    immediately before a cohort's prefill — an admission-time OOM.
``"artifact_load"``
    before a catalog member artifact is loaded/an engine is built — a
    deleted or tampered artifact surfacing at fleet-build time.

Every fire also counts a tagged variant ``"<point>:<tag>"`` (the engine's
``fault_tag``, ``"<entry>#r<replica>"`` in a fleet), so a spec can target
one specific engine out of a fleet sharing a single injector.

Crash specs raise :class:`InjectedFault`; delay specs sleep (a slow-step
straggler — the engine's :class:`StragglerMonitor` sees the inflated
step time). Occurrence indices are 0-based and fire at most once each,
so a rebuilt-after-crash engine serves cleanly: exactly the restore
discipline ``resilient_loop`` has always tested with ``fail_at_steps``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np


class InjectedFault(RuntimeError):
    """A deterministic, test-injected failure (never raised in
    production paths unless a :class:`FaultInjector` was attached)."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault: fire at the given 0-based occurrence indices
    of ``point`` (which may be a tagged variant like ``"decode:a@t#r0"``).
    """

    point: str
    at: Tuple[int, ...] = (0,)
    kind: str = "crash"             # "crash" | "delay"
    delay_s: float = 0.0
    message: str = ""

    def __post_init__(self):
        if self.kind not in ("crash", "delay"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        object.__setattr__(self, "at", tuple(int(i) for i in self.at))


def crash_at(point: str, *at: int, message: str = "") -> FaultSpec:
    """Crash spec: raise :class:`InjectedFault` at these occurrences of
    ``point`` (default: the first)."""
    return FaultSpec(point, at or (0,), "crash", 0.0, message)


def delay_at(point: str, delay_s: float, *at: int) -> FaultSpec:
    """Delay spec: sleep ``delay_s`` at these occurrences of ``point``
    (an injected straggler)."""
    return FaultSpec(point, at or (0,), "delay", delay_s)


class FaultInjector:
    """Deterministic failure injection for tests and chaos runs.

    The legacy train-loop interface (``fail_at_steps`` +
    :meth:`maybe_fail`) is unchanged; the serve stack uses named points:

        inj = FaultInjector(specs=[crash_at("decode", 5),
                                   delay_at("decode", 0.05, 9)])
        inj.fire("decode", tag="fast@v5e#r0")   # counts both keys

    ``fired_log`` records every fault actually delivered as
    ``(key, occurrence, kind)`` so tests can assert exactly what fired.
    """

    def __init__(self, fail_at_steps=(), specs=()):
        self.fail_at = set(fail_at_steps)
        self.fired = set()
        self.specs: List[FaultSpec] = list(specs)
        self.counts: Dict[str, int] = {}
        self.fired_log: List[Tuple[str, int, str]] = []

    def maybe_fail(self, step: int):
        """Legacy train-loop hook: raise once per scheduled step."""
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected fault at step {step}")

    def count(self, point: str) -> int:
        """Occurrences of ``point`` fired so far."""
        return self.counts.get(point, 0)

    def fire(self, point: str, tag: Optional[str] = None) -> float:
        """Count one occurrence of ``point`` (and of ``point:tag``),
        deliver any scheduled fault, and return seconds slept.

        Both keys are counted *before* any fault is delivered, so a
        crash never desynchronizes the tagged counter from the global
        one. When a delay and a crash land on the same occurrence the
        delay runs first (a straggler that then dies)."""
        keys = [point] if tag is None else [point, f"{point}:{tag}"]
        hits = []
        for key in keys:
            n = self.counts.get(key, 0)
            self.counts[key] = n + 1
            for spec in self.specs:
                if spec.point == key and n in spec.at:
                    hits.append((spec, key, n))
        slept = 0.0
        crash = None
        for spec, key, n in hits:
            self.fired_log.append((key, n, spec.kind))
            if spec.kind == "delay":
                time.sleep(spec.delay_s)
                slept += spec.delay_s
            elif crash is None:
                crash = (spec, key, n)
        if crash is not None:
            spec, key, n = crash
            raise InjectedFault(
                spec.message or f"injected {key!r} fault "
                                f"(occurrence {n})")
        return slept


# ---------------------------------------------------------------------------
# Straggler monitoring
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StragglerMonitor:
    """Per-step deadline watch: steps slower than ``factor`` x rolling
    median are counted as stragglers.

    ``skip_first`` warmup samples are discarded entirely — they never
    enter the median window. Without it the threshold is seeded from the
    first 5 samples *including* warmup/compile steps, which inflates the
    median and hides early stragglers (the serve engine's first decode
    ticks pay jit compilation, so serve-side monitors must skip them).
    """

    factor: float = 3.0
    window: int = 32
    skip_first: int = 0
    min_samples: int = 5
    _times: List[float] = dataclasses.field(default_factory=list)
    _skipped: int = 0
    stragglers: int = 0

    def observe(self, seconds: float) -> bool:
        """Returns True if this step was a straggler."""
        if self._skipped < self.skip_first:
            self._skipped += 1
            return False
        is_straggler = False
        if len(self._times) >= self.min_samples:
            med = float(np.median(self._times[-self.window:]))
            is_straggler = seconds > self.factor * med
        self._times.append(seconds)
        if is_straggler:
            self.stragglers += 1
        return is_straggler

    def reset(self) -> None:
        """Forget the rolling window and straggler count — an engine's
        ``reset_stats()`` calls this so post-swap/post-warmup medians
        aren't polluted by earlier generations. The warmup skip stays
        spent: compilation already happened, re-skipping would discard
        real samples."""
        self._times.clear()
        self.stragglers = 0

    @property
    def samples(self) -> int:
        """Recorded (post-warmup) samples."""
        return len(self._times)

    @property
    def median_s(self) -> float:
        return float(np.median(self._times)) if self._times else 0.0

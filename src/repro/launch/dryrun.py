import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver
  1. builds the full published config and its ShapeDtypeStruct inputs,
  2. jits the right step (train_4k -> train_step with optimizer;
     prefill_32k -> prefill_step; decode_* / long_* -> serve_step),
  3. ``.lower().compile()`` on the 16x16 (single-pod, 256 chip) and
     2x16x16 (multi-pod, 512 chip) meshes,
  4. records memory_analysis / cost_analysis / per-collective bytes into a
     JSON artifact consumed by the roofline benchmark and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_1_7b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import math
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs.base import (ARCH_IDS, SHAPES, ModelConfig, ShapeConfig,
                                get_config, shape_applicable)
from repro.launch import specs, steps
from repro.launch.mesh import make_production_mesh
from repro.optim.optimizers import OptState
from repro.sharding import logical, rules

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / \
    "dryrun_artifacts"

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _parse_collectives(hlo_text: str):
    """Sum result bytes per collective op class from post-SPMD HLO."""
    out = {c: {"bytes": 0, "count": 0} for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if not s or "=" not in s:
            continue
        for c in _COLLECTIVES:
            # match the op name after '=', e.g.  %x = bf16[..] all-gather(
            if f" {c}(" in s or f" {c}-start(" in s:
                lhs = s.split("=", 1)[1]
                op_pos = lhs.find(c)
                typestr = lhs[:op_pos]
                total = 0
                for m in _SHAPE_RE.finditer(typestr):
                    dt, dims = m.group(1), m.group(2)
                    if dt not in _DTYPE_BYTES:
                        continue
                    n = 1
                    for d in dims.split(","):
                        if d:
                            n *= int(d)
                    total += n * _DTYPE_BYTES[dt]
                out[c]["bytes"] += total
                out[c]["count"] += 1
                break
    return out


def _mem_analysis(compiled):
    try:
        ma = compiled.memory_analysis()
        if ma is None:
            return {}
        keys = ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes")
        return {k: int(getattr(ma, k)) for k in keys if hasattr(ma, k)}
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def _cost_analysis(compiled):
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and (
                    "flops" in k or "bytes" in k or "utilization" not in k)}
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             save: bool = True, hlo_dump: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "n_devices": 512 if multi_pod else 256}

    ok, why = shape_applicable(cfg, shape)
    if not ok:
        record.update(status="skipped", reason=why)
        _save(record, save)
        return record

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        model = steps.build_model(cfg, mesh)
        params_shape = specs.param_specs(cfg)
        lrules = rules.logical_rules(mesh, seq_shard=shape.kind != "decode")

        with mesh, logical.set_rules(mesh, lrules):
            if shape.kind == "train":
                batch = specs.batch_specs(cfg, shape)
                step = steps.make_train_step(cfg, model)
                jitted = steps.jit_train_step(step, mesh, params_shape, batch)
                opt_shape = jax.eval_shape(
                    lambda p: __import__("repro.optim.optimizers",
                                         fromlist=["adamw_init"])
                    .adamw_init(p), params_shape)
                lowered = jitted.lower(params_shape, opt_shape, batch)
            elif shape.kind == "prefill":
                batch = specs.batch_specs(cfg, shape)
                step = steps.make_prefill_step(cfg, model, shape.seq_len)
                caches_shape = jax.eval_shape(
                    lambda: model.init_caches(shape.global_batch,
                                              shape.seq_len))
                jitted = steps.jit_prefill_step(step, mesh, cfg, model,
                                                params_shape, batch,
                                                caches_shape)
                lowered = jitted.lower(params_shape, batch)
            else:  # decode
                token, caches_shape = specs.decode_specs(cfg, shape)
                step = steps.make_serve_step(cfg, model)
                jitted = steps.jit_serve_step(step, mesh, cfg, model,
                                              params_shape, caches_shape,
                                              token)
                lowered = jitted.lower(params_shape, token, caches_shape)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        hlo = compiled.as_text()
        from repro.launch import hlo_stats
        record.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            param_bytes_global=specs.spec_bytes(params_shape),
            memory_analysis=_mem_analysis(compiled),
            cost_analysis=_cost_analysis(compiled),
            collectives=_parse_collectives(hlo),
            # loop-corrected per-device stats (see hlo_stats.py)
            hlo_stats=hlo_stats.stats_from_text(
                hlo, n_devices=record["n_devices"]),
            hlo_lines=hlo.count("\n"),
        )
        if hlo_dump:
            (ARTIFACT_DIR / f"{arch}__{shape_name}__{mesh_name}.hlo.txt"
             ).write_text(hlo)
        del compiled, lowered, hlo
    except Exception as e:
        record.update(status="failed", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:])
    _save(record, save)
    return record


def _save(record: dict, save: bool):
    if not save:
        return
    ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)
    name = f"{record['arch']}__{record['shape']}__{record['mesh']}.json"
    (ARTIFACT_DIR / name).write_text(json.dumps(record, indent=2))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell")
    ap.add_argument("--hlo-dump", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else (args.arch,)
    shapes = list(SHAPES) if (args.all or not args.shape) else (args.shape,)
    meshes = {"single": (False,), "multi": (True,),
              "both": (False, True)}[args.mesh]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    n_ok = n_skip = n_fail = 0
    for a, s, mp in cells:
        r = run_cell(a, s, mp, hlo_dump=args.hlo_dump)
        tag = {"ok": "OK  ", "skipped": "SKIP", "failed": "FAIL"}[r["status"]]
        extra = ""
        if r["status"] == "ok":
            fl = r["cost_analysis"].get("flops", 0)
            extra = (f"compile {r['compile_s']:.1f}s flops {fl:.3g} "
                     f"hlo_lines {r['hlo_lines']}")
            n_ok += 1
        elif r["status"] == "skipped":
            extra = r["reason"]
            n_skip += 1
        else:
            extra = r["error"][:160]
            n_fail += 1
        print(f"[{tag}] {a:24s} {s:12s} {r['mesh']:6s} {extra}", flush=True)
    print(f"\ntotal: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())

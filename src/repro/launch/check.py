"""``launch/check.py`` — thin alias for ``python -m repro.analysis``.

Runs the static kernel checker, the jaxpr auditor, and the paged-KV
sanitizer over a config x target matrix and exits non-zero on errors:

  PYTHONPATH=src python -m repro.launch.check \
      --config granite_moe_1b_a400m --targets tpu_v5e,edge

All flags are forwarded verbatim — see ``python -m repro.analysis -h``.
"""
import sys

from repro.analysis.__main__ import main

if __name__ == "__main__":
    sys.exit(main())

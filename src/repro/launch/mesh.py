"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not module-level state) so importing
this module never touches jax device initialization — the dry-run must set
XLA_FLAGS before anything initializes the backend.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_mesh_compat(shape, axes, devices=None):
    """``jax.make_mesh`` across jax versions: newer jax takes ``axis_types``
    (we want Auto, its default); jax <= 0.4 has neither the kwarg nor
    ``jax.sharding.AxisType`` — omitting them is the same behavior."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes, devices=devices)
    return jax.make_mesh(shape, axes, devices=devices,
                         axis_types=(axis_type.Auto,) * len(shape))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod prepends a 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import math
    n = math.prod(shape)
    return make_mesh_compat(shape, axes, jax.devices()[:n])


def make_test_mesh(n_devices: Optional[int] = None, *,
                   model: Optional[int] = None):
    """Small mesh over however many (host) devices exist — for CI tests."""
    n = n_devices or len(jax.devices())
    model = model or (2 if n % 2 == 0 and n > 1 else 1)
    data = n // model
    return make_mesh_compat((data, model), ("data", "model"),
                            jax.devices()[: data * model])


def required_devices(multi_pod: bool) -> int:
    return 512 if multi_pod else 256

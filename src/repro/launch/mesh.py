"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not module-level state) so importing
this module never touches jax device initialization — the dry-run must set
XLA_FLAGS before anything initializes the backend.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax


class MeshError(ValueError):
    """Requested mesh shape does not fit the available devices."""


def make_mesh_compat(shape, axes, devices=None):
    """``jax.make_mesh`` across jax versions: newer jax takes ``axis_types``
    (we want Auto, its default); jax <= 0.4 has neither the kwarg nor
    ``jax.sharding.AxisType`` — omitting them is the same behavior."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes, devices=devices)
    return jax.make_mesh(shape, axes, devices=devices,
                         axis_types=(axis_type.Auto,) * len(shape))


def _check_devices(shape: Tuple[int, ...], axes: Tuple[str, ...],
                   what: str) -> None:
    n = math.prod(shape)
    avail = len(jax.devices())
    if avail < n:
        dims = "x".join(str(s) for s in shape)
        raise MeshError(
            f"{what} needs {n} devices ({dims} over axes {axes}) but only "
            f"{avail} {'is' if avail == 1 else 'are'} available — on a "
            f"host-only machine set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n} before jax "
            f"initializes, or request a smaller mesh")


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod prepends a 2-pod axis.

    Raises :class:`MeshError` naming the requested vs available device
    count when the pod does not fit — never silently truncates."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    _check_devices(shape, axes,
                   f"make_production_mesh(multi_pod={multi_pod})")
    n = math.prod(shape)
    return make_mesh_compat(shape, axes, jax.devices()[:n])


def make_test_mesh(n_devices: Optional[int] = None, *,
                   model: Optional[int] = None):
    """Small ``(data, model)`` mesh over however many (host) devices exist
    — for CI tests and host-device sharded serving. ``model`` pins the
    tensor-parallel axis; it must divide ``n_devices``."""
    n = n_devices or len(jax.devices())
    model = model or (2 if n % 2 == 0 and n > 1 else 1)
    if model < 1 or n % model != 0:
        raise MeshError(
            f"make_test_mesh: model axis {model} does not divide the "
            f"{n} requested device(s) — a ({n // model}, {model}) "
            f"(data, model) mesh is not expressible; pick a model degree "
            f"dividing {n}")
    data = n // model
    _check_devices((data, model), ("data", "model"),
                   f"make_test_mesh(n_devices={n}, model={model})")
    return make_mesh_compat((data, model), ("data", "model"),
                            jax.devices()[: data * model])


def required_devices(multi_pod: bool) -> int:
    return 512 if multi_pod else 256

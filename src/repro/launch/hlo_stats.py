"""Loop-aware HLO statistics for the roofline analysis.

``compiled.cost_analysis()`` on the CPU backend counts while-loop bodies
ONCE (HloCostAnalysis does not multiply by trip count), which undercounts a
scan-over-layers model by ~n_layers x. This parser walks the
post-optimization HLO text instead:

  * per-computation: dot FLOPs (from result shape x contracting dims),
    HBM bytes at fusion granularity (operands + results of top-level ops —
    fusion-internal intermediates never touch HBM), collective wire bytes
    (class-specific ring formulas using the replica-group size);
  * a call graph (while bodies x known_trip_count from backend_config,
    fusions / calls / conditionals x 1) propagates totals to ENTRY.

Every number is per device (the HLO is the SPMD-partitioned module).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2,
                "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
                "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
                "token": 0, "f8e4m3fn": 1, "f8e5m2": 1}

_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{")
_LHS_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\((.*)$")


def _split_op(line: str):
    """-> (name, typestr, opcode, args) or None. Handles tuple result types
    containing '=' inside /*index=k*/ comments."""
    m = _LHS_RE.match(line)
    if not m:
        return None
    name, rhs = m.group(1), m.group(2).strip()
    if rhs.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        typestr, rest = rhs[:end + 1], rhs[end + 1:]
    else:
        j = rhs.find("(")
        if j < 0:
            return None
        k = rhs.rfind(" ", 0, j)
        if k < 0:
            return None
        typestr, rest = rhs[:k], rhs[k:]
    om = _OPCODE_RE.match(rest)
    if not om:
        return None
    return name, typestr, om.group(1), om.group(2)
_TRIP_RE = re.compile(r'known_trip_count[\'"]?\s*:\s*\{\s*[\'"]n[\'"]\s*:'
                      r'\s*[\'"](\d+)[\'"]')
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "partition-id", "replica-id",
             "iota", "reshape"}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _type_bytes(typestr: str) -> int:
    total = 0
    for m in _TYPE_RE.finditer(typestr):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _type_shape(typestr: str) -> Optional[Tuple[str, Tuple[int, ...]]]:
    m = _TYPE_RE.search(typestr)
    if not m:
        return None
    dims = tuple(int(d) for d in m.group(2).split(",") if d)
    return m.group(1), dims


@dataclasses.dataclass
class CompStats:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    collective_counts: Dict[str, int] = dataclasses.field(
        default_factory=dict)
    # (child_comp, multiplier, kind) edges
    children: List[Tuple[str, float, str]] = dataclasses.field(
        default_factory=list)


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return default


def _wire_bytes(op: str, result_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * result_bytes * (g - 1) / g
    if op == "all-gather":
        return result_bytes * (g - 1) / g
    if op == "reduce-scatter":
        return result_bytes * (g - 1)           # input = result * g
    if op == "all-to-all":
        return result_bytes * (g - 1) / g
    return float(result_bytes)                   # collective-permute


def parse_hlo(text: str, *, n_devices: int = 256) -> Dict[str, CompStats]:
    comps: Dict[str, CompStats] = {}
    symtab: Dict[str, str] = {}
    cur: Optional[str] = None
    entry: Optional[str] = None

    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None or line.endswith("{"):
            h = _HEADER_RE.match(line.strip())
            if h and line.strip().endswith("{"):
                cur = h.group(1)
                comps[cur] = CompStats()
                symtab = {}
                if line.strip().startswith("ENTRY"):
                    entry = cur
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        parsed = _split_op(line)
        if not parsed:
            continue
        name, typestr, opcode, rest = parsed
        symtab[name] = typestr
        if opcode in _SKIP_OPS:
            continue
        st = comps[cur]
        result_bytes = _type_bytes(typestr)

        # --- collectives ---
        base = opcode[:-6] if opcode.endswith("-start") else opcode
        if base in _COLLECTIVES:
            g = _group_size(line, n_devices)
            wb = _wire_bytes(base, result_bytes, g)
            st.collective_bytes[base] = st.collective_bytes.get(base, 0.0) + wb
            st.bytes += result_bytes        # it also touches HBM
            st.collective_counts[base] = st.collective_counts.get(base, 0) + 1
            continue

        # --- call graph ---
        if opcode == "while":
            trip = 1
            t = _TRIP_RE.search(line)
            if t:
                trip = int(t.group(1))
            b = re.search(r"body=%?([\w\.\-]+)", line)
            c = re.search(r"condition=%?([\w\.\-]+)", line)
            if b:
                st.children.append((b.group(1), float(trip), "while"))
            if c:
                st.children.append((c.group(1), float(trip), "while_cond"))
            continue
        if opcode == "fusion":
            cc = re.search(r"calls=%?([\w\.\-]+)", line)
            if cc:
                # flops inside fusions count; bytes counted at this level
                st.children.append((cc.group(1), 1.0, "fusion"))
        if opcode in ("call", "custom-call"):
            cc = re.search(r"to_apply=%?([\w\.\-]+)", line)
            if cc:
                st.children.append((cc.group(1), 1.0, "call"))
        if opcode == "conditional":
            for cc in re.finditer(r"(?:true_computation|false_computation|"
                                  r"branch_computations=\{)%?([\w\.\-]+)",
                                  line):
                st.children.append((cc.group(1), 1.0, "cond"))

        # --- dot flops ---
        if opcode == "dot":
            out = _type_shape(typestr)
            lhs_m = re.match(r"\s*%?([\w\.\-]+)", rest)
            cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
            contract = 1
            if lhs_m and cdims and lhs_m.group(1) in symtab:
                lshape = _type_shape(symtab[lhs_m.group(1)])
                if lshape:
                    for d in cdims.group(1).split(","):
                        if d and int(d) < len(lshape[1]):
                            contract *= lshape[1][int(d)]
            if out:
                st.flops += 2.0 * math.prod(out[1] or (1,)) * contract

        # --- HBM bytes (fusion granularity) ---
        # Sliced accesses read/write only the slice, not the full operand:
        # counting the operand of a dynamic-slice inside a scan body (the
        # whole xs array) once per trip would overstate traffic by the
        # sequence length.
        if opcode in ("dynamic-slice", "gather"):
            st.bytes += 2 * result_bytes
            continue
        if opcode == "dynamic-update-slice":
            # aliased in place: traffic = the update slice (2nd operand)
            ops = re.findall(r"%([\w\.\-]+)", rest)
            upd = symtab.get(ops[1]) if len(ops) > 1 else None
            st.bytes += 2 * (_type_bytes(upd) if upd else result_bytes)
            continue
        if opcode in ("scatter", "select-and-scatter"):
            ops = re.findall(r"%([\w\.\-]+)", rest)
            upd = symtab.get(ops[-1]) if ops else None
            st.bytes += 2 * (_type_bytes(upd) if upd else result_bytes)
            continue
        operand_bytes = 0
        for om in re.finditer(r"%([\w\.\-]+)", rest.split("),")[0]):
            t = symtab.get(om.group(1))
            if t:
                operand_bytes += _type_bytes(t)
        st.bytes += result_bytes + operand_bytes

    comps["__entry__"] = comps.get(entry, CompStats()) if entry else \
        CompStats()
    comps["__entry_name__"] = entry  # type: ignore
    return comps


def aggregate(comps: Dict[str, CompStats]) -> Dict[str, float]:
    """Propagate child totals (x multiplier) up to ENTRY."""
    entry = comps.get("__entry_name__")
    memo: Dict[str, Dict[str, float]] = {}
    visiting = set()

    def total(name: str) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        if name in visiting or name not in comps or \
                not isinstance(comps[name], CompStats):
            return {"flops": 0.0, "bytes": 0.0,
                    **{f"coll_{c}": 0.0 for c in _COLLECTIVES}}
        visiting.add(name)
        st = comps[name]
        out = {"flops": st.flops, "bytes": st.bytes}
        for c in _COLLECTIVES:
            out[f"coll_{c}"] = st.collective_bytes.get(c, 0.0)
        for child, mult, kind in st.children:
            sub = total(child)
            for k in out:
                if kind == "fusion" and k == "bytes":
                    continue        # fusion-internal traffic stays on-chip
                out[k] += sub[k] * mult
        visiting.discard(name)
        memo[name] = out
        return out

    if not entry:
        return {}
    agg = total(entry)
    agg["collective_bytes"] = sum(agg[f"coll_{c}"] for c in _COLLECTIVES)
    return agg


def stats_from_text(text: str, *, n_devices: int = 256) -> Dict[str, float]:
    return aggregate(parse_hlo(text, n_devices=n_devices))

"""Sharded training driver (production entry point).

On real hardware this runs under ``jax.distributed`` with one process per
host; on this container it drives the same code over N host devices
(``--devices N`` sets xla_force_host_platform_device_count) so the whole
stack — sharded step, checkpoint/restore, elastic re-mesh — is exercised.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3_1_7b \
      --reduced --devices 8 --steps 20 --dp 4 --tp 2
"""
import argparse
import os
import sys


def _early_env():
    ap = _parser()
    args, _ = ap.parse_known_args()
    if args.devices > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")
    return args


def _parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--resume", action="store_true")
    return ap


def main():
    args = _early_env()
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config, get_reduced_config
    from repro.data.pipeline import DataPipeline
    from repro.launch import steps as steps_mod
    from repro.optim.optimizers import adamw_init, sgd_init
    from repro.models.model import init_params
    from repro.sharding import logical, rules
    from repro.train.checkpoint import CheckpointManager

    cfg = (get_reduced_config if args.reduced else get_config)(args.arch)
    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((args.dp, args.tp), ("data", "model"),
                            jax.devices()[: args.dp * args.tp])
    model = steps_mod.build_model(cfg, mesh)
    pipe = DataPipeline(cfg, global_batch=args.batch, seq_len=args.seq)

    params = init_params(jax.random.PRNGKey(0), cfg)
    init = adamw_init if args.optimizer == "adamw" else sgd_init
    opt_state = init(params)

    lrules = rules.logical_rules(mesh)
    step = steps_mod.make_train_step(cfg, model, lr=args.lr,
                                     optimizer=args.optimizer)
    with mesh, logical.set_rules(mesh, lrules):
        jitted = steps_mod.jit_train_step(
            step, mesh, jax.eval_shape(lambda: params),
            jax.eval_shape(lambda: pipe.batch(0)),
            optimizer=args.optimizer, donate=False)

        pspec = rules.param_pspecs(params, mesh)
        params = jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            params, pspec, is_leaf=lambda x: isinstance(x, P))

        ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
        start = 0
        if ckpt and args.resume and ckpt.latest_step() is not None:
            start, state, _ = ckpt.restore(
                {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            print(f"resumed from step {start}")

        for i in range(start, args.steps):
            batch = pipe.batch(i)
            params, opt_state, metrics = jitted(params, opt_state, batch)
            if i % 5 == 0 or i == args.steps - 1:
                print(f"step {i:4d}  loss {float(metrics['loss']):.4f}  "
                      f"acc {float(metrics['acc']):.3f}")
            if ckpt and (i + 1) % 10 == 0:
                ckpt.save(i + 1, {"params": params, "opt": opt_state})
        if ckpt:
            ckpt.save(args.steps, {"params": params, "opt": opt_state},
                      block=True)
    print("done")


if __name__ == "__main__":
    main()

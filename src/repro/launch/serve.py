"""Sharded serving driver (production entry point).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_1_7b --reduced \
      --devices 4 --dp 2 --tp 2 --requests 8

Serving a deployment artifact (the export -> load -> serve flow; the
prune/tune session that produced it need not exist in this process):

  PYTHONPATH=src python -m repro.launch.serve --artifact path/to/artifact

Serving a whole catalog (Plan.export_catalog output) through the
SLO-aware router — per-request latency budgets dispatch to the cheapest
satisfying frontier artifact:

  PYTHONPATH=src python -m repro.launch.serve --catalog path/to/fleet \
      --budget-ms 5,50 --requests 16

Fault-tolerant fleet serving: ``--replicas N`` puts every entry behind a
ReplicaSupervisor (N engines, crash recovery, deadline-ordered bounded
intake), ``--max-queue``/``--retry-budget`` bound admission and
re-queues, and ``--chaos`` injects a deterministic failure mix (engine
crash mid-decode, a straggler tick) to demonstrate recovery:

  PYTHONPATH=src python -m repro.launch.serve --catalog path/to/fleet \
      --replicas 2 --max-queue 32 --retry-budget 3 --chaos

Autopilot serving: ``--autopilot`` puts the catalog router under the
online control plane — every ``--check-every`` steps it scores each
entry's predicted-vs-measured drift and budget-violation rate, and on a
threshold crossing it replans under the recalibrated replay oracle and
hot-swaps the new catalog generation in (new requests route on the new
generation, in-flight requests drain on the old engines; a worse
generation is rolled back after ``--probation-steps``):

  PYTHONPATH=src python -m repro.launch.serve --catalog path/to/fleet \
      --autopilot --budget-ms 5,50 --requests 16 --max-swaps 1
"""
import argparse
import os


def _early_env():
    ap = _parser()
    args, _ = ap.parse_known_args()
    if args.devices > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")
    return args


def _parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--artifact", default=None,
                    help="serve a DeploymentArtifact directory (overrides "
                         "--arch/--reduced; params, config, and the tuned "
                         "decode-step prediction all come from the artifact)")
    ap.add_argument("--catalog", default=None,
                    help="serve an ArtifactCatalog directory (a "
                         "Plan.export_catalog output) through the SLO "
                         "router; overrides --artifact/--arch")
    ap.add_argument("--budget-ms", default=None,
                    help="comma-separated per-request latency budgets in "
                         "ms, cycled over the synthetic requests "
                         "(catalog mode; e.g. '5,50')")
    ap.add_argument("--floor", type=float, default=None,
                    help="per-request accuracy floor (catalog mode)")
    ap.add_argument("--route-policy", default="quality",
                    choices=["quality", "cheapest"])
    ap.add_argument("--on-unroutable", default="flag",
                    choices=["reject", "flag"])
    ap.add_argument("--scheduler", default="bucketed",
                    choices=["bucketed", "fifo", "wave"],
                    help="engine admission policy (wave = the legacy "
                         "blocking drain, kept for comparison)")
    ap.add_argument("--record", default=None,
                    help="record the observed decode step into this "
                         "MeasurementLog JSON (feeds "
                         "DeploymentArtifact.recalibrated_oracle)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="supervised engine replicas per catalog entry "
                         "(or per artifact); >1 implies fleet serving")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bound each entry's intake + in-flight; overflow "
                         "is shed with RouteError at submit")
    ap.add_argument("--retry-budget", type=int, default=2,
                    help="per-request re-queue budget after engine "
                         "crashes (beyond it the request fails "
                         "explicitly)")
    ap.add_argument("--chaos", action="store_true",
                    help="inject a deterministic failure mix (decode "
                         "crash + straggler) to demonstrate supervised "
                         "recovery")
    ap.add_argument("--autopilot", action="store_true",
                    help="catalog mode only: watch per-entry drift "
                         "(oracle_rel_error, budget_violation_rate, "
                         "crashes/quarantine), replan under the "
                         "recalibrated oracle, and hot-swap new catalog "
                         "generations with zero downtime")
    ap.add_argument("--check-every", type=int, default=16,
                    help="router steps between autopilot health sweeps")
    ap.add_argument("--rel-error-threshold", type=float, default=0.5,
                    help="windowed |measured-predicted|/predicted that "
                         "counts as oracle drift")
    ap.add_argument("--violation-threshold", type=float, default=0.5,
                    help="per-entry budget-violation rate that counts "
                         "as drift")
    ap.add_argument("--probation-steps", type=int, default=64,
                    help="router steps a freshly swapped generation "
                         "serves before it is judged (worse violation "
                         "rate than the outgoing generation -> rollback)")
    ap.add_argument("--cooldown-steps", type=int, default=64,
                    help="minimum router steps between replans "
                         "(a rollback quadruples it)")
    ap.add_argument("--keep-generations", type=int, default=3,
                    help="old catalog generations kept on disk after a "
                         "passed probation")
    ap.add_argument("--max-swaps", type=int, default=None,
                    help="hard cap on autonomous swaps (default: "
                         "unlimited)")
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel serving degree: >1 shards "
                         "params and KV over the mesh's model axis "
                         "(ShardedServeEngine); the mesh must supply tp "
                         "devices — errors name the shortfall")
    ap.add_argument("--mesh", default=None,
                    help="explicit (data, model) serving mesh as DxM "
                         "(e.g. 1x2); must agree with --tp and fit "
                         "--devices — mismatches raise MeshError naming "
                         "both shapes")
    ap.add_argument("--replicas-per-entry", type=int, default=None,
                    help="catalog mode: supervised engine replicas per "
                         "catalog entry (overrides --replicas there; "
                         "each replica of a tp>1 entry gets the full "
                         "sharded mesh)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    return ap


def _serving_mesh(args):
    """The (data, model) serving mesh implied by --mesh/--tp, or None
    for plain single-device serving. Every failure mode raises
    :class:`~repro.launch.mesh.MeshError` naming the shapes involved:
    a --mesh string whose model axis disagrees with --tp, or a mesh
    that needs more devices than --devices forced into existence."""
    if args.mesh is None and args.tp <= 1:
        return None
    from repro.launch.mesh import MeshError, make_test_mesh
    from repro.serve.distributed import validate_mesh
    if args.mesh is not None:
        try:
            data, model = (int(x) for x in args.mesh.lower().split("x"))
        except ValueError:
            raise SystemExit(
                f"--mesh must be DATAxMODEL (e.g. 1x2), got {args.mesh!r}")
        if args.tp > 1 and model != args.tp:
            raise MeshError(
                f"--mesh {args.mesh} has a model axis of {model} but "
                f"--tp {args.tp} asks for {args.tp} model shards — a "
                f"({data}, {model}) (data, model) mesh cannot serve "
                f"tp={args.tp}; pass --mesh {data}x{args.tp} or drop --tp")
        mesh = make_test_mesh(n_devices=data * model, model=model)
    else:
        mesh = make_test_mesh(n_devices=args.tp, model=args.tp)
    validate_mesh(mesh, tp=args.tp if args.tp > 1 else None,
                  what=f"--tp {args.tp}")
    return mesh


def _requests(args, cfg, budgets):
    import numpy as np
    rng = np.random.default_rng(0)
    from repro.serve.engine import Request
    for i in range(args.requests):
        budget = budgets[i % len(budgets)] if budgets else None
        yield Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                size=args.prompt_len).astype(np.int32),
            max_new_tokens=args.max_new,
            temperature=0.0 if i % 2 == 0 else 0.8,
            latency_budget_s=budget,
            accuracy_floor=args.floor)


def _print_stats(stats, indent=""):
    for k, v in stats.items():
        if k == "per_artifact":
            for name, sub in v.items():
                print(f"{indent}[{name}]")
                _print_stats(sub, indent + "  ")
        elif k == "per_replica":
            for i, sub in enumerate(v):
                print(f"{indent}[replica {i}]")
                _print_stats(sub, indent + "  ")
        else:
            print(f"{indent}{k}: {v}")


def _catalog_replan(catalog):
    """Replan closure for a disk-loaded catalog (no in-process Plan to
    re-run): re-sweep the catalog's own strategy x target arms under the
    recalibrated oracle, scoring accuracy by parameter retention — the
    serve driver has no training data, so retention stands in for the
    eval hook; a real deployment drives the Autopilot through the Python
    API with its own TrainHooks instead."""
    from repro.api import CPruneConfig, TrainHooks, Workload, plan

    cfg = catalog.artifact(catalog.names[0]).cfg
    strategies = list(dict.fromkeys(e.strategy for e in catalog.entries))
    targets = list(dict.fromkeys(e.target for e in catalog.entries))

    def _count(p):
        import jax
        return sum(x.size for x in jax.tree_util.tree_leaves(p))

    def _replan(trigger, oracle):
        import jax

        from repro.models.model import init_params
        params = init_params(jax.random.PRNGKey(0), cfg)
        n0 = _count(params)
        hooks = TrainHooks(short_term_train=lambda p, s: p,
                           eval_acc=lambda p, s: _count(p) / n0)
        return plan(cfg, accuracy_floor=0.0, targets=targets,
                    strategies=strategies,
                    workload=Workload(tokens_global=8192), hooks=hooks,
                    params=params, pcfg=CPruneConfig(a_g=0.0, seq_len=64),
                    oracle=oracle)

    return _replan


def _chaos_injector():
    """The --chaos failure mix: one engine crash early in decode plus
    one straggler tick — deterministic, so every run demonstrates a
    contained crash, a cold rebuild, and a re-queue."""
    from repro.util.faults import FaultInjector, crash_at, delay_at
    return FaultInjector(specs=[crash_at("decode", 3),
                                delay_at("decode", 0.05, 10)])


def main():
    args = _early_env()
    import jax

    from repro.configs import get_config, get_reduced_config
    from repro.core.oracle import MeasurementLog
    from repro.models.model import init_params
    from repro.serve.engine import ServeEngine

    log = MeasurementLog() if args.record else None
    budgets = [float(b) * 1e-3 for b in args.budget_ms.split(",")] \
        if args.budget_ms else None

    mesh = _serving_mesh(args)
    if mesh is not None:
        print(f"serving mesh: "
              f"{dict((k, int(v)) for k, v in dict(mesh.shape).items())} "
              f"(tp={int(dict(mesh.shape)['model'])})")

    faults = _chaos_injector() if args.chaos else None
    retry = None
    if args.retry_budget != 2 or args.chaos:
        from repro.serve.fleet import RetryPolicy
        retry = RetryPolicy(max_retries=args.retry_budget)

    if args.catalog:
        from repro.serve.fleet import RouteError
        from repro.serve.router import ArtifactCatalog, Router
        # fleet serving loads lazily: a broken member is quarantined at
        # its engine-build time instead of refusing the whole catalog
        catalog = ArtifactCatalog.load(args.catalog, lazy=True)
        print(f"routing over catalog {args.catalog}:\n{catalog.summary()}")
        router = Router(catalog, policy=args.route_policy,
                        on_unroutable=args.on_unroutable,
                        scheduler=args.scheduler, measurements=log,
                        replicas=args.replicas_per_entry or args.replicas,
                        max_queue=args.max_queue,
                        retry=retry, faults=faults, mesh=mesh)
        cfg = catalog.artifact(catalog.names[0]).cfg
        pilot = None
        if args.autopilot:
            from repro.serve.autopilot import Autopilot, AutopilotConfig
            acfg = AutopilotConfig(
                check_every=args.check_every,
                rel_error_threshold=args.rel_error_threshold,
                violation_threshold=args.violation_threshold,
                probation_steps=args.probation_steps,
                cooldown_steps=args.cooldown_steps,
                keep_generations=args.keep_generations,
                max_swaps=args.max_swaps)
            pilot = Autopilot(router, replan=_catalog_replan(catalog),
                              config=acfg, log=log, faults=faults)
            print(f"autopilot on: check_every={acfg.check_every} "
                  f"rel_error>{acfg.rel_error_threshold} "
                  f"violation_rate>{acfg.violation_threshold} "
                  f"probation={acfg.probation_steps} "
                  f"keep={acfg.keep_generations} generations")
        shed = 0
        for req in _requests(args, cfg, budgets):
            try:
                router.submit(req)
            except RouteError as e:
                shed += 1
                print(f"shed: {e}")
        if pilot is not None:
            pstats = pilot.run()
            stats = router.stats()
            print("autopilot:")
            _print_stats(pstats, "  ")
        else:
            stats = router.run()
        _print_stats(stats)
        if shed:
            print(f"shed_at_submit: {shed}")
        if stats["quarantined"]:
            print(f"quarantined entries: {stats['quarantined']}")
        if log is not None:
            log.save(args.record)
            print(f"recorded {len(log)} measurement(s) -> {args.record}")
        return

    art = None
    if args.artifact:
        from repro.api.artifact import DeploymentArtifact
        art = DeploymentArtifact.load(args.artifact)
        cfg = art.cfg
    else:
        cfg = (get_reduced_config if args.reduced else get_config)(args.arch)
    if cfg.is_encoder_only:
        raise SystemExit("encoder-only arch has no decode step")
    if art is not None and (args.replicas > 1 or args.chaos):
        # supervised fleet over one artifact: crash recovery + re-queue
        from repro.serve.fleet import ReplicaSupervisor
        sup = ReplicaSupervisor.from_artifact(
            art, replicas=args.replicas, name=art.cfg.name,
            faults=faults, retry=retry, max_queue=args.max_queue,
            engine_kwargs=dict(max_batch=min(8, args.requests),
                               max_seq=args.prompt_len + args.max_new,
                               scheduler=args.scheduler, measurements=log,
                               **({"mesh": mesh}
                                  if mesh is not None else {})))
        print(f"supervising {args.replicas} replica(s) of {args.artifact} "
              f"(model={cfg.name}, chaos={'on' if args.chaos else 'off'})")
        for req in _requests(args, cfg, budgets):
            sup.submit(req)
        _print_stats(sup.run())
        if log is not None:
            for eng in sup.engines:
                if eng._step_times:
                    eng.record_measurements()
            log.save(args.record)
            print(f"recorded {len(log)} measurement(s) -> {args.record}")
        return
    if art is not None:
        eng = ServeEngine.from_artifact(
            art, max_batch=min(8, args.requests),
            max_seq=args.prompt_len + args.max_new,
            scheduler=args.scheduler, measurements=log, mesh=mesh)
        print(f"serving artifact {args.artifact} "
              f"(model={cfg.name}, target={art.target.name}, "
              f"oracle={art.oracle.name}, tuned_digest={art.tuned_digest})")
    else:
        params = init_params(jax.random.PRNGKey(0), cfg)
        if mesh is not None:
            from repro.serve.distributed import ShardedServeEngine
            eng = ShardedServeEngine(
                cfg, params, mesh=mesh, max_batch=min(8, args.requests),
                max_seq=args.prompt_len + args.max_new,
                scheduler=args.scheduler, measurements=log)
        else:
            eng = ServeEngine(cfg, params, max_batch=min(8, args.requests),
                              max_seq=args.prompt_len + args.max_new,
                              scheduler=args.scheduler, measurements=log)
    for req in _requests(args, cfg, budgets):
        eng.submit(req)
    stats = eng.run()
    _print_stats(stats)
    if log is not None:
        log.save(args.record)
        print(f"recorded {len(log)} measurement(s) -> {args.record}")


if __name__ == "__main__":
    main()

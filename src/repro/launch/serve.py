"""Sharded serving driver (production entry point).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_1_7b --reduced \
      --devices 4 --dp 2 --tp 2 --requests 8

Serving a deployment artifact (the export -> load -> serve flow; the
prune/tune session that produced it need not exist in this process):

  PYTHONPATH=src python -m repro.launch.serve --artifact path/to/artifact
"""
import argparse
import os


def _early_env():
    ap = _parser()
    args, _ = ap.parse_known_args()
    if args.devices > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")
    return args


def _parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_1_7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--artifact", default=None,
                    help="serve a DeploymentArtifact directory (overrides "
                         "--arch/--reduced; params, config, and the tuned "
                         "decode-step prediction all come from the artifact)")
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    return ap


def main():
    args = _early_env()
    import numpy as np
    import jax

    from repro.configs import get_config, get_reduced_config
    from repro.models.model import init_params
    from repro.serve.engine import Request, ServeEngine

    art = None
    if args.artifact:
        from repro.api.artifact import DeploymentArtifact
        art = DeploymentArtifact.load(args.artifact)
        cfg = art.cfg
    else:
        cfg = (get_reduced_config if args.reduced else get_config)(args.arch)
    if cfg.is_encoder_only:
        raise SystemExit("encoder-only arch has no decode step")
    if art is not None:
        eng = ServeEngine.from_artifact(
            art, max_batch=min(8, args.requests),
            max_seq=args.prompt_len + args.max_new)
        print(f"serving artifact {args.artifact} "
              f"(model={cfg.name}, target={art.target.name}, "
              f"oracle={art.oracle.name}, tuned_digest={art.tuned_digest})")
    else:
        params = init_params(jax.random.PRNGKey(0), cfg)
        eng = ServeEngine(cfg, params, max_batch=min(8, args.requests),
                          max_seq=args.prompt_len + args.max_new)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                size=args.prompt_len).astype(np.int32),
            max_new_tokens=args.max_new,
            temperature=0.0 if i % 2 == 0 else 0.8))
    stats = eng.run()
    for k, v in stats.items():
        print(f"{k}: {v}")


if __name__ == "__main__":
    main()

"""ShapeDtypeStruct stand-ins for every model input — no device allocation.

``input_specs(cfg, shape)`` builds the batch for a train/prefill step or the
(token, caches) pair for a decode step; ``param_specs`` and ``cache_specs``
come from jax.eval_shape over the real initializers, so the dry-run lowers
the exact production pytrees.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import Model, init_params

S = jax.ShapeDtypeStruct


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Inputs of one train/prefill step."""
    B, L = shape.global_batch, shape.seq_len
    if cfg.frontend == "audio_frames":
        return {
            "frames": S((B, L, cfg.d_model), jnp.dtype(cfg.dtype)),
            "labels": S((B, L), jnp.int32),
            "mask": S((B, L), jnp.bool_),
        }
    batch: Dict[str, Any] = {"tokens": S((B, L), jnp.int32)}
    if cfg.frontend == "vision_patches":
        F = min(cfg.frontend_seq, L // 2)
        batch["patch_embeds"] = S((B, F, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch


def decode_specs(cfg: ModelConfig, shape: ShapeConfig
                 ) -> Tuple[Any, Dict[str, Any]]:
    """(token, caches) for one decode step with a seq_len-deep cache."""
    B, L = shape.global_batch, shape.seq_len
    model = Model(cfg)
    caches = jax.eval_shape(lambda: model.init_caches(B, L))
    token = S((B, 1), jnp.int32)
    return token, caches


def param_specs(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))


def spec_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))

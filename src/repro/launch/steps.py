"""Jitted, sharded train / prefill / serve steps for the production meshes."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import Model
from repro.optim.optimizers import (OptState, adamw_init, adamw_update,
                                    clip_by_global_norm)
from repro.sharding import logical, rules


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def build_model(cfg: ModelConfig, mesh: Optional[Mesh], *,
                seq_shard: bool = True, zero3: bool = True) -> Model:
    if mesh is None:
        return Model(cfg)

    def shard_fn(x):
        return logical.constrain(x, ("batch", "seq", None))

    gather_fn = rules.zero3_gather_fn(mesh) if zero3 else None
    return Model(cfg, shard_fn=shard_fn, gather_fn=gather_fn)


def make_train_step(cfg: ModelConfig, model: Model, *, lr: float = 1e-4,
                    optimizer: str = "adamw", grad_clip: float = 1.0):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = model.loss_fn(p, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        grads, gn = clip_by_global_norm(grads, grad_clip)
        if optimizer == "adamw":
            params, opt_state = adamw_update(params, grads, opt_state, lr=lr)
        else:
            from repro.optim.optimizers import sgd_update
            params, opt_state = sgd_update(params, grads, opt_state, lr=lr)
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics["grad_norm"] = gn
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, model: Model, max_seq: int):
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_seq)
    return prefill_step


def make_serve_step(cfg: ModelConfig, model: Model):
    def serve_step(params, token, caches):
        return model.decode_step(params, token, caches)
    return serve_step


# ---------------------------------------------------------------------------
# Sharding assembly
# ---------------------------------------------------------------------------

def train_shardings(mesh: Mesh, params_shape, batch_shape):
    pspec = rules.param_pspecs(params_shape, mesh)
    opt_spec = OptState(step=P(), m=pspec, v=pspec)
    bspec = rules.batch_pspecs(batch_shape, mesh)
    metrics_spec = None  # replicated scalars
    return pspec, opt_spec, bspec


def jit_train_step(train_step, mesh: Mesh, params_shape, batch_shape, *,
                   optimizer: str = "adamw", donate: bool = True):
    pspec, opt_spec, bspec = train_shardings(mesh, params_shape, batch_shape)
    if optimizer != "adamw":
        opt_spec = OptState(step=P(), m=opt_spec.m, v=P())
    in_sh = (_ns(mesh, pspec), _ns(mesh, opt_spec), _ns(mesh, bspec))
    out_sh = (_ns(mesh, pspec), _ns(mesh, opt_spec), None)
    return jax.jit(
        train_step, in_shardings=in_sh, out_shardings=out_sh,
        donate_argnums=(0, 1) if donate else ())


def jit_serve_step(serve_step, mesh: Mesh, cfg, model, params_shape,
                   caches_shape, token_shape, *, donate: bool = True):
    pspec = rules.param_pspecs(params_shape, mesh)
    cspec = rules.cache_pspecs(model, caches_shape, mesh)
    DATA = rules.data_axes(mesh)
    DATA = DATA if len(DATA) > 1 else (DATA[0] if DATA else None)
    B = token_shape.shape[0]
    tok_spec = rules.fit_spec((DATA, None), token_shape.shape, mesh)
    logits_spec = rules.fit_spec((DATA, None, "model"),
                                 (B, 1, cfg.vocab_size), mesh)
    return jax.jit(
        serve_step,
        in_shardings=(_ns(mesh, pspec),
                      NamedSharding(mesh, tok_spec),
                      _ns(mesh, cspec)),
        out_shardings=(NamedSharding(mesh, logits_spec), _ns(mesh, cspec)),
        donate_argnums=(2,) if donate else ())


def jit_prefill_step(prefill_step, mesh: Mesh, cfg, model, params_shape,
                     batch_shape, caches_shape):
    pspec = rules.param_pspecs(params_shape, mesh)
    bspec = rules.batch_pspecs(batch_shape, mesh)
    cspec = rules.cache_pspecs(model, caches_shape, mesh)
    DATA = rules.data_axes(mesh)
    DATA = DATA if len(DATA) > 1 else (DATA[0] if DATA else None)
    B = jax.tree.leaves(batch_shape)[0].shape[0]
    logits_spec = rules.fit_spec((DATA, None, "model"),
                                 (B, 1, cfg.vocab_size), mesh)
    return jax.jit(
        prefill_step,
        in_shardings=(_ns(mesh, pspec), _ns(mesh, bspec)),
        out_shardings=(NamedSharding(mesh, logits_spec),
                       {"stack": _ns(mesh, cspec["stack"]),
                        "tail": _ns(mesh, cspec["tail"]),
                        "pos": NamedSharding(mesh, P())}))

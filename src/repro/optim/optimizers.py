"""Optimizers: SGD+momentum (paper's choice) and AdamW (production LM).

States are plain pytrees mirroring the params, so they shard with the same
PartitionSpecs (ZeRO-style: fully sharded over data x model along with the
FSDP param sharding — no replicated optimizer memory).

``adamw_update`` keeps m/v in fp32 regardless of param dtype (bf16-safe).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    m: Any           # momentum / first moment (fp32)
    v: Any           # second moment (fp32; unused for SGD -> zeros((1,)))


def _zeros_like_f32(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale)
                        .astype(g.dtype), grads), gn


# ---------------------------------------------------------------------------
# SGD + momentum (the paper trains pruned models with SGD)
# ---------------------------------------------------------------------------

def sgd_init(params) -> OptState:
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=_zeros_like_f32(params), v=jnp.zeros((1,), jnp.float32))


def sgd_update(params, grads, state: OptState, *, lr: float,
               momentum: float = 0.9, weight_decay: float = 0.0
               ) -> Tuple[Any, OptState]:
    def upd(p, g, m):
        gf = g.astype(jnp.float32)
        if weight_decay:
            gf = gf + weight_decay * p.astype(jnp.float32)
        m_new = momentum * m + gf
        p_new = p.astype(jnp.float32) - lr * m_new
        return p_new.astype(p.dtype), m_new

    flat = jax.tree.map(upd, params, grads, state.m)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(step=state.step + 1, m=new_m, v=state.v)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params) -> OptState:
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=_zeros_like_f32(params), v=_zeros_like_f32(params))


def adamw_update(params, grads, state: OptState, *, lr: float,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1) -> Tuple[Any, OptState]:
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * gf
        v_new = b2 * v + (1 - b2) * jnp.square(gf)
        mh = m_new / c1
        vh = v_new / c2
        pf = p.astype(jnp.float32)
        p_new = pf - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * pf)
        return p_new.astype(p.dtype), m_new, v_new

    flat = jax.tree.map(upd, params, grads, state.m, state.v)
    is_t = lambda x: isinstance(x, tuple)
    return (jax.tree.map(lambda t: t[0], flat, is_leaf=is_t),
            OptState(step=step,
                     m=jax.tree.map(lambda t: t[1], flat, is_leaf=is_t),
                     v=jax.tree.map(lambda t: t[2], flat, is_leaf=is_t)))

"""Deterministic synthetic datasets (learnable, CPU-fast).

The LM task is a noisy permutation Markov chain: token_{t+1} = perm[token_t]
with probability ``p_follow``, else uniform. A model that learns the
permutation reaches ~p_follow next-token accuracy — so short-term training
inside the CPrune loop produces a real, moving accuracy signal, which the
accept/reject gates (a_s >= alpha * a_p) need.

Everything is a pure function of (seed, step, shard) — restarts replay the
exact same stream with zero loader state (the fault-tolerance story).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp

P_FOLLOW = 0.9


@functools.partial(jax.jit, static_argnums=(1, 2, 3))
def _markov_tokens(key, batch: int, seq: int, vocab: int) -> jax.Array:
    kperm, kstart, knoise, kchoice = jax.random.split(key, 4)
    # the permutation is derived from the dataset seed only (key foldable):
    perm = jax.random.permutation(jax.random.PRNGKey(1234), vocab)
    start = jax.random.randint(kstart, (batch,), 0, vocab)

    def step(tok, ks):
        k1, k2 = jax.random.split(ks)
        follow = jax.random.uniform(k1, (batch,)) < P_FOLLOW
        rand = jax.random.randint(k2, (batch,), 0, vocab)
        nxt = jnp.where(follow, perm[tok], rand)
        return nxt, nxt

    keys = jax.random.split(knoise, seq - 1)
    _, rest = jax.lax.scan(step, start, keys)
    return jnp.concatenate([start[None], rest], axis=0).T  # (batch, seq)


def markov_batch(seed: int, step: int, shard: int, *, batch: int, seq: int,
                 vocab: int) -> Dict[str, jax.Array]:
    key = jax.random.fold_in(jax.random.fold_in(
        jax.random.PRNGKey(seed), step), shard)
    return {"tokens": _markov_tokens(key, batch, seq, vocab)}


def masked_audio_batch(seed: int, step: int, shard: int, *, batch: int,
                       seq: int, vocab: int, d_model: int
                       ) -> Dict[str, jax.Array]:
    """HuBERT-style: frame embeddings + cluster labels + mask.

    Frames carry a linear signature of their label so the task is learnable:
    frame = W[label] + noise.
    """
    key = jax.random.fold_in(jax.random.fold_in(
        jax.random.PRNGKey(seed), step), shard)
    k1, k2, k3 = jax.random.split(key, 3)
    labels = _markov_tokens(k1, batch, seq, vocab)
    codebook = jax.random.normal(jax.random.PRNGKey(77), (vocab, d_model))
    frames = codebook[labels] + 0.3 * jax.random.normal(
        k2, (batch, seq, d_model))
    mask = jax.random.uniform(k3, (batch, seq)) < 0.4
    return {"frames": frames, "labels": labels, "mask": mask}


def vlm_batch(seed: int, step: int, shard: int, *, batch: int, seq: int,
              vocab: int, d_model: int, n_patches: int
              ) -> Dict[str, jax.Array]:
    key = jax.random.fold_in(jax.random.fold_in(
        jax.random.PRNGKey(seed), step), shard)
    k1, k2 = jax.random.split(key)
    out = {"tokens": _markov_tokens(k1, batch, seq, vocab)}
    F = min(n_patches, seq // 2)
    out["patch_embeds"] = jax.random.normal(k2, (batch, F, d_model)) * 0.02
    return out


def batch_for(cfg, seed: int, step: int, shard: int, *, batch: int,
              seq: int) -> Dict[str, jax.Array]:
    """Dispatch on the arch family's frontend."""
    if cfg.frontend == "audio_frames":
        return masked_audio_batch(seed, step, shard, batch=batch, seq=seq,
                                  vocab=cfg.vocab_size, d_model=cfg.d_model)
    if cfg.frontend == "vision_patches":
        return vlm_batch(seed, step, shard, batch=batch, seq=seq,
                         vocab=cfg.vocab_size, d_model=cfg.d_model,
                         n_patches=cfg.frontend_seq)
    return markov_batch(seed, step, shard, batch=batch, seq=seq,
                        vocab=cfg.vocab_size)

"""Shard-aware deterministic data pipeline.

Each data-parallel shard draws its own slice of the global batch as a pure
function of (seed, step, shard_id); the host feeding a given mesh slice
computes only its local arrays. Determinism properties (tested):

  * restart safety: batch(step) after a restart == batch(step) before it;
  * elasticity: re-sharding to n' shards preserves the *global* batch for
    a given step (shards are carved out of one global stream);
  * no two shards overlap.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data import synthetic


@dataclasses.dataclass
class DataPipeline:
    cfg: ModelConfig
    global_batch: int
    seq_len: int
    n_shards: int = 1
    shard_id: int = 0
    seed: int = 0

    def __post_init__(self):
        if self.global_batch % self.n_shards != 0:
            raise ValueError("global_batch must divide over shards")
        self.local_batch = self.global_batch // self.n_shards

    # -- the global stream is generated per-(step); shards slice it --------

    def global_batch_at(self, step: int) -> Dict[str, jax.Array]:
        return synthetic.batch_for(self.cfg, self.seed, step, 0,
                                   batch=self.global_batch, seq=self.seq_len)

    def batch(self, step: int) -> Dict[str, jax.Array]:
        g = self.global_batch_at(step)
        lo = self.shard_id * self.local_batch
        hi = lo + self.local_batch
        return {k: v[lo:hi] for k, v in g.items()}

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1

    def reshard(self, n_shards: int, shard_id: int) -> "DataPipeline":
        """Elastic re-shard: same global stream, new slice geometry."""
        return dataclasses.replace(self, n_shards=n_shards,
                                   shard_id=shard_id)

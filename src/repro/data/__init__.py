from repro.data.pipeline import DataPipeline
from repro.data.synthetic import markov_batch, masked_audio_batch, vlm_batch

__all__ = ["DataPipeline", "markov_batch", "masked_audio_batch", "vlm_batch"]

"""Jaxpr auditor: trace the serve/train steps abstractly and walk them.

Everything here runs on :class:`jax.ShapeDtypeStruct` avals — params
come from ``jax.eval_shape(init_params, ...)``, caches/pools from
``eval_shape`` over their init functions — so a 100B-parameter config
audits in milliseconds without materializing a single buffer, and the
pass works identically on CPU and TPU hosts.

``J001 f32-promotion``
    a projection/FFN-shaped ``dot_general`` (fewer than two batch dims)
    whose *inputs* are f32 inside a bf16-configured step. Attention's
    online-softmax contractions (two batch dims) intentionally run in
    f32 and are exempt; so is anything fed bf16 with an f32 accumulator
    (``preferred_element_type`` promotion is the MXU regime, not a bug).
``J002 host-transfer``
    ``device_put`` / callback primitives inside the step: each one is a
    host<->device round trip per decode token.
``J003 missed-donation``
    the paged pools argument is not donated into the engine's jitted
    step — without ``tf.aliasing_output`` on the pool buffers every
    decode token copies the whole pool (:func:`audit_engine_donation`
    inspects the *engine's actual* jitted callables).
``J004 recompile-hazard``
    serve shapes outside the pow2/bucket sets the scheduler guarantees:
    ``compact="exact"`` retraces per width, a non-pow2 ``max_batch``
    adds a stray width, a ``max_seq`` off the page grid strays off the
    pow2-padded table column set.
``J005 replicated-param``
    a large parameter that resolves to fully-replicated under a sharded
    ``(data, model)`` mesh spec — every model shard holds a full copy,
    so tensor parallelism buys no HBM for it. Advisory: small tables
    (norm scales, router gates) are *meant* to replicate; the check only
    names leaves above a size floor.

The sharding-related checks are device-free: J005 uses
:class:`repro.sharding.rules.SpecMesh` (spec math reads only the mesh
*shape*), and :func:`audit_engine_donation` / :func:`audit_engine_steps`
audit a live engine's own jits, which is the same abstract tracing
whether the engine is single-device or a mesh-sharded
:class:`~repro.serve.distributed.ShardedServeEngine` — so J002/J003 run
under a tp=2 mesh exactly as under one device.

Severities: shipped configs must audit error-free, so J001/J004/J005 are
warnings (observations about numerics/layout/compile behavior) and
J002/J003 — which are outright serving bugs — are errors.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Set, Tuple

import jax
import numpy as np

from repro.analysis.diagnostics import ERROR, WARNING, Diagnostic
from repro.configs.base import ModelConfig
from repro.models.model import Model, init_params
from repro.models.paged_cache import init_paged_pools, paged_compatible

try:  # jax >= 0.4.33 exposes the stable jaxpr types under jax.extend
    from jax.extend import core as jex_core
    _JAXPR_TYPES = (jex_core.Jaxpr, jex_core.ClosedJaxpr)
except (ImportError, AttributeError):  # pragma: no cover - older jax
    from jax import core as jex_core
    _JAXPR_TYPES = (jex_core.Jaxpr, jex_core.ClosedJaxpr)

#: primitives that force a host<->device round trip inside a step
_TRANSFER_PRIMS = {"device_put", "pure_callback", "io_callback",
                   "outside_call", "infeed", "outfeed"}
_DEBUG_PRIMS = {"debug_callback", "debug_print"}


def _as_jaxpr(x):
    return x.jaxpr if hasattr(x, "jaxpr") else x


def _iter_eqns(jaxpr):
    """Depth-first over every equation, including sub-jaxprs (scan/cond/
    while/pjit bodies)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            if isinstance(v, _JAXPR_TYPES):
                yield from _iter_eqns(_as_jaxpr(v))
            elif isinstance(v, (tuple, list)):
                for x in v:
                    if isinstance(x, _JAXPR_TYPES):
                        yield from _iter_eqns(_as_jaxpr(x))


def audit_jaxpr(jaxpr, *, site: str, expect_bf16: bool) -> List[Diagnostic]:
    """J001/J002 over one traced step."""
    jaxpr = _as_jaxpr(jaxpr)
    out: List[Diagnostic] = []
    seen_dots: Set[Tuple] = set()
    for eqn in _iter_eqns(jaxpr):
        name = eqn.primitive.name
        if name in _TRANSFER_PRIMS:
            out.append(Diagnostic(
                "J002", ERROR, f"{site}:{name}",
                f"{name} inside the jitted step forces a host<->device "
                f"transfer every invocation",
                fix_hint="move the transfer outside the step (feed the "
                         "value as an argument)"))
        elif name in _DEBUG_PRIMS:
            out.append(Diagnostic(
                "J002", WARNING, f"{site}:{name}",
                f"{name} inside the jitted step synchronizes with the "
                f"host",
                fix_hint="strip debug callbacks from production steps"))
        elif name == "dot_general" and expect_bf16:
            lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
            if (lhs.dtype == np.float32 and rhs.dtype == np.float32):
                (_, _), (lb, _rb) = eqn.params["dimension_numbers"]
                if len(lb) < 2:
                    key = (tuple(lhs.shape), tuple(rhs.shape), tuple(lb))
                    if key in seen_dots:
                        continue
                    seen_dots.add(key)
                    out.append(Diagnostic(
                        "J001", WARNING,
                        f"{site}:dot_general{list(lhs.shape)}x"
                        f"{list(rhs.shape)}",
                        "f32 x f32 GEMM inside a bf16-configured step "
                        "(4x MXU cost vs bf16 in / f32 accum)",
                        fix_hint="keep operands bf16 and request the "
                                 "f32 accumulator via "
                                 "preferred_element_type"))
    return out


# -- abstract tracing helpers ----------------------------------------------

def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), np.dtype(dtype))


def _abstract(tree):
    return jax.tree.map(lambda a: _sds(a.shape, a.dtype), tree)


def param_avals(cfg: ModelConfig):
    """The param pytree as ShapeDtypeStructs — no materialization."""
    return jax.eval_shape(lambda k: init_params(k, cfg),
                          _sds((2,), np.uint32))


def trace_decode_step(cfg: ModelConfig, *, max_batch: int = 8,
                      max_seq: int = 512):
    model = Model(cfg)
    params = param_avals(cfg)
    caches = jax.eval_shape(lambda: model.init_caches(max_batch, max_seq))
    token = _sds((max_batch, 1), np.int32)
    return jax.make_jaxpr(model.decode_step)(params, token, caches)


def trace_decode_step_paged(cfg: ModelConfig, *, max_batch: int = 8,
                            max_seq: int = 512, page_size: int = 16):
    model = Model(cfg)
    params = param_avals(cfg)
    n_blocks = 2 + max_batch * (-(-max_seq // page_size))
    pools = jax.eval_shape(
        lambda: init_paged_pools(model, n_blocks, page_size))
    n_cols = -(-max_seq // page_size)
    token = _sds((max_batch, 1), np.int32)
    table = _sds((max_batch, n_cols), np.int32)
    pos = _sds((), np.int32)
    return jax.make_jaxpr(model.decode_step_paged)(params, token, pools,
                                                   table, pos)


def trace_prefill_chunk(cfg: ModelConfig, *, max_batch: int = 8,
                        max_seq: int = 512, page_size: int = 16,
                        chunk: int = 32):
    model = Model(cfg)
    params = param_avals(cfg)
    n_blocks = 2 + max_batch * (-(-max_seq // page_size))
    pools = jax.eval_shape(
        lambda: init_paged_pools(model, n_blocks, page_size))
    n_cols = -(-max_seq // page_size)
    tokens = _sds((max_batch, chunk), np.int32)
    table = _sds((max_batch, n_cols), np.int32)
    start = _sds((), np.int32)
    last = _sds((), np.int32)
    return jax.make_jaxpr(model.prefill_chunk_paged)(
        params, tokens, pools, table, start, last)


def _batch_avals(cfg: ModelConfig, batch: int, seq: int):
    """One train batch as avals, shaped per frontend (mirrors
    ``launch.specs.batch_specs``)."""
    if cfg.frontend == "audio_frames":
        return {"frames": _sds((batch, seq, cfg.d_model), cfg.dtype),
                "labels": _sds((batch, seq), np.int32),
                "mask": _sds((batch, seq), np.bool_)}
    b = {"tokens": _sds((batch, seq), np.int32)}
    if cfg.frontend == "vision_patches":
        f = min(cfg.frontend_seq, seq // 2)
        b["patch_embeds"] = _sds((batch, f, cfg.d_model), cfg.dtype)
    return b


def trace_train_step(cfg: ModelConfig, *, batch: int = 2, seq: int = 64):
    model = Model(cfg)
    params = param_avals(cfg)

    def step(p, b):
        loss, _metrics = model.loss_fn(p, b)
        return loss
    return jax.make_jaxpr(jax.grad(step))(params,
                                          _batch_avals(cfg, batch, seq))


# -- the pass ---------------------------------------------------------------

def audit_model(cfg: ModelConfig, *, max_batch: int = 8, max_seq: int = 512,
                page_size: int = 16, include_train: bool = True
                ) -> List[Diagnostic]:
    """J001/J002 over the decode step, the paged decode/chunked-prefill
    steps (paged-compatible configs), and the train step."""
    bf16 = cfg.dtype == "bfloat16"
    out = audit_jaxpr(
        trace_decode_step(cfg, max_batch=max_batch, max_seq=max_seq),
        site=f"{cfg.name}/decode_step", expect_bf16=bf16)
    if paged_compatible(cfg):
        out.extend(audit_jaxpr(
            trace_decode_step_paged(cfg, max_batch=max_batch,
                                    max_seq=max_seq, page_size=page_size),
            site=f"{cfg.name}/decode_step_paged", expect_bf16=bf16))
        if cfg.rope != "mrope" and cfg.frontend == "none":
            out.extend(audit_jaxpr(
                trace_prefill_chunk(cfg, max_batch=max_batch,
                                    max_seq=max_seq, page_size=page_size,
                                    chunk=2 * page_size),
                site=f"{cfg.name}/prefill_chunk_paged", expect_bf16=bf16))
    if include_train:
        out.extend(audit_jaxpr(
            trace_train_step(cfg),
            site=f"{cfg.name}/train_step", expect_bf16=bf16))
    return out


def audit_serve_shapes(scheduler_config, *, max_batch: int = 8,
                       max_seq: int = 512) -> List[Diagnostic]:
    """J004: static recompilation hazards in a serve configuration."""
    out: List[Diagnostic] = []
    sc = scheduler_config
    if sc.compact == "exact":
        out.append(Diagnostic(
            "J004", WARNING, "scheduler.compact",
            "compact='exact' retraces the decode step once per distinct "
            "surviving width (O(max_batch) compiles)",
            fix_hint="use compact='pow2' (O(log max_batch) shapes)"))
    if max_batch & (max_batch - 1):
        out.append(Diagnostic(
            "J004", WARNING, "max_batch",
            f"max_batch={max_batch} is not a power of two; admitted "
            f"full-width groups add a stray decode shape outside the "
            f"pow2 compaction set",
            fix_hint="size max_batch to a power of two"))
    if sc.kv_layout == "paged" and max_seq % sc.page_size:
        out.append(Diagnostic(
            "J004", WARNING, "max_seq",
            f"max_seq={max_seq} is not a multiple of "
            f"page_size={sc.page_size}; the last block is permanently "
            f"part-padded and table growth strays off the pow2 column "
            f"grid",
            fix_hint="round max_seq to a page_size multiple"))
    return out


def audit_param_sharding(cfg: ModelConfig, *, tp: int = 2,
                         min_mib: float = 1.0) -> List[Diagnostic]:
    """J005: params left fully replicated by the sharding rules under a
    ``(1, tp)`` mesh spec. Device-free — the rule table is resolved
    against a :class:`~repro.sharding.rules.SpecMesh`, so a 100B config
    audits on a 1-CPU host."""
    from repro.sharding import rules
    if tp < 2:
        return []
    mesh = rules.SpecMesh({"data": 1, "model": int(tp)})
    avals = param_avals(cfg)
    pspecs = rules.param_pspecs(avals, mesh)
    floor = int(min_mib * (1 << 20))
    out: List[Diagnostic] = []

    def model_sharded(spec) -> bool:
        # the data axis is size 1 on a serving mesh, so only a 'model'
        # entry means the param is actually split across shards
        for ax in tuple(spec):
            axes = ax if isinstance(ax, (tuple, list)) else (ax,)
            if "model" in axes:
                return True
        return False

    def walk(avals, specs, prefix=""):
        for k in sorted(avals):
            path = f"{prefix}/{k}" if prefix else k
            a, s = avals[k], specs[k]
            if isinstance(a, dict):
                walk(a, s, path)
                continue
            nbytes = int(np.prod(a.shape)) * np.dtype(a.dtype).itemsize
            if nbytes < floor or model_sharded(s):
                continue
            out.append(Diagnostic(
                "J005", WARNING, f"{cfg.name}/{path}",
                f"param {path} ({nbytes / (1 << 20):.1f} MiB, shape "
                f"{list(a.shape)}) is not sharded over the model axis "
                f"under a (1, {tp}) (data, model) mesh — each of the "
                f"{tp} model shards holds a full copy",
                fix_hint="add a trailing-dim rule for it in "
                         "repro.sharding.rules (or accept replication "
                         "for small/irregular tables)"))

    walk(avals, pspecs)
    return out


def audit_engine_steps(engine) -> List[Diagnostic]:
    """J001/J002 over a live engine's *actual* jitted decode step.
    Tracing is abstract and placement-blind, so this runs identically
    for a single-device engine and a tp>1
    :class:`~repro.serve.distributed.ShardedServeEngine` — the mesh
    changes where buffers live, not what the jaxpr contains."""
    bf16 = engine.cfg.dtype == "bfloat16"
    site = f"{engine.cfg.name}@tp{getattr(engine, 'tp', 1)}"
    params = _abstract(engine.params)
    cur = _sds((engine.max_batch, 1), np.int32)
    if getattr(engine, "kv_layout", "contiguous") == "paged":
        sc = engine.scheduler.config
        n_cols = max(1, -(-engine.max_seq // sc.page_size))
        pools = _abstract(engine._pools)
        table = _sds((engine.max_batch, n_cols), np.int32)
        pos = _sds((), np.int32)
        jaxpr = jax.make_jaxpr(engine.model.decode_step_paged)(
            params, cur, pools, table, pos)
        return audit_jaxpr(jaxpr, site=f"{site}/decode_step_paged",
                           expect_bf16=bf16)
    caches = jax.eval_shape(
        lambda: engine.model.init_caches(engine.max_batch, engine.max_seq))
    jaxpr = jax.make_jaxpr(engine.model.decode_step)(params, cur, caches)
    return audit_jaxpr(jaxpr, site=f"{site}/decode_step", expect_bf16=bf16)


def audit_engine_donation(engine) -> List[Diagnostic]:
    """J003 against a live engine's *actual* jitted paged steps: lower
    them at the engine's shapes and require pool aliasing in the
    lowered text. Contiguous engines trivially pass."""
    out: List[Diagnostic] = []
    if getattr(engine, "kv_layout", "contiguous") != "paged":
        return out
    sc = engine.scheduler.config
    n_cols = max(1, -(-engine.max_seq // sc.page_size))
    params = _abstract(engine.params)
    pools = _abstract(engine._pools)
    cur = _sds((engine.max_batch, 1), np.int32)
    table = _sds((engine.max_batch, n_cols), np.int32)
    pos = _sds((), np.int32)
    checks = [("decode_step_paged",
               lambda: engine._decode_paged.lower(params, cur, pools,
                                                  table, pos))]
    if sc.prefill_chunk:
        toks = _sds((engine.max_batch, sc.prefill_chunk), np.int32)
        checks.append(("prefill_chunk_paged",
                       lambda: engine._chunk_step.lower(
                           params, toks, pools, table, pos, pos)))
    for name, lower in checks:
        text = lower().as_text()
        if "aliasing_output" not in text:
            out.append(Diagnostic(
                "J003", ERROR, f"engine.{name}",
                "the block pools are not donated into the jitted step — "
                "every invocation copies the entire KV pool",
                fix_hint="jit with donate_argnums=<pools arg index>"))
    return out

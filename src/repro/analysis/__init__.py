"""Static analysis & sanitizers: compiler-informed checks that run
*before* (or alongside) device execution.

Three passes, one front door:

* :mod:`~repro.analysis.kernels` — static Pallas-kernel checker
  (``K001``-``K004``): tile divisibility, grid bounds, dtype rules, and
  per-call VMEM footprints against a ``TargetSpec``, without compiling.
* :mod:`~repro.analysis.jaxpr_audit` — jaxpr auditor (``J001``-``J005``):
  abstract traces of the decode/prefill/train steps walked for f32
  promotions, host transfers, missed donation, recompile hazards.
* :mod:`~repro.analysis.kv_sanitizer` — ASAN-style paged-KV sanitizer
  (``V001``-``V005``): allocator refcounts vs live block tables, run at
  every quantum when ``SchedulerConfig(debug_kv=True)``.

Front door: ``python -m repro.analysis`` (or ``launch/check.py``) runs
all passes over a config+target matrix and exits non-zero on errors.
``session.export()`` / ``Plan.export_catalog()`` run the kernel checker
for the artifact's own target and stamp ``artifact.json`` with
``checks: {passed, codes}``.

Only the diagnostic records live at package level — the passes import
models/serve machinery, so pull them in explicitly
(``from repro.analysis import kernels``) to keep this package cheap to
import from inside the engine.
"""
from repro.analysis.diagnostics import (DIAGNOSTIC_CODES, ERROR, WARNING,
                                        AnalysisReport, Diagnostic)

__all__ = [
    "DIAGNOSTIC_CODES",
    "ERROR",
    "WARNING",
    "AnalysisReport",
    "Diagnostic",
]

"""Static checker for the Pallas kernel launches (no compilation).

Every kernel in :mod:`repro.kernels` launches from a small amount of
host-side geometry — block shapes, padded operand dims, a grid, VMEM
scratch. This pass re-derives that geometry (mirroring each kernel's own
padding/clipping math) as a :class:`KernelCall` and validates it against
the active/passed :class:`~repro.api.targets.TargetSpec`:

``K001 tile-not-divisible``
    a chosen tile is not a multiple of the hardware extent it maps onto
    (``bm``/``bq``/``bs`` second-minor tiles -> SUBLANE, ``bk``/``bn``/
    ``bw`` minor tiles -> LANE). A tile covering the whole (padded) dim
    is exempt — the kernel pads the operand itself and the grid has one
    step over that dim.
``K002 grid-bounds``
    a grid dimension <= 0, or a total step count past int32.
``K003 vmem-overflow``
    the per-call footprint (double-buffered input blocks + f32
    accumulator/scratch, :func:`cost_model.block_vmem_bytes` for GEMMs
    and the same convention for the rest) exceeds ``target.vmem_bytes``.
``K004 dtype-rule``
    inputs wider than f32 (unsupported on the MXU) — error; f32 inputs
    on a GEMM kernel (bf16 in / f32 accum is the expected regime) —
    warning.

:func:`check_model_kernels` enumerates the whole launch set one config
implies — every tuned GEMM in its task table plus the attention/scan
kernels its layer kinds use at the serve shapes — which is what the CLI
and the artifact export stamp run. Everything here is plain arithmetic:
no jit, no kernel build, no device.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.diagnostics import ERROR, WARNING, Diagnostic
from repro.configs.base import ATTN, LOCAL_ATTN, RGLRU, RWKV
from repro.core import cost_model, oracle as oracle_mod, tuner, tuning_cache
from repro.core.cost_model import Block
from repro.core.tasks import TaskTable, Workload, local_gemm_dims
from repro.models.paged_cache import RESERVED_BLOCKS

_MAX_GRID_STEPS = 2**31 - 1


def _ceil_to(x: int, b: int) -> int:
    return -(-x // b) * b


@dataclasses.dataclass(frozen=True)
class KernelCall:
    """One Pallas launch, statically described.

    ``tiles`` maps a tile name to ``(tile, padded_dim, hw_extent)`` —
    the K001 inputs; ``vmem_bytes`` is the double-buffered footprint.
    """

    kernel: str                    # matmul | moe_gmm | flash_attention | ...
    site: str                      # human label ("stack/pos0:ffn up" etc.)
    grid: Tuple[int, ...]
    tiles: Dict[str, Tuple[int, int, int]]
    vmem_bytes: int
    dtype_bytes: int
    is_gemm: bool = True


# -- per-kernel describers (mirror each kernel's launch math) ---------------

def describe_matmul(m: int, k: int, n: int, block: Block, *,
                    dtype_bytes: int = 2, site: str = "matmul",
                    lane: int = 128, sublane: int = 8) -> KernelCall:
    bm, bk, bn = block.bm, block.bk, block.bn
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    return KernelCall(
        kernel="matmul", site=site,
        grid=(mp // bm, np_ // bn, kp // bk),
        tiles={"bm": (bm, mp, sublane), "bk": (bk, kp, lane),
               "bn": (bn, np_, lane)},
        vmem_bytes=cost_model.block_vmem_bytes(bm, bk, bn, dtype_bytes),
        dtype_bytes=dtype_bytes)


def describe_moe_gmm(n_experts: int, c: int, k: int, n: int, block: Block, *,
                     dtype_bytes: int = 2, site: str = "moe_gmm",
                     lane: int = 128, sublane: int = 8) -> KernelCall:
    # the kernel clips the block to the operand dims before padding
    bm, bk, bn = min(block.bm, c), min(block.bk, k), min(block.bn, n)
    cp, kp, np_ = _ceil_to(c, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    return KernelCall(
        kernel="moe_gmm", site=site,
        grid=(n_experts, cp // bm, np_ // bn, kp // bk),
        tiles={"bm": (bm, cp, sublane), "bk": (bk, kp, lane),
               "bn": (bn, np_, lane)},
        vmem_bytes=cost_model.block_vmem_bytes(bm, bk, bn, dtype_bytes),
        dtype_bytes=dtype_bytes)


def describe_flash_attention(batch: int, sq: int, sk: int, n_heads: int,
                             head_dim: int, *, bq: int = 128, bk: int = 128,
                             dtype_bytes: int = 2,
                             site: str = "flash_attention",
                             lane: int = 128, sublane: int = 8) -> KernelCall:
    bq = min(bq, max(sq, 8))
    bk = min(bk, max(sk, 8))
    sqp, skp = _ceil_to(max(sq, 8), bq), _ceil_to(max(sk, 8), bk)
    d = head_dim
    # q/k/v blocks double-buffered + f32 online-softmax scratch
    # ((bq,128) running max + (bq,128) running sum + (bq,D) accumulator)
    vmem = (2 * dtype_bytes * (bq * d + 2 * bk * d)
            + 4 * (2 * bq * 128 + bq * d))
    return KernelCall(
        kernel="flash_attention", site=site,
        grid=(batch * n_heads, sqp // bq, skp // bk),
        tiles={"bq": (bq, sqp, sublane), "bk": (bk, skp, sublane)},
        vmem_bytes=vmem, dtype_bytes=dtype_bytes, is_gemm=False)


def describe_paged_attention(batch: int, n_heads: int, head_dim: int,
                             n_cols: int, page_size: int, *,
                             dtype_bytes: int = 2,
                             site: str = "paged_attention",
                             lane: int = 128, sublane: int = 8) -> KernelCall:
    d, bs = head_dim, page_size
    # q (1,1,D) + one KV block (1,bs,1,D) each way, double-buffered;
    # f32 scratch (1,128)x2 + (1,D)
    vmem = 2 * dtype_bytes * (d + 2 * bs * d) + 4 * (2 * 128 + d)
    return KernelCall(
        kernel="paged_attention", site=site,
        grid=(batch, n_heads, n_cols),
        tiles={"bs": (bs, n_cols * bs, sublane)},
        vmem_bytes=vmem, dtype_bytes=dtype_bytes, is_gemm=False)


def describe_rwkv6_scan(batch: int, seq: int, n_heads: int, head_dim: int, *,
                        bs: int = 64, dtype_bytes: int = 2,
                        site: str = "rwkv6_scan",
                        lane: int = 128, sublane: int = 8) -> KernelCall:
    bs = min(bs, seq)
    sp = _ceil_to(seq, bs)
    d = head_dim
    # r/k/v/w blocks + the (D,) bonus row, double-buffered; f32 state
    # scratch (D,D) + carried state block (D,D)
    vmem = (2 * dtype_bytes * (4 * bs * d + d) + 4 * 2 * d * d)
    return KernelCall(
        kernel="rwkv6_scan", site=site,
        grid=(batch * n_heads, sp // bs),
        tiles={"bs": (bs, sp, sublane)},
        vmem_bytes=vmem, dtype_bytes=dtype_bytes, is_gemm=False)


def describe_rglru_scan(batch: int, seq: int, width: int, *, bs: int = 128,
                        bw: int = 128, dtype_bytes: int = 2,
                        site: str = "rglru_scan",
                        lane: int = 128, sublane: int = 8) -> KernelCall:
    bs, bw = min(bs, seq), min(bw, width)
    sp, wp = _ceil_to(seq, bs), _ceil_to(width, bw)
    # a/x blocks double-buffered + f32 carry scratch (1,bw)
    vmem = 2 * dtype_bytes * (2 * bs * bw) + 4 * bw
    return KernelCall(
        kernel="rglru_scan", site=site,
        grid=(batch, wp // bw, sp // bs),
        tiles={"bs": (bs, sp, sublane), "bw": (bw, wp, lane)},
        vmem_bytes=vmem, dtype_bytes=dtype_bytes, is_gemm=False)


# -- checks -----------------------------------------------------------------

def check_call(call: KernelCall, target) -> List[Diagnostic]:
    """Validate one described launch against ``target`` (anything with
    ``vmem_bytes``; lane/sublane are carried in the call's tiles)."""
    out: List[Diagnostic] = []
    where = f"{call.kernel}[{call.site}]"
    for name, (tile, dim, hw) in call.tiles.items():
        if tile < dim and tile % hw:
            out.append(Diagnostic(
                "K001", ERROR, where,
                f"{name}={tile} tiles a dim of {dim} but is not a "
                f"multiple of the hardware extent {hw}",
                fix_hint=f"round {name} to a multiple of {hw} (or cover "
                         f"the whole dim)"))
    if any(g <= 0 for g in call.grid):
        out.append(Diagnostic(
            "K002", ERROR, where,
            f"grid {call.grid} has a non-positive dimension",
            fix_hint="operand dims and blocks must be >= 1"))
    else:
        steps = 1
        for g in call.grid:
            steps *= g
        if steps > _MAX_GRID_STEPS:
            out.append(Diagnostic(
                "K002", ERROR, where,
                f"grid {call.grid} totals {steps} steps (> int32)",
                fix_hint="grow the blocks; the grid must index in int32"))
    vmem_budget = int(getattr(target, "vmem_bytes"))
    if call.vmem_bytes > vmem_budget:
        out.append(Diagnostic(
            "K003", ERROR, where,
            f"per-call VMEM footprint {call.vmem_bytes} B exceeds the "
            f"target budget {vmem_budget} B",
            fix_hint="shrink the block config (or retune for this "
                     "target — the tuner filters candidates by VMEM)"))
    if call.dtype_bytes > 4:
        out.append(Diagnostic(
            "K004", ERROR, where,
            f"{call.dtype_bytes}-byte inputs are unsupported on the MXU",
            fix_hint="cast inputs to bf16 (or f32)"))
    elif call.dtype_bytes == 4 and call.is_gemm:
        out.append(Diagnostic(
            "K004", WARNING, where,
            "f32 GEMM inputs; the MXU regime is bf16 in / f32 accum",
            fix_hint="store weights/activations in bf16 and keep the "
                     "f32 accumulator"))
    return out


def _target_geom(target) -> Tuple[int, int]:
    return (int(getattr(target, "lane", cost_model.LANE)),
            int(getattr(target, "sublane", cost_model.SUBLANE)))


def check_table_kernels(table: TaskTable, target) -> List[Diagnostic]:
    """K-checks for every tuned GEMM program in a task table."""
    lane, sublane = _target_geom(target)
    out: List[Diagnostic] = []
    for task in table.tasks:
        site = task.sites[0]
        for gname, prog in task.programs.items():
            label = f"{site.site_id} {gname}"
            if site.kind in ("moe_ffn",) and prog.batch > 1:
                call = describe_moe_gmm(
                    prog.batch, prog.m, prog.k, prog.n, prog.block,
                    dtype_bytes=prog.dtype_bytes, site=label,
                    lane=lane, sublane=sublane)
            else:
                call = describe_matmul(
                    prog.m, prog.k, prog.n, prog.block,
                    dtype_bytes=prog.dtype_bytes, site=label,
                    lane=lane, sublane=sublane)
            out.extend(check_call(call, target))
    return out


def check_model_kernels(cfg, target, *, table: Optional[TaskTable] = None,
                        workload: Optional[Workload] = None,
                        max_batch: int = 8, max_seq: int = 512,
                        page_size: int = 16,
                        sites: Optional[Sequence] = None
                        ) -> List[Diagnostic]:
    """The full launch set one config implies on ``target``.

    GEMMs come from ``table`` (an artifact's embedded
    :class:`TaskTable`); when none is given, a table is tuned here under
    a *private* ProgramCache with the target activated only for the
    duration — a check run never touches the process-wide caches
    (see :func:`tests.test_analysis`'s no-global-mutation test).
    Attention/scan launches are derived from the config's layer kinds at
    the serve shapes.
    """
    from repro.models.model import prune_sites
    lane, sublane = _target_geom(target)
    db = 2 if cfg.dtype == "bfloat16" else 4
    out: List[Diagnostic] = []

    if table is None:
        site_list = list(sites) if sites is not None else prune_sites(cfg)
        wl = workload or Workload(tokens_global=max_batch * max_seq)
        cache = tuning_cache.ProgramCache()   # private: no global fallout
        with tuner.target_activation(target), \
                oracle_mod.use_oracle("analytic"):
            table = TaskTable(site_list, wl)
            for task in table.tasks:
                s = task.sites[0]
                epi = tuner._epilogue_ops_for(s.op_kind)
                for g in s.gemms:
                    m, k, n, b = local_gemm_dims(s, g, wl)
                    task.programs[g.name] = tuner.tune_gemm(
                        m, k, n, batch=b, dtype_bytes=wl.dtype_bytes,
                        epilogue_ops=epi, cache=cache)
                task.tuned_mode = "tuned"
    out.extend(check_table_kernels(table, target))

    kinds = set(cfg.layer_kinds())
    if kinds & {ATTN, LOCAL_ATTN}:
        out.extend(check_call(describe_flash_attention(
            max_batch, max_seq, max_seq, cfg.n_heads, cfg.head_dim,
            dtype_bytes=db, site=f"{cfg.name} prefill", lane=lane,
            sublane=sublane), target))
        n_cols = -(-max_seq // page_size)
        out.extend(check_call(describe_paged_attention(
            max_batch, cfg.n_heads, cfg.head_dim, n_cols, page_size,
            dtype_bytes=db, site=f"{cfg.name} paged decode", lane=lane,
            sublane=sublane), target))
    if RWKV in kinds:
        out.extend(check_call(describe_rwkv6_scan(
            max_batch, max_seq, max(1, cfg.d_model // cfg.rwkv_head_dim),
            cfg.rwkv_head_dim, dtype_bytes=db,
            site=f"{cfg.name} rwkv6", lane=lane, sublane=sublane), target))
    if RGLRU in kinds:
        out.extend(check_call(describe_rglru_scan(
            max_batch, max_seq, cfg.rglru_width, dtype_bytes=db,
            site=f"{cfg.name} rglru", lane=lane, sublane=sublane), target))
    return out


def check_artifact_kernels(artifact) -> List[Diagnostic]:
    """K-checks for a :class:`DeploymentArtifact` against its *own*
    target, using its embedded tuned table (no retuning, no global
    state). This is what the export stamp records."""
    defaults = artifact.metadata.get("serve_defaults") or {}
    return check_model_kernels(
        artifact.cfg, artifact.target, table=artifact.table,
        workload=artifact.workload,
        max_batch=defaults.get("max_batch", 8),
        max_seq=defaults.get("max_seq", 512))


def pool_blocks_for(max_batch: int, max_seq: int, page_size: int) -> int:
    """The engine's default pool sizing (kept here for CLI reporting)."""
    return RESERVED_BLOCKS + max_batch * (-(-max_seq // page_size))

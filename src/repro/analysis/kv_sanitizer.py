"""ASAN-style sanitizer for the paged KV cache's host-side bookkeeping.

The :class:`~repro.models.paged_cache.BlockAllocator` and the per-group
block tables (:class:`~repro.serve.scheduler.PagedSlotGroup`) are pure
host state, so their whole invariant set can be checked exactly between
scheduler quanta — no device sync, no probes in the hot loop:

``V001 kv-leak``
    a block holds references but no live table row reaches it (the
    registry holds no refcount of its own, so unreachable + referenced
    means the refs can never be returned — the pool shrank for good).
``V002 kv-refcount-mismatch``
    a block's refcount differs from its live-table occurrence count; a
    deficit means a future release will double-free it under other rows.
``V003 kv-dangling-entry``
    a live table row references a block that is on the free list — its
    contents can be reallocated and overwritten under the row.
``V004 kv-cow-violation``
    the block a live row last decoded into is shared (refcount > 1):
    the write mutated another row's data without a copy-on-write split.
``V005 kv-accounting``
    free list + referenced blocks + reserved ids must tile the pool
    exactly (no duplicates, no reserved ids on the free list, no
    refcounts on free blocks), and the share registry must be involutive
    (``registry[key] == bid`` <-> ``block_key[bid] == key``).

:func:`check_engine` snapshots a :class:`ServeEngine`'s allocator and
live groups; the engine calls it after every :meth:`step` when
``SchedulerConfig(debug_kv=True)`` (or ``REPRO_DEBUG_KV=1``) is set and
raises :class:`KVSanitizerError` on the first violation.
"""
from __future__ import annotations

from collections import Counter
from typing import Iterable, List, Sequence, Tuple

import numpy as np

from repro.analysis.diagnostics import ERROR, Diagnostic
from repro.models.paged_cache import RESERVED_BLOCKS, BlockAllocator


class KVSanitizerError(RuntimeError):
    """A paged-KV invariant violation (carries the diagnostics)."""

    def __init__(self, diagnostics: List[Diagnostic]):
        self.diagnostics = list(diagnostics)
        lines = "\n".join(f"  {d}" for d in self.diagnostics)
        super().__init__(
            f"paged-KV sanitizer: {len(self.diagnostics)} violation(s)\n"
            f"{lines}")


def check_allocator(alloc: BlockAllocator,
                    tables: Iterable[np.ndarray]) -> List[Diagnostic]:
    """Reachability + accounting sweep: the allocator's refcounts, free
    list, and share registry against the live block ``tables``. (The
    COW check V004 needs per-group decode positions — see
    :func:`check_engine`.)"""
    diags: List[Diagnostic] = []

    # occurrences of each block id across every live table entry
    occ: Counter = Counter()
    for table in tables:
        for bid in np.asarray(table).ravel():
            if bid >= RESERVED_BLOCKS:
                occ[int(bid)] += 1

    free_list = list(alloc._free)
    free_set = set(free_list)
    ref = alloc._ref

    # V005: structural accounting first — everything else assumes it
    if len(free_list) != len(free_set):
        dup = sorted(b for b, c in Counter(free_list).items() if c > 1)
        diags.append(Diagnostic(
            "V005", ERROR, "allocator",
            f"free list holds duplicate block ids {dup[:8]}",
            fix_hint="a block was freed twice; audit the decref path"))
    bad_reserved = sorted(b for b in free_set if b < RESERVED_BLOCKS)
    if bad_reserved:
        diags.append(Diagnostic(
            "V005", ERROR, "allocator",
            f"reserved block ids {bad_reserved} are on the free list",
            fix_hint="ids < RESERVED_BLOCKS must never be allocated"))
    referenced = {int(b) for b in np.flatnonzero(ref > 0)}
    expected = set(range(RESERVED_BLOCKS, alloc.n_blocks))
    untracked = expected - free_set - referenced
    if untracked:
        diags.append(Diagnostic(
            "V005", ERROR, "allocator",
            f"blocks {sorted(untracked)[:8]} are neither free nor "
            f"referenced (free {len(free_set)} + referenced "
            f"{len(referenced)} + reserved {RESERVED_BLOCKS} != "
            f"{alloc.n_blocks})",
            fix_hint="blocks_in_use + blocks_free + reserved must equal "
                     "n_blocks"))
    both = free_set & referenced
    if both:
        diags.append(Diagnostic(
            "V005", ERROR, "allocator",
            f"blocks {sorted(both)[:8]} are on the free list with a "
            f"positive refcount",
            fix_hint="decref must zero the refcount before freeing"))
    for key, bid in alloc._registry.items():
        if alloc._block_key.get(bid) != key:
            diags.append(Diagnostic(
                "V005", ERROR, f"block {bid}",
                "share registry entry has no matching reverse mapping",
                fix_hint="publish/decref must keep registry and "
                         "block_key in lockstep"))

    # V001/V002/V003: refcounts vs table reachability
    for bid in sorted(referenced - set(occ)):
        diags.append(Diagnostic(
            "V001", ERROR, f"block {bid}",
            f"refcount {int(ref[bid])} but unreachable from any live "
            f"table row — leaked",
            fix_hint="decref blocks acquired for a cohort that never "
                     "became a live group (admission failure paths)"))
    for bid, n in sorted(occ.items()):
        r = int(ref[bid])
        if bid in free_set or r == 0:
            diags.append(Diagnostic(
                "V003", ERROR, f"block {bid}",
                f"referenced by {n} live table "
                f"entr{'y' if n == 1 else 'ies'} but the block is free",
                fix_hint="a row outlived its blocks; release/compact "
                         "decref'd a block still in a table"))
        elif r != n:
            diags.append(Diagnostic(
                "V002", ERROR, f"block {bid}",
                f"refcount {r} != {n} live table occurrence(s)",
                fix_hint="every table entry must hold exactly one "
                         "reference (the share registry holds none)"))
    return diags


def check_cow(alloc: BlockAllocator, table: np.ndarray,
              live: Sequence[bool], *, pos: int, plen: int,
              block_size: int, label: str = "group") -> List[Diagnostic]:
    """V004 for one group: the column decode last wrote (position
    ``pos - 1``) must be private (or reserved scratch) for every live
    row. Skipped when no decode write has happened (``pos <= plen``)."""
    diags: List[Diagnostic] = []
    table = np.asarray(table)
    if pos <= plen or table.size == 0:
        return diags
    col = (pos - 1) // block_size
    if col >= table.shape[1]:
        return diags
    for i, is_live in enumerate(live):
        if not is_live:
            continue
        bid = int(table[i, col])
        if bid >= RESERVED_BLOCKS and alloc.refcount(bid) > 1:
            diags.append(Diagnostic(
                "V004", ERROR, f"block {bid}",
                f"{label} row {i} decoded into a block shared by "
                f"{alloc.refcount(bid)} references",
                fix_hint="the write frontier must be a private block "
                         "(copy-on-write split on incref)"))
    return diags


def check_engine(engine) -> List[Diagnostic]:
    """One full sweep of an engine's paged-KV state (empty for
    contiguous engines). Duck-typed to avoid a serve<->analysis import
    cycle — anything with ``kv_allocator`` and paged ``groups`` works."""
    alloc = getattr(engine, "kv_allocator", None)
    if alloc is None:
        return []
    from repro.serve.scheduler import PagedSlotGroup
    paged = [g for g in engine.groups if isinstance(g, PagedSlotGroup)]
    diags = check_allocator(alloc, [g.table for g in paged])
    for gi, g in enumerate(paged):
        if g.prefilling:
            continue
        diags.extend(check_cow(
            alloc, g.table, [r is not None for r in g.requests],
            pos=g.pos, plen=g.plen, block_size=g.block_size,
            label=f"group[{gi}]"))
    return diags

"""Structured diagnostics shared by every analysis pass.

A :class:`Diagnostic` is one named finding — ``K003 vmem-overflow at
layer.qkv`` — with a severity and a fix hint. Passes return lists of
them; :class:`AnalysisReport` aggregates lists across passes and decides
the process exit code (errors fail, warnings don't), so the CLI, the
export stamp, and the test fixtures all consume the same records.

Code namespaces: ``K***`` kernel static checker (:mod:`.kernels`),
``J***`` jaxpr auditor (:mod:`.jaxpr_audit`), ``V***`` paged-KV
sanitizer (:mod:`.kv_sanitizer`).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List

ERROR = "error"
WARNING = "warning"
SEVERITIES = (ERROR, WARNING)

#: code -> short meaning (the README table mirrors this)
DIAGNOSTIC_CODES: Dict[str, str] = {
    "K001": "tile-not-divisible",
    "K002": "grid-bounds",
    "K003": "vmem-overflow",
    "K004": "dtype-rule",
    "J001": "f32-promotion",
    "J002": "host-transfer",
    "J003": "missed-donation",
    "J004": "recompile-hazard",
    "J005": "replicated-param",
    "V001": "kv-leak",
    "V002": "kv-refcount-mismatch",
    "V003": "kv-dangling-entry",
    "V004": "kv-cow-violation",
    "V005": "kv-accounting",
}


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One named finding from an analysis pass."""

    code: str            # e.g. "K003"
    severity: str        # "error" | "warning"
    site: str            # where: kernel call / jaxpr eqn / block id
    message: str         # what is wrong, with the numbers
    fix_hint: str = ""   # what to change

    def __post_init__(self):
        if self.code not in DIAGNOSTIC_CODES:
            raise ValueError(f"unknown diagnostic code {self.code!r}")
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}")

    @property
    def name(self) -> str:
        return DIAGNOSTIC_CODES[self.code]

    def __str__(self) -> str:
        hint = f" (fix: {self.fix_hint})" if self.fix_hint else ""
        return (f"{self.code} {self.name} [{self.severity}] "
                f"{self.site}: {self.message}{hint}")


@dataclasses.dataclass
class AnalysisReport:
    """Aggregated findings across passes; ``ok`` gates the exit code."""

    diagnostics: List[Diagnostic] = dataclasses.field(default_factory=list)

    def extend(self, diags: Iterable[Diagnostic]) -> "AnalysisReport":
        self.diagnostics.extend(diags)
        return self

    @property
    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == ERROR]

    @property
    def warnings(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == WARNING]

    @property
    def ok(self) -> bool:
        return not self.errors

    @property
    def codes(self) -> List[str]:
        """Distinct codes present, sorted (the export stamp records this)."""
        return sorted({d.code for d in self.diagnostics})

    def summary(self) -> str:
        head = (f"{len(self.errors)} error(s), "
                f"{len(self.warnings)} warning(s)")
        if not self.diagnostics:
            return head
        return head + "\n" + "\n".join(f"  {d}" for d in self.diagnostics)

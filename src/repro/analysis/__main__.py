"""Front door: ``python -m repro.analysis`` — all passes, one exit code.

Runs the kernel static checker over a config x target matrix, the jaxpr
auditor per config, and the paged-KV sanitizer against a short
end-to-end serve of each paged-compatible config's *reduced* variant
(real engine, ``debug_kv=True``, mixed direct/chunked/shared-prefix
admissions). Exits non-zero iff any pass reports an error; warnings
print but don't fail.

    python -m repro.analysis                                # everything
    python -m repro.analysis --config granite_moe_1b_a400m \
        --targets tpu_v5e,edge
    python -m repro.analysis --passes kernels,jaxpr         # skip serve

``launch/check.py`` is a thin alias for environments that invoke repo
scripts by path.
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis.diagnostics import AnalysisReport


def _parse(argv=None) -> argparse.Namespace:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static kernel/jaxpr checks + paged-KV sanitizer")
    ap.add_argument("--config", default="all",
                    help="comma-separated config names (default: all "
                         "shipped configs)")
    ap.add_argument("--targets", default="tpu_v5e",
                    help="comma-separated target names for the kernel "
                         "pass (default: tpu_v5e)")
    ap.add_argument("--passes", default="kernels,jaxpr,kv",
                    help="subset of kernels,jaxpr,kv to run")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=512)
    ap.add_argument("--tp", type=int, default=2,
                    help="model-parallel degree for the J005 "
                         "replicated-param audit (default: 2; 1 skips it)")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="print only the final summary line")
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = _parse(argv)
    from repro.analysis import jaxpr_audit, kernels
    from repro.api.targets import get_target
    from repro.configs import all_configs, get_config

    if args.config == "all":
        cfgs = [get_config(n) for n in all_configs()]
    else:
        cfgs = [get_config(n) for n in args.config.split(",")]
    targets = [get_target(t) for t in args.targets.split(",")]
    passes = set(args.passes.split(","))
    unknown = passes - {"kernels", "jaxpr", "kv"}
    if unknown:
        print(f"unknown pass(es): {sorted(unknown)}", file=sys.stderr)
        return 2

    report = AnalysisReport()

    def emit(pass_name: str, what: str, diags) -> None:
        report.extend(diags)
        errs = sum(1 for d in diags if d.severity == "error")
        if not args.quiet:
            print(f"[{pass_name}] {what}: {len(diags)} finding(s), "
                  f"{errs} error(s)")
            for d in diags:
                print(f"  {d}")

    if "kernels" in passes:
        for cfg in cfgs:
            for tgt in targets:
                emit("kernels", f"{cfg.name} @ {tgt.name}",
                     kernels.check_model_kernels(
                         cfg, tgt, max_batch=args.max_batch,
                         max_seq=args.max_seq))

    if "jaxpr" in passes:
        from repro.serve.scheduler import SchedulerConfig
        for cfg in cfgs:
            emit("jaxpr", cfg.name,
                 jaxpr_audit.audit_model(cfg, max_batch=args.max_batch,
                                         max_seq=args.max_seq))
            emit("jaxpr", f"{cfg.name} sharding (tp={args.tp})",
                 jaxpr_audit.audit_param_sharding(cfg, tp=args.tp))
        emit("jaxpr", "serve shapes",
             jaxpr_audit.audit_serve_shapes(
                 SchedulerConfig(), max_batch=args.max_batch,
                 max_seq=args.max_seq))

    if "kv" in passes:
        emit_kv(cfgs, emit, quiet=args.quiet)

    print(f"repro.analysis: {report.summary().splitlines()[0]}"
          f"{' — FAIL' if not report.ok else ''}")
    return 0 if report.ok else 1


def emit_kv(cfgs, emit, *, quiet: bool = False) -> None:
    """Serve each paged-compatible config's reduced variant end-to-end
    under ``debug_kv=True`` — direct, shared-prefix, and chunked
    admissions — plus the donation audit on the live engine. A sanitizer
    violation surfaces as its diagnostics (the engine raises them)."""
    import jax
    import numpy as np

    from repro.analysis.jaxpr_audit import audit_engine_donation
    from repro.analysis.kv_sanitizer import KVSanitizerError
    from repro.configs import get_reduced_config
    from repro.models.paged_cache import paged_compatible
    from repro.serve.engine import Request, ServeEngine
    from repro.serve.scheduler import SchedulerConfig

    for cfg in cfgs:
        if not paged_compatible(cfg):
            if not quiet:
                print(f"[kv] {cfg.name}: skipped (not paged-compatible)")
            continue
        rcfg = get_reduced_config(cfg.name)
        from repro.models.model import init_params
        params = init_params(jax.random.PRNGKey(0), rcfg)
        chunkable = rcfg.rope != "mrope" and rcfg.frontend == "none"
        sched = SchedulerConfig(debug_kv=True, page_size=8,
                                prefill_chunk=16 if chunkable else 0)
        eng = ServeEngine(rcfg, params, max_batch=4, max_seq=64,
                          scheduler=sched)
        rng = np.random.default_rng(0)
        shared = rng.integers(1, 50, 11).astype(np.int32)
        prompts = [shared, shared.copy(),                 # shared prefix
                   rng.integers(1, 50, 5).astype(np.int32),
                   rng.integers(1, 50, 24).astype(np.int32)]
        if chunkable:                                      # chunked path
            prompts.append(rng.integers(1, 50, 40).astype(np.int32))
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=6))
        try:
            stats = eng.serve_forever()
        except KVSanitizerError as e:
            emit("kv", f"{cfg.name} (reduced serve)", e.diagnostics)
            continue
        emit("kv", f"{cfg.name} (reduced serve, "
                   f"{stats['kv_debug_checks']} checks)", [])
        emit("kv", f"{cfg.name} (donation)", audit_engine_donation(eng))


if __name__ == "__main__":
    sys.exit(main())

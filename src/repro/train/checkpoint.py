"""Fault-tolerant checkpointing: atomic, async, elastic.

Layout:
  <dir>/step_<n>.tmp/...   (write)
  <dir>/step_<n>/          (atomic rename on completion)
      manifest.json        tree structure, shapes, dtypes, mesh shape, step
      arr_<i>.npy          one file per leaf

Properties (tested in tests/test_fault_tolerance.py):
  * a crash mid-save never corrupts the latest checkpoint (tmp + rename);
  * restore works onto a *different* mesh (elastic re-shard: leaves are
    loaded host-side and device_put with the new sharding);
  * retention keeps the newest k checkpoints;
  * async saves overlap the next train step (background thread).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        out.append((jax.tree_util.keystr(path), leaf))
    return out, treedef


class CheckpointManager:
    def __init__(self, directory, *, keep: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state: Dict[str, Any],
             extra: Optional[Dict[str, Any]] = None, *,
             block: bool = False) -> None:
        """state: pytree dict. Async by default; ``wait()`` to join."""
        self.wait()
        # pull to host before handing to the writer thread
        host_state = jax.tree.map(lambda x: np.asarray(x), state)

        def _write():
            try:
                self._write_sync(step, host_state, extra or {})
            except BaseException as e:  # pragma: no cover
                self._error = e

        if self.async_save and not block:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def _write_sync(self, step: int, state, extra: Dict[str, Any]):
        tmp = self.dir / f"step_{step}.tmp"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        leaves, treedef = _flatten(state)
        manifest = {
            "step": step,
            "extra": extra,
            "treedef": jax.tree_util.tree_structure(state).__repr__(),
            "leaves": [],
        }
        for i, (path, leaf) in enumerate(leaves):
            arr = np.asarray(leaf)
            np.save(tmp / f"arr_{i}.npy", arr)
            manifest["leaves"].append(
                {"path": path, "file": f"arr_{i}.npy",
                 "shape": list(arr.shape), "dtype": str(arr.dtype)})
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)          # atomic on POSIX
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for p in self.dir.iterdir():
            if p.is_dir() and p.name.startswith("step_") \
                    and not p.name.endswith(".tmp"):
                try:
                    out.append(int(p.name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Dict[str, Any], step: Optional[int] = None, *,
                shardings=None) -> Tuple[int, Dict[str, Any], Dict[str, Any]]:
        """Restore into the structure of ``like``; optionally device_put with
        new shardings (elastic re-mesh)."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        by_path = {m["path"]: m for m in manifest["leaves"]}
        leaves, treedef = _flatten(like)
        out_leaves = []
        for path, leaf in leaves:
            m = by_path[path]
            arr = np.load(d / m["file"])
            out_leaves.append(arr)
        state = jax.tree_util.tree_unflatten(treedef, out_leaves)
        if shardings is not None:
            state = jax.tree.map(
                lambda a, s: jax.device_put(a, s), state, shardings)
        return step, state, manifest["extra"]

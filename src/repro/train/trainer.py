"""Trainer: the production train loop.

Wires together model, optimizer, data pipeline, checkpointing, straggler
monitoring and (optionally) a mesh. Used by examples/train_lm.py (CPU,
single device) and by launch/train.py (sharded). Supports gradient
accumulation (microbatching) and CPrune-produced pruned params (shapes are
read from the params, never from the config).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataPipeline
from repro.models.model import Model, init_params
from repro.optim.optimizers import (adamw_init, adamw_update,
                                    clip_by_global_norm, sgd_init,
                                    sgd_update)
from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import StragglerMonitor, resilient_loop


@dataclasses.dataclass
class TrainerConfig:
    lr: float = 3e-4
    optimizer: str = "adamw"        # adamw | sgd (paper uses SGD)
    momentum: float = 0.9
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    grad_accum: int = 1             # microbatches per step
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep_ckpts: int = 2
    log_every: int = 10
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig,
                 pipeline: DataPipeline, *, params=None, model: Model = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.pipeline = pipeline
        self.model = model or Model(cfg)
        self.params = params if params is not None else init_params(
            jax.random.PRNGKey(tcfg.seed), cfg)
        init = adamw_init if tcfg.optimizer == "adamw" else sgd_init
        self.opt_state = init(self.params)
        self.monitor = StragglerMonitor()
        self.ckpt = (CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep_ckpts)
                     if tcfg.ckpt_dir else None)
        self.metrics_log: list = []
        self._step_fn = jax.jit(self._make_step())

    def _make_step(self):
        tcfg = self.tcfg
        model = self.model

        def one_micro(p, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda pp: model.loss_fn(pp, batch), has_aux=True)(p)
            return loss, metrics, grads

        def step(params, opt_state, batches):
            # gradient accumulation over the leading microbatch axis
            def accum(carry, batch):
                loss_sum, grads_sum = carry
                loss, metrics, grads = one_micro(params, batch)
                grads_sum = jax.tree.map(jnp.add, grads_sum, grads)
                return (loss_sum + loss, grads_sum), metrics

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype),
                                 params)
            (loss_sum, grads), metrics = jax.lax.scan(
                accum, (jnp.float32(0.0), zeros), batches)
            n = tcfg.grad_accum
            grads = jax.tree.map(lambda g: g / n, grads)
            grads, gn = clip_by_global_norm(grads, tcfg.grad_clip)
            if tcfg.optimizer == "adamw":
                params, opt_state = adamw_update(
                    params, grads, opt_state, lr=tcfg.lr,
                    weight_decay=tcfg.weight_decay)
            else:
                params, opt_state = sgd_update(
                    params, grads, opt_state, lr=tcfg.lr,
                    momentum=tcfg.momentum,
                    weight_decay=tcfg.weight_decay)
            out_metrics = {k: v[-1] for k, v in metrics.items()}
            out_metrics["loss"] = loss_sum / n
            out_metrics["grad_norm"] = gn
            return params, opt_state, out_metrics

        return step

    def _microbatches(self, step: int):
        b = self.pipeline.batch(step)
        n = self.tcfg.grad_accum
        if n == 1:
            return jax.tree.map(lambda x: x[None], b)
        return jax.tree.map(
            lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), b)

    def train_step(self, step: int):
        batches = self._microbatches(step)
        self.params, self.opt_state, metrics = self._step_fn(
            self.params, self.opt_state, batches)
        return metrics

    def run(self, n_steps: int, *, start_step: int = 0,
            injector=None) -> Dict[str, Any]:
        state = {"params": self.params, "opt": self.opt_state}

        def step_fn(step, state):
            self.params = state["params"]
            self.opt_state = state["opt"]
            metrics = self.train_step(step)
            if step % self.tcfg.log_every == 0:
                host = {k: float(v) for k, v in metrics.items()}
                host["step"] = step
                self.metrics_log.append(host)
            return {"params": self.params, "opt": self.opt_state}

        state, stats = resilient_loop(
            n_steps=n_steps, state=state, step_fn=step_fn, ckpt=self.ckpt,
            ckpt_every=self.tcfg.ckpt_every, monitor=self.monitor,
            injector=injector, start_step=start_step)
        self.params = state["params"]
        self.opt_state = state["opt"]
        stats["median_step_s"] = self.monitor.median_s
        return stats

    def eval_batch(self, step: int = 10 ** 6):
        batch = self.pipeline.batch(step)
        loss, metrics = jax.jit(self.model.loss_fn)(self.params, batch)
        return {k: float(v) for k, v in metrics.items()} | {
            "loss": float(loss)}

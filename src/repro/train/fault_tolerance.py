"""Fault-tolerance runtime: crash recovery, straggler watch, grad compression.

* ``resilient_loop`` — drives train steps with automatic restore-from-latest
  checkpoint on failure (bounded retries). Failures are injectable for
  tests (``FaultInjector``).
* ``StragglerMonitor`` / ``FaultInjector`` — now live in
  :mod:`repro.util.faults` (shared with the serving fleet, which uses the
  same injection discipline for engine crashes, prefill OOMs, artifact
  load failures, and slow-step stragglers); re-exported here unchanged.
* ``compress_grads`` / ``decompress_grads`` — int8 error-feedback gradient
  compression for DCN-bound (cross-pod) reductions: quantize to int8 with
  per-tensor scale, carry the residual to the next step. 4x wire-format
  reduction on the pod axis all-reduce.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.util.faults import (FaultInjector, FaultSpec, InjectedFault,
                               StragglerMonitor)

__all__ = ["FaultInjector", "FaultSpec", "InjectedFault", "StragglerMonitor",
           "resilient_loop", "compress_grads", "decompress_grads"]


def resilient_loop(*, n_steps: int, state: Dict[str, Any],
                   step_fn: Callable[[int, Dict[str, Any]], Dict[str, Any]],
                   ckpt, ckpt_every: int = 10,
                   max_restarts: int = 3,
                   injector: Optional[FaultInjector] = None,
                   monitor: Optional[StragglerMonitor] = None,
                   start_step: int = 0) -> Tuple[Dict[str, Any], Dict]:
    """Run ``step_fn`` n_steps times with checkpoint/restart semantics.

    ``state`` must be a pytree dict; ``step_fn(step, state) -> state``.
    Returns (final state, stats).
    """
    stats = {"restarts": 0, "stragglers": 0, "steps_run": 0}
    step = start_step
    restarts = 0
    while step < n_steps:
        try:
            t0 = time.time()
            if injector is not None:
                injector.maybe_fail(step)
            state = step_fn(step, state)
            dt = time.time() - t0
            if monitor is not None and monitor.observe(dt):
                stats["stragglers"] += 1
            stats["steps_run"] += 1
            step += 1
            if ckpt is not None and step % ckpt_every == 0:
                ckpt.save(step, state, {"step": step})
        except Exception:
            restarts += 1
            stats["restarts"] += 1
            if restarts > max_restarts or ckpt is None:
                raise
            latest = ckpt.latest_step()
            if latest is None:
                step = start_step      # restart from scratch
                continue
            step, state, _ = ckpt.restore(state, latest)
    if ckpt is not None:
        ckpt.save(step, state, {"step": step}, block=True)
        ckpt.wait()
    return state, stats


# ---------------------------------------------------------------------------
# Gradient compression (error feedback int8)
# ---------------------------------------------------------------------------

def compress_grads(grads, residual=None):
    """Quantize each leaf to int8 with per-tensor scale + error feedback.

    Returns (q_grads {q, scale}, new_residual). Applying
    ``decompress_grads`` and adding the returned residual next step makes
    the scheme unbiased over time (Seide et al. / EF-SGD).
    """
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32),
                                grads)

    def q_one(g, r):
        gf = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_r = gf - q.astype(jnp.float32) * scale
        return {"q": q, "scale": scale}, new_r

    flat = jax.tree.map(q_one, grads, residual)
    is_pair = lambda x: isinstance(x, tuple) and len(x) == 2 \
        and isinstance(x[0], dict)
    qg = jax.tree.map(lambda t: t[0], flat, is_leaf=is_pair)
    new_res = jax.tree.map(lambda t: t[1], flat, is_leaf=is_pair)
    return qg, new_res


def decompress_grads(qgrads, like=None):
    def d_one(d):
        return d["q"].astype(jnp.float32) * d["scale"]
    return jax.tree.map(d_one, qgrads,
                        is_leaf=lambda x: isinstance(x, dict) and "q" in x)

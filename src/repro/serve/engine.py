"""Batched serving engine: prefill + decode with a static-batch scheduler.

Design (vLLM-style, sized down to what a CPU example can drive):
  * fixed decode batch of ``max_batch`` slots, each slot holding one
    request's KV cache rows (caches are allocated once for the whole batch,
    slots turn over as requests finish — continuous batching);
  * prompts are prefix-padded to a common length per admission wave and run
    through the jitted prefill; decode then proceeds one token per step for
    the *whole batch*;
  * sampling: greedy or temperature, per request;
  * finished slots are refilled from the queue on the next wave.

For the production mesh the same engine drives the sharded serve_step
(launch/serve.py); here everything stays single-device jit.
"""
from __future__ import annotations

import dataclasses
import os
import time
from collections import deque
from typing import Any, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (prompt_len,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 max_seq: int = 512, seed: int = 0,
                 predicted_step_s: Optional[float] = None):
        self.cfg = cfg
        self.params = params
        self.model = Model(cfg)
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.key = jax.random.PRNGKey(seed)
        self.queue: deque[Request] = deque()
        self.done: List[Request] = []
        # the latency oracle's prediction for one decode step of this
        # model at max_batch (PruningSession.serve computes it); run()
        # reports it against the measured wall-clock per step so the
        # oracle's error on the *real* executing model is observable
        self.predicted_step_s = predicted_step_s
        self._decode_steps = 0
        self._decode_wall_s = 0.0
        self._step_times: List[float] = []
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, max_seq))
        self._decode = jax.jit(self.model.decode_step)

    @classmethod
    def from_artifact(cls, artifact: Union[str, "os.PathLike", Any], *,
                      max_batch: Optional[int] = None,
                      max_seq: Optional[int] = None, seed: int = 0,
                      predict_step: bool = True) -> "ServeEngine":
        """Serve a :class:`~repro.api.artifact.DeploymentArtifact` (an
        instance or a directory path) without constructing a
        ``PruningSession`` — the cheap, restartable half of the pipeline.

        ``max_batch``/``max_seq`` default to the artifact's recorded serve
        defaults, in which case the export-time decode-step prediction is
        reused; other shapes re-derive the prediction from the artifact's
        own target + oracle (None when its replay log cannot score them).
        """
        if isinstance(artifact, (str, os.PathLike)):
            from repro.api.artifact import DeploymentArtifact
            artifact = DeploymentArtifact.load(os.fspath(artifact))
        defaults = artifact.metadata.get("serve_defaults") or {}
        if max_batch is None:
            max_batch = defaults.get("max_batch", 8)
        if max_seq is None:
            max_seq = defaults.get("max_seq", 512)
        predicted = None
        if predict_step:
            if (max_batch == defaults.get("max_batch")
                    and max_seq == defaults.get("max_seq")):
                predicted = artifact.metadata.get("predicted_step_s")
            if predicted is None:
                # other dims — or an artifact exported without a
                # prediction — re-derive from the artifact's own
                # target + oracle (None when its log cannot score it)
                predicted = artifact.predict_step_s(max_batch, max_seq)
        return cls(artifact.cfg, artifact.params, max_batch=max_batch,
                   max_seq=max_seq, seed=seed, predicted_step_s=predicted)

    def submit(self, req: Request):
        req.t_submit = time.time()
        self.queue.append(req)

    # -- one admission wave: take up to max_batch requests, run them --------

    def _run_wave(self) -> None:
        # admit a batch of equal-length prompts (no pad pollution of the
        # causal cache); unequal lengths wait for the next wave
        wave: List[Request] = []
        skipped: List[Request] = []
        plen = None
        while self.queue and len(wave) < self.max_batch:
            r = self.queue.popleft()
            if plen is None:
                plen = len(r.prompt)
            if len(r.prompt) == plen:
                wave.append(r)
            else:
                skipped.append(r)
        for r in reversed(skipped):
            self.queue.appendleft(r)
        if not wave:
            return
        B = len(wave)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(wave):
            toks[i] = r.prompt
        logits, caches = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        t_first = time.time()
        for r in wave:
            r.t_first_token = t_first

        max_new = max(r.max_new_tokens for r in wave)
        cur = self._sample(logits, wave)
        for i, r in enumerate(wave):
            r.output.append(int(cur[i, 0]))
        for step in range(1, max_new):
            t0 = time.perf_counter()
            logits, caches = self._decode(self.params, cur, caches)
            jax.block_until_ready(logits)
            dt = time.perf_counter() - t0
            self._decode_wall_s += dt
            self._step_times.append(dt)
            self._decode_steps += 1
            cur = self._sample(logits, wave)
            now = time.time()
            for i, r in enumerate(wave):
                if len(r.output) < r.max_new_tokens:
                    r.output.append(int(cur[i, 0]))
                    if len(r.output) == r.max_new_tokens:
                        r.done, r.t_done = True, now
        now = time.time()
        for r in wave:
            r.done = True
            r.t_done = r.t_done or now
            self.done.append(r)

    def _sample(self, logits: jax.Array, wave: List[Request]) -> jax.Array:
        self.key, sub = jax.random.split(self.key)
        greedy = jnp.argmax(logits[:, 0], axis=-1)
        temps = jnp.asarray([r.temperature for r in wave])[:, None]
        noisy = jax.random.categorical(
            sub, logits[:, 0] / jnp.maximum(temps, 1e-6))
        tok = jnp.where(temps[:, 0] > 0, noisy, greedy)
        return tok[:, None].astype(jnp.int32)

    @staticmethod
    def _pct(xs: List[float], q: float) -> float:
        return float(np.percentile(np.asarray(xs), q)) if xs else 0.0

    def run(self) -> Dict[str, Any]:
        t0 = time.time()
        waves = 0
        while self.queue:
            self._run_wave()
            waves += 1
        wall = time.time() - t0
        total_tokens = sum(len(r.output) for r in self.done)
        ttfts = [r.t_first_token - r.t_submit for r in self.done]
        decodes = [r.t_done - r.t_first_token for r in self.done]
        stats = {
            "requests": len(self.done),
            "waves": waves,
            "total_new_tokens": total_tokens,
            "wall_s": wall,
            "tokens_per_s": total_tokens / max(wall, 1e-9),
            "mean_ttft_s": float(np.mean(ttfts)) if ttfts else 0.0,
            # tail latency: TTFT and per-request decode time across
            # requests, plus per-decode-step percentiles — the serve-time
            # check for the planner's per-step latency claims
            "p50_ttft_s": self._pct(ttfts, 50),
            "p95_ttft_s": self._pct(ttfts, 95),
            "p50_decode_s": self._pct(decodes, 50),
            "p95_decode_s": self._pct(decodes, 95),
            "p50_step_s": self._pct(self._step_times, 50),
            "p95_step_s": self._pct(self._step_times, 95),
            # predicted-vs-measured step latency: how wrong the latency
            # oracle is on the model that is actually executing
            "decode_steps": self._decode_steps,
            "measured_step_s": self._decode_wall_s / self._decode_steps
            if self._decode_steps else 0.0,
            "predicted_step_s": self.predicted_step_s,
        }
        if self.predicted_step_s is not None and self._decode_steps:
            meas = stats["measured_step_s"]
            stats["oracle_rel_error"] = \
                (self.predicted_step_s - meas) / max(meas, 1e-12)
        return stats

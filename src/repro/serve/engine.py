"""Serving engine: prefill + decode behind a stepped scheduler core.

Design (vLLM-style, sized down to what a CPU example can drive):
  * a global budget of ``max_batch`` decode slots, shared by every live
    :class:`~repro.serve.scheduler.SlotGroup` (one admitted cohort of
    equal-length prompts mid-decode);
  * admission, prompt-length bucketing, and slot compaction live in
    :mod:`repro.serve.scheduler`; the engine is the execution half —
    :meth:`step` runs exactly one scheduling quantum (admit one cohort,
    or advance every live group one decode token) and never blocks on a
    queue, :meth:`serve_forever` loops it under an optional deadline;
  * finished requests release their slots mid-decode (groups compact to
    the surviving rows), so the next cohort prefils while earlier
    groups are still decoding — continuous batching at group
    granularity instead of the old blocking wave drain;
  * sampling: greedy or temperature, per request;
  * :meth:`run` is the legacy front door: a thin wrapper over
    ``serve_forever()`` with bit-identical greedy outputs.

Engines optionally record their measured decode-step seconds into a
:class:`~repro.core.oracle.MeasurementLog` (``measurements=``), which is
how a serve run feeds the latency oracle that planned it — see
``DeploymentArtifact.recalibrated_oracle``.

For the production mesh the same engine drives the sharded serve_step
(launch/serve.py); here everything stays single-device jit.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTN, LOCAL_ATTN, ModelConfig
from repro.core.oracle import MeasurementLog
from repro.models.model import Model
from repro.models.paged_cache import (RESERVED_BLOCKS, SCRATCH_BLOCK,
                                      BlockAllocator, init_paged_pools,
                                      paged_compatible,
                                      scatter_prefill_blocks)
from repro.serve.scheduler import (PagedSlotGroup, Scheduler,
                                   SchedulerConfig, SlotGroup)
from repro.util.faults import FaultInjector, StragglerMonitor


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (prompt_len,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    # per-request SLO (consumed by repro.serve.router.Router; the plain
    # engine ignores both): route to the cheapest artifact whose recorded
    # accuracy >= accuracy_floor and predicted latency <= latency_budget_s
    latency_budget_s: Optional[float] = None
    accuracy_floor: Optional[float] = None
    # filled by the engine / router:
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    routed_to: Optional[str] = None
    slo_infeasible: bool = False
    # fleet supervision (repro.serve.fleet): re-queue/reject accounting.
    # A request ends in exactly one of three states: done, failed
    # (explicit, with a reason), or still in flight — never silently lost.
    retries: int = 0
    failed: bool = False
    fail_reason: Optional[str] = None

    @property
    def deadline_s(self) -> float:
        """Absolute wall-clock deadline (inf when unbudgeted or not yet
        submitted — the budget clock starts at first submit)."""
        if self.latency_budget_s is None or not self.t_submit:
            return float("inf")
        return self.t_submit + self.latency_budget_s

    def reset_for_retry(self) -> None:
        """Forget partial progress so a re-queued request re-prefils from
        its original prompt (greedy decode then reproduces the exact
        fault-free output). The submit time — and therefore the deadline
        — is deliberately preserved."""
        self.output = []
        self.done = False
        self.t_first_token = 0.0
        self.t_done = 0.0
        self.retries += 1


class ServeEngine:
    """The stepped serving engine (the ``Engine`` half of the redesign;
    :class:`~repro.serve.scheduler.Scheduler` is the policy half)."""

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 max_seq: int = 512, seed: int = 0,
                 predicted_step_s: Optional[float] = None,
                 scheduler: Union[SchedulerConfig, str, None] = None,
                 measurements: Optional[MeasurementLog] = None,
                 measurement_tag: Optional[str] = None,
                 faults: Optional[FaultInjector] = None,
                 fault_tag: Optional[str] = None,
                 straggler: Optional[StragglerMonitor] = None,
                 kv_pool_blocks: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.model = Model(cfg)
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.key = jax.random.PRNGKey(seed)
        if scheduler is None:
            scheduler = SchedulerConfig()
        elif isinstance(scheduler, str):
            scheduler = SchedulerConfig(policy=scheduler)
        if scheduler.policy == "wave" and (scheduler.compact != "off"
                                          or scheduler.kv_layout != "contiguous"):
            # the legacy baseline verbatim: no compaction, contiguous KV
            scheduler = dataclasses.replace(scheduler, compact="off",
                                            kv_layout="contiguous",
                                            prefill_chunk=0)
        if scheduler.kv_layout == "paged" and not paged_compatible(cfg):
            # recurrent mixers / sliding windows have no block-table
            # analogue here — serve them from the contiguous layout
            scheduler = dataclasses.replace(scheduler,
                                            kv_layout="contiguous",
                                            prefill_chunk=0)
        self.kv_layout = scheduler.kv_layout
        self.scheduler = Scheduler(scheduler)
        self.groups: List[SlotGroup] = []
        self.done: List[Request] = []
        # the latency oracle's prediction for one decode step of this
        # model at max_batch (PruningSession.serve computes it); stats()
        # report it against the measured wall-clock per step so the
        # oracle's error on the *real* executing model is observable
        self.predicted_step_s = predicted_step_s
        # a serve run can record its observed decode step into a
        # MeasurementLog and hand it back to the oracle that planned it
        self.measurements = measurements
        self.measurement_tag = measurement_tag or cfg.name
        # fault injection (repro.util.faults): the engine fires the
        # "decode"/"prefill" points, tagged so a fleet-shared injector
        # can target one replica; straggler watches decode-tick wall time
        self.faults = faults
        self.fault_tag = fault_tag or self.measurement_tag
        self.straggler = straggler
        # physically copied cache rows (engine-owned; every SlotGroup's
        # compact() increments it — the paged layout's zero-copy gate)
        self._copy_counter = {"rows": 0}
        # peak-KV accounting: bytes one token position costs across every
        # attention layer's K+V
        n_attn = sum(1 for k in cfg.layer_kinds() if k in (ATTN, LOCAL_ATTN))
        self._kv_row_bytes = (n_attn * 2 * cfg.n_kv_heads * cfg.head_dim
                              * jnp.dtype(cfg.dtype).itemsize)
        self._live_kv_slots = 0   # contiguous: currently allocated slots
        self._peak_kv_slots = 0
        self.kv_allocator: Optional[BlockAllocator] = None
        if self.kv_layout == "paged":
            sc = self.scheduler.config
            if sc.prefill_chunk and (cfg.rope == "mrope"
                                     or cfg.frontend != "none"):
                raise ValueError(
                    "prefill_chunk requires a text-only rope model (mrope "
                    "positions and frontend inputs are not chunkable)")
            bs = sc.page_size
            n_blocks = kv_pool_blocks if kv_pool_blocks is not None else \
                RESERVED_BLOCKS + max_batch * (-(-max_seq // bs))
            self.kv_allocator = BlockAllocator(n_blocks)
            self._pools = init_paged_pools(self.model, n_blocks, bs)
            # donate the pools: the in-place block writes then update the
            # buffers directly instead of copying the whole pool per step
            self._decode_paged = jax.jit(self.model.decode_step_paged,
                                         donate_argnums=2)
            self._chunk_step = jax.jit(self.model.prefill_chunk_paged,
                                       donate_argnums=2)
            # prefill padded to the cohort's block multiple, not max_seq —
            # short prompts don't pay full-length attention at admission
            self._prefill_padded = jax.jit(
                lambda p, b, ms: self.model.prefill(p, b, ms),
                static_argnums=2)
        # paged-KV sanitizer (repro.analysis.kv_sanitizer) at every
        # quantum boundary: SchedulerConfig(debug_kv=True), or
        # REPRO_DEBUG_KV=1 to flip it on without touching call sites
        self._debug_kv = self.kv_layout == "paged" and (
            self.scheduler.config.debug_kv
            or os.environ.get("REPRO_DEBUG_KV", "0") not in ("", "0"))
        self.reset_stats()
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, max_seq))
        self._decode = jax.jit(self.model.decode_step)

    @classmethod
    def from_artifact(cls, artifact: Union[str, "os.PathLike", Any], *,
                      max_batch: Optional[int] = None,
                      max_seq: Optional[int] = None, seed: int = 0,
                      predict_step: bool = True,
                      scheduler: Union[SchedulerConfig, str, None] = None,
                      measurements: Optional[MeasurementLog] = None,
                      faults: Optional[FaultInjector] = None,
                      fault_tag: Optional[str] = None,
                      straggler: Optional[StragglerMonitor] = None,
                      mesh=None) -> "ServeEngine":
        """Serve a :class:`~repro.api.artifact.DeploymentArtifact` (an
        instance or a directory path) without constructing a
        ``PruningSession`` — the cheap, restartable half of the pipeline.

        ``max_batch``/``max_seq`` default to the artifact's recorded serve
        defaults, in which case the export-time decode-step prediction is
        reused; other shapes re-derive the prediction from the artifact's
        own target + oracle (None when its replay log cannot score them).

        ``mesh`` (a ``(data, model)`` device mesh) serves the artifact
        sharded through :class:`repro.serve.distributed.ShardedServeEngine`;
        a partition-stamped (tp > 1) artifact gets its default ``(1, tp)``
        mesh even without one. The mesh is validated against the
        artifact's partition with errors naming the mesh shape.
        """
        if isinstance(artifact, (str, os.PathLike)):
            from repro.api.artifact import DeploymentArtifact
            artifact = DeploymentArtifact.load(os.fspath(artifact))
        extra: Dict[str, Any] = {}
        if mesh is not None or int(getattr(artifact, "tp", 1)) > 1:
            from repro.serve.distributed import ShardedServeEngine
            if not issubclass(cls, ShardedServeEngine):
                return ShardedServeEngine.for_artifact(
                    artifact, mesh=mesh, max_batch=max_batch,
                    max_seq=max_seq, seed=seed, predict_step=predict_step,
                    scheduler=scheduler, measurements=measurements,
                    faults=faults, fault_tag=fault_tag,
                    straggler=straggler)
            extra["mesh"] = mesh
        defaults = artifact.metadata.get("serve_defaults") or {}
        if max_batch is None:
            max_batch = defaults.get("max_batch", 8)
        if max_seq is None:
            max_seq = defaults.get("max_seq", 512)
        predicted = None
        if predict_step:
            if (max_batch == defaults.get("max_batch")
                    and max_seq == defaults.get("max_seq")):
                predicted = artifact.metadata.get("predicted_step_s")
            if predicted is None:
                # other dims — or an artifact exported without a
                # prediction — re-derive from the artifact's own
                # target + oracle (None when its log cannot score it)
                predicted = artifact.predict_step_s(max_batch, max_seq)
        return cls(artifact.cfg, artifact.params, max_batch=max_batch,
                   max_seq=max_seq, seed=seed, predicted_step_s=predicted,
                   scheduler=scheduler, measurements=measurements,
                   measurement_tag=artifact.measurement_tag,
                   faults=faults, fault_tag=fault_tag, straggler=straggler,
                   **extra)

    # -- queueing -----------------------------------------------------------

    def submit(self, req: Request):
        # a re-queued request keeps its original submit time: the SLO
        # clock (deadline_s) must not restart just because a replica died
        if not req.t_submit:
            req.t_submit = time.time()
        self.scheduler.submit(req)

    @property
    def pending(self) -> List[Request]:
        """Requests admitted to the scheduler but not yet prefilled."""
        return self.scheduler.pending

    @property
    def has_work(self) -> bool:
        return bool(len(self.scheduler) or self.groups)

    def in_flight(self) -> List[Request]:
        """Every submitted-but-unfinished request: scheduler-pending plus
        the live decode rows. This is what a supervisor re-queues after a
        crash — by construction it is disjoint from ``done``, so nothing
        is ever counted twice or lost."""
        live = list(self.scheduler.pending)
        seen = {id(r) for r in live}
        for g in self.groups:
            for r in g.requests:
                if r is not None and not r.done and id(r) not in seen:
                    seen.add(id(r))
                    live.append(r)
        return live

    # -- the stepped core ---------------------------------------------------

    def step(self) -> Dict[str, Any]:
        """One non-blocking scheduling quantum.

        Admits one cohort (prefill + first sampled token) when the
        scheduler yields one for the free slots; otherwise advances every
        live group one decode token; otherwise reports ``idle``. Returns
        a small event record — callers interleave ``step()`` with their
        own work (the router round-robins it across engines)."""
        t0 = time.perf_counter()
        try:
            result = self._step_inner()
        finally:
            # wall time accrues per quantum, so an engine driven by an
            # external loop (the router round-robin) still reports a
            # meaningful tokens_per_s
            self._wall_s += time.perf_counter() - t0
        if self._debug_kv:
            self._kv_debug_sweep()
        return result

    def _kv_debug_sweep(self) -> None:
        """Quantum-boundary sanitizer sweep (``debug_kv``): every paged-KV
        invariant over the allocator + live tables, raising
        ``KVSanitizerError`` on the first violation. Host-side only — no
        device sync — but O(pool), so it stays behind the debug flag."""
        from repro.analysis.kv_sanitizer import (KVSanitizerError,
                                                 check_engine)
        diags = check_engine(self)
        self._kv_debug_checks += 1
        if diags:
            self._kv_debug_violations += len(diags)
            raise KVSanitizerError(diags)

    def _step_inner(self) -> Dict[str, Any]:
        free = self.max_batch - sum(g.width for g in self.groups)
        batch = self.scheduler.select(free, live_groups=len(self.groups))
        if batch:
            try:
                self._admit(batch)
            except Exception:
                # an admission crash (e.g. injected prefill OOM) must
                # not lose the cohort: the scheduler already popped
                # it, so hand it back before propagating — the
                # supervisor then finds every request in in_flight()
                for r in batch:
                    self.scheduler.submit(r)
                raise
            return {"event": "prefill", "admitted": len(batch),
                    "prompt_len": len(batch[0].prompt),
                    "live_groups": len(self.groups)}
        if self.groups:
            new_tokens = self._decode_tick()
            return {"event": "decode",
                    "live_groups": len(self.groups),
                    "new_tokens": new_tokens}
        return {"event": "idle", "pending": len(self.scheduler)}

    def serve_forever(self, deadline_s: Optional[float] = None
                      ) -> Dict[str, Any]:
        """Step until drained, or until ``deadline_s`` wall seconds pass.

        Returns :meth:`stats`. The engine is resumable: a deadline exit
        leaves pending requests and live groups intact, and a later call
        (or :meth:`step`) picks up exactly where it stopped."""
        t0 = time.time()
        while True:
            if deadline_s is not None and time.time() - t0 >= deadline_s:
                break
            if self.step()["event"] == "idle":
                break
        if self.measurements is not None and self._step_times:
            self.record_measurements()
        return self.stats()

    def run(self) -> Dict[str, Any]:
        """Legacy blocking drain — a thin wrapper over
        :meth:`serve_forever` with identical greedy outputs."""
        return self.serve_forever()

    # -- internal: admission + decode ---------------------------------------

    def _admit(self, reqs: List[Request]) -> SlotGroup:
        if self.faults is not None:
            self.faults.fire("prefill", self.fault_tag)
        if self.kv_layout == "paged":
            return self._admit_paged(reqs)
        plen = len(reqs[0].prompt)
        toks = np.zeros((len(reqs), plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i] = r.prompt
        logits, caches = self._prefill(self.params,
                                       {"tokens": jnp.asarray(toks)})
        t_first = time.time()
        for r in reqs:
            r.t_first_token = t_first
        cur = self._sample(logits, reqs)
        for i, r in enumerate(reqs):
            r.output.append(int(cur[i, 0]))
        self._prefills += 1
        self._prefill_tokens += len(reqs) * plen
        self._live_kv_slots += len(reqs) * self.max_seq
        self._peak_kv_slots = max(self._peak_kv_slots, self._live_kv_slots)
        group = SlotGroup(reqs, caches, cur, plen)
        group.copy_counter = self._copy_counter
        self.groups.append(group)
        self._retire(group)
        return group

    def _admit_paged(self, reqs: List[Request]) -> SlotGroup:
        """Paged admission: prefill each *distinct* prompt once at the
        cohort's block-padded length, scatter whole KV blocks into the
        pools, and point every row's block table at them — full prefix
        blocks shared (refcounted) across identical prompt heads, the
        partial frontier block always private per row."""
        sc = self.scheduler.config
        bs = sc.page_size
        plen = len(reqs[0].prompt)
        if sc.prefill_chunk and plen > sc.prefill_chunk:
            return self._admit_chunked(reqs)
        W = len(reqs)
        alloc = self.kv_allocator
        prompts = [np.asarray(r.prompt, np.int32) for r in reqs]
        share = sc.share_prefix
        if share:
            # whole-prompt dedup within the cohort: prefill unique rows
            # only, fan the last-token logits back out per request
            uniq: Dict[bytes, int] = {}
            u_prompts: List[np.ndarray] = []
            row_to_u: List[int] = []
            for p in prompts:
                kb = p.tobytes()
                if kb not in uniq:
                    uniq[kb] = len(u_prompts)
                    u_prompts.append(p)
                row_to_u.append(uniq[kb])
        else:
            u_prompts, row_to_u = prompts, list(range(W))
        U = len(u_prompts)
        padded = -(-plen // bs) * bs
        ncb = padded // bs
        # tokens stay at plen (logits come from the true last position);
        # only the returned cache is block-padded — its slots past plen
        # hold garbage at absolute positions the causal mask hides until
        # decode overwrites them
        logits_u, caches = self._prefill_padded(
            self.params, {"tokens": jnp.asarray(np.stack(u_prompts))},
            padded)

        # block tables: one canonical table per unique prompt, built
        # column by column against the share registry; later rows with
        # the same prompt incref the full columns and get a private
        # frontier block (scattered from the same prefill row)
        rows_s: List[int] = []   # scatter worklist into the U prefill rows
        cols_s: List[int] = []
        bids_s: List[int] = []
        # every reference acquired below, in order — pool exhaustion
        # mid-table must return them all before the cohort is re-queued,
        # or the pool shrinks for good (a V001 leak under debug_kv)
        acquired: List[int] = []
        u_tables = np.zeros((U, ncb), np.int32)
        try:
            for u, p in enumerate(u_prompts):
                for j in range(ncb):
                    full = (j + 1) * bs <= plen
                    bid = None
                    if share and full:
                        # plen and U are part of the key: k/v bits can
                        # differ across padded lengths / batch widths, and
                        # a shared block must be byte-for-byte one
                        # computation
                        key = (plen, U, p[:(j + 1) * bs].tobytes())
                        bid = alloc.share(key)
                        if bid is not None:
                            acquired.append(bid)
                        else:
                            bid = alloc.alloc()
                            acquired.append(bid)
                            alloc.publish(key, bid)
                            rows_s.append(u); cols_s.append(j)
                            bids_s.append(bid)
                    else:
                        bid = alloc.alloc()
                        acquired.append(bid)
                        rows_s.append(u); cols_s.append(j); bids_s.append(bid)
                    u_tables[u, j] = bid
            table = np.zeros((W, ncb), np.int32)
            seen_u: Dict[int, int] = {}
            frontier = ncb - 1 if plen % bs else None
            for i in range(W):
                u = row_to_u[i]
                if u not in seen_u:
                    seen_u[u] = i
                    table[i] = u_tables[u]
                    continue
                for j in range(ncb):
                    if j == frontier:
                        bid = alloc.alloc()  # private frontier per duplicate
                        acquired.append(bid)
                        rows_s.append(u); cols_s.append(j); bids_s.append(bid)
                    else:
                        bid = int(u_tables[u, j])
                        alloc.incref(bid, shared=True)
                        acquired.append(bid)
                    table[i, j] = bid
        except BaseException:
            for bid in reversed(acquired):
                alloc.decref(bid)
            raise
        self._pools = scatter_prefill_blocks(
            self._pools, caches, rows_s, cols_s, bids_s, block_size=bs)

        t_first = time.time()
        for r in reqs:
            r.t_first_token = t_first
        logits = logits_u if U == W else jnp.take(
            logits_u, jnp.asarray(row_to_u, jnp.int32), axis=0)
        cur = self._sample(logits, reqs)
        for i, r in enumerate(reqs):
            r.output.append(int(cur[i, 0]))
        self._prefills += 1
        self._prefill_tokens += U * plen
        group = PagedSlotGroup(reqs, table, cur, plen, allocator=alloc,
                               block_size=bs, pos=plen)
        group.copy_counter = self._copy_counter
        self.groups.append(group)
        self._retire(group)
        return group

    def _admit_chunked(self, reqs: List[Request]) -> SlotGroup:
        """Admit a long-prompt cohort for chunked prefill: allocate its
        real blocks (chunk-padding columns point at the scratch block)
        and let ``_decode_tick`` advance one chunk per tick, interleaved
        with other groups' decode steps. The first token is sampled when
        the last chunk lands. Chunked cohorts skip the share registry."""
        sc = self.scheduler.config
        bs, C = sc.page_size, sc.prefill_chunk
        W = len(reqs)
        plen = len(reqs[0].prompt)
        alloc = self.kv_allocator
        n_chunks = -(-plen // C)
        total_cols = n_chunks * C // bs
        ncb_real = -(-plen // bs)
        table = np.full((W, total_cols), SCRATCH_BLOCK, np.int32)
        acquired: List[int] = []
        try:
            for i in range(W):
                for j in range(ncb_real):
                    bid = alloc.alloc()
                    acquired.append(bid)
                    table[i, j] = bid
        except BaseException:
            # pool exhausted mid-table: return every block already taken
            # before the cohort is re-queued, or they leak for good
            for bid in reversed(acquired):
                alloc.decref(bid)
            raise
        prompt_padded = np.zeros((W, n_chunks * C), np.int32)
        for i, r in enumerate(reqs):
            prompt_padded[i, :plen] = r.prompt
        group = PagedSlotGroup(reqs, table, None, plen, allocator=alloc,
                               block_size=bs, pos=plen)
        group.n_chunks = n_chunks
        group.prompt_padded = prompt_padded
        group.copy_counter = self._copy_counter
        self._prefills += 1
        self.groups.append(group)
        return group

    def _decode_tick(self) -> int:
        new_tokens = 0
        self._ticks += 1
        for group in list(self.groups):
            if isinstance(group, PagedSlotGroup) and group.prefilling:
                self._chunk_tick(group)
                continue
            t0 = time.perf_counter()
            if self.faults is not None:
                # inside the timed region: a delay spec shows up as a
                # slow step (the straggler monitor must see it), a crash
                # spec kills the tick with the group state untouched
                self.faults.fire("decode", self.fault_tag)
            if isinstance(group, PagedSlotGroup):
                if group.pos % group.block_size == 0:
                    # decode is about to cross into a new block-table
                    # column (prefill filled columns 0..ceil(plen/bs)-1)
                    group.ensure_frontier()
                logits, self._pools = self._decode_paged(
                    self.params, group.cur, self._pools,
                    group.device_table(), jnp.int32(group.pos))
                group.pos += 1
            else:
                logits, group.caches = self._decode(self.params, group.cur,
                                                    group.caches)
            jax.block_until_ready(logits)
            dt = time.perf_counter() - t0
            if self.straggler is not None:
                self.straggler.observe(dt)
            self._decode_wall_s += dt
            self._step_times.append(dt)
            self._step_widths.append(group.width)
            self._decode_steps += 1
            self._slot_steps += group.width
            self._active_slot_steps += sum(
                1 for r in group.requests if r is not None)
            group.cur = self._sample(logits, group.requests)
            for i, r in enumerate(group.requests):
                if r is not None and len(r.output) < r.max_new_tokens:
                    r.output.append(int(group.cur[i, 0]))
                    new_tokens += 1
            self._retire(group)
        return new_tokens

    def _chunk_tick(self, group: PagedSlotGroup) -> None:
        """Advance one prefill chunk of a chunked-admission group (no
        fault point: chunk work belongs to the admission's prefill)."""
        C = self.scheduler.config.prefill_chunk
        c = group.chunks_done
        start = c * C
        toks = jnp.asarray(group.prompt_padded[:, start:start + C])
        last = min(group.plen - 1 - start, C - 1)
        logits, self._pools = self._chunk_step(
            self.params, toks, self._pools, group.device_table(),
            jnp.int32(start), jnp.int32(last))
        jax.block_until_ready(logits)
        group.chunks_done += 1
        self._chunk_steps += 1
        self._prefill_tokens += group.width * C
        if not group.prefilling:
            t_first = time.time()
            for r in group.requests:
                if r is not None:
                    r.t_first_token = t_first
            group.cur = self._sample(logits, group.requests)
            for i, r in enumerate(group.requests):
                if r is not None:
                    r.output.append(int(group.cur[i, 0]))
            self._retire(group)

    def _retire(self, group: SlotGroup) -> None:
        """Move finished requests out of their rows, drop the group when
        empty, and compact the surviving rows (freed slots return to the
        global budget, so the next cohort can be admitted mid-decode)."""
        now = time.time()
        for i, r in enumerate(group.requests):
            if r is not None and len(r.output) >= r.max_new_tokens:
                r.done, r.t_done = True, now
                self.done.append(r)
                group.requests[i] = None
        if all(r is None for r in group.requests):
            self.groups.remove(group)
            if isinstance(group, PagedSlotGroup):
                group.release()   # refcounts drop; orphaned blocks free
            else:
                self._live_kv_slots -= group.width * self.max_seq
            return
        freed = group.compact(self.scheduler.config.compact)
        if freed and not isinstance(group, PagedSlotGroup):
            self._live_kv_slots -= freed * self.max_seq

    def _sample(self, logits: jax.Array,
                rows: List[Optional[Request]]) -> jax.Array:
        self.key, sub = jax.random.split(self.key)
        greedy = jnp.argmax(logits[:, 0], axis=-1)
        temps = jnp.asarray([r.temperature if r is not None else 0.0
                             for r in rows])[:, None]
        noisy = jax.random.categorical(
            sub, logits[:, 0] / jnp.maximum(temps, 1e-6))
        tok = jnp.where(temps[:, 0] > 0, noisy, greedy)
        return tok[:, None].astype(jnp.int32)

    # -- stats + measurement feedback ---------------------------------------

    def reset_stats(self) -> None:
        """Zero every counter and forget retired requests (their Request
        objects keep their outputs). Benchmarks use this to exclude a
        warmup drain from a timed one."""
        self.done = []
        self._prefills = 0
        self._ticks = 0
        self._decode_steps = 0
        self._decode_wall_s = 0.0
        self._slot_steps = 0
        self._active_slot_steps = 0
        self._step_times: List[float] = []
        self._step_widths: List[int] = []
        self._wall_s = 0.0
        self._prefill_tokens = 0
        self._chunk_steps = 0
        self._copy_counter["rows"] = 0
        self._kv_debug_checks = 0
        self._kv_debug_violations = 0
        self._peak_kv_slots = self._live_kv_slots
        if self.kv_allocator is not None:
            self.kv_allocator.reset_stats()
        if self.straggler is not None:
            # post-swap stats must not inherit pre-swap medians
            self.straggler.reset()

    def record_measurements(self, log: Optional[MeasurementLog] = None
                            ) -> Optional[str]:
        """Record the observed decode step (median over this engine's
        timed steps) into ``log`` (default: the attached ``measurements``
        log) under :meth:`MeasurementLog.step_key`; returns the key, or
        None when no step has run yet.

        The key claims a step at this engine's batch shape, but
        compaction runs many steps at narrower widths (which are cheaper)
        — so only the samples taken at the *widest* width observed (the
        full ``max_batch`` whenever it ever filled) enter the median."""
        log = self.measurements if log is None else log
        if log is None:
            raise ValueError("no MeasurementLog to record into; construct "
                             "the engine with measurements=MeasurementLog() "
                             "or pass one explicitly")
        if not self._step_times:
            return None
        widest = max(self._step_widths)
        samples = [t for t, w in zip(self._step_times, self._step_widths)
                   if w == widest]
        key = MeasurementLog.step_key(self.measurement_tag, self.max_batch,
                                      self.max_seq)
        log.record(key, float(np.median(np.asarray(samples))))
        return key

    @staticmethod
    def _pct(xs: List[float], q: float) -> float:
        """Percentile with an empty-sample guard: an idle engine reports
        zeros, never NaN."""
        return float(np.percentile(np.asarray(xs), q)) if xs else 0.0

    def stats(self) -> Dict[str, Any]:
        total_tokens = sum(len(r.output) for r in self.done)
        ttfts = [r.t_first_token - r.t_submit for r in self.done]
        decodes = [r.t_done - r.t_first_token for r in self.done]
        stats = {
            "requests": len(self.done),
            "waves": self._prefills,          # legacy name for prefills
            "prefills": self._prefills,
            "total_new_tokens": total_tokens,
            "wall_s": self._wall_s,
            "tokens_per_s": total_tokens / max(self._wall_s, 1e-9),
            "mean_ttft_s": float(np.mean(ttfts)) if ttfts else 0.0,
            # tail latency: TTFT and per-request decode time across
            # requests, plus per-decode-step percentiles — the serve-time
            # check for the planner's per-step latency claims
            "p50_ttft_s": self._pct(ttfts, 50),
            "p95_ttft_s": self._pct(ttfts, 95),
            "p50_decode_s": self._pct(decodes, 50),
            "p95_decode_s": self._pct(decodes, 95),
            "p50_step_s": self._pct(self._step_times, 50),
            "p95_step_s": self._pct(self._step_times, 95),
            # scheduler-core accounting: decode_steps counts jitted decode
            # calls (one per live group per tick), slot_steps the batch
            # rows they carried, active_slot_steps the rows doing useful
            # work; occupancy is useful rows over the global slot budget
            "decode_steps": self._decode_steps,
            "decode_ticks": self._ticks,
            "slot_steps": self._slot_steps,
            "active_slot_steps": self._active_slot_steps,
            "mean_batch_occupancy": (
                self._active_slot_steps / (self._ticks * self.max_batch)
                if self._ticks else 0.0),
            # decode ticks slower than factor x rolling median (0 when no
            # StragglerMonitor is attached — fleets attach one per engine)
            "straggler_steps": (self.straggler.stragglers
                                if self.straggler is not None else 0),
            # predicted-vs-measured step latency: how wrong the latency
            # oracle is on the model that is actually executing
            "measured_step_s": self._decode_wall_s / self._decode_steps
            if self._decode_steps else 0.0,
            "predicted_step_s": self.predicted_step_s,
            # KV storage accounting. kv_row_copies counts physically
            # gathered cache rows (paged compaction rewrites tables, so
            # it stays 0 there); peak_kv_bytes is the peak *used* KV —
            # block-granular for paged, width x max_seq for contiguous
            "kv_layout": self.kv_layout,
            "kv_row_copies": self._copy_counter["rows"],
            "prefill_tokens": self._prefill_tokens,
            "chunk_steps": self._chunk_steps,
            "kv_blocks_peak": (self.kv_allocator.peak_blocks
                               if self.kv_allocator is not None else 0),
            "kv_blocks_in_use": (self.kv_allocator.blocks_in_use
                                 if self.kv_allocator is not None else 0),
            "kv_shared_blocks": (self.kv_allocator.shared_hits
                                 if self.kv_allocator is not None else 0),
            # paged-KV sanitizer accounting (debug_kv): quantum-boundary
            # sweeps run and invariant violations seen (violations also
            # raise, so a drained run should report checks > 0, 0 here)
            "kv_debug_checks": self._kv_debug_checks,
            "kv_debug_violations": self._kv_debug_violations,
            "peak_kv_bytes": (
                self.kv_allocator.peak_blocks
                * self.scheduler.config.page_size * self._kv_row_bytes
                if self.kv_layout == "paged"
                else self._peak_kv_slots * self._kv_row_bytes),
        }
        if self.predicted_step_s is not None and self._decode_steps:
            meas = stats["measured_step_s"]
            stats["oracle_rel_error"] = \
                (self.predicted_step_s - meas) / max(meas, 1e-12)
        return stats


#: The redesign's name for the execution half; ``ServeEngine`` is kept as
#: the primary name because every artifact/session entry point returns it.
Engine = ServeEngine

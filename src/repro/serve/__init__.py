from repro.serve.engine import Engine, Request, ServeEngine
from repro.serve.fleet import ReplicaSupervisor, RetryPolicy, RouteError
from repro.serve.router import ArtifactCatalog, CatalogEntry, Router
from repro.serve.scheduler import (PagedSlotGroup, Scheduler,
                                   SchedulerConfig, SlotGroup)
from repro.serve.autopilot import Autopilot, AutopilotConfig, replan_from

__all__ = ["ArtifactCatalog", "Autopilot", "AutopilotConfig",
           "CatalogEntry", "Engine", "PagedSlotGroup", "ReplicaSupervisor",
           "Request", "RetryPolicy", "RouteError", "Router", "Scheduler",
           "SchedulerConfig", "ServeEngine", "SlotGroup", "replan_from"]

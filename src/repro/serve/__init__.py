from repro.serve.engine import Engine, Request, ServeEngine
from repro.serve.fleet import (ReplicaSet, ReplicaSupervisor, RetryPolicy,
                               RouteError, outstanding_tokens)
from repro.serve.router import ArtifactCatalog, CatalogEntry, Router
from repro.serve.scheduler import (PagedSlotGroup, Scheduler,
                                   SchedulerConfig, SlotGroup)
from repro.serve.autopilot import Autopilot, AutopilotConfig, replan_from
from repro.serve.distributed import (ShardedServeEngine, mesh_for_artifact,
                                     validate_mesh)

__all__ = ["ArtifactCatalog", "Autopilot", "AutopilotConfig",
           "CatalogEntry", "Engine", "PagedSlotGroup", "ReplicaSet",
           "ReplicaSupervisor", "Request", "RetryPolicy", "RouteError",
           "Router", "Scheduler", "SchedulerConfig", "ServeEngine",
           "ShardedServeEngine", "SlotGroup", "mesh_for_artifact",
           "outstanding_tokens", "replan_from", "validate_mesh"]

from repro.serve.engine import Engine, Request, ServeEngine
from repro.serve.router import (ArtifactCatalog, CatalogEntry, RouteError,
                                Router)
from repro.serve.scheduler import Scheduler, SchedulerConfig, SlotGroup

__all__ = ["ArtifactCatalog", "CatalogEntry", "Engine", "Request",
           "RouteError", "Router", "Scheduler", "SchedulerConfig",
           "ServeEngine", "SlotGroup"]

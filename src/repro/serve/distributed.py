"""Tensor-parallel sharded serving over a device mesh.

CPrune's premise is target-aware execution, and "the target" can be a
mesh, not a chip: this module takes a (possibly partition-stamped)
:class:`~repro.api.artifact.DeploymentArtifact` from single-device
serving to mesh-sharded serving.

:class:`ShardedServeEngine` is the :class:`~repro.serve.engine.ServeEngine`
with its arrays placed instead of its logic changed:

* params are ``jax.device_put`` with :class:`~jax.sharding.NamedSharding`
  resolved from :mod:`repro.sharding.rules` — the same trailing-dim rule
  table the training mesh uses, fitted to the serving mesh (axes a dim
  does not divide fall back to replicated);
* paged KV **pools** shard their ``n_kv_heads`` axis over ``model``;
  contiguous KV caches come out of the jitted prefill already placed by
  GSPMD propagation from the sharded params;
* paged **block tables** stay host-side numpy exactly as before and are
  consumed replicated, so admission/compaction remain pointer rewrites —
  sharding never touches the allocator;
* the decode/prefill step functions are the engine's own jits: tracing
  happens on first call with committed sharded inputs, so GSPMD
  partitions the very same jaxpr the single-device engine runs. Greedy
  decode therefore reproduces the tp=1 token stream (enforced
  bit-identical by tests/test_distributed_serve.py).

The mesh is ``(data, model)`` as built by
:func:`repro.launch.mesh.make_test_mesh` /
:func:`~repro.launch.mesh.make_production_mesh`; on CPU CI
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` makes tp=2 real.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional, Union

import jax
import numpy as np

from repro.launch.mesh import MeshError, make_test_mesh
from repro.serve.engine import ServeEngine
from repro.sharding import rules

__all__ = ["ShardedServeEngine", "MeshError", "mesh_for_artifact",
           "validate_mesh"]


def mesh_for_artifact(artifact) -> "jax.sharding.Mesh":
    """The default serving mesh for a partition-stamped artifact: all of
    the model axis (``tp`` shards), no data parallelism — ``(1, tp)``.
    Raises :class:`MeshError` naming the device shortfall when the host
    cannot express it."""
    tp = int(getattr(artifact, "tp", 1))
    return make_test_mesh(n_devices=tp, model=tp)


def validate_mesh(mesh, *, tp: Optional[int] = None,
                  what: str = "artifact") -> int:
    """Check a serving mesh carries a ``model`` axis and (when ``tp`` is
    given) that the axis matches the requested/partitioned degree.
    Returns the mesh's model degree. Errors name the mesh shape, never
    just "mismatch"."""
    shape = dict(mesh.shape)
    if "model" not in shape:
        raise MeshError(
            f"serving mesh must carry a 'model' axis for tensor "
            f"parallelism; got mesh axes {tuple(shape)} (shape {shape})")
    mtp = int(shape["model"])
    if tp is not None and tp > 1 and mtp != tp:
        raise MeshError(
            f"{what} is partitioned for tp={tp} model shards but the "
            f"mesh's model axis is {mtp} (mesh shape {shape}) — rebuild "
            f"the mesh with model={tp} (e.g. "
            f"make_test_mesh(n_devices={tp}, model={tp}))")
    return mtp


def _pool_pspecs(pools, mesh):
    """Paged pool specs: ``(n_blocks, block_size, n_kv, head_dim)`` (plus
    an optional leading stack axis) with the KV-head axis over ``model``
    — the same head sharding the contiguous cache rules use, expressed on
    the pool layout. Falls back to replicated when heads don't divide."""
    return jax.tree.map(
        lambda x: rules.fit_spec((None, None, "model", None),
                                 np.shape(x), mesh), pools)


class ShardedServeEngine(ServeEngine):
    """A :class:`ServeEngine` whose params and KV storage live sharded on
    a ``(data, model)`` mesh. Scheduling, admission, compaction, fault
    handling, and stats are inherited unchanged — only array placement
    differs, so every supervisor/router/autopilot layer stacks on top
    exactly as for the single-device engine."""

    def __init__(self, cfg, params, *, mesh, **kw):
        self.mesh = mesh
        self.tp = validate_mesh(mesh, what=cfg.name)
        super().__init__(cfg, params, **kw)
        # place params per the rule table; jits trace lazily, so their
        # first call sees committed sharded inputs and GSPMD partitions
        # the identical single-device jaxpr under the mesh
        self.param_pspecs = rules.param_pspecs(self.params, mesh)
        self.params = jax.device_put(
            self.params, rules.shardings_of(self.param_pspecs, mesh))
        if self.kv_layout == "paged":
            # shard the pools' KV-head axis; block tables remain host
            # numpy (PagedSlotGroup) and enter each step replicated
            self._pools = jax.device_put(
                self._pools,
                rules.shardings_of(_pool_pspecs(self._pools, mesh), mesh))

    def stats(self) -> Dict[str, Any]:
        out = super().stats()
        out["mesh"] = {k: int(v) for k, v in dict(self.mesh.shape).items()}
        out["tp"] = self.tp
        return out

    @classmethod
    def for_artifact(cls, artifact: Union[str, "os.PathLike", Any], *,
                     mesh=None, **kw) -> "ShardedServeEngine":
        """Build a sharded engine for an artifact (path or instance).

        ``mesh=None`` on a partition-stamped artifact gets the default
        ``(1, tp)`` mesh; an explicit mesh is validated against the
        artifact's partition (errors name the mesh shape). Unpartitioned
        artifacts may also be served sharded — the partition stamp is a
        pricing/validation record, the layout itself always derives from
        the sharding rules."""
        if isinstance(artifact, (str, os.PathLike)):
            from repro.api.artifact import DeploymentArtifact
            artifact = DeploymentArtifact.load(os.fspath(artifact))
        if mesh is None:
            mesh = mesh_for_artifact(artifact)
        validate_mesh(mesh, tp=int(getattr(artifact, "tp", 1)),
                      what=f"artifact {artifact.measurement_tag!r}")
        return ServeEngine.from_artifact.__func__(
            cls, artifact, mesh=mesh, **kw)

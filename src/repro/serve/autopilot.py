"""Autopilot control plane: drift-triggered replanning + hot-swap.

CPrune's thesis is that compiler/serve-time *measurements* steer the
pruned model. The offline pipeline already closes most of that loop —
``plan()`` sweeps strategies under a measurement-backed oracle, serving
records the observed decode step, and
:meth:`DeploymentArtifact.recalibrated_oracle` folds the observation
back into the oracle — but a human still had to notice the drift and
rerun ``plan()``. :class:`Autopilot` removes the human:

    watch  — every ``check_every`` router steps, read each catalog
             entry's health signals from one place (`Router.stats()`):
             predicted-vs-measured ``oracle_rel_error`` scored over a
             :class:`MeasurementLog` observation window
             (:func:`repro.core.oracle.score_drift`), the per-entry
             ``budget_violation_rate``, and the supervisor's
             crash/quarantine counts.
    replan — when a signal crosses its threshold, recalibrate the drift
             source's replay oracle against the observed step and re-run
             the *prior plan's own sweep* under it
             (:func:`repro.api.planner.replan` — the ProgramCache keys
             carry the new oracle fingerprint, so the re-sweep is warm
             but never reuses stale winners).
    swap   — export the new frontier as a side-by-side catalog
             generation (:class:`repro.api.artifact.GenerationStore`),
             flip the ``CURRENT`` pointer atomically, and
             :meth:`Router.swap` it live: new requests route on the new
             generation, in-flight requests drain on the old engines,
             and the old fleets retire only at zero in-flight work.
    judge  — the new generation is on *probation* for
             ``probation_steps``; if its budget-violation rate is
             strictly worse than the outgoing generation's, the
             autopilot flips back (:meth:`rollback`) — the same
             half-open discipline the fleet's circuit breaker uses —
             and backs off; otherwise old generations are retired down
             to ``keep_generations``.

Crash safety is the store's: a kill at any point of the swap (the
``swap_export`` / ``swap_commit`` fault points make this testable)
leaves either the old or the new generation fully current — never a
torn catalog.

The replan runs inline by default — "background" in the sense that
serving is never disturbed: admitted requests keep their engines, and
the swap itself is O(pointer flip). ``background=True`` moves the
expensive ``plan()`` sweep to a worker thread and applies the finished
swap on a later control tick; the serve loop keeps stepping meanwhile.
(The sweep briefly activates target/oracle globals, which is safe
because decode steps never consult them — but only one replan runs at a
time.)
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional

from repro.api.artifact import ArtifactError, GenerationStore
from repro.core.oracle import DriftReport, MeasurementLog, score_drift
from repro.serve.router import ArtifactCatalog, Router

__all__ = ["Autopilot", "AutopilotConfig", "replan_from"]


@dataclasses.dataclass(frozen=True)
class AutopilotConfig:
    """Thresholds and pacing for the control loop.

    ``check_every``
        router steps between health sweeps.
    ``rel_error_threshold``
        |windowed (measured-predicted)/predicted| that counts as oracle
        drift.
    ``violation_threshold`` / ``min_budgeted``
        per-entry budget-violation rate that counts as drift, once at
        least ``min_budgeted`` budgeted requests completed there.
    ``crash_threshold``
        supervisor crash count that counts as drift (quarantine always
        does).
    ``min_window``
        observed decode steps (one per sweep) before ``oracle_rel_error``
        is trusted — a single straggler must not trigger a replan.
    ``probation_steps``
        router steps the new generation must serve before it is judged
        against the outgoing generation's violation rate.
    ``cooldown_steps``
        minimum router steps between replans; a rollback quadruples it.
    ``keep_generations``
        old generations kept on disk after a passed probation.
    ``max_swaps``
        hard cap on autonomous swaps (None = unlimited) — a safety
        valve for demos and tests.
    """

    check_every: int = 16
    rel_error_threshold: float = 0.5
    violation_threshold: float = 0.5
    crash_threshold: int = 5
    min_window: int = 2
    min_budgeted: int = 4
    probation_steps: int = 64
    cooldown_steps: int = 64
    keep_generations: int = 3
    max_swaps: Optional[int] = None


def replan_from(prior) -> Callable[[Dict[str, Any], Any], Any]:
    """The default replan callable: re-run ``prior``'s (a :class:`Plan`)
    own sweep under the recalibrated oracle via
    :func:`repro.api.planner.replan`."""
    def _replan(trigger: Dict[str, Any], oracle) -> Any:
        from repro.api.planner import replan
        return replan(prior, oracle=oracle)
    return _replan


class Autopilot:
    """Drift-triggered replan + zero-downtime hot-swap over one
    :class:`Router`.

    ``replan`` is either a prior :class:`~repro.api.planner.Plan` (its
    own sweep is re-run under the recalibrated oracle) or a callable
    ``(trigger, oracle) -> Plan`` for custom replanning. ``store``
    defaults to a :class:`GenerationStore` over the router catalog's
    base root; ``log`` is the shared measurement log the control loop
    records observed decode steps into (bounded by default — a
    week-long serve process must not grow it without limit). ``faults``
    fires the ``swap_export``/``swap_commit`` points so chaos tests can
    kill a swap mid-flight.
    """

    def __init__(self, router: Router, *, replan,
                 store: Optional[GenerationStore] = None,
                 config: Optional[AutopilotConfig] = None,
                 log: Optional[MeasurementLog] = None,
                 faults=None, background: bool = False):
        self.router = router
        self.config = config or AutopilotConfig()
        self.replan = replan if callable(replan) else replan_from(replan)
        self.store = store or GenerationStore(
            getattr(router.catalog, "base_root", router.catalog.root),
            keep_last=self.config.keep_generations, faults=faults)
        self.log = log if log is not None else MeasurementLog(
            max_entries=256)
        self.faults = faults
        self.background = background
        self._steps = 0
        self._sweeps = 0
        self._replans = 0
        self._swaps = 0
        self._rollbacks = 0
        self._cooldown_until = 0
        self._probation: Optional[Dict[str, Any]] = None
        self._last_trigger: Optional[Dict[str, Any]] = None
        self._skips: Dict[str, int] = {}
        self._events: List[str] = []
        self._worker: Optional[threading.Thread] = None
        self._pending: Optional[Dict[str, Any]] = None

    # -- the control loop ---------------------------------------------------

    def step(self) -> Dict[str, Any]:
        """One serve quantum plus (periodically) one health sweep — the
        drop-in replacement for ``router.step()`` in a serve loop."""
        ev = self.router.step()
        self._steps += 1
        if self.config.check_every and \
                self._steps % self.config.check_every == 0:
            self.sweep()
        return ev

    def run(self, deadline_s: Optional[float] = None) -> Dict[str, Any]:
        """Step until the router drains and no replan is in flight (or
        ``deadline_s``); returns :meth:`stats`."""
        t0 = time.time()
        while self.router.has_work or self._worker is not None \
                or self._pending is not None:
            if deadline_s is not None and time.time() - t0 >= deadline_s:
                break
            if not self.router.has_work and self._worker is not None \
                    and self._worker.is_alive():
                time.sleep(0.005)       # idle wait for the background plan
            self.step()
        return self.stats()

    def sweep(self) -> Optional[Dict[str, Any]]:
        """One health pass: refresh measurements, apply a finished
        background replan, resolve probation, and — when out of cooldown
        and a signal crosses its threshold — trigger a replan+swap.
        Returns the trigger acted on, if any."""
        self._sweeps += 1
        self._record_measurements()
        self._poll_worker()
        if self._probation is not None:
            if self._steps >= self._probation["until"]:
                self._resolve_probation()
            return None
        if self._steps < self._cooldown_until:
            return None
        if self._worker is not None:
            return None                 # a replan is already in flight
        if self.config.max_swaps is not None \
                and self._swaps >= self.config.max_swaps:
            return None
        trigger = self._detect()
        if trigger is None:
            return None
        self._last_trigger = trigger
        self.replan_and_swap(trigger)
        return trigger

    # -- watch: health signals ----------------------------------------------

    def _record_measurements(self) -> None:
        """Fold every live engine's observed decode step into the shared
        log — one observation per engine per sweep, so the per-key
        window measures sweeps, not raw ticks."""
        for sup in self.router._fleets.values():
            for eng in sup.engines:
                if eng._step_times:
                    eng.record_measurements(self.log)

    def _entry_dims(self, name: str) -> Dict[str, int]:
        sup = self.router._fleets.get(name)
        eng = sup.engines[0] if sup is not None and sup.engines else None
        if eng is not None:
            return {"max_batch": eng.max_batch, "max_seq": eng.max_seq}
        try:
            art = self.router.catalog.artifact(name)
            defaults = art.metadata.get("serve_defaults") or {}
        except (ArtifactError, KeyError):
            defaults = {}
        return {"max_batch": defaults.get("max_batch", 8),
                "max_seq": defaults.get("max_seq", 512)}

    def _drift(self, name: str) -> Optional[DriftReport]:
        """Windowed predicted-vs-measured drift for one entry, or None
        without enough evidence."""
        sup = self.router._fleets.get(name)
        if sup is None:
            return None
        eng = sup.engines[0] if sup.engines else None
        predicted = eng.predicted_step_s if eng is not None else None
        if predicted is None:
            predicted = self.router.catalog.get(name).predicted_step_s
        if not predicted:
            return None
        try:
            art = self.router.catalog.artifact(name)
        except (ArtifactError, KeyError):
            return None
        dims = self._entry_dims(name)
        key = MeasurementLog.step_key(art.measurement_tag,
                                      dims["max_batch"], dims["max_seq"])
        return score_drift(self.log, key, predicted,
                           min_window=self.config.min_window)

    def _detect(self) -> Optional[Dict[str, Any]]:
        """Scan every *current-generation* entry; return the strongest
        tripped trigger (largest drift magnitude wins; violation rate
        breaks ties), or None when everything is healthy."""
        cfg = self.config
        tripped: List[Dict[str, Any]] = []
        for name, sup in self.router._fleets.items():
            st = sup.stats()
            drift = self._drift(name)
            reasons = []
            if drift is not None and drift.magnitude \
                    >= cfg.rel_error_threshold:
                reasons.append(
                    f"oracle_rel_error {drift.rel_error:+.2f} over "
                    f"{drift.window} obs (threshold "
                    f"{cfg.rel_error_threshold})")
            if st["budgeted_requests"] >= cfg.min_budgeted \
                    and st["budget_violation_rate"] \
                    >= cfg.violation_threshold:
                reasons.append(
                    f"budget_violation_rate "
                    f"{st['budget_violation_rate']:.2f} over "
                    f"{st['budgeted_requests']} budgeted (threshold "
                    f"{cfg.violation_threshold})")
            if st["crashes"] >= cfg.crash_threshold:
                reasons.append(f"{st['crashes']} crashes (threshold "
                               f"{cfg.crash_threshold})")
            if name in self.router._quarantined:
                reasons.append("quarantined: "
                               + self.router._quarantined[name]["reason"])
            if reasons:
                rec = {"name": name, "reasons": reasons, "drift": drift,
                       "generation": self.router.generation,
                       "violation_rate": st["budget_violation_rate"]}
                rec.update(self._entry_dims(name))
                tripped.append(rec)
        if not tripped:
            return None
        tripped.sort(key=lambda t: (
            -(t["drift"].magnitude if t["drift"] is not None else 0.0),
            -t["violation_rate"]))
        return tripped[0]

    # -- replan + swap ------------------------------------------------------

    def replan_and_swap(self, trigger: Dict[str, Any]) -> bool:
        """Recalibrate the drift source's oracle, replan, and hot-swap
        the winner in as a new catalog generation. Planning errors are
        contained (the old generation keeps serving, the trigger goes
        into cooldown); injected swap faults propagate — they simulate a
        process kill, and the store's atomic flip is the recovery
        story."""
        name = trigger["name"]
        try:
            art = self.router.catalog.artifact(name)
            oracle = art.recalibrated_oracle(
                self.log, max_batch=trigger["max_batch"],
                max_seq=trigger["max_seq"])
        except (ArtifactError, KeyError) as e:
            self._skip("recalibrate", f"{name}: {e}")
            return False
        if oracle is art.oracle:
            # degenerate single-entry log: nothing actually rescaled
            self._skip("recalibrate", f"{name}: degenerate rescale")
            return False
        self._replans += 1
        self._event(f"replan triggered by {name!r}: "
                    + "; ".join(trigger["reasons"]))
        if self.background:
            self._worker = threading.Thread(
                target=self._replan_worker, args=(trigger, oracle),
                daemon=True)
            self._worker.start()
            return True
        try:
            new_plan = self.replan(trigger, oracle)
        except Exception as e:          # noqa: BLE001 — planning must
            # never take serving down with it
            self._skip("replan", f"{type(e).__name__}: {e}")
            return False
        return self._apply(new_plan, trigger)

    def _replan_worker(self, trigger: Dict[str, Any], oracle) -> None:
        try:
            pl = self.replan(trigger, oracle)
            self._pending = {"plan": pl, "trigger": trigger}
        except Exception as e:          # noqa: BLE001
            self._pending = {"error": f"{type(e).__name__}: {e}",
                             "trigger": trigger}

    def _poll_worker(self) -> None:
        if self._worker is None or self._worker.is_alive():
            return
        self._worker.join()
        self._worker = None
        pending, self._pending = self._pending, None
        if pending is None:
            return
        if "error" in pending:
            self._skip("replan", pending["error"])
            return
        self._apply(pending["plan"], pending["trigger"])

    def _apply(self, new_plan, trigger: Dict[str, Any]) -> bool:
        """Stage → export → commit → swap. The pointer flip is the only
        commit point; everything before it is invisible to readers."""
        pre = self._gen_violation_rate()
        gen_id, staged = self.store.stage()
        if self.faults is not None:
            self.faults.fire("swap_export", f"gen{gen_id}")
        try:
            new_plan.export_catalog(staged,
                                    max_batch=trigger["max_batch"],
                                    max_seq=trigger["max_seq"])
        except (ArtifactError, ValueError) as e:
            # includes PlanError (empty frontier): the orphaned stage is
            # reclaimed by the next stage(); the old generation serves on
            self._skip("export", f"{type(e).__name__}: {e}")
            self._cooldown_until = self._steps + self.config.cooldown_steps
            return False
        self.store.commit(gen_id)
        catalog = ArtifactCatalog.load(self.store.root, lazy=True)
        self.router.swap(catalog)
        self._swaps += 1
        self._probation = {
            "until": self._steps + self.config.probation_steps,
            "pre": pre, "generation": catalog.generation,
            "trigger": trigger["name"],
        }
        self._cooldown_until = self._steps + self.config.cooldown_steps
        self._event(f"swapped in generation {catalog.generation} "
                    f"(pre-swap violation rate {pre['rate']:.2f}); "
                    f"probation until step {self._probation['until']}")
        return True

    # -- judge: probation + rollback ----------------------------------------

    def _gen_violation_rate(self) -> Dict[str, Any]:
        """Budget-violation record of the *current* generation's fleets
        only (retired generations are excluded — each generation is
        judged on its own traffic)."""
        done = [r for sup in self.router._fleets.values()
                for r in sup.completed]
        budgeted = [r for r in done if r.latency_budget_s is not None]
        violations = [r for r in budgeted
                      if r.t_done - r.t_submit > r.latency_budget_s]
        return {"budgeted": len(budgeted), "violations": len(violations),
                "rate": (len(violations) / len(budgeted)
                         if budgeted else 0.0)}

    def _resolve_probation(self) -> None:
        assert self._probation is not None
        cur = self._gen_violation_rate()
        pre = self._probation["pre"]
        if cur["budgeted"] >= self.config.min_budgeted \
                and cur["rate"] > pre["rate"]:
            self._event(
                f"probation FAILED: generation "
                f"{self._probation['generation']} violation rate "
                f"{cur['rate']:.2f} > pre-swap {pre['rate']:.2f}; "
                f"rolling back")
            self.rollback()
            return
        self._event(f"probation passed: generation "
                    f"{self._probation['generation']} violation rate "
                    f"{cur['rate']:.2f} (pre-swap {pre['rate']:.2f})")
        self._probation = None
        retired = self.store.retire()
        if retired:
            self._event(f"retired generations {retired}")

    def rollback(self) -> Dict[str, Any]:
        """Flip back to the previous generation and swap it live — the
        half-open discipline: the failed generation stays on disk, the
        cooldown is quadrupled, and a later trigger may try again."""
        gen_id, _ = self.store.rollback()
        catalog = ArtifactCatalog.load(self.store.root, lazy=True)
        self.router.swap(catalog)
        self._rollbacks += 1
        self._probation = None
        self._cooldown_until = self._steps \
            + 4 * max(1, self.config.cooldown_steps)
        self._event(f"rolled back to generation {gen_id}")
        return {"generation": gen_id}

    # -- bookkeeping --------------------------------------------------------

    def _skip(self, stage: str, why: str) -> None:
        self._skips[stage] = self._skips.get(stage, 0) + 1
        self._event(f"skipped at {stage}: {why}")

    def _event(self, msg: str) -> None:
        self._events.append(f"step {self._steps}: {msg}")
        del self._events[:-50]

    def stats(self) -> Dict[str, Any]:
        return {
            "steps": self._steps,
            "sweeps": self._sweeps,
            "replans": self._replans,
            "swaps": self._swaps,
            "rollbacks": self._rollbacks,
            "generation": self.router.generation,
            "probation": (None if self._probation is None else {
                "generation": self._probation["generation"],
                "until": self._probation["until"],
                "pre_rate": self._probation["pre"]["rate"],
            }),
            "cooldown_until": self._cooldown_until,
            "replan_in_flight": self._worker is not None,
            "last_trigger": (None if self._last_trigger is None else {
                "name": self._last_trigger["name"],
                "reasons": self._last_trigger["reasons"],
            }),
            "skips": dict(self._skips),
            "log_entries": len(self.log),
            "log_evicted": self.log.evicted,
            "events": list(self._events),
        }

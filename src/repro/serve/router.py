"""SLO-aware artifact router: per-request constraints at serve time.

The planner's front door is a pair of constraints (accuracy floor,
latency budget) — this module keeps that language alive *per request*
instead of freezing it at deploy time. ``Plan.export_catalog(dir)``
writes the whole Pareto frontier as an :class:`ArtifactCatalog` (one
validated :class:`DeploymentArtifact` per frontier candidate plus a
``catalog.json`` manifest), and a :class:`Router` admits
:class:`~repro.serve.engine.Request`\\ s carrying ``latency_budget_s`` /
``accuracy_floor`` and dispatches each to the catalog entry that
satisfies them:

    catalog = plan(...).export_catalog("fleet/")      # or ArtifactCatalog.load
    router = Router(catalog)
    router.submit(Request(rid=0, prompt=p, max_new_tokens=16,
                          latency_budget_s=5e-3))     # -> fast artifact
    router.submit(Request(rid=1, prompt=p, max_new_tokens=16,
                          latency_budget_s=1.0,
                          accuracy_floor=0.9))        # -> accurate artifact
    stats = router.run()

Routing uses the *oracle-predicted* step latency recorded in each
artifact (``predicted_step_s`` × ``max_new_tokens`` approximates the
request's decode time) and the recorded accuracy. The default policy
spends the budget on quality: among feasible entries, highest accuracy
wins and ties break toward the cheaper entry; ``policy="cheapest"``
implements the strict lowest-latency-that-satisfies reading. Requests no
entry can satisfy are rejected with :class:`RouteError` (or best-effort
dispatched and flagged with ``on_unroutable="flag"``).

Per-artifact engines spin up lazily on first dispatch — each one wrapped
in a :class:`~repro.serve.fleet.ReplicaSupervisor` (crash recovery,
bounded deadline-ordered intake, re-queue with retries) — and share the
router's stats: per-artifact token/s, a routing histogram, and the
measured budget-violation rate — the serve-time check that the planner's
constraint math survived contact with the hardware.

Fault containment at the catalog level: an entry whose artifact fails to
load (``ArtifactError``) or whose supervisor trips ``breaker_k``
consecutive crashes is **quarantined** — removed from dispatch, its
requests falling back to the cheapest remaining entry that still fits
their budget, and periodically probed (every ``probe_every`` router
steps) for recovery. When nothing healthy fits, the router sheds the
request with an explicit :class:`RouteError` instead of queueing past
its deadline.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Dict, Iterator, List, Optional, Union

from repro.api.artifact import (ArtifactError, DeploymentArtifact,
                                GenerationStore)
from repro.core.oracle import MeasurementLog
from repro.serve.engine import Request, ServeEngine
from repro.serve.fleet import ReplicaSupervisor, RetryPolicy, RouteError
from repro.serve.scheduler import SchedulerConfig
from repro.util.faults import FaultInjector

CATALOG_VERSION = 1
CATALOG_NAME = "catalog.json"

POLICIES = ("quality", "cheapest")
ON_UNROUTABLE = ("reject", "flag")

__all__ = ["ArtifactCatalog", "CatalogEntry", "RouteError", "Router",
           "CATALOG_VERSION", "CATALOG_NAME"]


@dataclasses.dataclass(frozen=True)
class CatalogEntry:
    """One frontier artifact in the manifest — the numbers the router
    routes by, pinned at export time and cross-checked against the
    artifact's own metadata on load."""

    name: str                       # "<strategy>@<target>"
    path: str                       # directory, relative to catalog root
    strategy: str
    target: str
    accuracy: float
    latency_s: float                # the plan's ranked whole-model latency
    predicted_step_s: Optional[float]   # oracle decode step @ serve defaults
    tuned_digest: Optional[str]
    # export-time static-analysis stamp ({"passed": bool, "codes": [...]});
    # None in manifests written before repro.analysis existed
    checks: Optional[Dict[str, Any]] = None
    # tensor-parallel degree of the artifact's partition stamp; 1 in
    # manifests written before sharded serving existed
    tp: int = 1

    def describe(self) -> str:
        step = ("?" if self.predicted_step_s is None
                else f"{self.predicted_step_s * 1e3:.3f}ms")
        shard = "" if self.tp == 1 else f"  tp={self.tp}"
        return (f"{self.name:>20s}  acc={self.accuracy:.3f}  "
                f"step={step}{shard}")


class ArtifactCatalog:
    """A directory of frontier :class:`DeploymentArtifact`\\ s plus the
    ``catalog.json`` manifest. :meth:`load` validates every member
    through ``DeploymentArtifact.load`` (a tampered member raises the
    usual :class:`ArtifactError`) and refuses a manifest whose routing
    numbers disagree with its artifacts' own metadata."""

    def __init__(self, root: str, entries: List[CatalogEntry],
                 artifacts: Dict[str, DeploymentArtifact]):
        self.root = root                # the directory actually read
        self.entries = list(entries)
        self._artifacts = dict(artifacts)
        self.lazy = False
        # generation-store identity (set by load): base_root is the
        # stable catalog root whose CURRENT pointer selected this
        # generation; a pointer-less root is simply generation 0
        self.base_root = root
        self.generation = 0

    def __len__(self) -> int:
        return len(self.entries)

    def __iter__(self) -> Iterator[CatalogEntry]:
        return iter(self.entries)

    @property
    def names(self) -> List[str]:
        return [e.name for e in self.entries]

    def get(self, name: str) -> CatalogEntry:
        for e in self.entries:
            if e.name == name:
                return e
        raise KeyError(f"no catalog entry {name!r}; entries: {self.names}")

    def artifact(self, name: str) -> DeploymentArtifact:
        """The (validated) member artifact. In a lazy catalog the member
        is loaded on first use — and *re-attempted* on every call after a
        failure, so a quarantine probe can succeed once the artifact is
        repaired on disk."""
        entry = self.get(name)
        if name not in self._artifacts:
            art = DeploymentArtifact.load(os.path.join(self.root,
                                                       entry.path))
            self._check_entry(entry, art)
            self._artifacts[name] = art
        return self._artifacts[name]

    @staticmethod
    def _check_entry(entry: CatalogEntry, art: DeploymentArtifact) -> None:
        meta = art.metadata
        recorded = (meta.get("final_acc"), meta.get("latency_total_s"),
                    meta.get("predicted_step_s"), art.tuned_digest)
        claimed = (entry.accuracy, entry.latency_s,
                   entry.predicted_step_s, entry.tuned_digest)
        if recorded != claimed:
            raise ArtifactError(
                f"catalog entry {entry.name!r} does not match its "
                f"artifact's metadata (manifest claims {claimed!r}, "
                f"artifact records {recorded!r}) — the manifest or the "
                f"artifact was modified after export")
        if art.tp != entry.tp:
            raise ArtifactError(
                f"catalog entry {entry.name!r} claims tp={entry.tp} but "
                f"its artifact is partitioned for tp={art.tp} — the "
                f"manifest or the artifact was modified after export")

    def summary(self) -> str:
        return "\n".join(e.describe() for e in self.entries)

    @classmethod
    def load(cls, root: str, *, lazy: bool = False,
             check_devices: bool = True) -> "ArtifactCatalog":
        """Load the manifest and — by default — every member artifact.

        ``check_devices=False`` skips only the per-member device-count
        validation of partition-stamped (tp > 1) artifacts — the
        export-side verification re-read uses it, since a catalog is
        often exported on a smaller host than it serves on.

        ``lazy=True`` defers member loading (and its fingerprint
        validation) to the first :meth:`artifact` call per entry. This is
        the fleet-serving mode: one tampered or deleted member then
        surfaces as an :class:`~repro.api.artifact.ArtifactError` at that
        entry's engine-build time, where the :class:`Router` quarantines
        the entry and keeps the rest of the catalog serving, instead of
        refusing the whole catalog up front.

        A root carrying a ``CURRENT`` generation pointer (written by
        :class:`~repro.api.artifact.GenerationStore` during a hot-swap)
        is resolved to its current generation directory first; a plain
        root loads as generation 0 exactly as before."""
        generation, actual = GenerationStore.resolve(root)
        manifest = os.path.join(actual, CATALOG_NAME)
        if not os.path.exists(manifest):
            raise ArtifactError(f"no artifact catalog at {actual!r} "
                                f"(missing {CATALOG_NAME})")
        base_root, root = root, actual
        try:
            with open(manifest) as f:
                blob = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            raise ArtifactError(f"malformed catalog manifest at {root!r}: "
                                f"{type(e).__name__}: {e}") from e
        ver = blob.get("version")
        if ver != CATALOG_VERSION:
            raise ArtifactError(
                f"unsupported catalog version {ver!r} (this build reads "
                f"version {CATALOG_VERSION})")
        entries, artifacts = [], {}
        for d in blob.get("entries", []):
            try:
                entry = CatalogEntry(**d)
            except TypeError as e:
                raise ArtifactError(
                    f"malformed catalog entry in {manifest!r}: {e}") from e
            if not lazy:
                # a tampered member fails DeploymentArtifact.load's own
                # fingerprint validation — the catalog adds no second
                # scheme — and the manifest's routing numbers must agree
                # with the artifact's own metadata
                art = DeploymentArtifact.load(os.path.join(root, entry.path),
                                              check_devices=check_devices)
                cls._check_entry(entry, art)
                artifacts[entry.name] = art
            entries.append(entry)
        if not entries:
            raise ArtifactError(f"catalog at {root!r} lists no artifacts")
        cat = cls(root, entries, artifacts)
        cat.lazy = lazy
        cat.base_root = base_root
        cat.generation = generation
        return cat


def _step_or_inf(e: CatalogEntry) -> float:
    """Sort key: an entry without a prediction never wins a latency
    comparison."""
    return e.predicted_step_s if e.predicted_step_s is not None \
        else float("inf")


class Router:
    """Dispatch requests to the catalog entry that satisfies their SLO,
    over lazily-constructed, crash-supervised per-artifact engine fleets.

    Fleet knobs: ``replicas`` engines per entry (each behind one
    :class:`~repro.serve.fleet.ReplicaSupervisor`), ``max_queue`` bounds
    each entry's intake + in-flight (overload sheds with
    :class:`RouteError` at submit), ``retry`` is the per-entry
    :class:`~repro.serve.fleet.RetryPolicy`, ``breaker_k`` consecutive
    engine crashes quarantine an entry, and quarantined entries are
    probed every ``probe_every`` router steps (:meth:`probe` forces
    one). ``faults`` attaches a shared
    :class:`~repro.util.faults.FaultInjector` to every engine it builds
    — chaos testing uses this to kill replicas deterministically.
    """

    def __init__(self, catalog: ArtifactCatalog, *,
                 policy: str = "quality",
                 on_unroutable: str = "reject",
                 max_batch: Optional[int] = None,
                 max_seq: Optional[int] = None,
                 seed: int = 0,
                 scheduler: Union[SchedulerConfig, str, None] = None,
                 measurements: Optional[MeasurementLog] = None,
                 replicas: int = 1,
                 max_queue: Optional[int] = None,
                 retry: Optional[RetryPolicy] = None,
                 breaker_k: int = 3,
                 probe_every: int = 64,
                 faults: Optional[FaultInjector] = None,
                 mesh=None):
        if policy not in POLICIES:
            raise ValueError(f"unknown routing policy {policy!r}; "
                             f"policies: {list(POLICIES)}")
        if on_unroutable not in ON_UNROUTABLE:
            raise ValueError(f"unknown on_unroutable mode "
                             f"{on_unroutable!r}; modes: "
                             f"{list(ON_UNROUTABLE)}")
        self.catalog = catalog
        self.policy = policy
        self.on_unroutable = on_unroutable
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.seed = seed
        self.scheduler = scheduler
        self.measurements = measurements
        self.replicas = replicas
        self.max_queue = max_queue
        self.retry = retry or RetryPolicy()
        self.breaker_k = breaker_k
        self.probe_every = probe_every
        self.faults = faults
        # serving mesh shared by every fleet engine (None = single
        # device; partition-stamped entries get their default mesh from
        # ServeEngine.from_artifact regardless)
        self.mesh = mesh
        self._fleets: Dict[str, ReplicaSupervisor] = {}
        self._quarantined: Dict[str, Dict[str, Any]] = {}
        self._histogram: Dict[str, int] = {}
        self._flagged = 0
        self._rejected = 0
        self._steps = 0
        self._probes = 0
        self._recovered = 0
        self._wall_s = 0.0
        # hot-swap state: fleets of prior generations drain here until
        # idle, then fold their accounting into the retired accumulators
        # so stats stay zero-loss across generations
        self.generation = getattr(catalog, "generation", 0)
        self._retiring: List[Dict[str, Any]] = []
        self._swaps = 0
        self._retired_fleets = 0
        self._retired_done: List[Request] = []
        self._retired_failed: List[Request] = []
        self._retired_counts = {"submitted": 0, "crashes": 0,
                                "rebuilds": 0, "requeued": 0, "shed": 0}

    # -- the routing decision ----------------------------------------------

    @staticmethod
    def estimate_request_s(entry: CatalogEntry,
                           req: Request) -> Optional[float]:
        """Oracle-predicted serve time for ``req`` on ``entry``: the
        decode-step prediction times the token budget (the first token
        is prefill-priced as one step). None when the entry carries no
        prediction — such an entry can never promise a budget."""
        if entry.predicted_step_s is None:
            return None
        return entry.predicted_step_s * max(1, req.max_new_tokens)

    def _candidates(self, req: Request) -> List[CatalogEntry]:
        """SLO-feasible, non-quarantined entries in dispatch-preference
        order (the policy's order); empty when nothing qualifies."""
        feasible = []
        for e in self.catalog:
            if e.name in self._quarantined:
                continue
            if req.accuracy_floor is not None \
                    and e.accuracy < req.accuracy_floor:
                continue
            if req.latency_budget_s is not None:
                est = self.estimate_request_s(e, req)
                if est is None or est > req.latency_budget_s:
                    continue
            feasible.append(e)
        if self.policy == "quality":
            # the budget buys accuracy; equal accuracy -> cheaper wins
            feasible.sort(key=lambda e: (-e.accuracy, _step_or_inf(e)))
        else:
            # cheapest satisfying entry first
            feasible.sort(key=lambda e: (_step_or_inf(e), -e.accuracy))
        return feasible

    def route(self, req: Request) -> CatalogEntry:
        """Pure routing decision (no enqueue). Raises :class:`RouteError`
        when nothing satisfies the request and the router rejects; in
        ``flag`` mode returns the fastest healthy entry best-effort and
        marks ``req.slo_infeasible``."""
        feasible = self._candidates(req)
        if feasible:
            return feasible[0]
        healthy = [e for e in self.catalog
                   if e.name not in self._quarantined]
        if not healthy:
            self._rejected += 1
            raise RouteError(
                f"every catalog entry is quarantined "
                f"({dict((n, q['reason']) for n, q in self._quarantined.items())}); "
                f"request {req.rid} shed")
        if self.on_unroutable == "reject":
            self._rejected += 1
            raise RouteError(
                f"no catalog entry satisfies request {req.rid} "
                f"(accuracy_floor={req.accuracy_floor!r}, "
                f"latency_budget_s={req.latency_budget_s!r}, "
                f"max_new_tokens={req.max_new_tokens}); catalog:\n"
                + self.catalog.summary())
        # flag: best-effort on the fastest healthy entry, visibly marked
        req.slo_infeasible = True
        self._flagged += 1
        return min(healthy, key=lambda e: (_step_or_inf(e), -e.accuracy))

    # -- supervised fleets + quarantine -------------------------------------

    def _fleet(self, name: str) -> ReplicaSupervisor:
        """The (lazily constructed) supervised engine fleet for entry
        ``name`` — replica 0 is built eagerly so a broken artifact
        surfaces here, where the caller can quarantine and fall back."""
        if name not in self._fleets:
            entry = self.catalog.get(name)
            idx = len(self._fleets)
            sup = ReplicaSupervisor.from_artifact(
                lambda _n=name: self.catalog.artifact(_n),
                replicas=self.replicas, name=name,
                seed=self.seed + idx * 101,
                faults=self.faults, retry=self.retry,
                max_queue=self.max_queue,
                est_step_s=entry.predicted_step_s,
                engine_kwargs=dict(
                    max_batch=self.max_batch, max_seq=self.max_seq,
                    scheduler=self.scheduler,
                    measurements=self.measurements,
                    **({"mesh": self.mesh}
                       if self.mesh is not None else {})))
            sup.start()                 # propagate build errors eagerly
            self._fleets[name] = sup
        return self._fleets[name]

    def engine(self, name: str) -> ServeEngine:
        """Back-compat: entry ``name``'s primary replica engine.

        A failed lazy build (tampered/deleted artifact, injected load
        fault) quarantines the entry before propagating, so later
        ``submit`` calls fall back to healthy entries instead of
        re-tripping the same error."""
        try:
            return self._fleet(name).primary
        except Exception as e:          # noqa: BLE001 — ArtifactError et al
            self._quarantine(name, f"{type(e).__name__}: {e}")
            raise

    def _quarantine(self, name: str, reason: str) -> None:
        if name in self._quarantined:
            return
        rec = self._quarantined.setdefault(
            name, {"reason": reason, "at_step": self._steps, "probes": 0})
        rec["reason"] = reason

    def probe(self) -> List[str]:
        """Half-open probe of every quarantined entry; returns the names
        restored to dispatch. Runs automatically every ``probe_every``
        router steps."""
        restored = []
        for name in list(self._quarantined):
            self._quarantined[name]["probes"] += 1
            self._probes += 1
            sup = self._fleets.get(name)
            try:
                ok = sup.probe() if sup is not None else bool(
                    self._fleet(name))
            except Exception:           # noqa: BLE001 — probe must not throw
                ok = False
            if ok:
                del self._quarantined[name]
                self._recovered += 1
                restored.append(name)
        return restored

    # -- hot swap -----------------------------------------------------------

    def swap(self, catalog: ArtifactCatalog) -> Dict[str, Any]:
        """Zero-downtime generation swap: install ``catalog`` for every
        *future* routing decision, while each current fleet enters drain
        mode — its already-admitted requests (intake + in-flight) keep
        stepping to completion on the old engines, and the fleet is
        retired only once its supervisor reports zero in-flight work.
        Nothing is re-routed and nothing is dropped: a request admitted
        before the swap completes on the old generation with the exact
        output it would have produced without the swap. Quarantine state
        belongs to the outgoing generation and is cleared."""
        draining = []
        for name, sup in self._fleets.items():
            sup.drain()
            rec = {"name": name, "generation": self.generation, "sup": sup}
            if sup.idle:
                self._retire(rec)
            else:
                self._retiring.append(rec)
                draining.append(name)
        self._fleets = {}
        self._quarantined = {}
        self.catalog = catalog
        self.generation = getattr(catalog, "generation",
                                  self.generation + 1)
        self._swaps += 1
        return {"generation": self.generation, "draining": draining}

    def _retire(self, rec: Dict[str, Any]) -> None:
        """Fold a drained supervisor's accounting into the router-level
        accumulators — completed/failed requests and counters survive the
        generation that produced them."""
        sup = rec["sup"]
        self._retired_fleets += 1
        self._retired_done.extend(sup.completed)
        self._retired_failed.extend(sup.failed)
        for key in self._retired_counts:
            self._retired_counts[key] += getattr(sup, key)

    # -- dispatch + drive ---------------------------------------------------

    def submit(self, req: Request) -> str:
        """Route ``req`` and enqueue it on that entry's supervised
        fleet; returns the entry name (recorded on ``req.routed_to``).

        Graceful degradation: if the preferred entry fails to build
        (quarantine) or sheds at admission (saturated / deadline
        infeasible through its backlog), the next policy-ordered
        candidate is tried — the cheapest entry that still fits wins.
        When nothing healthy can take it, the request is rejected with
        :class:`RouteError`; a ``flag``-mode router still best-efforts
        SLO-infeasible requests onto the fastest healthy entry, but an
        overloaded (bounded-queue) fleet always sheds."""
        candidates = self._candidates(req)
        if not candidates:
            entry = self.route(req)     # flag-mode fallback, or raises
            candidates = [entry]
        shed_reasons = []
        for entry in candidates:
            try:
                sup = self._fleet(entry.name)
            except Exception as e:      # noqa: BLE001 — ArtifactError,
                # injected load faults, anything the factory throws:
                # contain it as a quarantine and fall back
                self._quarantine(entry.name,
                                 f"{type(e).__name__}: {e}")
                shed_reasons.append(f"{entry.name}: build failed")
                continue
            if sup.dead:
                self._quarantine(entry.name,
                                 sup.death_reason or "supervisor dead")
                shed_reasons.append(f"{entry.name}: dead")
                continue
            try:
                sup.submit(req)
            except RouteError as e:
                shed_reasons.append(str(e))
                continue
            req.routed_to = entry.name
            self._histogram[entry.name] = \
                self._histogram.get(entry.name, 0) + 1
            return entry.name
        self._rejected += 1
        raise RouteError(
            f"request {req.rid} shed: no healthy catalog entry could "
            f"admit it ({'; '.join(shed_reasons)})")

    @property
    def has_work(self) -> bool:
        return any(s.has_work for s in self._fleets.values()) \
            or any(r["sup"].has_work for r in self._retiring)

    def step(self) -> Dict[str, Any]:
        """One quantum across the fleet: every supervised entry with work
        advances one :meth:`ReplicaSupervisor.step` (which contains
        crashes and rebuilds replicas). Wall time accrues per quantum, so
        a fleet driven by an external ``step()`` loop still reports a
        meaningful ``tokens_per_s``. Trips breakers and runs periodic
        quarantine probes."""
        t0 = time.perf_counter()
        try:
            self._steps += 1
            events = {}
            for name, sup in self._fleets.items():
                if sup.has_work:
                    events[name] = sup.step()["event"]
                if name not in self._quarantined:
                    if sup.dead:
                        self._quarantine(
                            name, sup.death_reason or "supervisor dead")
                    elif self.breaker_k and \
                            sup.consecutive_crashes >= self.breaker_k:
                        self._quarantine(
                            name, f"circuit breaker: "
                                  f"{sup.consecutive_crashes} consecutive "
                                  f"crashes (last: {sup.last_error})")
            # retiring generations keep draining alongside the current one
            for rec in list(self._retiring):
                sup = rec["sup"]
                if sup.has_work:
                    label = f"{rec['name']}@gen{rec['generation']}"
                    events[label] = sup.step()["event"]
                if sup.idle:
                    self._retiring.remove(rec)
                    self._retire(rec)
            if self._quarantined and self.probe_every \
                    and self._steps % self.probe_every == 0:
                self.probe()
            return {"event": "fleet" if events else "idle",
                    "engines": events}
        finally:
            self._wall_s += time.perf_counter() - t0

    def run(self, deadline_s: Optional[float] = None) -> Dict[str, Any]:
        """Round-robin the fleet until drained (or ``deadline_s``);
        returns :meth:`stats`."""
        t0 = time.time()
        while self.has_work:
            if deadline_s is not None and time.time() - t0 >= deadline_s:
                break
            self.step()
        if self.measurements is not None:
            sups = list(self._fleets.values()) \
                + [r["sup"] for r in self._retiring]
            for sup in sups:
                for eng in sup.engines:
                    if eng._step_times:
                        eng.record_measurements()
        return self.stats()

    def reset_stats(self) -> None:
        """Zero the router's counters and every fleet's stats (engines
        and their compiled programs are kept — benchmarks use this to
        exclude a warmup drain from a timed one). Quarantine state is
        health, not stats, and survives."""
        for sup in self._fleets.values():
            sup.reset_stats()
        for rec in self._retiring:
            rec["sup"].reset_stats()
        self._retired_done = []
        self._retired_failed = []
        self._retired_counts = {k: 0 for k in self._retired_counts}
        self._histogram = {}
        self._flagged = 0
        self._rejected = 0
        self._probes = 0
        self._recovered = 0
        self._wall_s = 0.0

    def stats(self) -> Dict[str, Any]:
        """Fleet-wide serving stats: the routing histogram, per-artifact
        supervisor stats (crashes, rebuilds, re-queues, per-replica
        engine stats, and the drift signals ``oracle_rel_error`` /
        ``measurement_window`` / per-entry ``budget_violation_rate``),
        quarantine state, and the measured budget-violation rate.
        Aggregates span generations: requests completed by retiring or
        retired fleets stay counted after a hot-swap, so the zero-loss
        accounting (``submitted == requests + failed + in-flight``)
        holds across swaps."""
        per_artifact = {name: sup.stats()
                        for name, sup in self._fleets.items()}
        retiring_sups = [rec["sup"] for rec in self._retiring]
        all_sups = list(self._fleets.values()) + retiring_sups
        done = [r for sup in all_sups for r in sup.completed] \
            + self._retired_done
        failed = [r for sup in all_sups for r in sup.failed] \
            + self._retired_failed
        budgeted = [r for r in done if r.latency_budget_s is not None]
        violations = [r for r in budgeted
                      if r.t_done - r.t_submit > r.latency_budget_s]
        total_tokens = sum(len(r.output) for r in done)

        def _count(attr: str) -> int:
            return sum(getattr(s, attr) for s in all_sups) \
                + self._retired_counts[attr]

        return {
            "requests": len(done),
            "total_new_tokens": total_tokens,
            "wall_s": self._wall_s,
            "tokens_per_s": total_tokens / max(self._wall_s, 1e-9),
            "routing": dict(self._histogram),
            "rejected": self._rejected,
            "flagged": self._flagged,
            "submitted": _count("submitted"),
            "budgeted_requests": len(budgeted),
            "budget_violations": len(violations),
            "budget_violation_rate": (len(violations) / len(budgeted)
                                      if budgeted else 0.0),
            # fault-tolerance accounting (fleet-wide sums; per-entry
            # detail lives in per_artifact)
            "failed": len(failed),
            "crashes": _count("crashes"),
            "rebuilds": _count("rebuilds"),
            "requeued": _count("requeued"),
            "shed": _count("shed"),
            "quarantined": {name: q["reason"]
                            for name, q in self._quarantined.items()},
            "probes": self._probes,
            "recovered": self._recovered,
            "per_artifact": per_artifact,
            # hot-swap accounting
            "generation": self.generation,
            "swaps": self._swaps,
            "retired_fleets": self._retired_fleets,
            "retiring": [{"name": rec["name"],
                          "generation": rec["generation"],
                          "in_flight": rec["sup"].in_flight_count}
                         for rec in self._retiring],
        }

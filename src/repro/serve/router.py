"""SLO-aware artifact router: per-request constraints at serve time.

The planner's front door is a pair of constraints (accuracy floor,
latency budget) — this module keeps that language alive *per request*
instead of freezing it at deploy time. ``Plan.export_catalog(dir)``
writes the whole Pareto frontier as an :class:`ArtifactCatalog` (one
validated :class:`DeploymentArtifact` per frontier candidate plus a
``catalog.json`` manifest), and a :class:`Router` admits
:class:`~repro.serve.engine.Request`\\ s carrying ``latency_budget_s`` /
``accuracy_floor`` and dispatches each to the catalog entry that
satisfies them:

    catalog = plan(...).export_catalog("fleet/")      # or ArtifactCatalog.load
    router = Router(catalog)
    router.submit(Request(rid=0, prompt=p, max_new_tokens=16,
                          latency_budget_s=5e-3))     # -> fast artifact
    router.submit(Request(rid=1, prompt=p, max_new_tokens=16,
                          latency_budget_s=1.0,
                          accuracy_floor=0.9))        # -> accurate artifact
    stats = router.run()

Routing uses the *oracle-predicted* step latency recorded in each
artifact (``predicted_step_s`` × ``max_new_tokens`` approximates the
request's decode time) and the recorded accuracy. The default policy
spends the budget on quality: among feasible entries, highest accuracy
wins and ties break toward the cheaper entry; ``policy="cheapest"``
implements the strict lowest-latency-that-satisfies reading. Requests no
entry can satisfy are rejected with :class:`RouteError` (or best-effort
dispatched and flagged with ``on_unroutable="flag"``).

Per-artifact engines spin up lazily on first dispatch and share the
router's stats: per-artifact token/s, a routing histogram, and the
measured budget-violation rate — the serve-time check that the planner's
constraint math survived contact with the hardware.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Dict, Iterator, List, Optional, Union

from repro.api.artifact import ArtifactError, DeploymentArtifact
from repro.core.oracle import MeasurementLog
from repro.serve.engine import Request, ServeEngine
from repro.serve.scheduler import SchedulerConfig

CATALOG_VERSION = 1
CATALOG_NAME = "catalog.json"

POLICIES = ("quality", "cheapest")
ON_UNROUTABLE = ("reject", "flag")


class RouteError(ValueError):
    """No catalog entry satisfies a request's SLO (or the catalog is
    unusable for routing)."""


@dataclasses.dataclass(frozen=True)
class CatalogEntry:
    """One frontier artifact in the manifest — the numbers the router
    routes by, pinned at export time and cross-checked against the
    artifact's own metadata on load."""

    name: str                       # "<strategy>@<target>"
    path: str                       # directory, relative to catalog root
    strategy: str
    target: str
    accuracy: float
    latency_s: float                # the plan's ranked whole-model latency
    predicted_step_s: Optional[float]   # oracle decode step @ serve defaults
    tuned_digest: Optional[str]

    def describe(self) -> str:
        step = ("?" if self.predicted_step_s is None
                else f"{self.predicted_step_s * 1e3:.3f}ms")
        return (f"{self.name:>20s}  acc={self.accuracy:.3f}  "
                f"step={step}")


class ArtifactCatalog:
    """A directory of frontier :class:`DeploymentArtifact`\\ s plus the
    ``catalog.json`` manifest. :meth:`load` validates every member
    through ``DeploymentArtifact.load`` (a tampered member raises the
    usual :class:`ArtifactError`) and refuses a manifest whose routing
    numbers disagree with its artifacts' own metadata."""

    def __init__(self, root: str, entries: List[CatalogEntry],
                 artifacts: Dict[str, DeploymentArtifact]):
        self.root = root
        self.entries = list(entries)
        self._artifacts = dict(artifacts)

    def __len__(self) -> int:
        return len(self.entries)

    def __iter__(self) -> Iterator[CatalogEntry]:
        return iter(self.entries)

    @property
    def names(self) -> List[str]:
        return [e.name for e in self.entries]

    def get(self, name: str) -> CatalogEntry:
        for e in self.entries:
            if e.name == name:
                return e
        raise KeyError(f"no catalog entry {name!r}; entries: {self.names}")

    def artifact(self, name: str) -> DeploymentArtifact:
        self.get(name)
        return self._artifacts[name]

    def summary(self) -> str:
        return "\n".join(e.describe() for e in self.entries)

    @classmethod
    def load(cls, root: str) -> "ArtifactCatalog":
        manifest = os.path.join(root, CATALOG_NAME)
        if not os.path.exists(manifest):
            raise ArtifactError(f"no artifact catalog at {root!r} "
                                f"(missing {CATALOG_NAME})")
        try:
            with open(manifest) as f:
                blob = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            raise ArtifactError(f"malformed catalog manifest at {root!r}: "
                                f"{type(e).__name__}: {e}") from e
        ver = blob.get("version")
        if ver != CATALOG_VERSION:
            raise ArtifactError(
                f"unsupported catalog version {ver!r} (this build reads "
                f"version {CATALOG_VERSION})")
        entries, artifacts = [], {}
        for d in blob.get("entries", []):
            try:
                entry = CatalogEntry(**d)
            except TypeError as e:
                raise ArtifactError(
                    f"malformed catalog entry in {manifest!r}: {e}") from e
            # a tampered member fails DeploymentArtifact.load's own
            # fingerprint validation — the catalog adds no second scheme
            art = DeploymentArtifact.load(os.path.join(root, entry.path))
            meta = art.metadata
            recorded = (meta.get("final_acc"), meta.get("latency_total_s"),
                        meta.get("predicted_step_s"), art.tuned_digest)
            claimed = (entry.accuracy, entry.latency_s,
                       entry.predicted_step_s, entry.tuned_digest)
            if recorded != claimed:
                raise ArtifactError(
                    f"catalog entry {entry.name!r} does not match its "
                    f"artifact's metadata (manifest claims {claimed!r}, "
                    f"artifact records {recorded!r}) — the manifest or the "
                    f"artifact was modified after export")
            entries.append(entry)
            artifacts[entry.name] = art
        if not entries:
            raise ArtifactError(f"catalog at {root!r} lists no artifacts")
        return cls(root, entries, artifacts)


def _step_or_inf(e: CatalogEntry) -> float:
    """Sort key: an entry without a prediction never wins a latency
    comparison."""
    return e.predicted_step_s if e.predicted_step_s is not None \
        else float("inf")


class Router:
    """Dispatch requests to the catalog entry that satisfies their SLO,
    over lazily-constructed per-artifact engines."""

    def __init__(self, catalog: ArtifactCatalog, *,
                 policy: str = "quality",
                 on_unroutable: str = "reject",
                 max_batch: Optional[int] = None,
                 max_seq: Optional[int] = None,
                 seed: int = 0,
                 scheduler: Union[SchedulerConfig, str, None] = None,
                 measurements: Optional[MeasurementLog] = None):
        if policy not in POLICIES:
            raise ValueError(f"unknown routing policy {policy!r}; "
                             f"policies: {list(POLICIES)}")
        if on_unroutable not in ON_UNROUTABLE:
            raise ValueError(f"unknown on_unroutable mode "
                             f"{on_unroutable!r}; modes: "
                             f"{list(ON_UNROUTABLE)}")
        self.catalog = catalog
        self.policy = policy
        self.on_unroutable = on_unroutable
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.seed = seed
        self.scheduler = scheduler
        self.measurements = measurements
        self._engines: Dict[str, ServeEngine] = {}
        self._histogram: Dict[str, int] = {}
        self._flagged = 0
        self._rejected = 0
        self._wall_s = 0.0

    # -- the routing decision ----------------------------------------------

    @staticmethod
    def estimate_request_s(entry: CatalogEntry,
                           req: Request) -> Optional[float]:
        """Oracle-predicted serve time for ``req`` on ``entry``: the
        decode-step prediction times the token budget (the first token
        is prefill-priced as one step). None when the entry carries no
        prediction — such an entry can never promise a budget."""
        if entry.predicted_step_s is None:
            return None
        return entry.predicted_step_s * max(1, req.max_new_tokens)

    def route(self, req: Request) -> CatalogEntry:
        """Pure routing decision (no enqueue). Raises :class:`RouteError`
        when nothing satisfies the request and the router rejects; in
        ``flag`` mode returns the fastest entry best-effort and marks
        ``req.slo_infeasible``."""
        feasible = []
        for e in self.catalog:
            if req.accuracy_floor is not None \
                    and e.accuracy < req.accuracy_floor:
                continue
            if req.latency_budget_s is not None:
                est = self.estimate_request_s(e, req)
                if est is None or est > req.latency_budget_s:
                    continue
            feasible.append(e)
        if feasible:
            if self.policy == "quality":
                # the budget buys accuracy; equal accuracy -> cheaper wins
                return min(feasible, key=lambda e: (-e.accuracy,
                                                    _step_or_inf(e)))
            # cheapest satisfying entry
            return min(feasible, key=lambda e: (_step_or_inf(e),
                                                -e.accuracy))
        if self.on_unroutable == "reject":
            self._rejected += 1
            raise RouteError(
                f"no catalog entry satisfies request {req.rid} "
                f"(accuracy_floor={req.accuracy_floor!r}, "
                f"latency_budget_s={req.latency_budget_s!r}, "
                f"max_new_tokens={req.max_new_tokens}); catalog:\n"
                + self.catalog.summary())
        # flag: best-effort on the fastest entry, visibly marked
        req.slo_infeasible = True
        self._flagged += 1
        return min(self.catalog, key=lambda e: (_step_or_inf(e),
                                                -e.accuracy))

    # -- dispatch + drive ---------------------------------------------------

    def engine(self, name: str) -> ServeEngine:
        """The (lazily constructed) engine serving catalog entry
        ``name``."""
        if name not in self._engines:
            art = self.catalog.artifact(name)
            self._engines[name] = ServeEngine.from_artifact(
                art, max_batch=self.max_batch, max_seq=self.max_seq,
                seed=self.seed + len(self._engines),
                scheduler=self.scheduler, measurements=self.measurements)
        return self._engines[name]

    def submit(self, req: Request) -> str:
        """Route ``req`` and enqueue it on that artifact's engine;
        returns the entry name (also recorded on ``req.routed_to``)."""
        entry = self.route(req)
        req.routed_to = entry.name
        self._histogram[entry.name] = self._histogram.get(entry.name, 0) + 1
        self.engine(entry.name).submit(req)
        return entry.name

    @property
    def has_work(self) -> bool:
        return any(e.has_work for e in self._engines.values())

    def step(self) -> Dict[str, Any]:
        """One quantum across the fleet: every engine with work advances
        one :meth:`ServeEngine.step`. Wall time accrues per quantum (as
        in the engine), so a fleet driven by an external ``step()`` loop
        still reports a meaningful ``tokens_per_s``."""
        t0 = time.perf_counter()
        try:
            events = {name: eng.step()["event"]
                      for name, eng in self._engines.items()
                      if eng.has_work}
            return {"event": "fleet" if events else "idle",
                    "engines": events}
        finally:
            self._wall_s += time.perf_counter() - t0

    def run(self, deadline_s: Optional[float] = None) -> Dict[str, Any]:
        """Round-robin the fleet until drained (or ``deadline_s``);
        returns :meth:`stats`."""
        t0 = time.time()
        while self.has_work:
            if deadline_s is not None and time.time() - t0 >= deadline_s:
                break
            self.step()
        if self.measurements is not None:
            for eng in self._engines.values():
                if eng._step_times:
                    eng.record_measurements()
        return self.stats()

    def reset_stats(self) -> None:
        """Zero the router's counters and every live engine's stats
        (engines and their compiled programs are kept — benchmarks use
        this to exclude a warmup drain from a timed one)."""
        for eng in self._engines.values():
            eng.reset_stats()
        self._histogram = {}
        self._flagged = 0
        self._rejected = 0
        self._wall_s = 0.0

    def stats(self) -> Dict[str, Any]:
        """Fleet-wide serving stats: the routing histogram, per-artifact
        engine stats, and the measured budget-violation rate."""
        per_artifact = {name: eng.stats()
                        for name, eng in self._engines.items()}
        done = [r for eng in self._engines.values() for r in eng.done]
        budgeted = [r for r in done if r.latency_budget_s is not None]
        violations = [r for r in budgeted
                      if r.t_done - r.t_submit > r.latency_budget_s]
        total_tokens = sum(len(r.output) for r in done)
        return {
            "requests": len(done),
            "total_new_tokens": total_tokens,
            "wall_s": self._wall_s,
            "tokens_per_s": total_tokens / max(self._wall_s, 1e-9),
            "routing": dict(self._histogram),
            "rejected": self._rejected,
            "flagged": self._flagged,
            "budgeted_requests": len(budgeted),
            "budget_violations": len(violations),
            "budget_violation_rate": (len(violations) / len(budgeted)
                                      if budgeted else 0.0),
            "per_artifact": per_artifact,
        }

"""Scheduler core for the serving engine: admission + slot bookkeeping.

The old engine served in *waves*: admit up to ``max_batch`` equal-length
prompts, decode the whole batch ``max(max_new_tokens)`` steps, repeat.
Two well-known schedulers' diseases follow: head-of-line blocking (the
queue head's prompt length defines the wave, so one odd-length request
forces a tiny batch while a full batch's worth of other lengths waits)
and decode waste (every slot steps until the *longest* request in the
wave finishes). This module is the cure, split out of the engine so the
policy is inspectable and testable on its own:

``Scheduler``
    Pending requests live in prompt-length buckets (prefill needs equal
    lengths — the causal KV cache has no per-row padding mask).
    Admission picks the bucket that fills the free slots best, and
    orders requests *within* a bucket by ``max_new_tokens`` so a decode
    group finishes together instead of dragging finished slots through a
    long tail. The legacy ``fifo``/``wave`` policies keep the old
    head-of-line behavior for comparison benchmarks.

``SlotGroup``
    One admitted cohort mid-decode: its requests (row -> request), its
    KV caches, and the current token per row. Groups shrink as requests
    finish: :func:`gather_cache_rows` gathers the still-active rows into
    a smaller batch (``compact="pow2"`` snaps widths to powers of two so
    the decode jit compiles O(log max_batch) shapes, not one per width),
    and the freed slots go back to the engine's global budget — which is
    what lets the engine admit the next group *mid-decode* instead of at
    the end of the wave (continuous batching at group granularity).
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.models.attention import KVCache
from repro.models.paged_cache import RESERVED_BLOCKS, SCRATCH_BLOCK

POLICIES = ("bucketed", "fifo", "wave")
COMPACTION = ("pow2", "exact", "off")
KV_LAYOUTS = ("paged", "contiguous")


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Admission + compaction policy for the serving engine.

    ``policy``:
      * ``bucketed`` (default) — fullest prompt-length bucket first,
        requests inside a bucket grouped by ``max_new_tokens``; new
        groups are admitted whenever slots are free, including
        mid-decode of other groups.
      * ``fifo`` — the oldest pending request's bucket, in arrival
        order (head-of-line semantics), but still admits mid-decode.
      * ``wave`` — the legacy engine verbatim: ``fifo`` admission, one
        group at a time, no compaction. Kept as the measurable baseline
        for ``benchmarks/serve_bench.py``.

    ``compact``: ``pow2`` (default) gathers a group's still-active rows
    into the next power-of-two width once that halves the batch;
    ``exact`` compacts to the exact active count on every finish (one
    decode retrace per width); ``off`` never compacts (legacy).

    ``kv_layout``:
      * ``paged`` (default) — KV lives in fixed-size blocks from a shared
        pool behind a per-row block table (:mod:`repro.models.paged_cache`);
        compaction rewrites the table (zero cache-row copies), common
        prompt heads share refcounted prefix blocks, and decode attention
        reads through the table. Models ``paged_compatible`` rejects
        (recurrent mixers, sliding windows) silently fall back to
        contiguous; the ``wave`` policy always serves contiguous (it *is*
        the legacy engine).
      * ``contiguous`` — the legacy per-slot ``(max_seq, ...)`` caches,
        ``gather_cache_rows`` compaction. Kept for bit-identical
        comparison; outputs match ``paged`` token-for-token.

    ``share_prefix``: reuse full prefix blocks (and the prefill compute)
    across identical prompt heads; paged only. Off = every row private.

    ``page_size``: tokens per KV block (paged only).

    ``prefill_chunk``: 0 disables; otherwise a block-multiple chunk size —
    prompts longer than this are prefilled ``prefill_chunk`` tokens per
    engine tick, interleaved with other groups' decode ticks instead of
    stalling them behind one long prefill (paged only, text-only models).

    ``debug_kv``: run the paged-KV sanitizer
    (:mod:`repro.analysis.kv_sanitizer`) at every scheduler quantum
    boundary — refcount/reachability/COW invariants over the whole
    allocator + live tables. Exact but host-side-only work per quantum;
    a violation raises ``KVSanitizerError`` from ``engine.step()``.
    The ``REPRO_DEBUG_KV=1`` environment variable turns it on without
    touching call sites (paged only; ignored for contiguous layouts).
    """

    policy: str = "bucketed"
    compact: str = "pow2"
    kv_layout: str = "paged"
    share_prefix: bool = True
    page_size: int = 16
    prefill_chunk: int = 0
    debug_kv: bool = False

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(f"unknown scheduler policy {self.policy!r}; "
                             f"policies: {list(POLICIES)}")
        if self.compact not in COMPACTION:
            raise ValueError(f"unknown compaction mode {self.compact!r}; "
                             f"modes: {list(COMPACTION)}")
        if self.kv_layout not in KV_LAYOUTS:
            raise ValueError(f"unknown kv layout {self.kv_layout!r}; "
                             f"layouts: {list(KV_LAYOUTS)}")
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1 (got {self.page_size})")
        if self.prefill_chunk < 0 or (
                self.prefill_chunk and self.prefill_chunk % self.page_size):
            raise ValueError(
                f"prefill_chunk must be 0 or a positive multiple of "
                f"page_size={self.page_size} (got {self.prefill_chunk})")
        if self.prefill_chunk and self.kv_layout != "paged":
            raise ValueError("prefill_chunk requires kv_layout='paged'")


class Scheduler:
    """Prompt-length-bucketed admission over pending requests.

    The engine asks :meth:`select` for the next cohort each step; the
    scheduler answers with a list of equal-prompt-length requests sized
    to the free slots (or ``[]`` when nothing should be admitted yet).
    """

    def __init__(self, config: Optional[SchedulerConfig] = None):
        self.config = config or SchedulerConfig()
        self._buckets: Dict[int, Deque[Tuple[int, Any]]] = {}
        self._arrival = itertools.count()

    def __len__(self) -> int:
        return sum(len(b) for b in self._buckets.values())

    @property
    def pending(self) -> List[Any]:
        """All pending requests in arrival order (read-only snapshot)."""
        flat = [t for b in self._buckets.values() for t in b]
        return [r for _, r in sorted(flat, key=lambda t: t[0])]

    def submit(self, req) -> None:
        plen = len(req.prompt)
        self._buckets.setdefault(plen, deque()).append(
            (next(self._arrival), req))

    def _pick_bucket(self, free_slots: int) -> Optional[int]:
        live = {k: b for k, b in self._buckets.items() if b}
        if not live:
            return None
        if self.config.policy in ("fifo", "wave"):
            # head-of-line: the oldest pending request defines the cohort
            return min(live, key=lambda k: live[k][0][0])
        # bucketed: best fill of the free slots; ties go to the oldest head
        return max(live, key=lambda k: (min(len(live[k]), free_slots),
                                        -live[k][0][0]))

    def select(self, free_slots: int, *, live_groups: int = 0) -> List[Any]:
        """Admission decision: up to ``free_slots`` equal-length requests
        for one prefill, or ``[]``. ``wave`` policy refuses to admit
        while any group is still decoding (the legacy blocking drain)."""
        if free_slots <= 0 or not len(self):
            return []
        if self.config.policy == "wave" and live_groups > 0:
            return []
        key = self._pick_bucket(free_slots)
        if key is None:
            return []
        bucket = self._buckets[key]
        take = min(len(bucket), free_slots)
        if self.config.policy == "bucketed":
            # group similar decode lengths so the cohort finishes together
            # (the wave engine steps every slot max(max_new_tokens) times)
            ordered = sorted(bucket, key=lambda t: (t[1].max_new_tokens,
                                                    t[0]))
            chosen = ordered[:take]
            chosen_ids = {t[0] for t in chosen}
            rest = [t for t in bucket if t[0] not in chosen_ids]
            bucket.clear()
            bucket.extend(rest)
        else:
            chosen = [bucket.popleft() for _ in range(take)]
        return [r for _, r in chosen]


# ---------------------------------------------------------------------------
# Decode groups + cache-row gathering
# ---------------------------------------------------------------------------

def _gather(node, idx, axis: int):
    if isinstance(node, KVCache):
        # slot_pos is shared across rows (cache_len,) — only k/v have a
        # batch axis
        return node._replace(k=jnp.take(node.k, idx, axis=axis),
                             v=jnp.take(node.v, idx, axis=axis))
    if isinstance(node, dict):
        return {k: _gather(v, idx, axis) for k, v in node.items()}
    if isinstance(node, tuple) and hasattr(node, "_fields"):
        # recurrent states (RGLRUState/RWKVState): every field is
        # batch-axis aligned
        return type(node)(*(_gather(f, idx, axis) for f in node))
    if isinstance(node, tuple):
        return tuple(_gather(v, idx, axis) for v in node)
    return jnp.take(node, idx, axis=axis)


def gather_cache_rows(caches: Dict[str, Any], idx) -> Dict[str, Any]:
    """Select batch rows ``idx`` from a prefill/decode cache pytree.

    Stacked (scanned) layer caches carry a leading period axis, so their
    batch axis is 1; tail caches are batch-leading; the decode position
    is a scalar shared by every row and passes through unchanged."""
    idx = jnp.asarray(idx, jnp.int32)
    out = dict(caches)
    out["stack"] = _gather(caches["stack"], idx, 1)
    out["tail"] = _gather(caches["tail"], idx, 0)
    return out


def _pow2_at_least(n: int) -> int:
    if n <= 0:
        return 0  # a zero-active group compacts away entirely, not to width 1
    return 1 if n == 1 else 1 << (n - 1).bit_length()


class SlotGroup:
    """One admitted cohort mid-decode. ``requests[row]`` is the request
    fed by that batch row, or ``None`` for a pad row left by power-of-two
    compaction (its tokens are computed and discarded)."""

    #: engine-owned mutable dict {"rows": int} counting physically copied
    #: cache rows (the paged layout's zero-copy claim is asserted on it)
    copy_counter: Optional[Dict[str, int]] = None

    def __init__(self, requests: List[Any], caches: Dict[str, Any], cur,
                 plen: int):
        self.requests: List[Optional[Any]] = list(requests)
        self.caches = caches
        self.cur = cur
        self.plen = plen

    @property
    def width(self) -> int:
        return len(self.requests)

    @property
    def active_rows(self) -> List[int]:
        return [i for i, r in enumerate(self.requests)
                if r is not None and len(r.output) < r.max_new_tokens]

    @property
    def done(self) -> bool:
        return not self.active_rows

    def release(self) -> None:
        """Give the group's KV storage back (no-op for contiguous caches —
        they die with the last reference)."""
        self.caches = None
        self.cur = None

    def compact(self, mode: str) -> int:
        """Shrink the batch to the still-active rows per ``mode``;
        returns the number of slots freed (0 when nothing changed)."""
        if mode == "off":
            return 0
        active = self.active_rows
        if not active:
            # every row finished (or was a pad row) mid-tick: free the
            # whole group instead of gathering rows of an empty selection
            freed = self.width
            self.requests = []
            self.release()
            return freed
        target = len(active) if mode == "exact" else _pow2_at_least(
            len(active))
        if target >= self.width:
            return 0
        rows = active + [active[0]] * (target - len(active))
        freed = self.width - target
        self.requests = [self.requests[i] for i in active] \
            + [None] * (target - len(active))
        self.caches = gather_cache_rows(self.caches, rows)
        if self.copy_counter is not None:
            self.copy_counter["rows"] += len(rows)
        self.cur = jnp.take(self.cur, jnp.asarray(rows, jnp.int32), axis=0)
        return freed


class PagedSlotGroup(SlotGroup):
    """A cohort whose KV lives in pool blocks behind a per-row block
    table. ``table`` is host-side numpy ``(width, n_cols)`` int32 —
    compaction is a row-select on it plus refcount decrefs for blocks
    only the dropped rows referenced: zero cache-row copies. The device
    copy of the table (padded to a power-of-two column count so decode
    retraces O(log) shapes) is cached and rebuilt lazily on mutation."""

    def __init__(self, requests: List[Any], table, cur, plen: int, *,
                 allocator, block_size: int, pos: int):
        super().__init__(requests, caches=None, cur=cur, plen=plen)
        self.table = np.asarray(table, np.int32)
        self.alloc = allocator
        self.block_size = block_size
        self.pos = int(pos)              # next absolute decode position
        self._dev_table = None
        self._released = False
        # chunked-prefill bookkeeping (driven by the engine)
        self.chunks_done = 0
        self.n_chunks = 0
        self.prompt_padded: Optional[np.ndarray] = None

    @property
    def prefilling(self) -> bool:
        return self.chunks_done < self.n_chunks

    def device_table(self):
        if self._dev_table is None:
            W, nc = self.table.shape
            ncp = max(1, _pow2_at_least(nc))
            padded = np.zeros((W, ncp), np.int32)  # zero block: masked reads
            padded[:, :nc] = self.table
            self._dev_table = jnp.asarray(padded)
        return self._dev_table

    def ensure_frontier(self) -> None:
        """Make the table column for ``pos`` writable before a decode
        step lands there: a fresh private block per live row, the scratch
        block for pad rows (their writes are discarded garbage). Also
        upgrades chunk-padding scratch columns to real blocks as decode
        reaches them."""
        col = self.pos // self.block_size
        W, nc = self.table.shape
        changed = False
        if col >= nc:
            self.table = np.concatenate(
                [self.table, np.zeros((W, col + 1 - nc), np.int32)], axis=1)
            changed = True
        for i, r in enumerate(self.requests):
            if self.table[i, col] >= RESERVED_BLOCKS:
                continue
            self.table[i, col] = (self.alloc.alloc() if r is not None
                                  else SCRATCH_BLOCK)
            changed = True
        if changed:
            self._dev_table = None

    def release(self) -> None:
        if self._released:
            return
        self._released = True
        for row in self.table:
            for bid in row:
                if bid >= RESERVED_BLOCKS:
                    self.alloc.decref(int(bid))
        self.table = self.table[:0]
        self._dev_table = None
        self.cur = None

    def compact(self, mode: str) -> int:
        if mode == "off":
            return 0
        active = self.active_rows
        if not active:
            freed = self.width
            self.requests = []
            self.release()
            return freed
        target = len(active) if mode == "exact" else _pow2_at_least(
            len(active))
        if target >= self.width:
            return 0
        W, nc = self.table.shape
        keep = set(active)
        for i in range(W):
            if i in keep:
                continue
            for bid in self.table[i]:
                if bid >= RESERVED_BLOCKS:
                    self.alloc.decref(int(bid))
        n_pad = target - len(active)
        # pad rows write (and read back) only scratch garbage; their
        # sampled tokens are discarded with the row
        pad = np.full((n_pad, nc), SCRATCH_BLOCK, np.int32)
        self.table = np.concatenate([self.table[active], pad], axis=0)
        self.requests = [self.requests[i] for i in active] + [None] * n_pad
        rows = active + [active[0]] * n_pad
        self.cur = jnp.take(self.cur, jnp.asarray(rows, jnp.int32), axis=0)
        self._dev_table = None
        return W - target

"""Scheduler core for the serving engine: admission + slot bookkeeping.

The old engine served in *waves*: admit up to ``max_batch`` equal-length
prompts, decode the whole batch ``max(max_new_tokens)`` steps, repeat.
Two well-known schedulers' diseases follow: head-of-line blocking (the
queue head's prompt length defines the wave, so one odd-length request
forces a tiny batch while a full batch's worth of other lengths waits)
and decode waste (every slot steps until the *longest* request in the
wave finishes). This module is the cure, split out of the engine so the
policy is inspectable and testable on its own:

``Scheduler``
    Pending requests live in prompt-length buckets (prefill needs equal
    lengths — the causal KV cache has no per-row padding mask).
    Admission picks the bucket that fills the free slots best, and
    orders requests *within* a bucket by ``max_new_tokens`` so a decode
    group finishes together instead of dragging finished slots through a
    long tail. The legacy ``fifo``/``wave`` policies keep the old
    head-of-line behavior for comparison benchmarks.

``SlotGroup``
    One admitted cohort mid-decode: its requests (row -> request), its
    KV caches, and the current token per row. Groups shrink as requests
    finish: :func:`gather_cache_rows` gathers the still-active rows into
    a smaller batch (``compact="pow2"`` snaps widths to powers of two so
    the decode jit compiles O(log max_batch) shapes, not one per width),
    and the freed slots go back to the engine's global budget — which is
    what lets the engine admit the next group *mid-decode* instead of at
    the end of the wave (continuous batching at group granularity).
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple

import jax.numpy as jnp

from repro.models.attention import KVCache

POLICIES = ("bucketed", "fifo", "wave")
COMPACTION = ("pow2", "exact", "off")


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Admission + compaction policy for the serving engine.

    ``policy``:
      * ``bucketed`` (default) — fullest prompt-length bucket first,
        requests inside a bucket grouped by ``max_new_tokens``; new
        groups are admitted whenever slots are free, including
        mid-decode of other groups.
      * ``fifo`` — the oldest pending request's bucket, in arrival
        order (head-of-line semantics), but still admits mid-decode.
      * ``wave`` — the legacy engine verbatim: ``fifo`` admission, one
        group at a time, no compaction. Kept as the measurable baseline
        for ``benchmarks/serve_bench.py``.

    ``compact``: ``pow2`` (default) gathers a group's still-active rows
    into the next power-of-two width once that halves the batch;
    ``exact`` compacts to the exact active count on every finish (one
    decode retrace per width); ``off`` never compacts (legacy).
    """

    policy: str = "bucketed"
    compact: str = "pow2"

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(f"unknown scheduler policy {self.policy!r}; "
                             f"policies: {list(POLICIES)}")
        if self.compact not in COMPACTION:
            raise ValueError(f"unknown compaction mode {self.compact!r}; "
                             f"modes: {list(COMPACTION)}")


class Scheduler:
    """Prompt-length-bucketed admission over pending requests.

    The engine asks :meth:`select` for the next cohort each step; the
    scheduler answers with a list of equal-prompt-length requests sized
    to the free slots (or ``[]`` when nothing should be admitted yet).
    """

    def __init__(self, config: Optional[SchedulerConfig] = None):
        self.config = config or SchedulerConfig()
        self._buckets: Dict[int, Deque[Tuple[int, Any]]] = {}
        self._arrival = itertools.count()

    def __len__(self) -> int:
        return sum(len(b) for b in self._buckets.values())

    @property
    def pending(self) -> List[Any]:
        """All pending requests in arrival order (read-only snapshot)."""
        flat = [t for b in self._buckets.values() for t in b]
        return [r for _, r in sorted(flat, key=lambda t: t[0])]

    def submit(self, req) -> None:
        plen = len(req.prompt)
        self._buckets.setdefault(plen, deque()).append(
            (next(self._arrival), req))

    def _pick_bucket(self, free_slots: int) -> Optional[int]:
        live = {k: b for k, b in self._buckets.items() if b}
        if not live:
            return None
        if self.config.policy in ("fifo", "wave"):
            # head-of-line: the oldest pending request defines the cohort
            return min(live, key=lambda k: live[k][0][0])
        # bucketed: best fill of the free slots; ties go to the oldest head
        return max(live, key=lambda k: (min(len(live[k]), free_slots),
                                        -live[k][0][0]))

    def select(self, free_slots: int, *, live_groups: int = 0) -> List[Any]:
        """Admission decision: up to ``free_slots`` equal-length requests
        for one prefill, or ``[]``. ``wave`` policy refuses to admit
        while any group is still decoding (the legacy blocking drain)."""
        if free_slots <= 0 or not len(self):
            return []
        if self.config.policy == "wave" and live_groups > 0:
            return []
        key = self._pick_bucket(free_slots)
        if key is None:
            return []
        bucket = self._buckets[key]
        take = min(len(bucket), free_slots)
        if self.config.policy == "bucketed":
            # group similar decode lengths so the cohort finishes together
            # (the wave engine steps every slot max(max_new_tokens) times)
            ordered = sorted(bucket, key=lambda t: (t[1].max_new_tokens,
                                                    t[0]))
            chosen = ordered[:take]
            chosen_ids = {t[0] for t in chosen}
            rest = [t for t in bucket if t[0] not in chosen_ids]
            bucket.clear()
            bucket.extend(rest)
        else:
            chosen = [bucket.popleft() for _ in range(take)]
        return [r for _, r in chosen]


# ---------------------------------------------------------------------------
# Decode groups + cache-row gathering
# ---------------------------------------------------------------------------

def _gather(node, idx, axis: int):
    if isinstance(node, KVCache):
        # slot_pos is shared across rows (cache_len,) — only k/v have a
        # batch axis
        return node._replace(k=jnp.take(node.k, idx, axis=axis),
                             v=jnp.take(node.v, idx, axis=axis))
    if isinstance(node, dict):
        return {k: _gather(v, idx, axis) for k, v in node.items()}
    if isinstance(node, tuple) and hasattr(node, "_fields"):
        # recurrent states (RGLRUState/RWKVState): every field is
        # batch-axis aligned
        return type(node)(*(_gather(f, idx, axis) for f in node))
    if isinstance(node, tuple):
        return tuple(_gather(v, idx, axis) for v in node)
    return jnp.take(node, idx, axis=axis)


def gather_cache_rows(caches: Dict[str, Any], idx) -> Dict[str, Any]:
    """Select batch rows ``idx`` from a prefill/decode cache pytree.

    Stacked (scanned) layer caches carry a leading period axis, so their
    batch axis is 1; tail caches are batch-leading; the decode position
    is a scalar shared by every row and passes through unchanged."""
    idx = jnp.asarray(idx, jnp.int32)
    out = dict(caches)
    out["stack"] = _gather(caches["stack"], idx, 1)
    out["tail"] = _gather(caches["tail"], idx, 0)
    return out


def _pow2_at_least(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


class SlotGroup:
    """One admitted cohort mid-decode. ``requests[row]`` is the request
    fed by that batch row, or ``None`` for a pad row left by power-of-two
    compaction (its tokens are computed and discarded)."""

    def __init__(self, requests: List[Any], caches: Dict[str, Any], cur,
                 plen: int):
        self.requests: List[Optional[Any]] = list(requests)
        self.caches = caches
        self.cur = cur
        self.plen = plen

    @property
    def width(self) -> int:
        return len(self.requests)

    @property
    def active_rows(self) -> List[int]:
        return [i for i, r in enumerate(self.requests)
                if r is not None and len(r.output) < r.max_new_tokens]

    @property
    def done(self) -> bool:
        return not self.active_rows

    def compact(self, mode: str) -> int:
        """Shrink the batch to the still-active rows per ``mode``;
        returns the number of slots freed (0 when nothing changed)."""
        if mode == "off" or self.done:
            return 0
        active = self.active_rows
        target = len(active) if mode == "exact" else _pow2_at_least(
            len(active))
        if target >= self.width:
            return 0
        rows = active + [active[0]] * (target - len(active))
        freed = self.width - target
        self.requests = [self.requests[i] for i in active] \
            + [None] * (target - len(active))
        self.caches = gather_cache_rows(self.caches, rows)
        self.cur = jnp.take(self.cur, jnp.asarray(rows, jnp.int32), axis=0)
        return freed

"""Supervised serving fleet: replicas, crash recovery, admission control.

The scheduler-core engine (:mod:`repro.serve.engine`) assumes it lives
forever; this module drops that assumption. A :class:`ReplicaSupervisor`
wraps N engines serving one deployment artifact and keeps one invariant
no matter what the engines do:

    **every submitted request either completes or is explicitly
    rejected** — ``submitted == completed + failed + in_flight`` at all
    times, and ``in_flight`` drains to zero. Nothing is silently lost.

Mechanics, in the order a request meets them:

*Admission control.* Requests enter a bounded, deadline-ordered intake
heap (``max_queue`` bounds intake + engine in-flight together). A
request whose remaining ``latency_budget_s`` cannot cover its own
oracle-estimated serve time is rejected with :class:`RouteError` at
submit time — load is shed before it wastes decode ticks, not after.

*Dispatch.* Each supervisor quantum drains the intake front (earliest
deadline first) onto the live replica with the fewest **outstanding
tokens** (tokens still owed to its in-flight requests — two half-done
long requests weigh more than three nearly-finished short ones),
keeping per-engine queues shallow so the deadline ordering stays in the
intake where it is still mutable. Deadlines order and gate admission; once admitted, a
request is never killed by the wall clock — overruns are *reported*
(the router's ``budget_violation_rate``), matching how the rest of the
stack treats the oracle-priced SLO.

*Crash recovery.* A replica whose ``step()`` raises is torn down: its
finished requests are harvested, its in-flight requests are re-queued
with their original submit time (the SLO clock does not restart) after
:meth:`Request.reset_for_retry` clears partial output — greedy decode
then reproduces the exact fault-free tokens. Retries are bounded
(``RetryPolicy.max_retries``; beyond it the request fails explicitly)
and rebuilds are cold — ``factory(i)`` reconstructs the engine from the
artifact, with exponential backoff between consecutive rebuilds of the
same replica. A supervisor whose factory itself keeps failing (e.g. a
deleted artifact) declares itself dead, fails its queue explicitly, and
is quarantined by the router.

:class:`RouteError` lives here (the engine layer below needs it and the
router layer above re-exports it — importing it from
``repro.serve.router`` keeps working).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.serve.engine import Request, ServeEngine
from repro.util.faults import StragglerMonitor


def outstanding_tokens(eng: ServeEngine) -> int:
    """Tokens the engine still owes its in-flight requests — the load
    signal the balancer dispatches by. Request *count* undercounts a
    replica stuck with long generations; the token debt does not."""
    return sum(max(0, r.max_new_tokens - len(r.output))
               for r in eng.in_flight())


class RouteError(ValueError):
    """No catalog entry / replica can satisfy a request's SLO, or the
    fleet sheds it under overload (the catalog may also be unusable for
    routing). Every raise is an *explicit* rejection — the alternative
    the fleet never takes is dropping the request silently."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Retry/backoff knobs for one supervised entry.

    ``max_retries``
        per-request re-queue budget after engine crashes; the request
        fails explicitly (``fail_reason="retries"``) beyond it.
    ``backoff_s`` / ``backoff_factor``
        cold-rebuild delay for a crashed replica:
        ``backoff_s * backoff_factor**(crashes-1)`` seconds before the
        next rebuild attempt (0 = immediate, the test default).
    ``max_build_failures``
        consecutive factory failures before the supervisor declares
        itself dead (a permanently missing/tampered artifact).
    """

    max_retries: int = 2
    backoff_s: float = 0.0
    backoff_factor: float = 2.0
    max_build_failures: int = 2


class _Replica:
    __slots__ = ("index", "engine", "crashes", "down_until")

    def __init__(self, index: int):
        self.index = index
        self.engine: Optional[ServeEngine] = None
        self.crashes = 0
        self.down_until = 0.0


class ReplicaSupervisor:
    """N supervised engines serving one catalog entry.

    ``factory(i)`` builds (or cold-rebuilds) replica ``i``'s engine —
    typically ``ServeEngine.from_artifact`` plus a fresh
    :class:`StragglerMonitor`; any exception it raises counts as a build
    failure. ``est_step_s`` (the entry's oracle-predicted decode step)
    prices admission-time deadline checks; without it only hard expiry
    is enforced.
    """

    def __init__(self, factory: Callable[[int], ServeEngine], *,
                 replicas: int = 1, name: str = "fleet",
                 retry: Optional[RetryPolicy] = None,
                 max_queue: Optional[int] = None,
                 est_step_s: Optional[float] = None,
                 straggler_skip_first: int = 2,
                 straggler_factor: float = 3.0):
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        self.factory = factory
        self.name = name
        self.retry = retry or RetryPolicy()
        self.max_queue = max_queue
        self.est_step_s = est_step_s
        self.straggler_skip_first = straggler_skip_first
        self.straggler_factor = straggler_factor
        self._replicas = [_Replica(i) for i in range(replicas)]
        self._seq = itertools.count()
        self._intake: List[Any] = []            # (deadline, seq, Request)
        self._done: List[Request] = []          # harvested from dead engines
        self.failed: List[Request] = []         # explicit rejections
        self._harvested_step_times: List[float] = []
        self.dead = False
        self.draining = False
        self.death_reason: Optional[str] = None
        self.submitted = 0
        self.crashes = 0
        self.rebuilds = 0
        self.requeued = 0
        self.shed = 0                           # admission-time RouteErrors
        self.consecutive_crashes = 0            # feeds the router's breaker
        self.build_failures = 0                 # consecutive; reset on success
        self.straggler_steps = 0                # harvested from dead engines
        self.last_error: Optional[str] = None
        self._wall_s = 0.0
        # fleet-balancer accounting: where did requests actually land?
        self.dispatched = [0] * replicas        # per-replica dispatch histogram
        self.requeued_to_survivor = 0           # crash re-queues that landed
        #                                         on a *different* live replica
        self._last_replica: Dict[int, int] = {}  # rid -> last dispatch target

    # -- construction -------------------------------------------------------

    @classmethod
    def from_artifact(cls, artifact, *, replicas: int = 1,
                      name: Optional[str] = None, seed: int = 0,
                      faults=None, engine_kwargs: Optional[Dict] = None,
                      **kwargs) -> "ReplicaSupervisor":
        """Supervise ``replicas`` cold-built engines over one
        ``DeploymentArtifact`` (instance or directory path). ``artifact``
        may also be a zero-arg callable returning one — the fleet's lazy
        catalogs use this so a tampered member fails at *build* time,
        where the supervisor can contain it."""
        engine_kwargs = dict(engine_kwargs or {})
        tag = name or "artifact"

        def factory(i: int) -> ServeEngine:
            if faults is not None:
                faults.fire("artifact_load", tag)
            art = artifact() if callable(artifact) else artifact
            return ServeEngine.from_artifact(
                art, seed=seed + i, faults=faults,
                fault_tag=f"{tag}#r{i}", **engine_kwargs)

        return cls(factory, replicas=replicas, name=tag, **kwargs)

    # -- introspection ------------------------------------------------------

    @property
    def engines(self) -> List[ServeEngine]:
        """Live replica engines (crashed ones are absent until rebuilt)."""
        return [r.engine for r in self._replicas if r.engine is not None]

    @property
    def primary(self) -> ServeEngine:
        """Replica 0's engine, built on demand (propagates factory
        errors — the router turns them into a quarantine)."""
        rep = self._replicas[0]
        if rep.engine is None:
            rep.engine = self._build(rep)
        return rep.engine

    def start(self) -> None:
        """Eagerly build replica 0 so a broken artifact surfaces at
        submit time (where the router can fall back) instead of
        mid-drain."""
        self.primary

    @property
    def completed(self) -> List[Request]:
        return self._done + [r for e in self.engines for r in e.done]

    @property
    def in_flight_count(self) -> int:
        return len(self._intake) + sum(len(e.in_flight())
                                       for e in self.engines)

    @property
    def queue_depth(self) -> int:
        return self.in_flight_count

    @property
    def saturated(self) -> bool:
        return self.max_queue is not None \
            and self.in_flight_count >= self.max_queue

    @property
    def has_work(self) -> bool:
        if self.dead:
            return False
        return bool(self._intake) or any(e.has_work for e in self.engines)

    @property
    def idle(self) -> bool:
        """True when nothing is queued or decoding anywhere — the
        condition under which a draining supervisor may be retired
        without losing work."""
        return not self.has_work and self.in_flight_count == 0

    def drain(self) -> None:
        """Enter drain mode: every new :meth:`submit` is shed with
        :class:`RouteError`, while already-admitted work (intake + engine
        in-flight) keeps stepping to completion. The hot-swap discipline:
        a retiring generation finishes what it accepted and is torn down
        only once :attr:`idle`."""
        self.draining = True

    # -- admission ----------------------------------------------------------

    def _estimate_s(self, req: Request) -> float:
        """Oracle-priced decode time for ``req`` alone (predicted step x
        token budget) — deliberately the same per-request price the
        router routes by, NOT a queueing-delay estimate: budgets speak
        the oracle's language, and overload is the bounded queue's job.
        The check regains teeth for re-routed/re-queued requests, whose
        remaining budget has genuinely shrunk since first submit."""
        if self.est_step_s is None:
            return 0.0
        return self.est_step_s * max(1, req.max_new_tokens)

    def submit(self, req: Request) -> None:
        """Admit ``req`` to the deadline-ordered intake, or shed it with
        :class:`RouteError` — when the supervisor is dead, the queue is
        full, or the remaining budget cannot cover the estimated serve
        time through the current backlog."""
        if self.dead:
            self.shed += 1
            raise RouteError(f"entry {self.name!r} is dead "
                             f"({self.death_reason}); request {req.rid} "
                             f"not admitted")
        if self.draining:
            self.shed += 1
            raise RouteError(f"entry {self.name!r} is draining (retiring "
                             f"generation); request {req.rid} not admitted")
        if self.saturated:
            self.shed += 1
            raise RouteError(
                f"entry {self.name!r} is saturated ({self.in_flight_count}"
                f"/{self.max_queue} in flight); request {req.rid} shed at "
                f"admission")
        now = time.time()
        if not req.t_submit:
            req.t_submit = now
        if req.latency_budget_s is not None and not req.slo_infeasible:
            # (a flag-mode router has already accepted the SLO miss and
            # asked for best effort — don't re-shed at admission)
            # One clock snapshot: a fresh request's remaining budget is its
            # full budget, not full-budget-minus-a-few-microseconds.
            remaining = req.deadline_s - now
            est = self._estimate_s(req)
            if remaining < est:
                self.shed += 1
                raise RouteError(
                    f"request {req.rid} cannot meet its deadline on entry "
                    f"{self.name!r}: {remaining * 1e3:.3f} ms remaining < "
                    f"{est * 1e3:.3f} ms estimated; shed at admission")
        self.submitted += 1
        self._enqueue(req)

    def _enqueue(self, req: Request) -> None:
        heapq.heappush(self._intake, (req.deadline_s, next(self._seq), req))

    # -- the supervised quantum ---------------------------------------------

    def step(self) -> Dict[str, Any]:
        """One supervised quantum: rebuild due replicas, dispatch the
        intake front, advance every live engine one
        :meth:`ServeEngine.step`, and contain any crash."""
        t0 = time.perf_counter()
        try:
            completed_before = len(self.completed)
            self._pump()
            events: Dict[int, str] = {}
            for rep in self._replicas:
                if rep.engine is None or not rep.engine.has_work:
                    continue
                try:
                    events[rep.index] = rep.engine.step()["event"]
                except Exception as e:      # noqa: BLE001 — contain crashes
                    self._on_crash(rep, e)
                    events[rep.index] = "crash"
            if len(self.completed) > completed_before:
                # forward progress resets the breaker's crash streak
                self.consecutive_crashes = 0
            return {"event": "supervised" if events else "idle",
                    "replicas": events, "intake": len(self._intake)}
        finally:
            self._wall_s += time.perf_counter() - t0

    def run(self, deadline_s: Optional[float] = None) -> Dict[str, Any]:
        """Step until drained (or ``deadline_s``); returns :meth:`stats`."""
        t0 = time.time()
        while self.has_work:
            if deadline_s is not None and time.time() - t0 >= deadline_s:
                break
            self.step()
        return self.stats()

    def _pump(self) -> None:
        now = time.time()
        for rep in self._replicas:
            if rep.engine is None and not self.dead and now >= rep.down_until:
                try:
                    rep.engine = self._build(rep)
                except Exception as e:      # noqa: BLE001
                    self._on_build_failure(rep, e)
        live = [r for r in self._replicas if r.engine is not None]
        while self._intake and live:
            # Deadline-aware ORDERING only: budgets are oracle-priced
            # (predicted step seconds), so wall-clock expiry here would be
            # apples-to-oranges. Feasibility is checked against the oracle
            # estimate at admission and again on crash re-queue.
            # Keep per-engine queues shallow: deadline order lives in the
            # intake, engines only ever hold ~2 cohorts of lookahead.
            # Least-loaded = fewest OUTSTANDING TOKENS, not fewest
            # requests: the unit of engine work is the decode tick, and a
            # replica's backlog is the tokens it still owes.
            rep = min(live, key=lambda r: outstanding_tokens(r.engine))
            if len(rep.engine.in_flight()) >= 2 * rep.engine.max_batch:
                break
            _, _, req = heapq.heappop(self._intake)
            prev = self._last_replica.get(req.rid)
            if prev is not None and prev != rep.index:
                # a crash re-queue landing on a *surviving* replica —
                # recovery did not wait for the cold rebuild of the one
                # that died
                self.requeued_to_survivor += 1
            self._last_replica[req.rid] = rep.index
            self.dispatched[rep.index] += 1
            rep.engine.submit(req)

    def _build(self, rep: _Replica) -> ServeEngine:
        eng = self.factory(rep.index)
        if eng.straggler is None and self.straggler_factor is not None:
            # fresh monitor per (re)build: the rebuilt engine re-pays jit
            # compilation, which must not poison the straggler median
            eng.straggler = StragglerMonitor(
                factor=self.straggler_factor,
                skip_first=self.straggler_skip_first)
        self.build_failures = 0
        if rep.crashes:
            self.rebuilds += 1
        return eng

    def _on_build_failure(self, rep: _Replica, exc: Exception) -> None:
        self.build_failures += 1
        self.last_error = f"{type(exc).__name__}: {exc}"
        pol = self.retry
        rep.down_until = time.time() + pol.backoff_s * (
            pol.backoff_factor ** max(0, self.build_failures - 1))
        if self.build_failures > pol.max_build_failures \
                and not self.engines:
            self._die(f"engine build failed {self.build_failures}x "
                      f"(last: {self.last_error})")

    def _die(self, reason: str) -> None:
        """Permanent failure: fail every queued request explicitly; the
        router quarantines dead supervisors."""
        self.dead = True
        self.death_reason = reason
        while self._intake:
            _, _, req = heapq.heappop(self._intake)
            self._fail(req, "quarantined")
        for eng in self.engines:
            for req in eng.in_flight():
                self._fail(req, "quarantined")
        for rep in self._replicas:
            if rep.engine is not None:
                self._harvest(rep.engine)
                rep.engine = None

    def _on_crash(self, rep: _Replica, exc: Exception) -> None:
        """Tear the replica down, harvest its finished requests, and
        re-queue its in-flight ones (bounded retries, deadlines kept)."""
        self.crashes += 1
        self.consecutive_crashes += 1
        rep.crashes += 1
        self.last_error = f"{type(exc).__name__}: {exc}"
        eng, rep.engine = rep.engine, None
        pol = self.retry
        rep.down_until = time.time() + pol.backoff_s * (
            pol.backoff_factor ** max(0, rep.crashes - 1))
        self._harvest(eng)
        for req in eng.in_flight():
            if req.retries >= pol.max_retries:
                req.retries += 1
                self._fail(req, "retries")
            else:
                # the SLO clock keeps running (t_submit preserved), but a
                # late retry is NOT killed here: wall-clock overruns are
                # reported (the router's budget_violation_rate), never
                # enforced mid-flight — deadline feasibility is a
                # submit-time decision
                req.reset_for_retry()
                self.requeued += 1
                self._enqueue(req)

    def _harvest(self, eng: ServeEngine) -> None:
        """Preserve a dying engine's accounting: its finished requests,
        timed steps, and straggler count outlive it."""
        self._done.extend(eng.done)
        eng.done = []
        self._harvested_step_times.extend(eng._step_times)
        if eng.straggler is not None:
            self.straggler_steps += eng.straggler.stragglers

    def _fail(self, req: Request, reason: str) -> None:
        req.failed = True
        req.fail_reason = reason
        self.failed.append(req)

    def probe(self) -> bool:
        """Half-open probe for a dead supervisor: one rebuild attempt of
        replica 0. Success revives the supervisor (and clears the crash
        streak); failure leaves it dead. Used by the router's periodic
        quarantine probing; a no-op returning True when already live."""
        if not self.dead:
            self.consecutive_crashes = 0
            return True
        rep = self._replicas[0]
        try:
            eng = self.factory(rep.index)
        except Exception as e:              # noqa: BLE001
            self.last_error = f"{type(e).__name__}: {e}"
            return False
        self.dead = False
        self.death_reason = None
        self.build_failures = 0
        self.consecutive_crashes = 0
        rep.engine = eng
        if eng.straggler is None and self.straggler_factor is not None:
            eng.straggler = StragglerMonitor(
                factor=self.straggler_factor,
                skip_first=self.straggler_skip_first)
        return True

    # -- stats --------------------------------------------------------------

    def accounting(self) -> Dict[str, int]:
        """The zero-loss invariant, as numbers: ``submitted`` must equal
        ``completed + failed + in_flight`` (shed requests were never
        admitted, so they are accounted at the router)."""
        return {"submitted": self.submitted,
                "completed": len(self.completed),
                "failed": len(self.failed),
                "in_flight": self.in_flight_count}

    @staticmethod
    def _pct(xs: List[float], q: float) -> float:
        return float(np.percentile(np.asarray(xs), q)) if xs else 0.0

    def stats(self) -> Dict[str, Any]:
        done = self.completed
        total_tokens = sum(len(r.output) for r in done)
        step_times = list(self._harvested_step_times)
        predicted = None
        for eng in self.engines:
            step_times.extend(eng._step_times)
            if predicted is None:
                predicted = eng.predicted_step_s
        stragglers = self.straggler_steps + sum(
            e.straggler.stragglers for e in self.engines
            if e.straggler is not None)
        fails: Dict[str, int] = {}
        for r in self.failed:
            fails[r.fail_reason] = fails.get(r.fail_reason, 0) + 1
        budgeted = [r for r in done if r.latency_budget_s is not None]
        violations = [r for r in budgeted
                      if r.t_done - r.t_submit > r.latency_budget_s]
        measured = float(np.mean(step_times)) if step_times else 0.0
        pred_eff = predicted if predicted is not None else self.est_step_s
        rel_error = ((pred_eff - measured) / max(measured, 1e-12)
                     if pred_eff is not None and step_times else None)
        stats = {
            "requests": len(done),
            "total_new_tokens": total_tokens,
            "wall_s": self._wall_s,
            "tokens_per_s": total_tokens / max(self._wall_s, 1e-9),
            "p50_step_s": self._pct(step_times, 50),
            "p95_step_s": self._pct(step_times, 95),
            "measured_step_s": measured,
            "predicted_step_s": pred_eff,
            # drift signals (the autopilot's per-entry health inputs)
            "oracle_rel_error": rel_error,
            "measurement_window": len(step_times),
            "budgeted_requests": len(budgeted),
            "budget_violations": len(violations),
            "budget_violation_rate": (len(violations) / len(budgeted)
                                      if budgeted else 0.0),
            # supervision accounting
            "replicas": len(self._replicas),
            "live_replicas": len(self.engines),
            "crashes": self.crashes,
            "rebuilds": self.rebuilds,
            "requeued": self.requeued,
            "requeued_to_survivor": self.requeued_to_survivor,
            "dispatch_histogram": list(self.dispatched),
            "per_replica_occupancy": [
                {"replica": r.index,
                 "live": r.engine is not None,
                 "in_flight": (len(r.engine.in_flight())
                               if r.engine is not None else 0),
                 "outstanding_tokens": (outstanding_tokens(r.engine)
                                        if r.engine is not None else 0),
                 "dispatched": self.dispatched[r.index],
                 "crashes": r.crashes}
                for r in self._replicas],
            "retried_requests": sum(1 for r in done if r.retries),
            "max_retries_seen": max((r.retries for r in done + self.failed),
                                    default=0),
            "failed": len(self.failed),
            "failed_by_reason": fails,
            "shed": self.shed,
            "straggler_steps": stragglers,
            "dead": self.dead,
            "draining": self.draining,
            "queue_depth": len(self._intake),
            "in_flight": self.in_flight_count,
            "accounting": self.accounting(),
            "per_replica": [e.stats() for e in self.engines],
        }
        return stats

    def reset_stats(self) -> None:
        """Zero counters and forget retired/failed requests; live engines
        and their compiled programs are kept (benchmarks exclude a warmup
        drain this way). Supervision state (crash streaks, backoff,
        death) is preserved — stats are not health."""
        for eng in self.engines:
            eng.reset_stats()
        self._done = []
        self.failed = []
        self._harvested_step_times = []
        self.submitted = self.in_flight_count
        self.crashes = self.rebuilds = self.requeued = self.shed = 0
        self.requeued_to_survivor = 0
        self.dispatched = [0] * len(self._replicas)
        live = {r.rid for e in self.engines for r in e.in_flight()}
        live.update(req.rid for _, _, req in self._intake)
        self._last_replica = {rid: idx
                              for rid, idx in self._last_replica.items()
                              if rid in live}
        self.straggler_steps = 0
        self._wall_s = 0.0


# The router-facing name: the Router holds one ReplicaSet per catalog
# entry. Same object — the supervisor IS the fleet balancer; the alias
# names the role it plays above (dispatch + containment), not a subclass.
ReplicaSet = ReplicaSupervisor

"""Per-task program tuner (the AutoTVM/Ansor role, §2.2 of the paper).

For each task the tuner enumerates Pallas block configurations that fit the
VMEM budget, scores them with the *active latency oracle*
(:mod:`repro.core.oracle` — the analytic cost model by default, measured
Pallas-kernel timings or a deterministic replay log on request), and
records the fastest ``Program`` per constituent GEMM. The search is
exhaustive over a hardware-aligned candidate grid (a few hundred
candidates) — deterministic under the analytic and replay backends, so
CPrune iterations are reproducible.

Two engines produce bit-identical programs:

* ``vectorized`` (default) — scores the whole candidate grid in one NumPy
  pass (:func:`cost_model.matmul_cost_grid`) and memoizes the winner in the
  process-wide :class:`~repro.core.tuning_cache.ProgramCache`, so the
  thousands of identical GEMMs across CPrune iterations/configs tune once.
* ``reference`` — the original scalar Python loop, kept as the pre-PR
  baseline for ``benchmarks/tuner_bench.py`` and the equivalence tests.

The tuner also counts candidate evaluations ("tuning cost"), which the
paper's Fig. 9/11 ablations report as relative time cost; with the cache
active, ``candidates_evaluated`` counts only *real* grid evaluations, and
``cache_hits``/``cache_misses`` record the reuse the paper attributes to
keeping tuning logs across iterations.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import cost_model, oracle as oracle_mod, tuning_cache
from repro.core.cost_model import Block
from repro.core.program import Program
from repro.core.tasks import Task, TaskTable, Workload, local_gemm_dims
from repro.models.model import PruneSite


@dataclasses.dataclass
class TunerStats:
    candidates_evaluated: int = 0
    tasks_tuned: int = 0
    measurements: int = 0      # "on-device" cost-model invocations
    cache_hits: int = 0        # program served from the ProgramCache
    cache_misses: int = 0      # full grid searches actually run
    tasks_reused: int = 0      # tasks carried over by incremental retuning
    # per-backend oracle counters (all zero under the analytic backend)
    measured_programs: int = 0  # Pallas kernels actually built and timed
    measure_wall_s: float = 0.0  # wall-clock spent inside kernel timing
    replay_hits: int = 0        # measurements served from a replay log


# Lane-aligned candidate grid. bn/bk cover every multiple of 128 (not just
# powers of two) so re-tuning after a prune step can re-express the new dim
# without padding — the feedback loop the paper's TVM tuner provides.
_BM_CHOICES = (8, 16, 32, 64, 128, 256, 512)
_BK_CHOICES = tuple(128 * i for i in range(1, 9))      # 128..1024
_BN_CHOICES = tuple(128 * i for i in range(1, 17))     # 128..2048


_ENGINE = "vectorized"
_ENGINE_MODES = ("vectorized", "reference")


def engine() -> str:
    return _ENGINE


@contextlib.contextmanager
def engine_mode(mode: str) -> Iterator[None]:
    """Select the tuning engine: ``vectorized`` (default) or ``reference``.

    ``reference`` restores the full pre-cache behavior — scalar candidate
    loop, no ProgramCache, no incremental table reuse, no fixed-latency
    memo — so benchmarks can measure an honest before/after.

    Unknown modes are rejected before the engine is touched, and the prior
    engine is restored even when the body raises.
    """
    global _ENGINE
    if mode not in _ENGINE_MODES:
        raise ValueError(f"unknown tuning engine mode {mode!r}; "
                         f"valid modes: {_ENGINE_MODES}")
    old, _ENGINE = _ENGINE, mode
    try:
        yield
    finally:
        _ENGINE = old


def target_activation(target):
    """Context manager activating ``target`` (anything with ``.activate()``,
    e.g. :class:`repro.api.targets.TargetSpec`); no-op when ``None`` —
    the shared threading helper for tuner/latency/CPrune/baselines."""
    if target is None:
        return contextlib.nullcontext()
    return target.activate()


def _choices(m: int, k: int, n: int) -> Tuple[List[int], List[int], List[int]]:
    bms = [b for b in _BM_CHOICES if b <= max(8, 2 * m)]
    bks = [b for b in _BK_CHOICES if b <= max(128, 2 * k)]
    bns = [b for b in _BN_CHOICES if b <= max(128, 2 * n)]
    return bms, bks, bns


# Distinct dims collapse onto few distinct (choice-list, vmem) grids, so
# the meshgrid+filter construction — and the hardware-padded block dims,
# which depend only on the grid — are memoized. Entries are read-only.
_GRID_CACHE: Dict[Tuple, Tuple[np.ndarray, ...]] = {}


def clear_grid_cache() -> None:
    """Drop the memoized candidate grids (cold-start benchmarking). The
    public counterpart of the private ``_GRID_CACHE`` — callers must not
    reach into the module internals."""
    _GRID_CACHE.clear()


def _grid_with_hw(m: int, k: int, n: int, dtype_bytes: int,
                  vmem: Optional[int]) -> Tuple[np.ndarray, ...]:
    """(bm, bk, bn, bm_h, bk_h, bn_h) for the VMEM-filtered candidate grid.

    Enumeration order matches ``itertools.product(bms, bks, bns)`` so the
    vectorized argmin and the scalar loop break latency ties identically.
    """
    if vmem is None:
        vmem = cost_model.VMEM_BYTES      # read at call time (target swap)
    bms, bks, bns = _choices(m, k, n)
    # LANE/SUBLANE key the cached hardware padding, matching the
    # target_fingerprint invalidation contract
    key = (tuple(bms), tuple(bks), tuple(bns), dtype_bytes, vmem,
           cost_model.LANE, cost_model.SUBLANE)
    hit = _GRID_CACHE.get(key)
    if hit is not None:
        return hit
    bm, bk, bn = np.meshgrid(np.asarray(bms, np.int64),
                             np.asarray(bks, np.int64),
                             np.asarray(bns, np.int64), indexing="ij")
    bm, bk, bn = bm.ravel(), bk.ravel(), bn.ravel()
    fits = cost_model.block_vmem_bytes(bm, bk, bn, dtype_bytes) <= vmem
    bm, bk, bn = bm[fits], bk[fits], bn[fits]
    if bm.size == 0:
        bm, bk, bn = (np.array([8], np.int64), np.array([128], np.int64),
                      np.array([128], np.int64))
    entry = (bm, bk, bn,
             -(-bm // cost_model.SUBLANE) * cost_model.SUBLANE,
             -(-bk // cost_model.LANE) * cost_model.LANE,
             -(-bn // cost_model.LANE) * cost_model.LANE)
    for a in entry:
        a.setflags(write=False)
    _GRID_CACHE[key] = entry
    return entry


def candidate_grid(m: int, k: int, n: int, dtype_bytes: int = 2,
                   vmem: Optional[int] = None
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The candidate grid as parallel (bm, bk, bn) arrays, VMEM-filtered."""
    return _grid_with_hw(m, k, n, dtype_bytes, vmem)[:3]


def candidate_blocks(m: int, k: int, n: int, dtype_bytes: int = 2,
                     vmem: Optional[int] = None) -> List[Block]:
    """Hardware-aligned candidate grid, filtered to the VMEM budget."""
    bm, bk, bn = candidate_grid(m, k, n, dtype_bytes, vmem)
    return [Block(int(a), int(b), int(c)) for a, b, c in zip(bm, bk, bn)]


def _tune_gemm_reference(m: int, k: int, n: int, *, batch: int = 1,
                         dtype_bytes: int = 2, epilogue_ops: int = 0,
                         vmem: Optional[int] = None,
                         stats: Optional[TunerStats] = None) -> Program:
    """Pre-PR engine: scalar exhaustive loop, one cost call per candidate."""
    best: Optional[Tuple[float, Block]] = None
    for blk in candidate_blocks(m, k, n, dtype_bytes, vmem):
        lat = cost_model.matmul_cost(m, k, n, blk, dtype_bytes=dtype_bytes,
                                     batch=batch, epilogue_ops=epilogue_ops)
        if stats is not None:
            stats.candidates_evaluated += 1
        if best is None or lat < best[0]:
            best = (lat, blk)
    lat, blk = best
    return Program(m=m, k=k, n=n, block=blk, latency=lat,
                   dtype_bytes=dtype_bytes, batch=batch)


def tune_gemm(m: int, k: int, n: int, *, batch: int = 1,
              dtype_bytes: int = 2, epilogue_ops: int = 0,
              vmem: Optional[int] = None,
              stats: Optional[TunerStats] = None,
              cache: Optional[tuning_cache.ProgramCache] = None,
              target=None, oracle=None) -> Program:
    """Exhaustive search for the fastest block config of one GEMM.

    ``target`` tunes under a :class:`~repro.api.targets.TargetSpec` (or any
    object with ``.activate()``) instead of the currently active constants;
    ``oracle`` scores under a :class:`~repro.core.oracle.LatencyOracle`
    (name or instance) instead of the currently active backend;
    ``vmem`` overrides the target VMEM budget for this search;
    ``cache`` overrides the process-wide ProgramCache.
    """
    if target is not None:
        with target.activate():
            return tune_gemm(m, k, n, batch=batch, dtype_bytes=dtype_bytes,
                             epilogue_ops=epilogue_ops, vmem=vmem,
                             stats=stats, cache=cache, oracle=oracle)
    if oracle is not None:
        with oracle_mod.use_oracle(oracle):
            return tune_gemm(m, k, n, batch=batch, dtype_bytes=dtype_bytes,
                             epilogue_ops=epilogue_ops, vmem=vmem,
                             stats=stats, cache=cache)
    orc = oracle_mod.active_oracle()
    if _ENGINE == "reference":
        if orc.name != "analytic":
            raise RuntimeError(
                f"engine_mode('reference') is the pre-oracle analytic "
                f"baseline and cannot score with the {orc.name!r} backend")
        return _tune_gemm_reference(m, k, n, batch=batch,
                                    dtype_bytes=dtype_bytes,
                                    epilogue_ops=epilogue_ops, vmem=vmem,
                                    stats=stats)
    if cache is None:
        cache = tuning_cache.global_cache()
    key = tuning_cache.program_key(m, k, n, batch=batch,
                                   dtype_bytes=dtype_bytes,
                                   epilogue_ops=epilogue_ops, vmem=vmem)
    prog = cache.get(key)
    if prog is not None:
        if stats is not None:
            stats.cache_hits += 1
        return prog
    bm, bk, bn, bm_h, bk_h, bn_h = _grid_with_hw(m, k, n, dtype_bytes, vmem)
    lats = orc.score_grid(m, k, n, bm, bk, bn,
                          dtype_bytes=dtype_bytes, batch=batch,
                          epilogue_ops=epilogue_ops,
                          hw=(bm_h, bk_h, bn_h), stats=stats)
    i = int(np.argmin(lats))
    if stats is not None:
        stats.candidates_evaluated += int(lats.size)
        stats.cache_misses += 1
    prog = Program(m=m, k=k, n=n,
                   block=Block(int(bm[i]), int(bk[i]), int(bn[i])),
                   latency=float(lats[i]), dtype_bytes=dtype_bytes,
                   batch=batch)
    cache.put(key, prog)
    return prog


def untuned_gemm(m: int, k: int, n: int, *, batch: int = 1,
                 dtype_bytes: int = 2, epilogue_ops: int = 0) -> Program:
    """The 'without tuning' program (paper Fig. 10 ablation), costed by
    the active oracle."""
    blk = cost_model.default_block(m, k, n)
    lat = oracle_mod.active_oracle().score_one(
        m, k, n, blk, dtype_bytes=dtype_bytes, batch=batch,
        epilogue_ops=epilogue_ops)
    return Program(m=m, k=k, n=n, block=blk, latency=lat,
                   dtype_bytes=dtype_bytes, batch=batch)


def _epilogue_ops_for(op_kind: str) -> int:
    if "+" not in op_kind:
        return 0
    act = op_kind.split("+", 1)[1]
    return {"swiglu": 4, "geglu": 6, "gelu": 6, "relu2": 2, "silu": 3}.get(act, 2)


def tune_task(task: Task, wl: Workload, *, use_tuning: bool = True,
              vmem: Optional[int] = None,
              stats: Optional[TunerStats] = None, target=None,
              oracle=None) -> None:
    """Tune every constituent GEMM of a task; records fastest programs."""
    if target is not None:
        with target.activate():
            return tune_task(task, wl, use_tuning=use_tuning, vmem=vmem,
                             stats=stats, oracle=oracle)
    if oracle is not None:
        with oracle_mod.use_oracle(oracle):
            return tune_task(task, wl, use_tuning=use_tuning, vmem=vmem,
                             stats=stats)
    site = task.sites[0]
    epi = _epilogue_ops_for(site.op_kind)
    for g in site.gemms:
        m, k, n, b = local_gemm_dims(site, g, wl)
        if use_tuning:
            task.programs[g.name] = tune_gemm(
                m, k, n, batch=b, dtype_bytes=wl.dtype_bytes,
                epilogue_ops=epi, vmem=vmem, stats=stats)
        else:
            task.programs[g.name] = untuned_gemm(
                m, k, n, batch=b, dtype_bytes=wl.dtype_bytes, epilogue_ops=epi)
    task.tuned_mode = "tuned" if use_tuning else "untuned"
    if stats is not None:
        stats.tasks_tuned += 1
        stats.measurements += 1


def tune_table(table: TaskTable, *, use_tuning: bool = True,
               vmem: Optional[int] = None,
               stats: Optional[TunerStats] = None,
               prev: Optional[TaskTable] = None, target=None,
               oracle=None) -> TaskTable:
    """Tune all tasks; ``prev`` enables incremental retuning.

    When a previous table is given, any task whose signature is unchanged
    carries its tuned programs over verbatim — only the signatures the last
    prune step actually touched are re-searched (and those usually hit the
    ProgramCache for their untouched GEMMs anyway). Carry-over is refused
    when ``prev`` was tuned under a different target fingerprint, oracle
    backend, VMEM override, or workload: a signature match alone does not
    make its programs valid (the signature ignores sharding, target
    constants, and the scoring backend).

    ``target`` activates a registered target for the whole table tune —
    the fingerprint is computed under it, so a prev table from another
    target is refused and the ProgramCache keys per target. ``oracle``
    likewise activates a scoring backend for the whole tune.
    """
    if target is not None:
        with target.activate():
            return tune_table(table, use_tuning=use_tuning, vmem=vmem,
                              stats=stats, prev=prev, oracle=oracle)
    if oracle is not None:
        with oracle_mod.use_oracle(oracle):
            return tune_table(table, use_tuning=use_tuning, vmem=vmem,
                              stats=stats, prev=prev)
    mode = "tuned" if use_tuning else "untuned"
    fingerprint = tuning_cache.target_fingerprint() + (vmem,) \
        + oracle_mod.active_oracle().fingerprint()
    incremental = (prev is not None and _ENGINE != "reference"
                   and getattr(prev, "tuned_fingerprint", None) == fingerprint
                   and prev.wl == table.wl)
    for t in table.tasks:
        if incremental:
            old = prev.task_by_signature(t.signature)
            if old is not None and old.tuned_mode == mode:
                t.programs = dict(old.programs)
                t.tuned_mode = old.tuned_mode
                if stats is not None:
                    stats.tasks_reused += 1
                continue
        tune_task(t, table.wl, use_tuning=use_tuning, vmem=vmem, stats=stats)
    table.tuned_fingerprint = fingerprint
    return table


def build_tuned_table(sites: Sequence[PruneSite], wl: Workload, *,
                      use_tuning: bool = True,
                      vmem: Optional[int] = None,
                      stats: Optional[TunerStats] = None,
                      prev: Optional[TaskTable] = None,
                      target=None, oracle=None) -> TaskTable:
    table = TaskTable(sites, wl)
    return tune_table(table, use_tuning=use_tuning, vmem=vmem, stats=stats,
                      prev=prev, target=target, oracle=oracle)

"""Per-task program tuner (the AutoTVM/Ansor role, §2.2 of the paper).

For each task the tuner enumerates Pallas block configurations that fit the
VMEM budget, scores them with the analytic v5e cost model, and records the
fastest ``Program`` per constituent GEMM. The search is exhaustive over a
hardware-aligned candidate grid (a few hundred candidates) — deterministic,
so CPrune iterations are reproducible.

The tuner also counts candidate evaluations ("tuning cost"), which the
paper's Fig. 9/11 ablations report as relative time cost.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core import cost_model
from repro.core.cost_model import Block, VMEM_BYTES
from repro.core.program import Program
from repro.core.tasks import Task, TaskTable, Workload, local_gemm_dims
from repro.models.model import PruneSite


@dataclasses.dataclass
class TunerStats:
    candidates_evaluated: int = 0
    tasks_tuned: int = 0
    measurements: int = 0      # "on-device" cost-model invocations


# Lane-aligned candidate grid. bn/bk cover every multiple of 128 (not just
# powers of two) so re-tuning after a prune step can re-express the new dim
# without padding — the feedback loop the paper's TVM tuner provides.
_BM_CHOICES = (8, 16, 32, 64, 128, 256, 512)
_BK_CHOICES = tuple(128 * i for i in range(1, 9))      # 128..1024
_BN_CHOICES = tuple(128 * i for i in range(1, 17))     # 128..2048


def candidate_blocks(m: int, k: int, n: int, dtype_bytes: int = 2,
                     vmem: Optional[int] = None) -> List[Block]:
    """Hardware-aligned candidate grid, filtered to the VMEM budget."""
    if vmem is None:
        vmem = cost_model.VMEM_BYTES      # read at call time (target swap)
    bms = [b for b in _BM_CHOICES if b <= max(8, 2 * m)]
    bks = [b for b in _BK_CHOICES if b <= max(128, 2 * k)]
    bns = [b for b in _BN_CHOICES if b <= max(128, 2 * n)]
    out = []
    for bm, bk, bn in itertools.product(bms, bks, bns):
        blk = Block(bm, bk, bn)
        if blk.vmem_bytes(dtype_bytes) <= vmem:
            out.append(blk)
    return out or [Block(8, 128, 128)]


def tune_gemm(m: int, k: int, n: int, *, batch: int = 1,
              dtype_bytes: int = 2, epilogue_ops: int = 0,
              stats: Optional[TunerStats] = None) -> Program:
    """Exhaustive search for the fastest block config of one GEMM."""
    best: Optional[Tuple[float, Block]] = None
    for blk in candidate_blocks(m, k, n, dtype_bytes):
        lat = cost_model.matmul_cost(m, k, n, blk, dtype_bytes=dtype_bytes,
                                     batch=batch, epilogue_ops=epilogue_ops)
        if stats is not None:
            stats.candidates_evaluated += 1
        if best is None or lat < best[0]:
            best = (lat, blk)
    lat, blk = best
    return Program(m=m, k=k, n=n, block=blk, latency=lat,
                   dtype_bytes=dtype_bytes, batch=batch)


def untuned_gemm(m: int, k: int, n: int, *, batch: int = 1,
                 dtype_bytes: int = 2, epilogue_ops: int = 0) -> Program:
    """The 'without tuning' program (paper Fig. 10 ablation)."""
    blk = cost_model.default_block(m, k, n)
    lat = cost_model.matmul_cost(m, k, n, blk, dtype_bytes=dtype_bytes,
                                 batch=batch, epilogue_ops=epilogue_ops)
    return Program(m=m, k=k, n=n, block=blk, latency=lat,
                   dtype_bytes=dtype_bytes, batch=batch)


def _epilogue_ops_for(op_kind: str) -> int:
    if "+" not in op_kind:
        return 0
    act = op_kind.split("+", 1)[1]
    return {"swiglu": 4, "geglu": 6, "gelu": 6, "relu2": 2, "silu": 3}.get(act, 2)


def tune_task(task: Task, wl: Workload, *, use_tuning: bool = True,
              stats: Optional[TunerStats] = None) -> None:
    """Tune every constituent GEMM of a task; records fastest programs."""
    site = task.sites[0]
    epi = _epilogue_ops_for(site.op_kind)
    for g in site.gemms:
        m, k, n, b = local_gemm_dims(site, g, wl)
        if use_tuning:
            task.programs[g.name] = tune_gemm(
                m, k, n, batch=b, dtype_bytes=wl.dtype_bytes,
                epilogue_ops=epi, stats=stats)
        else:
            task.programs[g.name] = untuned_gemm(
                m, k, n, batch=b, dtype_bytes=wl.dtype_bytes, epilogue_ops=epi)
    task.tuned = True
    if stats is not None:
        stats.tasks_tuned += 1
        stats.measurements += 1


def tune_table(table: TaskTable, *, use_tuning: bool = True,
               stats: Optional[TunerStats] = None) -> TaskTable:
    for t in table.tasks:
        tune_task(t, table.wl, use_tuning=use_tuning, stats=stats)
    return table


def build_tuned_table(sites: Sequence[PruneSite], wl: Workload, *,
                      use_tuning: bool = True,
                      stats: Optional[TunerStats] = None) -> TaskTable:
    table = TaskTable(sites, wl)
    return tune_table(table, use_tuning=use_tuning, stats=stats)

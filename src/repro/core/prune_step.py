"""The structure-preserving prune step (paper §3.5).

    step = LCM over iterators i of  min_{mutable factor a in i} extent_i / a
         = LCM_i ( extent_i / max_mutable_factor_i )

extended with two TPU/cluster divisibility terms:
  * ``granularity`` — the semantic prune unit of the site (e.g. prune whole
    attention heads, one q-head per KV group);
  * ``shard_multiple`` — the tensor-parallel degree: pruned dims must remain
    divisible by the mesh axis they are sharded over, or every shard pads.
    (This is the multi-device generalization the paper did not need.)
"""
from __future__ import annotations

import math
from typing import Iterable, List, Sequence

from repro.core.program import Iterator, Program


def lcm(*vals: int) -> int:
    out = 1
    for v in vals:
        if v > 0:
            out = out * v // math.gcd(out, v)
    return out


def iterator_step(it: Iterator) -> int:
    """Minimal prunable count that keeps this iterator's structure."""
    quanta = it.prune_quanta()
    if not quanta:
        return it.extent  # fully immutable: can only remove everything
    return min(quanta)


def lcm_prune_step(iterators: Sequence[Iterator], *, granularity: int = 1,
                   shard_multiple: int = 1) -> int:
    """Paper formula + granularity/sharding divisibility."""
    steps = [iterator_step(it) for it in iterators]
    return lcm(*steps, granularity, shard_multiple)


def program_prune_step(programs: Sequence[tuple], *, granularity: int = 1,
                       shard_multiple: int = 1, unit_cols: int = 1,
                       roofline_guided: bool = False) -> int:
    """Prune step (in semantic units) for a site from its tuned programs.

    ``programs``: sequence of (Program, which_dim) where which_dim is 'n'
    or 'k' — the GEMM dim the prunable dimension maps to. ``unit_cols`` is
    the number of GEMM columns per semantic unit (head_dim for head
    pruning, 1 for channel pruning).

    ``roofline_guided`` (beyond-paper, DESIGN.md §7): restrict memory-bound
    programs to their layout iterators (lane-granular steps). NOTE: the A/B
    in EXPERIMENTS.md §Perf REFUTED this hypothesis — sub-block pruning
    leaves the padded block grid unchanged so the latency gate never
    passes; it independently re-validates the paper's §3.5 thesis. The
    flag stays for the ablation; default off.

    Returns the number of *semantic units* to prune at minimum.
    """
    its: List[Iterator] = []
    for prog, which in programs:
        dim_its = prog.dim_iterators(which)
        if roofline_guided and prog.memory_bound:
            dim_its = [it for it in dim_its if it.name.endswith(".layout")]
        its.extend(dim_its)
    step_cols = lcm_prune_step(its, granularity=1, shard_multiple=1)
    # convert columns -> semantic units (round up to whole units)
    step_units = max(1, -(-step_cols // unit_cols))
    return lcm(step_units, granularity, shard_multiple)

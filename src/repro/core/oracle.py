"""Pluggable latency oracles — the one answer site for "what does a
program cost".

CPrune's defining claim is that pruning decisions are informed by the
*compiler's measured execution* of candidate programs (the paper builds
and times every candidate with TVM on the target phone), not an analytic
proxy. This module makes that a swappable backend behind one protocol:

``analytic``
    The closed-form roofline model in :mod:`repro.core.cost_model`,
    evaluated over the whole candidate grid in one NumPy pass. The
    default — bit-identical to the pre-oracle scoring path.

``measured``
    Compiles and times the repo's own Pallas kernels
    (:mod:`repro.kernels.matmul` for plain GEMMs,
    :mod:`repro.kernels.moe_gmm` for batched/expert GEMMs) —
    ``pl.pallas_call`` in interpret mode on CPU, real compiled timings
    when a TPU backend is present. Measurements use warmup runs, k
    timed repeats with the extremes trimmed, and a median; large
    problems are measured on a clipped grid (a few grid steps per dim)
    and extrapolated by the exact grid-step ratio, the way per-block
    timings extrapolate in a tiled kernel. The analytic model pre-ranks
    the grid and only the shortlist is ever built and timed — the
    classic cost-model-guided measurement loop of AutoTVM/Ansor.

``replay``
    Deterministic record/playback of a ``measured`` run's log as a JSON
    artifact, so tests and CI exercise the measured code path — same
    shortlisting, same winner selection — without hardware variance.

Every consumer (tuner grid search, untuned programs, fixed-op latency,
attention/scan estimates) asks the *active* oracle; the tuning caches key
on :meth:`LatencyOracle.fingerprint`, so winners never cross backends.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import hashlib
import itertools
import json
import os
import time
from typing import Deque, Dict, Iterator, List, Optional, Protocol, Tuple, \
    Union, runtime_checkable

import numpy as np

from repro.core import cost_model
from repro.core.cost_model import Block

_LOG_VERSION = 1


@runtime_checkable
class LatencyOracle(Protocol):
    """What the tuner/latency stack needs to cost a program.

    ``score_grid`` is the tuner's inner loop (whole candidate grid at
    once); ``score_one`` costs a single fixed block config (untuned
    programs); the remaining methods cost the non-GEMM fixed ops so the
    latency model never reads :mod:`cost_model` directly.
    """

    name: str

    def fingerprint(self) -> Tuple: ...

    def score_grid(self, m: int, k: int, n: int,
                   bm: np.ndarray, bk: np.ndarray, bn: np.ndarray, *,
                   dtype_bytes: int, batch: int, epilogue_ops: int,
                   hw: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]],
                   stats=None) -> np.ndarray: ...

    def score_one(self, m: int, k: int, n: int, block: Block, *,
                  dtype_bytes: int, batch: int, epilogue_ops: int,
                  stats=None) -> float: ...

    def attention_cost(self, batch: int, sq: int, sk: int, n_heads: int,
                       head_dim: int, *, window: int = 0,
                       dtype_bytes: int = 2) -> float: ...

    def scan_cost(self, batch: int, seq: int, width: int,
                  state_bytes: int) -> float: ...

    def hbm_bytes_cost(self, n_bytes: int) -> float: ...

    def collective_cost(self, n_bytes: int, tp: int, *,
                        op: str = "all_reduce") -> float: ...


class AnalyticOracle:
    """The closed-form cost model of the *active* target constants —
    exactly the pre-oracle scoring path (enforced bit-identical by
    tests/test_oracle.py and the table1/fig8 golden checks)."""

    name = "analytic"

    def fingerprint(self) -> Tuple:
        return ("analytic",)

    def score_grid(self, m, k, n, bm, bk, bn, *, dtype_bytes, batch,
                   epilogue_ops, hw, stats=None) -> np.ndarray:
        return cost_model.matmul_cost_grid(
            m, k, n, bm, bk, bn, dtype_bytes=dtype_bytes, batch=batch,
            epilogue_ops=epilogue_ops, hw=hw)

    def score_one(self, m, k, n, block, *, dtype_bytes, batch,
                  epilogue_ops, stats=None) -> float:
        return cost_model.matmul_cost(m, k, n, block,
                                      dtype_bytes=dtype_bytes, batch=batch,
                                      epilogue_ops=epilogue_ops)

    def attention_cost(self, batch, sq, sk, n_heads, head_dim, *,
                       window=0, dtype_bytes=2) -> float:
        return cost_model.attention_cost(batch, sq, sk, n_heads, head_dim,
                                         window=window,
                                         dtype_bytes=dtype_bytes)

    def paged_attention_cost(self, batch, kv_len, n_heads, head_dim, *,
                             n_kv_heads=1, block_size=16,
                             dtype_bytes=2) -> float:
        """Decode attention through a block table (one query token per
        row, ``kv_len`` cached positions). Analytically identical to the
        dense decode estimate — paging changes *where* KV rows live, not
        how many bytes/FLOPs one step touches — so analytic fingerprints
        (and every tuning cache keyed on them) are unchanged.
        ``n_kv_heads``/``block_size`` only matter to measuring backends,
        which time the real kernel under those shapes."""
        del n_kv_heads, block_size
        return cost_model.attention_cost(batch, 1, kv_len, n_heads,
                                         head_dim, window=0,
                                         dtype_bytes=dtype_bytes)

    def scan_cost(self, batch, seq, width, state_bytes) -> float:
        return cost_model.scan_cost(batch, seq, width, state_bytes)

    def hbm_bytes_cost(self, n_bytes) -> float:
        return n_bytes / cost_model.HBM_BW

    def collective_cost(self, n_bytes, tp, *, op="all_reduce") -> float:
        """One TP collective (ring over ICI). Analytic in every backend —
        collectives are not Pallas programs a measuring oracle could time
        on a single host — so fingerprints (and every tuning cache keyed
        on them) are unchanged."""
        return cost_model.collective_cost(n_bytes, tp, op=op)


@dataclasses.dataclass(frozen=True)
class MeasurementConfig:
    """How the measured backend times a candidate program."""

    warmup: int = 1          # untimed runs before the clock starts
    repeats: int = 5         # timed runs per candidate
    trim: int = 1            # drop this many fastest+slowest before median
    measure_top_k: int = 4   # analytic-shortlisted candidates actually built
    max_grid_steps: int = 2  # grid steps measured per dim (then extrapolated)
    interpret: Optional[bool] = None   # None = interpret unless on a TPU

    def fingerprint(self) -> Tuple:
        return (self.warmup, self.repeats, self.trim, self.measure_top_k,
                self.max_grid_steps, self.interpret)

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


class MeasurementLog:
    """A map from measurement key to seconds, with JSON persistence —
    the replay artifact (and the measured backend's in-run memo).

    Long-running serve processes record into a log continuously, so the
    store can be bounded: ``max_entries`` caps the key count with LRU
    eviction (``lookup`` refreshes recency, the oldest key is dropped on
    overflow — the same discipline as ``latency._FIXED_CACHE``), and
    ``evicted`` counts what was dropped. Independently, every ``record``
    appends to a per-key observation window (``window(key)``, newest
    last, at most ``window_size`` samples) so drift detectors can reason
    about *recent* behaviour instead of a single overwritten scalar.
    Bounds and windows are runtime-only: ``digest``/``save``/``load``
    operate on ``entries`` exactly as before, so replay-artifact digests
    are unaffected.
    """

    def __init__(self, config: Optional[MeasurementConfig] = None, *,
                 max_entries: Optional[int] = None, window_size: int = 32):
        self.config = config or MeasurementConfig()
        self.entries: "collections.OrderedDict[str, float]" = \
            collections.OrderedDict()
        self.max_entries = max_entries
        self.window_size = max(1, int(window_size))
        self.history: Dict[str, Deque[float]] = {}
        self.evicted = 0
        # where this log last touched disk (set by save/load) — lets a
        # session checkpoint round-trip its replay artifact by path
        self.path: Optional[str] = None

    def __len__(self) -> int:
        return len(self.entries)

    def copy(self) -> "MeasurementLog":
        """Snapshot of the current entries (same config/bounds, no path)."""
        new = MeasurementLog(self.config, max_entries=self.max_entries,
                             window_size=self.window_size)
        new.entries = collections.OrderedDict(self.entries)
        new.history = {k: collections.deque(v, maxlen=self.window_size)
                       for k, v in self.history.items()}
        return new

    @staticmethod
    def gemm_key(m: int, k: int, n: int, batch: int, dtype_bytes: int,
                 block: Block) -> str:
        return (f"gemm:{m}:{k}:{n}:{batch}:{dtype_bytes}:"
                f"{block.bm}:{block.bk}:{block.bn}")

    @staticmethod
    def paged_attention_key(batch: int, kv_len: int, n_heads: int,
                            head_dim: int, n_kv_heads: int, block_size: int,
                            dtype_bytes: int) -> str:
        return (f"paged_attn:{batch}:{kv_len}:{n_heads}:{head_dim}:"
                f"{n_kv_heads}:{block_size}:{dtype_bytes}")

    @staticmethod
    def step_key(tag: str, max_batch: int, max_seq: int) -> str:
        """Key for a serve-time *observed* decode step (whole model, one
        token, ``max_batch`` rows, ``max_seq``-deep cache). Recorded by
        ``ServeEngine.record_measurements``; read back by
        ``DeploymentArtifact.recalibrated_oracle`` to close the
        plan -> serve -> replan loop. Never consulted by the replay
        scorer itself (which looks up ``gemm:`` keys only)."""
        return f"serve_step:{tag}:{max_batch}:{max_seq}"

    def scaled(self, factor: float, *, prefix: str = "gemm:"
               ) -> "MeasurementLog":
        """A new log with every ``prefix``-keyed entry multiplied by
        ``factor`` (other entries copied verbatim) — the recalibration
        primitive: serve-time observation / plan-time prediction becomes
        the factor, and a :class:`ReplayOracle` over the result predicts
        what serving actually measured."""
        new = MeasurementLog(self.config)
        new.entries = collections.OrderedDict(
            (k, v * factor if k.startswith(prefix) else v)
            for k, v in self.entries.items())
        return new

    def record(self, key: str, seconds: float) -> None:
        secs = float(seconds)
        if key in self.entries:
            self.entries.move_to_end(key)
        self.entries[key] = secs
        self.history.setdefault(
            key, collections.deque(maxlen=self.window_size)).append(secs)
        if self.max_entries is not None:
            while len(self.entries) > self.max_entries:
                old, _ = self.entries.popitem(last=False)
                self.history.pop(old, None)
                self.evicted += 1

    def lookup(self, key: str) -> Optional[float]:
        secs = self.entries.get(key)
        if secs is not None:
            self.entries.move_to_end(key)   # refresh LRU recency
        return secs

    def window(self, key: str) -> List[float]:
        """Recent observations recorded under ``key`` (newest last, at
        most ``window_size`` of them)."""
        return list(self.history.get(key, ()))

    def digest(self) -> str:
        blob = json.dumps([self.config.to_dict(),
                           sorted(self.entries.items())], sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    def save(self, path: str) -> int:
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"version": _LOG_VERSION,
                       "config": self.config.to_dict(),
                       "entries": self.entries}, f, indent=1)
        os.replace(tmp, path)
        self.path = path
        return len(self.entries)

    @classmethod
    def load(cls, path: str) -> "MeasurementLog":
        with open(path) as f:
            blob = json.load(f)
        if blob.get("version") != _LOG_VERSION:
            raise ValueError(f"unsupported measurement log version "
                             f"{blob.get('version')!r} in {path}")
        log = cls(MeasurementConfig(**blob["config"]))
        log.entries = collections.OrderedDict(
            (k, float(v)) for k, v in blob["entries"].items())
        log.path = path
        return log


def _trimmed_median(times, trim: int) -> float:
    ts = sorted(times)
    if trim > 0 and len(ts) > 2 * trim:
        ts = ts[trim:-trim]
    mid = len(ts) // 2
    if len(ts) % 2:
        return ts[mid]
    return 0.5 * (ts[mid - 1] + ts[mid])


@dataclasses.dataclass(frozen=True)
class DriftReport:
    """Predicted-vs-observed latency for one measurement key.

    ``rel_error`` is ``(measured - predicted) / predicted`` — positive
    means the target is *slower* than the plan-time oracle believed
    (the direction that breaks latency budgets). ``window`` is how many
    recent observations backed ``measured_s`` (their median)."""

    key: str
    predicted_s: float
    measured_s: float
    rel_error: float
    window: int

    @property
    def magnitude(self) -> float:
        return abs(self.rel_error)


def score_drift(log: MeasurementLog, key: str, predicted_s: float, *,
                min_window: int = 2) -> Optional[DriftReport]:
    """Score how far serve-time observations under ``key`` have drifted
    from a plan-time prediction.

    Uses the median of the log's recent observation window (not the
    latest sample) so a single straggler step doesn't trip a replan.
    Returns ``None`` when there is not yet enough evidence: fewer than
    ``min_window`` observations, or a non-positive prediction."""
    window = log.window(key)
    if len(window) < max(1, min_window) or predicted_s <= 0.0:
        return None
    measured = _trimmed_median(window, 0)
    return DriftReport(key=key, predicted_s=float(predicted_s),
                       measured_s=measured,
                       rel_error=(measured - predicted_s) / predicted_s,
                       window=len(window))


class _MeasurementOracle:
    """Shared scoring logic for the measured and replay backends: analytic
    pre-ranking shortlists the grid, then each shortlisted candidate's
    kernel seconds come from ``_gemm_seconds`` (a real timer or the log).
    Non-GEMM fixed ops (attention, scans, HBM gathers) and the fused
    epilogue term stay analytic in both — deterministic, so a replay of a
    measured run reproduces the exact same scores.
    """

    def __init__(self, config: MeasurementConfig):
        self.config = config
        self._analytic = AnalyticOracle()

    # subclasses: obtain kernel seconds for one (possibly clipped) problem
    def _gemm_seconds(self, m, k, n, batch, dtype_bytes, block,
                      stats=None) -> float:
        raise NotImplementedError

    def _epilogue_s(self, m, n, batch, epilogue_ops, block) -> float:
        if not epilogue_ops:
            return 0.0
        gm, gn = -(-m // block.bm), -(-n // block.bn)
        bm_h = -(-block.bm // cost_model.SUBLANE) * cost_model.SUBLANE
        bn_h = -(-block.bn // cost_model.LANE) * cost_model.LANE
        return cost_model.epilogue_cost(batch, epilogue_ops, gm, bm_h,
                                        gn, bn_h)

    def score_grid(self, m, k, n, bm, bk, bn, *, dtype_bytes, batch,
                   epilogue_ops, hw, stats=None) -> np.ndarray:
        base = self._analytic.score_grid(
            m, k, n, bm, bk, bn, dtype_bytes=dtype_bytes, batch=batch,
            epilogue_ops=epilogue_ops, hw=hw)
        k_top = max(1, self.config.measure_top_k)
        shortlist = np.argsort(base, kind="stable")[:k_top]
        out = np.full(base.shape, np.inf)
        for i in shortlist:
            blk = Block(int(bm[i]), int(bk[i]), int(bn[i]))
            out[i] = self._gemm_seconds(m, k, n, batch, dtype_bytes, blk,
                                        stats=stats) \
                + self._epilogue_s(m, n, batch, epilogue_ops, blk)
        return out

    def score_one(self, m, k, n, block, *, dtype_bytes, batch,
                  epilogue_ops, stats=None) -> float:
        return self._gemm_seconds(m, k, n, batch, dtype_bytes, block,
                                  stats=stats) \
            + self._epilogue_s(m, n, batch, epilogue_ops, block)

    # non-GEMM fixed ops: analytic in every backend (the repo has no
    # measured path for gathers/scans yet; keeping them analytic keeps
    # measured vs replay deterministic-by-construction)
    def attention_cost(self, *a, **kw) -> float:
        return self._analytic.attention_cost(*a, **kw)

    def paged_attention_cost(self, *a, **kw) -> float:
        return self._analytic.paged_attention_cost(*a, **kw)

    def scan_cost(self, *a, **kw) -> float:
        return self._analytic.scan_cost(*a, **kw)

    def hbm_bytes_cost(self, n_bytes) -> float:
        return self._analytic.hbm_bytes_cost(n_bytes)

    def collective_cost(self, *a, **kw) -> float:
        return self._analytic.collective_cost(*a, **kw)


# distinguishes each *recording* MeasuredOracle in cache fingerprints:
# a recorder must observe every tuning problem itself (warm ProgramCache /
# fixed-latency entries from an earlier measured run would otherwise
# starve the log and ship an incomplete replay artifact)
_RECORDING_IDS = itertools.count(1)


class MeasuredOracle(_MeasurementOracle):
    """Times the repo's own Pallas kernels for every shortlisted candidate.

    On this CPU container the kernels run with ``interpret=True`` (the
    same code path a TPU compiles); on a TPU backend they are real
    compiled timings. Pass ``record=MeasurementLog()`` to capture every
    measurement for later :class:`ReplayOracle` playback — the log also
    memoizes within the run, so a problem is never timed twice.
    """

    name = "measured"

    def __init__(self, config: Optional[MeasurementConfig] = None, *,
                 record: Optional[MeasurementLog] = None):
        super().__init__(config or MeasurementConfig())
        if record is not None and record.config != self.config:
            raise ValueError("record log's MeasurementConfig does not match "
                             "the oracle's")
        self.record = record
        self._recording_id = next(_RECORDING_IDS) if record is not None \
            else None

    def fingerprint(self) -> Tuple:
        fp = ("measured",) + self.config.fingerprint()
        if self._recording_id is not None:
            # each recorder is its own cache identity — see _RECORDING_IDS
            fp += ("recording", self._recording_id)
        return fp

    def _interpret(self) -> bool:
        if self.config.interpret is not None:
            return self.config.interpret
        import jax
        return jax.default_backend() != "tpu"

    def _clipped(self, m, k, n, batch, block):
        """Measured problem dims: at most ``max_grid_steps`` grid steps per
        dim (and 2 experts), plus the exact step-count ratio to scale the
        measured time back up — per-block extrapolation, not a model."""
        cap = max(1, self.config.max_grid_steps)
        gm, gk, gn = -(-m // block.bm), -(-k // block.bk), -(-n // block.bn)
        gm_c, gk_c, gn_c = min(gm, cap), min(gk, cap), min(gn, cap)
        b_c = min(batch, 2)
        scale = (gm * gk * gn * batch) / (gm_c * gk_c * gn_c * b_c)
        return (gm_c * block.bm, gk_c * block.bk, gn_c * block.bn, b_c,
                scale)

    def _time_kernel(self, m, k, n, batch, dtype_bytes, block) -> float:
        import jax
        import jax.numpy as jnp

        from repro.kernels import matmul as _mm
        from repro.kernels import moe_gmm as _gmm

        dtype = jnp.bfloat16 if dtype_bytes <= 2 else jnp.float32
        interpret = self._interpret()
        key = jax.random.PRNGKey(0)
        if batch == 1:
            a = jax.random.normal(key, (m, k), jnp.float32).astype(dtype)
            b = jnp.ones((k, n), dtype)
            fn = jax.jit(lambda x, y: _mm.matmul(
                x, y, block=block, interpret=interpret))
        else:
            a = jax.random.normal(key, (batch, m, k),
                                  jnp.float32).astype(dtype)
            b = jnp.ones((batch, k, n), dtype)
            fn = jax.jit(lambda x, y: _gmm.moe_gmm(
                x, y, block=block, interpret=interpret))
        for _ in range(max(0, self.config.warmup)):
            jax.block_until_ready(fn(a, b))
        times = []
        for _ in range(max(1, self.config.repeats)):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(a, b))
            times.append(time.perf_counter() - t0)
        return _trimmed_median(times, self.config.trim)

    def _gemm_seconds(self, m, k, n, batch, dtype_bytes, block,
                      stats=None) -> float:
        key = MeasurementLog.gemm_key(m, k, n, batch, dtype_bytes, block)
        if self.record is not None:
            hit = self.record.lookup(key)
            if hit is not None:
                return hit
        m_c, k_c, n_c, b_c, scale = self._clipped(m, k, n, batch, block)
        t0 = time.perf_counter()
        secs = self._time_kernel(m_c, k_c, n_c, b_c, dtype_bytes, block) \
            * scale
        if stats is not None:
            stats.measured_programs += 1
            stats.measure_wall_s += time.perf_counter() - t0
        if self.record is not None:
            self.record.record(key, secs)
        return secs

    def _time_paged_attention(self, batch, n_chunks, n_heads, head_dim,
                              n_kv_heads, block_size, dtype_bytes) -> float:
        import jax
        import jax.numpy as jnp

        from repro.kernels.paged_attention import paged_attention

        dtype = jnp.bfloat16 if dtype_bytes <= 2 else jnp.float32
        interpret = self._interpret()
        key = jax.random.PRNGKey(0)
        q = jax.random.normal(key, (batch, n_heads, head_dim),
                              jnp.float32).astype(dtype)
        n_blocks = batch * n_chunks
        k_pool = jax.random.normal(key, (n_blocks, block_size, n_kv_heads,
                                         head_dim), jnp.float32).astype(dtype)
        v_pool = jnp.ones_like(k_pool)
        table = jnp.arange(n_blocks, dtype=jnp.int32).reshape(batch, n_chunks)
        lens = jnp.full((batch,), n_chunks * block_size, jnp.int32)
        fn = jax.jit(lambda *a: paged_attention(*a, interpret=interpret))
        for _ in range(max(0, self.config.warmup)):
            jax.block_until_ready(fn(q, k_pool, v_pool, table, lens))
        times = []
        for _ in range(max(1, self.config.repeats)):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(q, k_pool, v_pool, table, lens))
            times.append(time.perf_counter() - t0)
        return _trimmed_median(times, self.config.trim)

    def paged_attention_cost(self, batch, kv_len, n_heads, head_dim, *,
                             n_kv_heads=1, block_size=16,
                             dtype_bytes=2) -> float:
        """Times the real paged-decode kernel (the grid is (batch, heads,
        kv chunks) — clip chunks and batch, extrapolate by the exact
        step-count ratio, exactly like :meth:`_clipped` for GEMMs).
        Memoized under the full-dimension key in ``record``, so a replay
        of this run reproduces the same step predictions."""
        mkey = MeasurementLog.paged_attention_key(
            batch, kv_len, n_heads, head_dim, n_kv_heads, block_size,
            dtype_bytes)
        if self.record is not None:
            hit = self.record.lookup(mkey)
            if hit is not None:
                return hit
        cap = max(1, self.config.max_grid_steps)
        n_chunks = max(1, -(-int(kv_len) // block_size))
        nc_c, b_c = min(n_chunks, cap), min(batch, 2)
        scale = (n_chunks * batch) / (nc_c * b_c)
        secs = self._time_paged_attention(
            b_c, nc_c, n_heads, head_dim, n_kv_heads, block_size,
            dtype_bytes) * scale
        if self.record is not None:
            self.record.record(mkey, secs)
        return secs


class ReplayOracle(_MeasurementOracle):
    """Plays a recorded :class:`MeasurementLog` back deterministically:
    same analytic shortlist (the log pins the MeasurementConfig), same
    per-candidate seconds, hence the same winners and the same CPrune
    history as the run that recorded it — without hardware variance."""

    name = "replay"

    def __init__(self, log: Union[MeasurementLog, str]):
        if isinstance(log, str):
            log = MeasurementLog.load(log)
        super().__init__(log.config)
        self.log = log
        self._digest = log.digest()

    @classmethod
    def from_file(cls, path: str) -> "ReplayOracle":
        return cls(path)

    def fingerprint(self) -> Tuple:
        return ("replay", self._digest) + self.config.fingerprint()

    def _gemm_seconds(self, m, k, n, batch, dtype_bytes, block,
                      stats=None) -> float:
        key = MeasurementLog.gemm_key(m, k, n, batch, dtype_bytes, block)
        secs = self.log.lookup(key)
        if secs is None:
            raise KeyError(
                f"measurement {key!r} not in the replay log ({len(self.log)} "
                f"entries) — the log was recorded for a different model/"
                f"workload/target; re-record with MeasuredOracle(record=...) "
                f"or session.calibrate()")
        if stats is not None:
            stats.replay_hits += 1
        return secs

    def paged_attention_cost(self, batch, kv_len, n_heads, head_dim, *,
                             n_kv_heads=1, block_size=16,
                             dtype_bytes=2) -> float:
        """Replays a recorded paged-kernel timing when the log has one;
        falls back to the analytic estimate otherwise. Unlike ``gemm:``
        keys this is a soft lookup — logs recorded before the paged
        layout existed (or on contiguous-only workloads) stay valid."""
        secs = self.log.lookup(MeasurementLog.paged_attention_key(
            batch, kv_len, n_heads, head_dim, n_kv_heads, block_size,
            dtype_bytes))
        if secs is not None:
            return secs
        return self._analytic.paged_attention_cost(
            batch, kv_len, n_heads, head_dim, n_kv_heads=n_kv_heads,
            block_size=block_size, dtype_bytes=dtype_bytes)


# ---------------------------------------------------------------------------
# Active-oracle plumbing (mirrors the target_activation contract)
# ---------------------------------------------------------------------------

ANALYTIC = AnalyticOracle()

_ACTIVE: LatencyOracle = ANALYTIC


def active_oracle() -> LatencyOracle:
    return _ACTIVE


@contextlib.contextmanager
def use_oracle(oracle: Union[str, LatencyOracle, None]
               ) -> Iterator[LatencyOracle]:
    """Install ``oracle`` as the process-wide scoring backend for the
    body; restores the previous one on exit, exceptions included."""
    global _ACTIVE
    old, _ACTIVE = _ACTIVE, get_oracle(oracle)
    try:
        yield _ACTIVE
    finally:
        _ACTIVE = old


def get_oracle(spec: Union[str, LatencyOracle, None], *,
               log: Union[MeasurementLog, str, None] = None,
               config: Optional[MeasurementConfig] = None) -> LatencyOracle:
    """Resolve an oracle: ``None`` -> the active one, a name
    (``analytic``/``measured``/``replay``) -> a backend instance, or any
    :class:`LatencyOracle` implementation passed through. ``replay``
    requires ``log`` (a :class:`MeasurementLog` or a JSON path)."""
    if spec is None:
        return _ACTIVE
    if not isinstance(spec, str):
        if isinstance(spec, LatencyOracle):
            return spec
        raise TypeError(f"oracle must be a backend name or implement the "
                        f"LatencyOracle protocol, got {type(spec).__name__}")
    if spec == "analytic":
        return ANALYTIC
    if spec == "measured":
        return MeasuredOracle(config)
    if spec == "replay":
        if log is None:
            raise ValueError("oracle='replay' needs log=<MeasurementLog or "
                             "path> (record one with session.calibrate() or "
                             "MeasuredOracle(record=MeasurementLog()))")
        return ReplayOracle(log)
    raise KeyError(f"unknown oracle {spec!r}; "
                   f"backends: ['analytic', 'measured', 'replay']")

"""Whole-model latency estimate on the target shard (FPS denominator).

latency(model) = sum over prunable tasks (tuned program latency x subgraphs)
               + fixed ops: non-prunable GEMMs (kv projections, recurrence
                 projections, unembed), attention score/value contractions,
                 and linear-recurrence scans.

The paper reports FPS = images/s on the phone; here
FPS = global_batch / step_latency on the target mesh shard.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ATTN, LOCAL_ATTN, RGLRU, RWKV, ModelConfig
from repro.core import tuner, tuning_cache
from repro.core import oracle as oracle_mod
from repro.core.tasks import TaskTable, Workload
from repro.models.model import PruneSite


@dataclasses.dataclass
class LatencyReport:
    total_s: float
    task_s: float
    fixed_s: float
    breakdown: Dict[str, float]

    @property
    def fps(self) -> float:
        return 1.0 / max(self.total_s, 1e-12)


def _head_dim_of(cfg, sites: Sequence[PruneSite], block_path: str) -> int:
    """Current q-head count for a block (after possible pruning)."""
    for s in sites:
        if s.kind == "heads" and s.block_path == block_path:
            return s.dim
    return cfg.n_heads


# Memo for the whole fixed-op computation. The only site-dependent inputs
# are the (rarely changing) per-block q-head counts, so candidate models
# that prune FFN/MoE dims re-read the fixed half for free. LRU-bounded:
# long multi-target/multi-oracle sessions churn the key space (every
# target swap and every oracle is a fresh key family), and the memo must
# not grow without limit.
_FIXED_CACHE: "collections.OrderedDict[Tuple, Tuple[float, Dict[str, float]]]" \
    = collections.OrderedDict()
_FIXED_CACHE_MAX = 2048
_FIXED_CACHE_EVICTIONS = 0


def clear_fixed_latency_cache() -> None:
    global _FIXED_CACHE_EVICTIONS
    _FIXED_CACHE.clear()
    _FIXED_CACHE_EVICTIONS = 0


def fixed_latency_cache_info() -> Dict[str, int]:
    """Observability for the fixed-op memo: current size, the size cap,
    and how many entries the cap has evicted since the last clear."""
    return {"size": len(_FIXED_CACHE), "max": _FIXED_CACHE_MAX,
            "evictions": _FIXED_CACHE_EVICTIONS}


def set_fixed_latency_cache_limit(n: int) -> None:
    """Resize the fixed-op memo bound (evicting oldest entries if needed)."""
    global _FIXED_CACHE_MAX
    if n < 1:
        raise ValueError(f"fixed-latency cache limit must be >= 1, got {n}")
    _FIXED_CACHE_MAX = n
    _fixed_cache_trim()


def _fixed_cache_trim() -> None:
    global _FIXED_CACHE_EVICTIONS
    while len(_FIXED_CACHE) > _FIXED_CACHE_MAX:
        _FIXED_CACHE.popitem(last=False)
        _FIXED_CACHE_EVICTIONS += 1


def _fixed_cache_key(cfg, sites, wl, seq_len, use_tuning,
                     decode_kv_len, kv_layout) -> Optional[Tuple]:
    heads = tuple(sorted((s.block_path, s.dim)
                         for s in sites if s.kind == "heads"))
    key = (cfg, heads, wl, seq_len, use_tuning, decode_kv_len, kv_layout) \
        + tuning_cache.target_fingerprint() \
        + oracle_mod.active_oracle().fingerprint()
    try:
        hash(key)
    except TypeError:        # non-hashable config variant: skip memoization
        return None
    return key


def fixed_latency(cfg: ModelConfig, sites: Sequence[PruneSite], wl: Workload,
                  *, seq_len: int, use_tuning: bool = True,
                  stats: Optional[tuner.TunerStats] = None, target=None,
                  oracle=None, decode_kv_len: Optional[int] = None,
                  kv_layout: str = "contiguous"
                  ) -> Tuple[float, Dict[str, float]]:
    """Latency of the non-prunable ops, per step, per shard. ``target``
    evaluates under a registered target, ``oracle`` under a scoring
    backend (the memo keys per target and per oracle through the
    fingerprints). ``decode_kv_len`` prices attention against a KV cache
    of that many keys instead of ``seq_len`` — with ``seq_len=1`` this
    turns the estimate into one *decode step* (per-token GEMMs + cached-
    key attention) rather than a prefill. ``kv_layout="paged"`` prices
    decode attention through the paged kernel instead (oracles without a
    ``paged_attention_cost`` fall back to the dense estimate, which is
    analytically identical)."""
    if target is not None:
        with target.activate():
            return fixed_latency(cfg, sites, wl, seq_len=seq_len,
                                 use_tuning=use_tuning, stats=stats,
                                 oracle=oracle, decode_kv_len=decode_kv_len,
                                 kv_layout=kv_layout)
    if oracle is not None:
        with oracle_mod.use_oracle(oracle):
            return fixed_latency(cfg, sites, wl, seq_len=seq_len,
                                 use_tuning=use_tuning, stats=stats,
                                 decode_kv_len=decode_kv_len,
                                 kv_layout=kv_layout)
    orc = oracle_mod.active_oracle()
    memo_key = None
    if tuner.engine() != "reference":
        memo_key = _fixed_cache_key(cfg, sites, wl, seq_len, use_tuning,
                                    decode_kv_len, kv_layout)
        if memo_key is not None and memo_key in _FIXED_CACHE:
            total, bd = _FIXED_CACHE[memo_key]
            _FIXED_CACHE.move_to_end(memo_key)
            return total, dict(bd)
    d = cfg.d_model
    m = wl.tokens_local
    batch_local = max(1, m // max(seq_len, 1))
    tp = wl.tp
    tune = (lambda *a, **k: tuner.tune_gemm(*a, stats=stats, **k)) \
        if use_tuning else tuner.untuned_gemm
    # TP collectives: oracle-probed like paged_attention_cost so custom
    # scoring backends can override; every shipped backend prices them
    # with the same analytic ring formula (tp=1 -> exactly 0.0)
    coll = getattr(orc, "collective_cost", None)
    if tp > 1 and coll is None:
        coll = oracle_mod.AnalyticOracle().collective_cost
    bd: Dict[str, float] = {}

    def add(name: str, sec: float):
        bd[name] = bd.get(name, 0.0) + sec

    pattern_paths = {}
    P = len(cfg.block_pattern)
    n_p = cfg.n_layers // P
    blocks = [(f"stack/pos{i}", k, n_p) for i, k in enumerate(cfg.block_pattern)
              if n_p > 0]
    blocks += [(f"tail/{i}", k, 1)
               for i, k in enumerate(cfg.layer_kinds()[n_p * P:])]

    for path, kind, mult in blocks:
        if kind in (ATTN, LOCAL_ATTN):
            hq = _head_dim_of(cfg, sites, path)
            hkv, hd = cfg.n_kv_heads, cfg.head_dim
            # kv projections (always fixed)
            kvp = tune(m, d, max(1, hkv * hd // min(tp, max(hkv, 1))),
                       dtype_bytes=wl.dtype_bytes)
            add("kv_proj", 2 * kvp.latency * mult)
            # q/o fixed only when there is no heads site (MHA)
            if not any(s.kind == "heads" and s.block_path == path
                       for s in sites):
                qp = tune(m, d, max(1, hq * hd // tp),
                          dtype_bytes=wl.dtype_bytes)
                op = tune(m, max(1, hq * hd // tp), d,
                          dtype_bytes=wl.dtype_bytes)
                add("qo_proj", (qp.latency + op.latency) * mult)
            window = cfg.sliding_window if (kind == LOCAL_ATTN or
                                            cfg.sliding_window > 0) else 0
            kv_len = decode_kv_len if decode_kv_len is not None else seq_len
            paged_cost = getattr(orc, "paged_attention_cost", None) \
                if (kv_layout == "paged" and seq_len == 1 and window == 0) \
                else None
            if paged_cost is not None:
                # one decode step through the block table — a measuring
                # oracle times the paged kernel itself here
                att = paged_cost(batch_local, kv_len, max(1, hq // tp), hd,
                                 n_kv_heads=max(1, hkv),
                                 dtype_bytes=wl.dtype_bytes)
            else:
                att = orc.attention_cost(
                    batch_local, seq_len, kv_len, max(1, hq // tp), hd,
                    window=window, dtype_bytes=wl.dtype_bytes)
            add("attention", att * mult)
        elif kind == RGLRU:
            w = cfg.rglru_width
            for nm, (kk, nn) in (("rg_in", (d, w // tp)),
                                 ("rg_gate", (d, w // tp)),
                                 ("rg_out", (w // tp, d))):
                p = tune(m, max(1, kk), max(1, nn), dtype_bytes=wl.dtype_bytes)
                add(nm, p.latency * mult)
            nb = max(1, cfg.n_heads)
            wb = max(1, w // nb)
            gate = tune(m, wb, wb, batch=nb, dtype_bytes=wl.dtype_bytes)
            add("rg_gates", 2 * gate.latency * mult)
            add("rg_scan", orc.scan_cost(
                batch_local, seq_len, w // tp, 4 * w // tp) * mult)
        elif kind == RWKV:
            for _ in range(5):
                p = tune(m, d, max(1, d // tp), dtype_bytes=wl.dtype_bytes)
                add("rwkv_proj", p.latency * mult)
            H = max(1, d // cfg.rwkv_head_dim)
            add("rwkv_scan", orc.scan_cost(
                batch_local, seq_len, d // tp,
                4 * (H // tp + 1) * cfg.rwkv_head_dim ** 2) * mult)

    if tp > 1:
        # Megatron-style layer sharding leaves partial sums at the two
        # row-parallel projections per layer (mixer output + FFN/MoE
        # down): one all-reduce of the residual activation each
        add("collective", 2 * cfg.n_layers
            * coll(m * d * wl.dtype_bytes, tp, op="all_reduce"))

    # embedding gather + unembed GEMM (vocab TP-sharded)
    add("embed", orc.hbm_bytes_cost(m * d * wl.dtype_bytes))
    un = tune(m, d, max(1, cfg.vocab_size // tp), dtype_bytes=wl.dtype_bytes)
    add("unembed", un.latency)
    if tp > 1:
        # vocab-sharded logits gathered once per step for sampling
        add("collective", coll(m * max(1, cfg.vocab_size // tp)
                               * wl.dtype_bytes, tp, op="all_gather"))
    total = sum(bd.values())
    if memo_key is not None:
        _FIXED_CACHE[memo_key] = (total, dict(bd))
        _fixed_cache_trim()
    return total, bd


def model_latency(cfg: ModelConfig, sites: Sequence[PruneSite],
                  table: TaskTable, *, seq_len: int, use_tuning: bool = True,
                  stats: Optional[tuner.TunerStats] = None,
                  target=None, oracle=None,
                  decode_kv_len: Optional[int] = None,
                  kv_layout: str = "contiguous") -> LatencyReport:
    if target is not None:
        with target.activate():
            return model_latency(cfg, sites, table, seq_len=seq_len,
                                 use_tuning=use_tuning, stats=stats,
                                 oracle=oracle, decode_kv_len=decode_kv_len,
                                 kv_layout=kv_layout)
    if oracle is not None:
        with oracle_mod.use_oracle(oracle):
            return model_latency(cfg, sites, table, seq_len=seq_len,
                                 use_tuning=use_tuning, stats=stats,
                                 decode_kv_len=decode_kv_len,
                                 kv_layout=kv_layout)
    task_s = table.total_task_latency()
    fixed_s, bd = fixed_latency(cfg, sites, table.wl, seq_len=seq_len,
                                use_tuning=use_tuning, stats=stats,
                                decode_kv_len=decode_kv_len,
                                kv_layout=kv_layout)
    bd = dict(bd)
    for t in table.tasks:
        key = f"task_{t.sites[0].kind}"
        bd[key] = bd.get(key, 0.0) + t.latency * t.n_subgraphs
    return LatencyReport(total_s=task_s + fixed_s, task_s=task_s,
                         fixed_s=fixed_s, breakdown=bd)

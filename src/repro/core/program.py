"""Program: a tuned kernel configuration and its iterator factorizations.

The paper (§3.5) reads the fastest TVM program's loop-split factors for the
filter-related iterators and derives the minimal structure-preserving prune
count. On TPU the analogous structure is the Pallas block config:

  * compute iterator over a GEMM dim X blocked by bx:
        X = grid_x x (bx // LANE) x LANE        (LANE = 128, immutable hw)
  * layout iterator over the output tile:
        X = (X_pad // LANE) x LANE

Factors flagged immutable (the hardware lane/sublane extents) cannot be
decremented by pruning — that is the TPU adaptation of "maintaining the
program structure": you can drop whole blocks or whole lane-groups, never
fractions of a lane.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

from repro.core.cost_model import LANE, Block, _ceil


@dataclasses.dataclass(frozen=True)
class Iterator:
    """One loop nest over a prunable dim: split factors + mutability flags."""

    name: str
    factors: Tuple[int, ...]
    mutable: Tuple[bool, ...]   # False = hardware extent, cannot shrink

    @property
    def extent(self) -> int:
        return math.prod(self.factors)

    def prune_quanta(self) -> List[int]:
        """Sizes removable by decrementing one mutable factor (paper Fig 5f).

        Decrementing factor a_i removes prod(factors)/a_i elements.
        """
        total = self.extent
        return [total // f for f, m in zip(self.factors, self.mutable)
                if m and f > 1]


@dataclasses.dataclass(frozen=True)
class Program:
    """A tuned program for one GEMM: block config + derived iterators."""

    m: int
    k: int
    n: int
    block: Block
    latency: float
    dtype_bytes: int = 2
    batch: int = 1

    @property
    def memory_bound(self) -> bool:
        """Whether HBM traffic (not MXU compute) dominates this program.

        Memory-bound GEMMs step at *lane* granularity (padded bytes), not
        block granularity — the roofline-guided prune-step extension
        (DESIGN.md §7) exploits this with finer steps.
        """
        from repro.core.cost_model import matmul_terms
        t_c, t_m = matmul_terms(self.m, self.k, self.n, self.block,
                                dtype_bytes=self.dtype_bytes,
                                batch=self.batch)
        return t_m > t_c

    def dim_iterators(self, which: str) -> List[Iterator]:
        """Iterators over GEMM dim 'n' or 'k' (the prunable ones).

        Returns the compute-grid iterator and the memory-layout iterator —
        the two iterator families the paper's LCM formula combines.
        """
        size = self.n if which == "n" else self.k
        b = self.block.bn if which == "n" else self.block.bk
        b = min(b, size)
        grid = _ceil(size, b)
        lanes = max(b // LANE, 1)
        lane_extent = min(b, LANE)
        compute = Iterator(
            name=f"{which}.compute",
            factors=(grid, lanes, lane_extent),
            mutable=(True, True, False),
        )
        layout = Iterator(
            name=f"{which}.layout",
            factors=(max(_ceil(size, LANE), 1), min(size, LANE)),
            mutable=(True, False),
        )
        return [compute, layout]

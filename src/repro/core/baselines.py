"""Baseline pruning schemes for the paper's Table 1 / Fig. 11 comparisons.

* ``uniform_prune``   — L1-magnitude structured pruning, uniform ratio per
                        site (Li et al. 2016; "PQF/FPGM+TVM" rows use the
                        same search with different ranking).
* ``netadapt_prune``  — hardware-aware exhaustive search: per iteration,
                        build one candidate per site (pruned just enough to
                        hit a latency reduction quantum), short-term train
                        every candidate, keep the most accurate. This is
                        the paper's main comparison point; it measures every
                        candidate (expensive) and knows nothing about the
                        compiler's program structure.

All baselines share the applier/cost-model so the comparison isolates the
*search policy*, exactly as the paper's Table 1 does (every row runs
through the same TVM auto-tuner).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import applier, latency, ranking, tuner
from repro.core.cprune import CPruneConfig, TrainHooks
from repro.core.tasks import TaskTable, Workload
from repro.models.model import PruneSite


@dataclasses.dataclass
class BaselineResult:
    params: Dict
    sites: List[PruneSite]
    latency: latency.LatencyReport
    acc: float
    candidates_evaluated: int   # "measurements" on device
    name: str


def _tuned_latency(cfg, sites, wl, pcfg, stats=None, prev=None):
    """(table, report) for the sites; ``prev`` enables incremental retune."""
    table = tuner.build_tuned_table(sites, wl, use_tuning=pcfg.use_tuning,
                                    stats=stats, prev=prev)
    rep = latency.model_latency(cfg, sites, table, seq_len=pcfg.seq_len,
                                use_tuning=pcfg.use_tuning, stats=stats)
    return table, rep


def uniform_prune(cfg: ModelConfig, params, sites: Sequence[PruneSite],
                  wl: Workload, hooks: TrainHooks, pcfg: CPruneConfig, *,
                  ratio: float, method: str = "l1",
                  name: str = "l1_uniform", target=None) -> BaselineResult:
    """Prune every site by ``ratio`` with the given ranking, then tune."""
    if target is not None:
        with target.activate():
            return uniform_prune(cfg, params, sites, wl, hooks, pcfg,
                                 ratio=ratio, method=method, name=name)
    sites = [s for s in sites if s.kind in pcfg.prunable_kinds
             and s.kind != "experts"]
    pruned: Dict[str, PruneSite] = {}
    new_params = params
    for site in sites:
        group = site.granularity if site.kind == "heads" else 1
        n_units = int(round(site.dim * ratio / max(group, 1))) * max(group, 1)
        n_units = min(n_units, site.dim - pcfg.min_dim_units)
        if n_units <= 0:
            continue
        scores = ranking.rank_units(new_params, site, method)
        new_params, new_site = applier.prune_site_by_rank(
            new_params, site, n_units, scores)
        pruned[site.site_id] = new_site
    new_sites = applier.refresh_sites(sites, pruned)
    if hooks.long_term_train is not None:
        new_params = hooks.long_term_train(new_params, new_sites)
    else:
        new_params = hooks.short_term_train(new_params, new_sites)
    acc = hooks.eval_acc(new_params, new_sites)
    _, rep = _tuned_latency(cfg, new_sites, wl, pcfg)
    return BaselineResult(new_params, new_sites, rep, acc, len(sites), name)


def netadapt_prune(cfg: ModelConfig, params, sites: Sequence[PruneSite],
                   wl: Workload, hooks: TrainHooks, pcfg: CPruneConfig, *,
                   latency_decay: float = 0.97, max_iterations: int = 30,
                   target=None) -> BaselineResult:
    """NetAdapt-style exhaustive hardware-aware pruning (paper §4.7).

    Per iteration: one candidate per site, each pruned by the smallest
    multiple of its semantic granularity that beats the latency budget;
    every candidate is short-term trained and measured (exhaustive), the
    best-accuracy candidate wins.
    """
    if target is not None:
        with target.activate():
            return netadapt_prune(cfg, params, sites, wl, hooks, pcfg,
                                  latency_decay=latency_decay,
                                  max_iterations=max_iterations)
    sites = [s for s in sites if s.kind in pcfg.prunable_kinds
             and s.kind != "experts"]
    stats = tuner.TunerStats()
    table, rep = _tuned_latency(cfg, sites, wl, pcfg, stats)
    rep0 = rep
    budget = rep.total_s * latency_decay
    evaluated = 0

    for it in range(max_iterations):
        acc_p = hooks.eval_acc(params, sites)
        if acc_p <= pcfg.a_g:
            break
        candidates = []
        for si, site in enumerate(sites):
            group = site.granularity if site.kind == "heads" else 1
            # grow the prune count until the latency budget is met
            # (NetAdapt has no program structure to consult, so it walks in
            # semantic-granularity steps — often too fine, cf. §3.5)
            found = None
            step = max(group, max(1, site.dim // 16))
            step = (step // max(group, 1)) * max(group, 1) or group
            n_units = step
            while site.dim - n_units >= pcfg.min_dim_units:
                scores = ranking.rank_units(params, site, pcfg.rank_method)
                cand_params, cand_site = applier.prune_site_by_rank(
                    params, site, n_units, scores)
                cand_sites = applier.refresh_sites(
                    sites, {site.site_id: cand_site})
                cand_table, cand_rep = _tuned_latency(
                    cfg, cand_sites, wl, pcfg, stats, prev=table)
                evaluated += 1
                if cand_rep.total_s <= budget:
                    found = (cand_params, cand_sites, cand_table, cand_rep)
                    break
                n_units += step
            if found is None:
                continue
            cand_params, cand_sites, cand_table, cand_rep = found
            cand_params = hooks.short_term_train(cand_params, cand_sites)
            a = hooks.eval_acc(cand_params, cand_sites)
            evaluated += 1
            candidates.append((a, cand_params, cand_sites, cand_table,
                               cand_rep))
        if not candidates:
            break
        a, params, sites, table, rep = max(candidates, key=lambda c: c[0])
        budget = rep.total_s * latency_decay
        if a < pcfg.a_g:
            break

    if hooks.long_term_train is not None:
        params = hooks.long_term_train(params, sites)
    acc = hooks.eval_acc(params, sites)
    return BaselineResult(params, sites, rep, acc,
                          evaluated + stats.candidates_evaluated,
                          "netadapt")

"""CPrune core: compiler-informed model pruning (the paper's contribution).

cost_model  — analytic latency model of the *active* target device
oracle      — pluggable latency backends: analytic | measured | replay
program     — tuned Pallas block configs + iterator factorizations
tuner       — per-task program search (the AutoTVM/Ansor role)
tasks       — subgraph/task decomposition + relationship table C
prune_step  — the LCM structure-preserving prune quantum (§3.5)
ranking     — L1 / FPGM filter selection
applier     — functional param-pytree surgery
latency     — whole-model latency/FPS estimates
cprune      — Algorithm 1 (the iterative loop)
baselines   — uniform-L1 / FPGM / NetAdapt-style comparisons
tuning_cache— process-wide ProgramCache + JSON tuning logs

These modules stay importable as before, but new code should go through
the :mod:`repro.api` front door (``PruningSession`` + the target and
strategy registries) — see the README's "Public API" migration table.
"""
from repro.core.cost_model import Block, matmul_cost, matmul_cost_grid
from repro.core.cprune import (CPrune, CPruneConfig, CPruneResult,
                               TrainHooks)
from repro.core.oracle import (AnalyticOracle, LatencyOracle, MeasuredOracle,
                               MeasurementConfig, MeasurementLog,
                               ReplayOracle, active_oracle, get_oracle,
                               use_oracle)
from repro.core.program import Iterator, Program
from repro.core.prune_step import lcm_prune_step, program_prune_step
from repro.core.tasks import Task, TaskTable, Workload
from repro.core.tuner import TunerStats, build_tuned_table, tune_gemm
from repro.core.tuning_cache import (ProgramCache, global_cache,
                                     reset_global_cache)


def clear_tuning_caches() -> None:
    """Cold-start every process-wide tuning cache: the ProgramCache, the
    fixed-latency memo, and the candidate-grid cache. Use this (not just
    ``reset_global_cache``) when measuring cold-start search cost."""
    from repro.core import latency, tuner
    reset_global_cache()
    latency.clear_fixed_latency_cache()
    tuner.clear_grid_cache()


# Thin deprecation shims: the session/target/strategy layer moved to
# repro.api, but `from repro.core import PruningSession` keeps working.
_API_SHIMS = ("PruningSession", "PruneResult", "TargetSpec", "get_target",
              "list_targets", "register_target", "get_strategy",
              "list_strategies", "register_strategy")


def __getattr__(name: str):
    if name in _API_SHIMS:
        import warnings

        import repro.api as _api
        warnings.warn(
            f"repro.core.{name} is a compatibility shim; import it from "
            f"repro.api instead", DeprecationWarning, stacklevel=2)
        return getattr(_api, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "Block", "matmul_cost", "matmul_cost_grid", "CPrune", "CPruneConfig",
    "CPruneResult", "TrainHooks", "Iterator", "Program", "lcm_prune_step",
    "program_prune_step", "Task", "TaskTable", "Workload", "TunerStats",
    "build_tuned_table", "tune_gemm", "ProgramCache", "global_cache",
    "reset_global_cache", "clear_tuning_caches", "AnalyticOracle",
    "LatencyOracle", "MeasuredOracle", "MeasurementConfig", "MeasurementLog",
    "ReplayOracle", "active_oracle", "get_oracle", "use_oracle",
]

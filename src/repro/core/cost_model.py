"""Analytic TPU v5e cost model — the "target device" of this reproduction.

The paper measures tuned programs on a phone; this container has no TPU, so
the cost model plays that role. It is deliberately a *step function* of the
tensor dims (ceil-division to MXU/VREG tiles and to the program's block
shape), which reproduces the paper's observation that conv/GEMM latency
grows in steps — the fact that makes structure-aware prune quanta matter.

Hardware constants (given for this assignment):
  peak bf16 compute : 197 TFLOP/s per chip
  HBM bandwidth     : 819 GB/s per chip
  ICI link bandwidth: ~50 GB/s per link
  MXU tile          : 128 x 128 (lane dim 128, sublane 8)
  VMEM budget       : 64 MiB usable for kernel working sets (configurable)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import numpy as np

PEAK_FLOPS_BF16 = 197e12
PEAK_FLOPS_F32 = PEAK_FLOPS_BF16 / 4      # MXU f32 is ~4x slower
HBM_BW = 819e9
ICI_BW = 50e9
VMEM_BYTES = 64 * 1024 * 1024
LANE = 128
SUBLANE = 8
MXU = 128
# fixed per-grid-step overhead (dispatch, semaphores) and per-call overhead
BLOCK_OVERHEAD_S = 0.4e-6
CALL_OVERHEAD_S = 2e-6
VPU_THROUGHPUT = 4e12                      # elementwise ops/s (epilogues)


def _ceil(a: int, b: int) -> int:
    return -(-a // b)


def _round_up(a: int, b: int) -> int:
    return _ceil(a, b) * b


def epilogue_cost(batch, epilogue_ops, gm, bm_h, gn, bn_h):
    """Fused-epilogue (activation/bias/norm on the output tile) term.
    Elementwise over arrays — the single source of truth for the scalar
    cost, the vectorized grid, and the measured oracle's analytic
    epilogue correction."""
    return batch * epilogue_ops * (gm * bm_h) * (gn * bn_h) / VPU_THROUGHPUT


def block_vmem_bytes(bm, bk, bn, dtype_bytes):
    """Working-set bytes of a (bm, bk, bn) block: double-buffered A/B input
    tiles + fp32 accumulator. Elementwise over arrays — the single source
    of truth for both Block.vmem_bytes and the tuner's vectorized filter."""
    return bm * bk * dtype_bytes * 2 + bk * bn * dtype_bytes * 2 \
        + bm * bn * 4


@dataclasses.dataclass(frozen=True)
class Block:
    """A Pallas matmul block config — the tuner's search unit."""

    bm: int
    bk: int
    bn: int

    def vmem_bytes(self, dtype_bytes: int) -> int:
        return block_vmem_bytes(self.bm, self.bk, self.bn, dtype_bytes)


def matmul_cost(m: int, k: int, n: int, block: Block, *,
                dtype_bytes: int = 2, batch: int = 1,
                epilogue_ops: int = 0) -> float:
    """Latency (s) of a (batch x) [m,k]x[k,n] GEMM with the given block config.

    Step-function semantics: dims are padded to the block grid, blocks are
    padded to hardware tiles. Compute and HBM-traffic terms overlap (take
    max), block dispatch overhead does not.
    """
    if m <= 0 or k <= 0 or n <= 0:
        return 0.0
    gm, gk, gn = _ceil(m, block.bm), _ceil(k, block.bk), _ceil(n, block.bn)
    # hardware padding inside a block
    bm_h = _round_up(block.bm, SUBLANE)
    bk_h = _round_up(block.bk, LANE)
    bn_h = _round_up(block.bn, LANE)
    n_blocks = gm * gk * gn * batch
    flops_per_block = 2 * bm_h * bk_h * bn_h
    peak = PEAK_FLOPS_BF16 if dtype_bytes <= 2 else PEAK_FLOPS_F32
    t_compute = n_blocks * flops_per_block / peak
    # HBM traffic: A panel re-read per N-block, B per M-block, C once
    bytes_a = gn * (gm * bm_h) * (gk * bk_h) * dtype_bytes
    bytes_b = gm * (gk * bk_h) * (gn * bn_h) * dtype_bytes
    bytes_c = (gm * bm_h) * (gn * bn_h) * dtype_bytes
    t_mem = batch * (bytes_a + bytes_b + bytes_c) / HBM_BW
    # epilogue (activation / bias / norm fused on output tile)
    t_epi = epilogue_cost(batch, epilogue_ops, gm, bm_h, gn, bn_h)
    return max(t_compute, t_mem) + t_epi + n_blocks * BLOCK_OVERHEAD_S \
        + CALL_OVERHEAD_S


def matmul_cost_grid(m: int, k: int, n: int,
                     bm: np.ndarray, bk: np.ndarray, bn: np.ndarray, *,
                     dtype_bytes: int = 2, batch: int = 1,
                     epilogue_ops: int = 0,
                     hw: Optional[Tuple[np.ndarray, np.ndarray,
                                        np.ndarray]] = None) -> np.ndarray:
    """Vectorized ``matmul_cost`` over a whole candidate grid.

    ``bm/bk/bn`` are parallel int arrays of block dims; returns the latency
    of every candidate in one NumPy pass. Bit-identical to the scalar path:
    every term is an exact int64 product converted to float64 in the same
    order the scalar code evaluates, so tuner selections cannot drift
    between the two implementations.

    ``hw`` optionally supplies the precomputed hardware-padded block dims
    ``(bm_h, bk_h, bn_h)`` — they depend only on the candidate grid, so the
    tuner caches them alongside the grid itself.
    """
    if m <= 0 or k <= 0 or n <= 0:
        return np.zeros(len(bm), dtype=np.float64)
    bm = np.asarray(bm, dtype=np.int64)
    bk = np.asarray(bk, dtype=np.int64)
    bn = np.asarray(bn, dtype=np.int64)
    gm, gk, gn = -(-m // bm), -(-k // bk), -(-n // bn)
    if hw is None:
        bm_h = -(-bm // SUBLANE) * SUBLANE
        bk_h = -(-bk // LANE) * LANE
        bn_h = -(-bn // LANE) * LANE
    else:
        bm_h, bk_h, bn_h = hw
    n_blocks = gm * gk * gn * batch
    flops_per_block = 2 * bm_h * bk_h * bn_h
    peak = PEAK_FLOPS_BF16 if dtype_bytes <= 2 else PEAK_FLOPS_F32
    t_compute = n_blocks * flops_per_block / peak
    bytes_a = gn * (gm * bm_h) * (gk * bk_h) * dtype_bytes
    bytes_b = gm * (gk * bk_h) * (gn * bn_h) * dtype_bytes
    bytes_c = (gm * bm_h) * (gn * bn_h) * dtype_bytes
    t_mem = batch * (bytes_a + bytes_b + bytes_c) / HBM_BW
    if epilogue_ops:
        t_epi = epilogue_cost(batch, epilogue_ops, gm, bm_h, gn, bn_h)
    else:
        t_epi = 0.0     # identical to the scalar path's exact-zero term
    return np.maximum(t_compute, t_mem) + t_epi \
        + n_blocks * BLOCK_OVERHEAD_S + CALL_OVERHEAD_S


def matmul_terms(m: int, k: int, n: int, block: Block, *,
                 dtype_bytes: int = 2, batch: int = 1
                 ) -> Tuple[float, float]:
    """(compute_s, memory_s) roofline terms for the blocked GEMM."""
    gm, gk, gn = _ceil(m, block.bm), _ceil(k, block.bk), _ceil(n, block.bn)
    bm_h = _round_up(block.bm, SUBLANE)
    bk_h = _round_up(block.bk, LANE)
    bn_h = _round_up(block.bn, LANE)
    peak = PEAK_FLOPS_BF16 if dtype_bytes <= 2 else PEAK_FLOPS_F32
    t_c = batch * gm * gk * gn * 2 * bm_h * bk_h * bn_h / peak
    bytes_a = gn * (gm * bm_h) * (gk * bk_h) * dtype_bytes
    bytes_b = gm * (gk * bk_h) * (gn * bn_h) * dtype_bytes
    bytes_c = (gm * bm_h) * (gn * bn_h) * dtype_bytes
    t_m = batch * (bytes_a + bytes_b + bytes_c) / HBM_BW
    return t_c, t_m


def default_block(m: int, k: int, n: int) -> Block:
    """The *untuned* program: a deliberately generic config (the paper's
    "without tuning" ablation uses this for every task)."""
    return Block(bm=min(_round_up(m, 8), 128), bk=min(_round_up(k, 128), 128),
                 bn=min(_round_up(n, 128), 128))


def attention_cost(batch: int, sq: int, sk: int, n_heads: int, head_dim: int,
                   *, window: int = 0, dtype_bytes: int = 2) -> float:
    """Latency of the attention score+value contraction (non-prunable op)."""
    if n_heads == 0:
        return 0.0
    kv_span = min(sk, window) if window > 0 else sk
    flops = 2 * 2 * batch * n_heads * sq * kv_span * head_dim
    t_c = flops / PEAK_FLOPS_BF16
    bytes_qkv = batch * (sq + 2 * kv_span) * n_heads * head_dim * dtype_bytes
    t_m = bytes_qkv / HBM_BW
    return max(t_c, t_m) + CALL_OVERHEAD_S


def scan_cost(batch: int, seq: int, width: int, state_bytes: int) -> float:
    """Latency of a linear-recurrence scan (RG-LRU / WKV): bandwidth bound."""
    bytes_total = batch * seq * width * 4 + state_bytes
    return bytes_total * 3 / HBM_BW + CALL_OVERHEAD_S


def collective_cost(n_bytes: int, tp: int, *, op: str = "all_reduce") -> float:
    """Latency of one tensor-parallel collective over ``tp`` ICI-linked
    shards (ring algorithm, bandwidth bound).

    An all-reduce moves ``2 * (tp-1)/tp`` of the payload over the wire
    (reduce-scatter + all-gather halves); an all-gather / reduce-scatter
    moves half that. ``tp <= 1`` is free — a single shard has nothing to
    exchange — so tp=1 plans price identically to before collectives
    existed.
    """
    if tp <= 1 or n_bytes <= 0:
        return 0.0
    if op == "all_reduce":
        wire = 2 * (tp - 1) * n_bytes / tp
    elif op in ("all_gather", "reduce_scatter"):
        wire = (tp - 1) * n_bytes / tp
    else:
        raise ValueError(f"unknown collective op {op!r}; expected "
                         "all_reduce / all_gather / reduce_scatter")
    return wire / ICI_BW + CALL_OVERHEAD_S

"""Functional pruning: slice param pytrees along a site's prunable axes.

Models read dimensions from param shapes at trace time, so pruning is pure
array surgery — no config rewrites, no module reconstruction. Stacked
(scanned) sites support *per-layer* keep indices: each subgraph prunes its
own lowest-ranked filters (paper §4.5) while the stack keeps one uniform
shape.
"""
from __future__ import annotations

import copy
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import PruneSite


def _get_parent(tree, path: str):
    parts = path.split("/")
    node = tree
    for part in parts[:-1]:
        node = node[part]
    return node, parts[-1]


def _shallow_copy_along(tree, path: str):
    """Copy the dict spine along path so the original pytree is unchanged."""
    parts = path.split("/")
    new_tree = dict(tree)
    node = new_tree
    for part in parts[:-1]:
        node[part] = dict(node[part])
        node = node[part]
    return new_tree, node, parts[-1]


def _take(arr, idx: np.ndarray, axis: int):
    # type-preserving: numpy params stay numpy (no jax dispatch/compile in
    # the candidate-surgery hot loop); jax arrays go through jnp as before
    if isinstance(arr, np.ndarray):
        return np.take(arr, np.asarray(idx), axis=axis)
    return jnp.take(arr, jnp.asarray(idx), axis=axis)


def _take_per_layer(arr, idx: np.ndarray, axis: int):
    """arr: (L, ...); idx: (L, n_keep); gather along `axis` per layer."""
    if isinstance(arr, np.ndarray):
        # contiguous per-layer gathers beat one broadcast take_along_axis
        idx = np.asarray(idx)
        return np.stack([np.take(arr[l], idx[l], axis=axis - 1)
                         for l in range(arr.shape[0])])
    xp = jnp
    idx = xp.asarray(idx)
    shape = [arr.shape[0]] + [1] * (arr.ndim - 1)
    shape[axis] = idx.shape[1]
    idx_b = idx.reshape(shape)
    idx_b = xp.broadcast_to(
        idx_b, tuple(arr.shape[i] if i != axis else idx.shape[1]
                     for i in range(arr.ndim)))
    return xp.take_along_axis(arr, idx_b, axis=axis)


def apply_keep(params: Dict, site: PruneSite, keep_idx: np.ndarray) -> Dict:
    """Return a new params pytree with the site pruned to ``keep_idx`` units.

    keep_idx: (n_keep,) shared or (L, n_keep) per-layer for stacked sites —
    indices in *unit* space (heads/channels/experts).
    """
    out = params
    per_layer = site.stacked and keep_idx.ndim == 2
    for rel_path, axis in site.param_axes:
        path = site.block_path + "/" + rel_path
        out, parent, leaf = _shallow_copy_along(out, path)
        arr = parent[leaf]
        ax = axis + (1 if site.stacked else 0)
        idx = keep_idx
        if site.unit_cols > 1 and arr.shape[ax] == site.dim * site.unit_cols:
            # expand unit indices to column indices
            cols = (idx[..., None] * site.unit_cols
                    + np.arange(site.unit_cols)[None])
            idx = cols.reshape(idx.shape[:-1] + (-1,))
        if per_layer:
            parent[leaf] = _take_per_layer(arr, idx, ax)
        else:
            parent[leaf] = _take(arr, idx, ax)
    return out


def prune_site_by_rank(params: Dict, site: PruneSite, n_prune_units: int,
                       scores: np.ndarray, *, single_subgraph: bool = False
                       ) -> Tuple[Dict, PruneSite]:
    """Prune ``n_prune_units`` lowest-scored units from the site.

    ``single_subgraph=True`` reproduces the NetAdapt-style ablation: only
    the first layer of a stacked site is pruned — but since scanned stacks
    must stay uniform, we emulate it by *masking* (zeroing) instead of
    slicing for all layers but the first. Used only by the Fig-9 ablation.
    """
    group = site.granularity if site.kind == "heads" else 1
    if single_subgraph and site.stacked and scores.ndim == 2:
        # zero the pruned channels of layer 0 only, keep shapes
        from repro.core.ranking import keep_indices
        drop = np.setdiff1d(np.arange(site.dim),
                            keep_indices(scores[0], n_prune_units, group=group))
        out = params
        for rel_path, axis in site.param_axes:
            path = site.block_path + "/" + rel_path
            out, parent, leaf = _shallow_copy_along(out, path)
            arr = parent[leaf]
            ax = axis + 1
            cols = drop
            if site.unit_cols > 1 and arr.shape[ax] == site.dim * site.unit_cols:
                cols = (drop[:, None] * site.unit_cols
                        + np.arange(site.unit_cols)[None]).reshape(-1)
            mask = np.ones((arr.shape[ax],), np.float32)
            mask[cols] = 0.0
            shape = [1] * arr.ndim
            shape[ax] = arr.shape[ax]
            parent[leaf] = arr * jnp.asarray(mask, arr.dtype).reshape(shape)
        return out, site
    from repro.core.ranking import keep_indices
    keep = keep_indices(scores, n_prune_units, group=group)
    new_params = apply_keep(params, site, keep)
    return new_params, site.with_dim(site.dim - n_prune_units)


def refresh_sites(sites: Sequence[PruneSite], pruned: Dict[str, PruneSite]
                  ) -> List[PruneSite]:
    """Replace sites by their pruned versions (by site_id)."""
    return [pruned.get(s.site_id, s) for s in sites]

"""Task / subgraph decomposition and the relationship table C (paper §3.4).

A *subgraph* is one prunable GEMM-shaped site instance; structurally
identical subgraphs (same op kind and GEMM shapes) map to one *task*. The
``TaskTable`` keeps the paper's three-way relationship:

    task  <->  associated subgraphs (sites x multiplicity)
    task  <->  fastest tuned Program per constituent GEMM

Shapes are evaluated *per device shard*: M = local tokens (batch sharded
over data axes), prunable N/K divided by the tensor-parallel degree when
the dim is model-sharded. The paper tunes for one phone; we tune for one
v5e shard of the production mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.program import Program
from repro.models.model import GemmSpec, PruneSite


@dataclasses.dataclass(frozen=True)
class Workload:
    """The target execution context a CPrune run optimizes for."""

    tokens_global: int          # batch x seq per step
    dp: int = 1                 # data-parallel degree (incl. pod axis)
    tp: int = 1                 # tensor/model-parallel degree
    dtype_bytes: int = 2

    @property
    def tokens_local(self) -> int:
        return max(1, self.tokens_global // self.dp)


def site_signature(site: PruneSite, wl: Workload) -> Tuple:
    """Task identity: op kind + per-shard GEMM shapes (paper: same subgraph
    properties -> same task)."""
    gs = tuple((g.name, g.k, g.n, g.batch, round(g.m_scale, 6))
               for g in site.gemms)
    return (site.kind, site.op_kind, site.unit_cols, gs)


def local_gemm_dims(site: PruneSite, g: GemmSpec, wl: Workload
                    ) -> Tuple[int, int, int, int]:
    """(m, k, n, batch) for one shard. The prunable dim is TP-sharded —
    except the experts router, a tiny GEMM replicated on every TP shard
    (matching ``prune_step``'s shard_multiple=1 for experts sites)."""
    m = max(1, int(wl.tokens_local * g.m_scale))
    k, n, b = g.k, g.n, g.batch
    if site.kind == "experts":
        return m, k, n, b
    if g.prunable == "n":
        n = max(1, n // wl.tp)
    elif g.prunable == "k":
        k = max(1, k // wl.tp)
    return m, k, n, b


@dataclasses.dataclass
class Task:
    """A group of identical subgraphs + their tuned programs."""

    task_id: int
    signature: Tuple
    sites: List[PruneSite]
    programs: Dict[str, Program] = dataclasses.field(default_factory=dict)
    tuned_mode: str = ""     # "tuned" | "untuned" once programs are recorded

    @property
    def tuned(self) -> bool:
        return bool(self.tuned_mode)

    @property
    def n_subgraphs(self) -> int:
        return sum(s.multiplicity for s in self.sites)

    @property
    def latency(self) -> float:
        """Per-subgraph latency (sum of constituent GEMM programs)."""
        return sum(p.latency for p in self.programs.values())

    @property
    def pruning_impact(self) -> float:
        """Paper §3.3: execution time x number of associated subgraphs."""
        return self.latency * self.n_subgraphs

    @property
    def prunable_dim(self) -> int:
        return self.sites[0].dim

    def prunable_programs(self) -> List[Tuple[Program, str]]:
        out = []
        for g in self.sites[0].gemms:
            if g.prunable in ("n", "k") and g.name in self.programs:
                out.append((self.programs[g.name], g.prunable))
        return out


class TaskTable:
    """The paper's table C: tasks <-> subgraphs <-> fastest programs."""

    def __init__(self, sites: Sequence[PruneSite], wl: Workload):
        self.wl = wl
        self.tasks: List[Task] = []
        by_sig: Dict[Tuple, Task] = {}
        self._by_site: Dict[str, Task] = {}
        for s in sites:
            sig = site_signature(s, wl)
            if sig not in by_sig:
                t = Task(task_id=len(self.tasks), signature=sig, sites=[])
                by_sig[sig] = t
                self.tasks.append(t)
            by_sig[sig].sites.append(s)
            self._by_site[s.site_id] = by_sig[sig]
        self._by_sig = by_sig

    def task_for_site(self, site_id: str) -> Optional[Task]:
        return self._by_site.get(site_id)

    def task_by_signature(self, signature: Tuple) -> Optional[Task]:
        """O(1) signature lookup — the hinge of incremental retuning."""
        return self._by_sig.get(signature)

    def ordered(self) -> List[Task]:
        """Prioritized task list R (descending pruning impact, §3.3)."""
        return sorted(self.tasks, key=lambda t: -t.pruning_impact)

    def total_task_latency(self) -> float:
        return sum(t.latency * t.n_subgraphs for t in self.tasks)

"""CPrune Algorithm 1 — the paper's iterative compiler-informed prune loop.

Symbols follow the paper:
  a_g    target (minimum) accuracy the user requires
  a_p    short-term accuracy of the previous best model
  a_s    short-term accuracy of the pruned candidate
  l_t    target execution time for the next iteration
  l_m    measured execution time of the candidate
  alpha  min allowable accuracy ratio after one prune step
  beta   ratio defining the next latency target
  R      prioritized task list; C  task/subgraph/program table

The training/eval half is injected (``TrainHooks``) so the same loop drives
the real JAX trainer in examples/ and fast synthetic surrogates in tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import applier, latency, prune_step, ranking, tuner
from repro.core.tasks import Task, TaskTable, Workload
from repro.models.model import PruneSite


@dataclasses.dataclass
class CPruneConfig:
    a_g: float                     # accuracy requirement (absolute)
    alpha: float = 0.97            # min acc ratio per accepted iteration
    beta: float = 0.98             # next latency target = beta * l_m
    max_iterations: int = 100
    rank_method: str = "l1"
    use_tuning: bool = True        # Fig. 10 ablation switch
    associated_subgraphs: bool = True   # Fig. 9 ablation switch
    selective_search: bool = True  # Fig. 11 ablation switch (False=NetAdapt-ish)
    min_dim_units: int = 8         # never prune a dim below this many units
    seq_len: int = 128             # workload sequence length (for fixed ops)
    prunable_kinds: Tuple[str, ...] = ("ffn", "moe_ffn", "heads", "experts")
    # beyond-paper (DESIGN.md §7): lane-granular steps for memory-bound tasks
    roofline_steps: bool = False


@dataclasses.dataclass
class TrainHooks:
    """Injected accuracy machinery.

    short_term_train(params, sites) -> params   (few steps of fine-tuning)
    eval_acc(params, sites) -> float            (short-term accuracy)
    long_term_train(params, sites) -> params    (final training, Alg.1 L17)
    """

    short_term_train: Callable
    eval_acc: Callable
    long_term_train: Optional[Callable] = None


@dataclasses.dataclass
class IterationRecord:
    iteration: int
    task_id: int
    task_kind: str
    prune_units: int
    dim_before: int
    dim_after: int
    l_before: float
    l_m: float
    a_s: float
    accepted: bool
    reason: str
    fps_rate: float                 # FPS gain vs original (paper Fig. 6)
    candidates_tried: int


@dataclasses.dataclass
class CPruneResult:
    params: Dict
    sites: List[PruneSite]
    history: List[IterationRecord]
    final_latency: latency.LatencyReport
    original_latency: latency.LatencyReport
    final_acc: float
    tuner_stats: tuner.TunerStats

    @property
    def fps_increase(self) -> float:
        return self.original_latency.total_s / self.final_latency.total_s


class CPrune:
    """The paper's Algorithm 1 over a JAX model."""

    def __init__(self, cfg: ModelConfig, sites: Sequence[PruneSite],
                 wl: Workload, hooks: TrainHooks, pcfg: CPruneConfig,
                 *, target=None, oracle=None):
        self.cfg = cfg
        self.wl = wl
        self.hooks = hooks
        self.pcfg = pcfg
        self.target = target      # TargetSpec (or None = active constants)
        self.oracle = oracle      # LatencyOracle (or None = active backend)
        self.stats = tuner.TunerStats()
        self.sites = [s for s in sites if s.kind in pcfg.prunable_kinds]

    # -- helpers ------------------------------------------------------------

    def _tuned_table(self, sites: Sequence[PruneSite],
                     prev: Optional[TaskTable] = None) -> TaskTable:
        """Tune a candidate's task table, carrying over every task whose
        signature the prune step did not touch (incremental retuning)."""
        return tuner.build_tuned_table(
            sites, self.wl, use_tuning=self.pcfg.use_tuning, stats=self.stats,
            prev=prev)

    def _latency(self, sites, table) -> latency.LatencyReport:
        return latency.model_latency(
            self.cfg, sites, table, seq_len=self.pcfg.seq_len,
            use_tuning=self.pcfg.use_tuning, stats=self.stats)

    def _prune_step_for(self, task: Task) -> int:
        site = task.sites[0]
        progs = task.prunable_programs()
        if not progs:
            return site.granularity
        return prune_step.program_prune_step(
            progs, granularity=site.granularity,
            shard_multiple=self.wl.tp if site.kind != "experts" else 1,
            unit_cols=site.unit_cols,
            roofline_guided=self.pcfg.roofline_steps)

    def _prune_task(self, params, sites: List[PruneSite], task: Task,
                    n_units: int) -> Tuple[Dict, List[PruneSite]]:
        """Prune all subgraphs associated with the task (§4.5) — or only the
        first site when associated_subgraphs=False (ablation)."""
        targets = task.sites if self.pcfg.associated_subgraphs \
            else task.sites[:1]
        pruned: Dict[str, PruneSite] = {}
        new_params = params
        for site in targets:
            if site.dim - n_units < self.pcfg.min_dim_units:
                continue
            scores = ranking.rank_units(new_params, site,
                                        self.pcfg.rank_method)
            new_params, new_site = applier.prune_site_by_rank(
                new_params, site, n_units, scores)
            pruned[site.site_id] = new_site
        if not pruned:
            return params, sites
        return new_params, applier.refresh_sites(sites, pruned)

    # -- Algorithm 1 ----------------------------------------------------------

    def run(self, params, *, verbose: bool = False) -> CPruneResult:
        """Run Algorithm 1 under the instance's target and latency oracle
        (tuner, cache fingerprints, and latency all see both for the
        whole loop)."""
        from repro.core import oracle as oracle_mod
        with tuner.target_activation(self.target), \
                oracle_mod.use_oracle(self.oracle):
            return self._run(params, verbose=verbose)

    def _run(self, params, *, verbose: bool = False) -> CPruneResult:
        pcfg = self.pcfg
        sites = list(self.sites)

        # Line 1: tune M, initialize l_t, a_p, C, R
        table = self._tuned_table(sites)
        rep0 = self._latency(sites, table)
        l_t = pcfg.beta * rep0.total_s
        a_p = self.hooks.eval_acc(params, sites)
        retired: set = set()          # tasks removed from R (Line 12)
        history: List[IterationRecord] = []
        rep = rep0

        it = 0
        # Line 2: while a_p > a_g and R != {}
        while a_p > pcfg.a_g and it < pcfg.max_iterations:
            R = [t for t in table.ordered() if t.signature not in retired]
            if not R:
                break
            accepted = False
            tried = 0
            # Line 3: for r in R (priority order; selective search tries the
            # head of the list first — exhaustive mode scores all of them)
            for r in R:
                tried += 1
                # Lines 4-6: prune step from the fastest program's structure
                n_units = self._prune_step_for(r)
                if r.prunable_dim - n_units < pcfg.min_dim_units:
                    retired.add(r.signature)
                    continue
                cand_params, cand_sites = self._prune_task(
                    params, sites, r, n_units)
                if cand_sites is sites:
                    retired.add(r.signature)
                    continue
                # Lines 7-9: extract tasks, tune, measure l_m — only the
                # pruned task's signatures are re-searched; the rest of the
                # table carries over from the current best model
                cand_table = self._tuned_table(cand_sites, prev=table)
                cand_rep = self._latency(cand_sites, cand_table)
                l_m = cand_rep.total_s
                # Line 10: must beat the latency target
                if l_m >= l_t:
                    if verbose:
                        print(f"  task {r.task_id}: l_m {l_m*1e3:.3f}ms >= "
                              f"l_t {l_t*1e3:.3f}ms, next task")
                    continue
                # Line 11: short-term train + accuracy
                cand_params = self.hooks.short_term_train(cand_params,
                                                          cand_sites)
                a_s = self.hooks.eval_acc(cand_params, cand_sites)
                # Line 12: accuracy gate -> retire task permanently
                if a_s < pcfg.alpha * a_p:
                    retired.add(r.signature)
                    history.append(IterationRecord(
                        iteration=it, task_id=r.task_id,
                        task_kind=r.sites[0].kind, prune_units=n_units,
                        dim_before=r.prunable_dim,
                        dim_after=r.prunable_dim - n_units,
                        l_before=rep.total_s, l_m=l_m, a_s=a_s,
                        accepted=False, reason="accuracy",
                        fps_rate=rep0.total_s / l_m,
                        candidates_tried=tried))
                    continue
                # Line 13: accept
                params, sites, table, rep = (cand_params, cand_sites,
                                             cand_table, cand_rep)
                l_t = pcfg.beta * l_m
                a_p = a_s
                history.append(IterationRecord(
                    iteration=it, task_id=r.task_id,
                    task_kind=r.sites[0].kind, prune_units=n_units,
                    dim_before=r.prunable_dim,
                    dim_after=r.prunable_dim - n_units,
                    l_before=history[-1].l_m if history else rep0.total_s,
                    l_m=l_m, a_s=a_s, accepted=True, reason="",
                    fps_rate=rep0.total_s / l_m, candidates_tried=tried))
                if verbose:
                    print(f"iter {it}: pruned task {r.task_id} "
                          f"({r.sites[0].kind}) by {n_units} -> "
                          f"l_m {l_m*1e3:.3f}ms  a_s {a_s:.4f}  "
                          f"FPSx {rep0.total_s/l_m:.2f}")
                accepted = True
                break   # Line 14
            it += 1
            if not accepted:
                # every task failed the latency or accuracy gate; the paper
                # implicitly re-enters with the same l_t — without a
                # candidate below l_t the loop would spin, so we terminate
                break

        # Line 17: final long-term training
        if self.hooks.long_term_train is not None:
            params = self.hooks.long_term_train(params, sites)
        final_acc = self.hooks.eval_acc(params, sites)
        return CPruneResult(
            params=params, sites=sites, history=history,
            final_latency=rep, original_latency=rep0, final_acc=final_acc,
            tuner_stats=self.stats)

"""Filter selection: which channels/heads/experts to remove.

The paper (§3.5, end): once the *count* is fixed by the program structure,
the *selection* is classical L1-norm magnitude ranking [Li et al. 2016].
FPGM (geometric-median) ranking is included for the Table 1 baseline.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import PruneSite


def _get_path(tree, path: str):
    node = tree
    for part in path.split("/"):
        node = node[part]
    return node


def site_param(params, site: PruneSite, rel_path: str):
    return _get_path(params, site.block_path + "/" + rel_path)


def _channel_scores_l1(params, site: PruneSite) -> np.ndarray:
    """L1 importance per prunable unit. Shape (L?, dim) — per-layer scores
    for stacked sites (each subgraph ranks its own filters, §4.5)."""
    total = None
    for rel_path, axis in site.param_axes:
        w = np.asarray(site_param(params, site, rel_path), np.float32)
        ax = axis + (1 if site.stacked else 0)
        # move prunable axis to position -1... then reduce all others except
        # (optional leading layer axis) to get per-unit scores
        w = np.moveaxis(np.abs(w), ax, -1)
        if site.stacked:
            red = tuple(range(1, w.ndim - 1))
            s = w.sum(axis=red)                       # (L, cols)
        else:
            s = w.sum(axis=tuple(range(w.ndim - 1)))  # (cols,)
        # fold unit_cols (e.g. head_dim columns per head)
        if site.unit_cols > 1 and s.shape[-1] == site.dim * site.unit_cols:
            s = s.reshape(s.shape[:-1] + (site.dim, site.unit_cols)).sum(-1)
        total = s if total is None else total + s
    return total


def _channel_scores_fpgm(params, site: PruneSite) -> np.ndarray:
    """FPGM: distance of each filter to the geometric median (approximated
    by the mean filter) — smaller distance = more redundant."""
    # use the first prunable-N param as the filter bank
    rel_path, axis = site.param_axes[0]
    w = np.asarray(site_param(params, site, rel_path), np.float32)
    ax = axis + (1 if site.stacked else 0)
    w = np.moveaxis(w, ax, -1)
    if site.stacked:
        L = w.shape[0]
        w = w.reshape(L, -1, w.shape[-1])              # (L, feat, cols)
        if site.unit_cols > 1:
            w = w.reshape(L, w.shape[1], site.dim, site.unit_cols)
            w = np.swapaxes(w, 1, 2).reshape(L, site.dim, -1)
        else:
            w = np.swapaxes(w, 1, 2)                   # (L, cols, feat)
        med = w.mean(axis=1, keepdims=True)
        return np.linalg.norm(w - med, axis=-1)        # (L, cols)
    w = w.reshape(-1, w.shape[-1])
    if site.unit_cols > 1:
        w = w.reshape(w.shape[0], site.dim, site.unit_cols)
        w = np.swapaxes(w, 0, 1).reshape(site.dim, -1)
    else:
        w = w.T
    med = w.mean(axis=0, keepdims=True)
    return np.linalg.norm(w - med, axis=-1)


def rank_units(params, site: PruneSite, method: str = "l1") -> np.ndarray:
    """Scores per prunable unit; lower = pruned first. (L?, dim)."""
    if method == "l1":
        return _channel_scores_l1(params, site)
    if method == "fpgm":
        return _channel_scores_fpgm(params, site)
    raise ValueError(method)


def keep_indices(scores: np.ndarray, n_prune: int, *,
                 group: int = 1) -> np.ndarray:
    """Indices of units to KEEP (sorted), pruning the n_prune lowest.

    ``group`` > 1 enforces uniform pruning across interleaved groups (GQA:
    prune the same number of q-heads from each KV group). Unit i belongs to
    group i % group... heads are laid out [g0u0, g1u0, ...]? We use
    contiguous blocks: head h belongs to group h // (dim/group).
    """
    dim = scores.shape[-1]
    n_keep = dim - n_prune

    def _lowest_out(row, n_drop):
        # O(n) selection: indices with the n_drop lowest scores out, sorted.
        # Which member of a tie straddling the cut survives is unspecified
        # (it already was under the previous unstable argsort).
        if n_drop <= 0:
            return np.arange(len(row))
        idx = np.argpartition(row, n_drop - 1)[n_drop:]
        return np.sort(idx)

    if group <= 1:
        if scores.ndim == 1:
            return _lowest_out(scores, n_prune)
        return np.stack([_lowest_out(row, n_prune) for row in scores])
    # grouped: prune n_prune/group lowest inside each contiguous group
    per_group = dim // group
    prune_per_group = n_prune // group

    def _one(row):
        kept = []
        for g in range(group):
            seg = row[g * per_group:(g + 1) * per_group]
            kept.append(_lowest_out(seg, prune_per_group) + g * per_group)
        return np.concatenate(kept)

    if scores.ndim == 1:
        return _one(scores)
    return np.stack([_one(r) for r in scores])

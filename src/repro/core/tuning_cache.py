"""Process-wide memoization of tuned programs (the paper's reuse of tuning
logs across CPrune iterations, cf. §4.2 "the tuning information of the
previous model is reused").

CPrune evaluates hundreds of candidate models, and almost every GEMM in a
candidate is *identical* to one already tuned — only the pruned task's
shapes change. The ``ProgramCache`` keys a tuned :class:`Program` by the
full tuning problem:

    (m, k, n, batch, dtype_bytes, epilogue_ops, vmem_budget,
     <target constants>, <oracle fingerprint>)

The target constants (peak FLOP/s, HBM bandwidth, VMEM budget, overheads)
are read from :mod:`repro.core.cost_model` at lookup time, so swapping the
emulated target (benchmarks/fig8_cross_target.py mutates those module
globals) transparently invalidates every entry — a different target is a
different key, never a stale hit. The oracle fingerprint (backend name +
measurement config + replay-log digest) is read from the active
:mod:`repro.core.oracle` backend the same way, so winners scored by the
analytic model can never be served to a measured/replay tune and vice
versa.

An optional JSON persistence layer serializes the cache so separate runs
(or separate configs in a sweep) reuse each other's tuning logs, the way
the paper reuses TVM tuning records on disk.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Dict, Optional, Tuple

from repro.core import cost_model
from repro.core import oracle as oracle_mod
from repro.core.cost_model import Block
from repro.core.program import Program

Key = Tuple

# v2: keys grew the active-oracle fingerprint; v1 logs no longer load
_FORMAT_VERSION = 2


def target_fingerprint() -> Tuple:
    """The cost-model constants a tuned program depends on.

    Read at call time: fig8-style target swaps mutate these module globals,
    and any change must miss the cache.
    """
    return (cost_model.PEAK_FLOPS_BF16, cost_model.PEAK_FLOPS_F32,
            cost_model.HBM_BW, cost_model.VMEM_BYTES,
            cost_model.BLOCK_OVERHEAD_S, cost_model.CALL_OVERHEAD_S,
            cost_model.VPU_THROUGHPUT, cost_model.LANE, cost_model.SUBLANE,
            cost_model.MXU)


def program_key(m: int, k: int, n: int, *, batch: int = 1,
                dtype_bytes: int = 2, epilogue_ops: int = 0,
                vmem: Optional[int] = None) -> Key:
    """Cache key for one GEMM tuning problem under the current target and
    the active scoring backend."""
    eff_vmem = cost_model.VMEM_BYTES if vmem is None else vmem
    return (m, k, n, batch, dtype_bytes, epilogue_ops,
            eff_vmem) + target_fingerprint() \
        + oracle_mod.active_oracle().fingerprint()


def program_to_dict(p: Program) -> Dict:
    """JSON-serializable form of a tuned program — the one wire format
    shared by the ProgramCache tuning log and deployment artifacts."""
    return {
        "m": p.m, "k": p.k, "n": p.n,
        "bm": p.block.bm, "bk": p.block.bk, "bn": p.block.bn,
        "latency": p.latency, "dtype_bytes": p.dtype_bytes,
        "batch": p.batch,
    }


def program_from_dict(d: Dict) -> Program:
    """Inverse of :func:`program_to_dict`."""
    return Program(m=d["m"], k=d["k"], n=d["n"],
                   block=Block(d["bm"], d["bk"], d["bn"]),
                   latency=d["latency"], dtype_bytes=d["dtype_bytes"],
                   batch=d["batch"])


class ProgramCache:
    """Thread-safe map from tuning problem to the fastest tuned Program."""

    def __init__(self):
        self._store: Dict[Key, Program] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._store)

    def get(self, key: Key) -> Optional[Program]:
        with self._lock:
            prog = self._store.get(key)
            if prog is None:
                self.misses += 1
            else:
                self.hits += 1
            return prog

    def put(self, key: Key, prog: Program) -> None:
        with self._lock:
            self._store[key] = prog

    def clear(self) -> None:
        with self._lock:
            self._store.clear()
            self.hits = 0
            self.misses = 0

    # -- JSON persistence (the on-disk tuning log) --------------------------

    def save(self, path: str) -> int:
        """Write all entries as JSON; returns the number saved."""
        entries = []
        with self._lock:
            for key, p in self._store.items():
                entries.append({"key": list(key),
                                "program": program_to_dict(p)})
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"version": _FORMAT_VERSION, "entries": entries}, f)
        os.replace(tmp, path)
        return len(entries)

    def load(self, path: str) -> int:
        """Merge entries from a JSON tuning log; returns the number loaded.

        Keys carry the target fingerprint, so logs recorded under a
        different target load harmlessly — they can never be hit until that
        target is active again.
        """
        with open(path) as f:
            blob = json.load(f)
        if blob.get("version") != _FORMAT_VERSION:
            return 0
        n = 0
        with self._lock:
            for e in blob["entries"]:
                self._store[tuple(e["key"])] = program_from_dict(e["program"])
                n += 1
        return n


_global_cache = ProgramCache()


def global_cache() -> ProgramCache:
    return _global_cache


def reset_global_cache() -> None:
    """Drop every memoized program (tests / cold-start benchmarking)."""
    _global_cache.clear()

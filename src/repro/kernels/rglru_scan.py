"""RG-LRU linear-recurrence Pallas kernel.

Computes s_t = a_t * s_{t-1} + x_t (elementwise over width W) with the
state held in VMEM across the whole sequence: grid (B, W/bw, S/bs) with the
sequence dim minor. Each grid step loads one (bs, bw) tile of a and x,
runs the recurrence serially in-register (VPU), writes the (bs, bw) output
tile, and leaves the carry in VMEM scratch for the next sequence block —
the state never round-trips HBM (the naive XLA scan writes it every step).

Validated with interpret=True against ref.rglru_scan_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.matmul import vmem


def _rglru_kernel(a_ref, x_ref, o_ref, s_ref, *, bs: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    a = a_ref[0].astype(jnp.float32)      # (bs, bw)
    x = x_ref[0].astype(jnp.float32)
    s0 = s_ref[...]                        # (1, bw)

    # in-block parallel scan via log-steps (associative combine)
    # (a_cum, y) after combining prefix segments
    def combine(c1, c2):
        a1, y1 = c1
        a2, y2 = c2
        return a1 * a2, a2 * y1 + y2

    # build cumulative products/sums with a sequential fori loop (bs small)
    def body(t, carry):
        s, out = carry
        s = a[t] * s + x[t]
        out = out.at[t].set(s)
        return s, out

    s_fin, out = jax.lax.fori_loop(
        0, bs, body, (s0[0], jnp.zeros((bs, a.shape[1]), jnp.float32)))
    o_ref[0] = out.astype(o_ref.dtype)
    s_ref[...] = s_fin[None]


def rglru_scan(a: jax.Array, x: jax.Array, *, bs: int = 128, bw: int = 128,
               interpret: bool = False):
    """a, x: (B, S, W). Returns (y, s_last) with zero initial state."""
    B, S, W = a.shape
    bs = min(bs, S)
    bw = min(bw, W)
    ps, pw = (-S) % bs, (-W) % bw
    if ps or pw:
        a = jnp.pad(a, ((0, 0), (0, ps), (0, pw)))
        x = jnp.pad(x, ((0, 0), (0, ps), (0, pw)))
    grid = (B, (W + pw) // bw, (S + ps) // bs)

    out = pl.pallas_call(
        functools.partial(_rglru_kernel, bs=bs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, bw), lambda b, w, s: (b, s, w)),
            pl.BlockSpec((1, bs, bw), lambda b, w, s: (b, s, w)),
        ],
        out_specs=pl.BlockSpec((1, bs, bw), lambda b, w, s: (b, s, w)),
        out_shape=jax.ShapeDtypeStruct((B, S + ps, W + pw), a.dtype),
        scratch_shapes=[vmem((1, bw), jnp.float32)],
        interpret=interpret,
    )(a, x)
    y = out[:, :S, :W]
    return y, y[:, -1].astype(jnp.float32)

"""Paged decode attention Pallas kernel: single-token queries reading K/V
through a block table (vLLM-style), online-softmax.

Grid: (B, Hq, n_cols) with the block-table column minor. The table and the
per-row sequence lengths ride in as scalar-prefetch operands
(``PrefetchScalarGridSpec``) so the KV BlockSpec index map can chase the
indirection — grid step (b, h, ki) DMAs pool block ``table[b, ki]`` for KV
head ``h // (Hq // Hkv)``; the pool itself never moves. Running max / sum /
accumulator live in VMEM scratch across column steps, exactly the
``flash_attention`` schedule with the KV walk order given by the table.

Numerics match ``blockwise_attention`` / ``ref.paged_attention_ref`` (the
oracle); positions are implicit — slot (c, o) holds absolute position
c * block_size + o, so masking ``c*bs + o >= seq_len`` is the causal mask.

``seq_lens`` must be >= 1 everywhere (a decode query always has at least
its own freshly written position; an all-masked *first* column would poison
the running max).

Validated with interpret=True against ref.paged_attention_ref.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.matmul import vmem

NEG_INF = -1e30


def _pa_kernel(tbl_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
               m_ref, l_ref, acc_ref, *, scale: float, bs: int, n_c: int):
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (1, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)         # (bs, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (1, bs)

    # slot o of column ki holds absolute position ki*bs + o; everything at
    # or past seq_len is unwritten (zero block, pad garbage, future slots)
    offs = ki * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    s = jnp.where(offs < lens_ref[b], s, NEG_INF)

    m_prev = m_ref[...][:, :1]                        # (1, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_ref[...][:, :1] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == n_c - 1)
    def _flush():
        l = l_ref[...][:, :1]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def paged_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                    block_table: jax.Array, seq_lens: jax.Array, *,
                    scale: Optional[float] = None,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Hq, D); k/v_pool: (n_blocks, bs, Hkv, D);
    block_table: (B, n_cols) int32; seq_lens: (B,) int32 (>= 1).
    Returns (B, Hq, D)."""
    B, Hq, D = q.shape
    _, bs, Hkv, _ = k_pool.shape
    g = Hq // Hkv
    n_c = block_table.shape[1]
    scale = scale if scale is not None else D ** -0.5
    grid = (B, Hq, n_c)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda b, h, ki, tbl, lens: (b, h, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda b, h, ki, tbl, lens: (tbl[b, ki], 0, h // g, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda b, h, ki, tbl, lens: (tbl[b, ki], 0, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, h, ki, tbl, lens: (b, h, 0)),
        scratch_shapes=[
            vmem((1, 128), jnp.float32),   # running max (lane-replicated)
            vmem((1, 128), jnp.float32),   # running sum
            vmem((1, D), jnp.float32),     # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_pa_kernel, scale=scale, bs=bs, n_c=n_c),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), q.dtype),
        interpret=interpret,
    )(block_table.astype(jnp.int32), seq_lens.astype(jnp.int32),
      q, k_pool, v_pool)

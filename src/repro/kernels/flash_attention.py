"""Flash attention Pallas kernel: blocked online-softmax, causal / sliding
window / GQA.

Grid: (B * Hq, Sq/bq, Sk/bk) with the KV dim minor — running max / sum /
accumulator live in VMEM scratch across KV steps (the FlashAttention-2
schedule adapted to the TPU pipeline; scores never touch HBM).

GQA is handled in the BlockSpec index maps: query head h reads KV head
h // (Hq // Hkv) — no KV replication in HBM.

Validated with interpret=True against ref.flash_attention_ref.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.matmul import vmem

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, causal: bool, window: int, n_k: int,
               bq: int, bk: int, sq: int, sk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
    k = k_ref[0].astype(jnp.float32)                  # (bk, D)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)

    qi = pl.program_id(1)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = k_pos < sk                                   # padding
    ok &= q_pos < sq
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...][:, :1]                        # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)                    # (bq, 1)
    l_new = l_ref[...][:, :1] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == n_k - 1)
    def _flush():
        l = l_ref[...][:, :1]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    scale: Optional[float] = None,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Sq, Hq, D); k/v: (B, Sk, Hkv, D) -> (B, Sq, Hq, D)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bq = min(bq, max(Sq, 8))
    bk = min(bk, max(Sk, 8))
    pq, pk = (-Sq) % bq, (-Sk) % bk
    # layout: (B*H, S, D)
    qt = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, D)
    kt = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)
    vt = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)
    if pq:
        qt = jnp.pad(qt, ((0, 0), (0, pq), (0, 0)))
    if pk:
        kt = jnp.pad(kt, ((0, 0), (0, pk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, pk), (0, 0)))
    grid = (B * Hq, (Sq + pq) // bq, (Sk + pk) // bk)

    def q_idx(bh, qi, ki):
        return (bh, qi, 0)

    def kv_idx(bh, qi, ki):
        b = bh // Hq
        h = bh % Hq
        return (b * Hkv + h // g, ki, 0)

    out = pl.pallas_call(
        functools.partial(_fa_kernel, scale=scale, causal=causal,
                          window=window, n_k=grid[2], bq=bq, bk=bk,
                          sq=Sq, sk=Sk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), q_idx),
            pl.BlockSpec((1, bk, D), kv_idx),
            pl.BlockSpec((1, bk, D), kv_idx),
        ],
        out_specs=pl.BlockSpec((1, bq, D), q_idx),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq + pq, D), q.dtype),
        scratch_shapes=[
            vmem((bq, 128), jnp.float32),   # running max (lane-replicated)
            vmem((bq, 128), jnp.float32),   # running sum
            vmem((bq, D), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :Sq].reshape(B, Hq, Sq, D).transpose(0, 2, 1, 3)
    return out

"""RWKV-6 WKV recurrence Pallas kernel.

State S (D x D per head) lives in VMEM for the entire sequence:
grid (B*H, S/bs) with the sequence dim minor. Each grid step loads a
(bs, D) tile of r/k/v/w, runs bs recurrence steps with the state resident
(outer products + row scaling on the VPU/MXU), writes the (bs, D) output
tile. The naive XLA scan ships the (D, D) state through HBM every token —
this kernel ships it never.

Validated with interpret=True against ref.rwkv6_scan_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.matmul import vmem


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_out_ref, s_ref,
                *, bs: int, n_heads: int):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)   # (bs, D)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)   # (D,) for this head

    def body(t, carry):
        s, out = carry                  # s: (D, D)
        kv = k[t][:, None] * v[t][None, :]          # (D, D)
        o = jnp.sum(r[t][:, None] * (s + u[:, None] * kv), axis=0)
        s = w[t][:, None] * s + kv
        out = out.at[t].set(o)
        return s, out

    s_fin, out = jax.lax.fori_loop(
        0, bs, body,
        (s_ref[...], jnp.zeros((bs, r.shape[1]), jnp.float32)))
    o_ref[0] = out.astype(o_ref.dtype)
    s_ref[...] = s_fin
    s_out_ref[0] = s_fin


def rwkv6_scan(r, k, v, w, u, *, bs: int = 64, interpret: bool = False):
    """r,k,v,w: (B, S, H, D); u: (H, D). Returns (o, s_last (B,H,D,D))."""
    B, S, H, D = r.shape
    bs = min(bs, S)
    ps = (-S) % bs

    def to_bh(x):
        x = x.transpose(0, 2, 1, 3).reshape(B * H, S, D)
        if ps:
            x = jnp.pad(x, ((0, 0), (0, ps), (0, 0)))
        return x

    rt, kt, vt = to_bh(r), to_bh(k), to_bh(v)
    # pad decay with ones so padded steps keep the state unchanged
    wt = to_bh(w)
    if ps:
        wt = wt.at[:, S:].set(1.0)
    grid = (B * H, (S + ps) // bs)

    o, s_last = pl.pallas_call(
        functools.partial(_wkv_kernel, bs=bs, n_heads=H),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, D), lambda bh, s: (bh, s, 0)),
            pl.BlockSpec((1, bs, D), lambda bh, s: (bh, s, 0)),
            pl.BlockSpec((1, bs, D), lambda bh, s: (bh, s, 0)),
            pl.BlockSpec((1, bs, D), lambda bh, s: (bh, s, 0)),
            pl.BlockSpec((1, D), lambda bh, s: (bh % H, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bs, D), lambda bh, s: (bh, s, 0)),
            pl.BlockSpec((1, D, D), lambda bh, s: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S + ps, D), r.dtype),
            jax.ShapeDtypeStruct((B * H, D, D), jnp.float32),
        ],
        scratch_shapes=[vmem((D, D), jnp.float32)],
        interpret=interpret,
    )(rt, kt, vt, wt, u)
    o = o[:, :S].reshape(B, H, S, D).transpose(0, 2, 1, 3)
    return o, s_last.reshape(B, H, D, D)

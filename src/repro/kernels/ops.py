"""Public jit'd wrappers for the Pallas kernels.

On a real TPU these call the compiled kernels; on this CPU container they
run in interpret mode (set ``REPRO_PALLAS_INTERPRET=0`` on TPU). The
wrappers are what the model layer would plug in via ``use_pallas=True``
paths and what the benchmarks time.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core.cost_model import Block
from repro.kernels import flash_attention as _fa
from repro.kernels import matmul as _mm
from repro.kernels import moe_gmm as _gmm
from repro.kernels import rglru_scan as _rg
from repro.kernels import rwkv6_scan as _wkv


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def matmul(a, b, *, bm: int = 128, bk: int = 256, bn: int = 256):
    return _mm.matmul(a, b, block=Block(bm, bk, bn), interpret=_interpret())


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "bq", "bk"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               bq=bq, bk=bk, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("bs", "bw"))
def rglru_scan(a, x, *, bs: int = 128, bw: int = 128):
    return _rg.rglru_scan(a, x, bs=bs, bw=bw, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("bs",))
def rwkv6_scan(r, k, v, w, u, *, bs: int = 64):
    return _wkv.rwkv6_scan(r, k, v, w, u, bs=bs, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def moe_gmm(x, w, *, bm: int = 128, bk: int = 256, bn: int = 256):
    return _gmm.moe_gmm(x, w, block=Block(bm, bk, bn),
                        interpret=_interpret())

"""Tiled matmul Pallas kernel — the tuner's target program.

The block config (bm, bk, bn) IS the "program structure" CPrune preserves:
the grid iterates (M/bm, N/bn, K/bk) with K minor (sequential accumulation
into a VMEM fp32 scratch tile). Pruning in multiples of bn (N) / bk (K)
removes whole grid steps without re-shaping any block.

TPU target: MXU-aligned blocks (bm mult of 8, bk/bn mult of 128), inputs
double-buffered by the Pallas pipeline, fp32 accumulator in VMEM.
Validated on CPU with interpret=True against ref.matmul_ref.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.cost_model import Block


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul(a: jax.Array, b: jax.Array, *, block: Block,
           out_dtype=None, interpret: bool = False) -> jax.Array:
    """[M, K] x [K, N] with the given block config. Pads to block multiples."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    out_dtype = out_dtype or a.dtype
    bm, bk, bn = block.bm, block.bk, block.bn
    pm, pk, pn = (-M) % bm, (-K) % bk, (-N) % bn
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    Mp, Kp, Np = M + pm, K + pk, N + pn
    grid = (Mp // bm, Np // bn, Kp // bk)

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[vmem((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out[:M, :N]


def vmem(shape, dtype):
    """VMEM scratch allocation (TPU); interpret mode emulates it on CPU."""
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)

"""Pallas TPU kernels (validated in interpret mode on CPU).

matmul          — tiled GEMM, configurable BlockSpec (the tuner's target)
flash_attention — blocked online-softmax attention (causal/SWA/GQA)
rglru_scan      — RG-LRU linear recurrence, state resident in VMEM
rwkv6_scan      — RWKV-6 WKV recurrence, (D,D) state resident in VMEM
moe_gmm         — grouped expert GEMM (MegaBlocks-style, TPU pipeline)
ops             — jit'd public wrappers; ref — pure-jnp oracles
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]

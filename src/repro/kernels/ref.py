"""Pure-jnp oracles for every Pallas kernel (fp32 math)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as _attn
from repro.models import rglru as _rglru
from repro.models import rwkv6 as _rwkv6


def matmul_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a.astype(jnp.float32),
                   b.astype(jnp.float32)).astype(out_dtype)


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        scale=None):
    """q: (B, Sq, Hq, D); k/v: (B, Sk, Hkv, D). Full-softmax oracle."""
    return _attn.direct_attention(q, k, v, causal=causal, window=window,
                                  scale=scale)


def paged_attention_ref(q, k_pool, v_pool, block_table, seq_lens, *,
                        scale=None):
    """Decode-attention-through-a-block-table oracle (blockwise math).

    q: (B, Hq, D); k/v_pool: (n_blocks, bs, Hkv, D); block_table: (B, n_cols)
    int32; seq_lens: (B,) int32 >= 1. Gathers each row's blocks into a dense
    (1, n_cols*bs, Hkv, D) sequence and runs ``blockwise_attention`` — slot
    (c, o) holds absolute position c*bs + o, the query sits at seq_len - 1."""
    B, Hq, D = q.shape
    _, bs, Hkv, _ = k_pool.shape
    n_c = block_table.shape[1]
    outs = []
    for b in range(B):
        kg = k_pool[block_table[b]].reshape(1, n_c * bs, Hkv, D)
        vg = v_pool[block_table[b]].reshape(1, n_c * bs, Hkv, D)
        L = int(seq_lens[b])
        iota = jnp.arange(n_c * bs, dtype=jnp.int32)
        o = _attn.blockwise_attention(
            q[b][None, None], kg, vg, causal=True,
            q_positions=jnp.asarray([L - 1], jnp.int32),
            k_positions=jnp.where(iota < L, iota, -1),
            scale=scale)
        outs.append(o[0, 0])
    return jnp.stack(outs)


def rglru_scan_ref(a: jax.Array, x: jax.Array, s0: jax.Array):
    """Elementwise linear recurrence: s_t = a_t s_{t-1} + x_t.

    a, x: (B, S, W); s0: (B, W). Returns (y (B,S,W), s_last)."""
    def step(s, inp):
        at, xt = inp
        s = at * s + xt
        return s, s

    af = a.astype(jnp.float32).swapaxes(0, 1)
    xf = x.astype(jnp.float32).swapaxes(0, 1)
    s_last, ys = jax.lax.scan(step, s0.astype(jnp.float32), (af, xf))
    return ys.swapaxes(0, 1).astype(a.dtype), s_last


def rwkv6_scan_ref(r, k, v, w, u, s0):
    """WKV oracle. r,k,v,w: (B, S, H, D); u: (H, D); s0: (B, H, D, D)."""
    return _rwkv6.wkv_scan(r, k, v, w, u, s0)


def moe_gmm_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Grouped GEMM oracle: (E, C, K) x (E, K, N) -> (E, C, N)."""
    return jnp.einsum("eck,ekn->ecn", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)

"""Pure-jnp oracles for every Pallas kernel (fp32 math)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as _attn
from repro.models import rglru as _rglru
from repro.models import rwkv6 as _rwkv6


def matmul_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a.astype(jnp.float32),
                   b.astype(jnp.float32)).astype(out_dtype)


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        scale=None):
    """q: (B, Sq, Hq, D); k/v: (B, Sk, Hkv, D). Full-softmax oracle."""
    return _attn.direct_attention(q, k, v, causal=causal, window=window,
                                  scale=scale)


def rglru_scan_ref(a: jax.Array, x: jax.Array, s0: jax.Array):
    """Elementwise linear recurrence: s_t = a_t s_{t-1} + x_t.

    a, x: (B, S, W); s0: (B, W). Returns (y (B,S,W), s_last)."""
    def step(s, inp):
        at, xt = inp
        s = at * s + xt
        return s, s

    af = a.astype(jnp.float32).swapaxes(0, 1)
    xf = x.astype(jnp.float32).swapaxes(0, 1)
    s_last, ys = jax.lax.scan(step, s0.astype(jnp.float32), (af, xf))
    return ys.swapaxes(0, 1).astype(a.dtype), s_last


def rwkv6_scan_ref(r, k, v, w, u, s0):
    """WKV oracle. r,k,v,w: (B, S, H, D); u: (H, D); s0: (B, H, D, D)."""
    return _rwkv6.wkv_scan(r, k, v, w, u, s0)


def moe_gmm_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Grouped GEMM oracle: (E, C, K) x (E, K, N) -> (E, C, N)."""
    return jnp.einsum("eck,ekn->ecn", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)

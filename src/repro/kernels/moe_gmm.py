"""Grouped expert GEMM Pallas kernel: (E, C, K) x (E, K, N) -> (E, C, N).

The MoE hot path after capacity dispatch. Grid (E, C/bm, N/bn, K/bk) with K
minor; expert weights stream through VMEM once per (C-block, N-block), the
fp32 accumulator lives in VMEM scratch. This is MegaBlocks' grouped GEMM
adapted to the TPU pipeline (dense per-expert tiles instead of CUDA
block-sparse descriptors).

Validated with interpret=True against ref.moe_gmm_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.cost_model import Block
from repro.kernels.matmul import vmem


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[0], w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(3) == n_k - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def moe_gmm(x: jax.Array, w: jax.Array, *, block: Block = Block(128, 256, 256),
            interpret: bool = False) -> jax.Array:
    """x: (E, C, K); w: (E, K, N) -> (E, C, N)."""
    E, C, K = x.shape
    _, _, N = w.shape
    bm, bk, bn = min(block.bm, C), min(block.bk, K), min(block.bn, N)
    pc, pk, pn = (-C) % bm, (-K) % bk, (-N) % bn
    if pc or pk:
        x = jnp.pad(x, ((0, 0), (0, pc), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, 0), (0, pk), (0, pn)))
    grid = (E, (C + pc) // bm, (N + pn) // bn, (K + pk) // bk)

    out = pl.pallas_call(
        functools.partial(_gmm_kernel, n_k=grid[3]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bk, bn), lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C + pc, N + pn), x.dtype),
        scratch_shapes=[vmem((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
    return out[:, :C, :N]

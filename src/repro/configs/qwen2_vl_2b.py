"""Qwen2-VL-2B — VLM language backbone with M-RoPE.

[arXiv:2409.12191; hf] 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936, M-RoPE, dynamic resolution. The vision frontend (ViT) is a
STUB: input_specs() provides precomputed patch embeddings merged into the
token stream, plus 3-channel (t,h,w) M-RoPE position ids.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2_vl_2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    activation="swiglu",
    rope="mrope",
    rope_theta=1000000.0,
    tie_embeddings=True,
    norm="rmsnorm",
    frontend="vision_patches",
    frontend_seq=256,
    source="arXiv:2409.12191",
)


def reduced() -> ModelConfig:
    return CONFIG.with_overrides(
        name="qwen2_vl_2b_reduced",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        frontend_seq=8,
    )

"""RecurrentGemma-9B — Griffin-style hybrid: RG-LRU + local attention, 1:2.

[arXiv:2402.19427; unverified] 38L d_model=4096 16H (GQA kv=1, i.e. MQA)
d_ff=12288 vocab=256000. Pattern: two RG-LRU recurrent blocks then one
local-attention block (window 2048), repeating.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma_9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "local_attn"),
    sliding_window=2048,
    rglru_width=4096,
    conv1d_width=4,
    activation="geglu",
    rope="rope",
    rope_theta=10000.0,
    tie_embeddings=True,
    norm="rmsnorm",
    logits_softcap=30.0,
    remat="full",
    source="arXiv:2402.19427",
)


def reduced() -> ModelConfig:
    return CONFIG.with_overrides(
        name="recurrentgemma_9b_reduced",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        sliding_window=32,
        rglru_width=64,
        logits_softcap=30.0,
    )

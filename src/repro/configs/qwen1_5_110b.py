"""Qwen1.5-110B — dense transformer with QKV bias.

[hf:Qwen/Qwen1.5-0.5B family; hf] 80L d_model=8192 64H (GQA kv=8)
d_ff=49152 vocab=152064, QKV bias.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1_5_110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=49152,
    vocab_size=152064,
    qkv_bias=True,
    activation="swiglu",
    rope="rope",
    rope_theta=1000000.0,
    norm="rmsnorm",
    remat="full",
    source="hf:Qwen/Qwen1.5-110B",
)


def reduced() -> ModelConfig:
    return CONFIG.with_overrides(
        name="qwen1_5_110b_reduced",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=256,
        vocab_size=256,
    )

"""Model configuration system.

One frozen dataclass covers every assigned architecture family (dense, MoE,
hybrid recurrent, SSM/RWKV, audio encoder, VLM backbone). Each architecture
ships as ``src/repro/configs/<id>.py`` exposing ``CONFIG`` (the exact
published shape) and ``reduced()`` (a tiny same-family variant for CPU smoke
tests and the CPrune example loops).

Configs are pure data — no jax imports here, so the launcher can read them
before device initialization (the dry-run must set XLA_FLAGS first).
"""
from __future__ import annotations

import dataclasses
import importlib
import math
from dataclasses import dataclass, field, replace
from typing import Any, Dict, Optional, Tuple

# Block kinds understood by models/blocks.py
ATTN = "attn"            # global (causal or bidirectional) attention block
LOCAL_ATTN = "local_attn"  # sliding-window attention block
RGLRU = "rglru"          # Griffin RG-LRU recurrent block
RWKV = "rwkv"            # RWKV-6 time-mix + channel-mix block

VALID_BLOCKS = (ATTN, LOCAL_ATTN, RGLRU, RWKV)


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters (exact published values in configs/<id>.py)."""

    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                   # query heads (0 for attention-free archs)
    n_kv_heads: int                # KV heads (GQA); == n_heads means MHA
    d_ff: int                      # dense-FFN hidden width
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0              # expert hidden width (0 -> d_ff)
    moe_cf: float = 1.25           # expert capacity factor (per-row dispatch)

    # --- attention details ---
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: int = 0        # 0 -> no sliding window on LOCAL_ATTN/ATTN
    causal: bool = True            # False for encoder-only (hubert)
    logits_softcap: float = 0.0

    # --- block pattern (repeated; remainder layers reuse the prefix) ---
    block_pattern: Tuple[str, ...] = (ATTN,)

    # --- FFN ---
    activation: str = "swiglu"     # swiglu | geglu | gelu | relu2 | silu

    # --- positional encoding ---
    rope: str = "rope"             # rope | mrope | none
    rope_theta: float = 10000.0

    # --- embeddings / norm ---
    tie_embeddings: bool = False
    norm: str = "rmsnorm"          # rmsnorm | layernorm

    # --- RWKV specifics ---
    rwkv_head_dim: int = 64

    # --- RG-LRU specifics ---
    rglru_width: int = 0           # recurrence width (0 -> d_model)
    conv1d_width: int = 4          # temporal conv in recurrent block

    # --- modality frontend stubs ---
    frontend: str = "none"         # none | audio_frames | vision_patches
    frontend_seq: int = 0          # patches/frames per sample for stub inputs

    # --- numerics / compile strategy ---
    dtype: str = "bfloat16"
    scan_layers: bool = True       # scan over layer stacks (keeps HLO small)
    remat: str = "dots"            # none | dots | full

    # --- provenance ---
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.moe_d_ff == 0 and self.n_experts > 0:
            object.__setattr__(self, "moe_d_ff", self.d_ff)
        if self.rglru_width == 0:
            object.__setattr__(self, "rglru_width", self.d_model)
        for b in self.block_pattern:
            if b not in VALID_BLOCKS:
                raise ValueError(f"unknown block kind {b!r}")

    # ------------------------------------------------------------------
    # Derived structure
    # ------------------------------------------------------------------
    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind, repeating ``block_pattern`` with remainder."""
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    def stacks(self) -> Dict[str, int]:
        """Block kind -> number of layers of that kind."""
        out: Dict[str, int] = {}
        for k in self.layer_kinds():
            out[k] = out.get(k, 0) + 1
        return out

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def attention_free(self) -> bool:
        return all(k == RWKV for k in self.block_pattern)

    @property
    def subquadratic(self) -> bool:
        """True if the arch supports O(1)-state or windowed decode at 500k ctx."""
        kinds = set(self.block_pattern)
        if kinds <= {RWKV, RGLRU, LOCAL_ATTN}:
            return True
        # global attention with a sliding window is still bounded-KV
        return self.sliding_window > 0

    @property
    def n_q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    # ------------------------------------------------------------------
    # Parameter counting (used by roofline: MODEL_FLOPS = 6·N·D)
    # ------------------------------------------------------------------
    def param_count(self, active_only: bool = False) -> int:
        d, dff, hd = self.d_model, self.d_ff, self.head_dim
        nq, nkv = self.n_heads, self.n_kv_heads
        total = 0
        glu = self.activation in ("swiglu", "geglu")
        for kind in self.layer_kinds():
            if kind in (ATTN, LOCAL_ATTN):
                attn = d * nq * hd + 2 * d * nkv * hd + nq * hd * d
                if self.qkv_bias:
                    attn += (nq + 2 * nkv) * hd
                total += attn
            elif kind == RGLRU:
                w = self.rglru_width
                # linear in/out + gates + conv1d + recurrence params
                total += 2 * d * w + 2 * w * w // 1 + self.conv1d_width * w + 2 * w
            elif kind == RWKV:
                # time-mix: r,k,v,g,o projections + decay LoRAs; channel-mix
                total += 5 * d * d + 6 * 32 * d * 2
            # FFN (dense or MoE)
            if self.n_experts > 0 and kind in (ATTN, LOCAL_ATTN, RGLRU):
                e_ff = self.moe_d_ff
                per_e = d * e_ff * (3 if glu else 2)
                total += self.n_experts * per_e + d * self.n_experts  # + router
            elif kind == RWKV:
                total += 2 * d * self.d_ff  # channel-mix (relu^2 k, v)
            else:
                total += d * dff * (3 if glu else 2)
        total += self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d  # lm head
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE uses top_k of n_experts)."""
        if self.n_experts == 0:
            return self.param_count()
        dense = self.param_count()
        glu = self.activation in ("swiglu", "geglu")
        per_e = self.d_model * self.moe_d_ff * (3 if glu else 2)
        n_moe_layers = sum(1 for k in self.layer_kinds() if k != RWKV)
        unused = (self.n_experts - self.top_k) * per_e * n_moe_layers
        return dense - unused

    def with_overrides(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (assigned shapes from the public pool)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


ARCH_IDS = (
    "recurrentgemma_9b",
    "mixtral_8x22b",
    "granite_moe_1b_a400m",
    "nemotron_4_15b",
    "qwen1_5_110b",
    "qwen3_1_7b",
    "internlm2_20b",
    "rwkv6_1_6b",
    "hubert_xlarge",
    "qwen2_vl_2b",
)


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch x shape) cell runs, with the reason when skipped."""
    if shape.kind == "decode" and cfg.is_encoder_only:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: 500k decode is the quadratic regime"
    return True, ""


def get_config(arch_id: str) -> ModelConfig:
    """Load the full published config for an assigned architecture."""
    arch_id = arch_id.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def get_reduced_config(arch_id: str) -> ModelConfig:
    """Load the reduced same-family smoke config for an architecture.

    Reduced configs run in float32 (CPU test numerics) — the full configs
    keep their production dtype (bfloat16).
    """
    arch_id = arch_id.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.reduced().with_overrides(dtype="float32")


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}

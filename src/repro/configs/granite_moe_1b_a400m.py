"""Granite-3.0-1B-A400M — fine-grained MoE, 32 experts top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf] 24L d_model=1024 16H
(GQA kv=8) d_ff=512 vocab=49155, MoE 32 experts top-8.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite_moe_1b_a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    n_experts=32,
    top_k=8,
    moe_d_ff=512,
    activation="swiglu",
    rope="rope",
    rope_theta=10000.0,
    tie_embeddings=True,
    norm="rmsnorm",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)


def reduced() -> ModelConfig:
    return CONFIG.with_overrides(
        name="granite_moe_1b_a400m_reduced",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=64,
        moe_d_ff=64,
        vocab_size=256,
        n_experts=8,
        top_k=2,
        moe_cf=8.0,     # dropless at smoke scale (decode==forward tests)
    )

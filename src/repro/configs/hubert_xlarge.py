"""HuBERT X-Large — encoder-only audio transformer (w2v2-style backbone).

[arXiv:2106.07447; unverified] 48L d_model=1280 16H (kv=16, i.e. MHA)
d_ff=5120 vocab=504 (cluster codebook). The conv waveform frontend is a
STUB: input_specs() provides precomputed frame embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert_xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    causal=False,              # encoder-only, bidirectional
    activation="gelu",
    rope="none",               # conv-positional frontend is stubbed
    norm="layernorm",
    frontend="audio_frames",
    source="arXiv:2106.07447",
)


def reduced() -> ModelConfig:
    return CONFIG.with_overrides(
        name="hubert_xlarge_reduced",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=32,
    )

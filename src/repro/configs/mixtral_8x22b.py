"""Mixtral-8x22B — sparse MoE transformer, 8 experts top-2, sliding window.

[arXiv:2401.04088; hf] 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, SWA.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral_8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    n_experts=8,
    top_k=2,
    moe_d_ff=16384,
    sliding_window=4096,
    activation="swiglu",
    rope="rope",
    rope_theta=1000000.0,
    norm="rmsnorm",
    remat="full",
    source="arXiv:2401.04088",
)


def reduced() -> ModelConfig:
    return CONFIG.with_overrides(
        name="mixtral_8x22b_reduced",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        moe_d_ff=128,
        vocab_size=256,
        n_experts=4,
        top_k=2,
        sliding_window=32,
        moe_cf=8.0,     # dropless at smoke scale (decode==forward tests)
    )

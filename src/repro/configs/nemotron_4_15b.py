"""Nemotron-4-15B — dense transformer with squared-ReLU FFN.

[arXiv:2402.16819; unverified] 32L d_model=6144 48H (GQA kv=8)
d_ff=24576 vocab=256000, squared-ReLU activation (no GLU).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron_4_15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256000,
    activation="relu2",
    rope="rope",
    rope_theta=10000.0,
    norm="layernorm",
    remat="full",
    source="arXiv:2402.16819",
)


def reduced() -> ModelConfig:
    return CONFIG.with_overrides(
        name="nemotron_4_15b_reduced",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=256,
        vocab_size=256,
    )

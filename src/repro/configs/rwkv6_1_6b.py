"""RWKV-6 "Finch" 1.6B — attention-free, data-dependent decay.

[arXiv:2404.05892; unverified] 24L d_model=2048 (attn-free) d_ff=7168
vocab=65536. Head size 64 -> 32 WKV heads.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6_1_6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=7168,
    vocab_size=65536,
    block_pattern=("rwkv",),
    rwkv_head_dim=64,
    activation="relu2",   # RWKV channel-mix uses squared ReLU
    rope="none",
    norm="layernorm",
    source="arXiv:2404.05892",
)


def reduced() -> ModelConfig:
    return CONFIG.with_overrides(
        name="rwkv6_1_6b_reduced",
        n_layers=2,
        d_model=64,
        d_ff=128,
        vocab_size=256,
        rwkv_head_dim=16,
    )

"""Qwen3-1.7B — dense transformer with QK-norm.

[hf:Qwen/Qwen3-8B family; hf] 28L d_model=2048 16H (GQA kv=8)
d_ff=6144 vocab=151936, qk_norm, GQA, head_dim=128.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3_1_7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151936,
    qk_norm=True,
    activation="swiglu",
    rope="rope",
    rope_theta=1000000.0,
    tie_embeddings=True,
    norm="rmsnorm",
    source="hf:Qwen/Qwen3-1.7B",
)


def reduced() -> ModelConfig:
    return CONFIG.with_overrides(
        name="qwen3_1_7b_reduced",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
    )

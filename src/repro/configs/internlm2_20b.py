"""InternLM2-20B — dense GQA transformer.

[arXiv:2403.17297; hf] 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2_20b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92544,
    activation="swiglu",
    rope="rope",
    rope_theta=1000000.0,
    norm="rmsnorm",
    remat="full",
    source="arXiv:2403.17297",
)


def reduced() -> ModelConfig:
    return CONFIG.with_overrides(
        name="internlm2_20b_reduced",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
    )

"""Logical-axis sharding constraints (MaxText-style).

Model code calls ``constrain(x, ("batch", "seq", None))`` with *logical*
names; the launcher activates a rule set mapping logical names to mesh
axes. Outside an active rule set the call is a no-op, so models run
unmodified on a single device.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _active():
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def set_rules(mesh: Mesh, rules: Dict[str, Union[str, Tuple[str, ...], None]]):
    """Activate logical->mesh axis rules for the enclosed trace."""
    prev = _active()
    _state.rules = (mesh, dict(rules))
    try:
        yield
    finally:
        _state.rules = prev


def resolve(names: Sequence[Optional[str]], shape=None) -> Optional[P]:
    active = _active()
    if active is None:
        return None
    mesh, rules = active
    axes = []
    for i, n in enumerate(names):
        ax = rules.get(n) if n is not None else None
        if ax is not None and shape is not None:
            sizes = mesh.shape
            total = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                total *= sizes[a]
            if shape[i] % total != 0:
                ax = None            # non-divisible: drop the constraint
        axes.append(ax)
    return P(*axes)


def constrain(x: jax.Array, names: Sequence[Optional[str]]) -> jax.Array:
    """Apply a logical sharding constraint (no-op without active rules)."""
    active = _active()
    if active is None:
        return x
    mesh, _ = active
    spec = resolve(names, x.shape)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

from repro.sharding.logical import constrain, set_rules
from repro.sharding.rules import (batch_pspecs, cache_pspecs, data_axes,
                                  param_pspecs)

__all__ = ["constrain", "set_rules", "batch_pspecs", "cache_pspecs",
           "data_axes", "param_pspecs"]

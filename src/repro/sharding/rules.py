"""Parameter / batch / cache PartitionSpecs for the production meshes.

Strategy (per DESIGN.md §5):
  * weights: tensor-parallel over ``model`` on heads / d_ff / vocab, and
    FSDP-style fully-sharded over the data axes on the complementary dim —
    a 110B-param arch must fit 16 GB/chip including optimizer state.
  * batch dims over the data axes (``('pod','data')`` on the multi-pod mesh).
  * decode KV caches: batch over data, sequence dim over ``model``
    (sequence-parallel KV), recurrent states: width/heads over ``model``.

Every rule degrades gracefully: a mesh axis is dropped for a dim it does
not divide (e.g. 12 heads on a 16-way model axis -> heads replicated, the
d_ff rule still shards the FFN).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ATTN, LOCAL_ATTN, RGLRU, RWKV, ModelConfig

Axis = Optional[Any]


class SpecMesh:
    """Shape-only stand-in for a :class:`jax.sharding.Mesh`.

    Everything in this module that computes bare PartitionSpecs (not
    NamedShardings) only reads ``mesh.shape``, so the spec math can run
    with no devices at all — artifact partition stamping and rule
    coverage tests use this instead of forcing backend init."""

    def __init__(self, shape: Dict[str, int]):
        self.shape = dict(shape)


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


# Trailing-dim rules: leaf-name -> spec for the LAST len(spec) dims.
# Leading dims (layer stacks, expert dims handled explicitly) replicate.
def _rules(DATA) -> Dict[str, Tuple[Axis, ...]]:
    return {
        # embeddings
        "embed": ("model", DATA),
        "lm_head": (DATA, "model"),
        # attention
        "wq": (DATA, "model", None),
        "wk": (DATA, "model", None),
        "wv": (DATA, "model", None),
        "wo": ("model", None, DATA),
        "bq": ("model", None),
        "bk": ("model", None),
        "bv": ("model", None),
        # FFN / MoE (rank-2 dense or rank-3 expert-stacked; trailing match)
        "w_up": (DATA, "model"),
        "w_gate": (DATA, "model"),
        "w_down": ("model", DATA),
        "router": (DATA, None),
        # RWKV
        "w_r": (DATA, "model"),
        "w_k": (DATA, "model"),
        "w_v": (DATA, "model"),
        "w_g": (DATA, "model"),
        "w_o": ("model", DATA),
        "w_ck": (DATA, "model"),
        "w_cv": ("model", DATA),
        "w_cr": (DATA, "model"),
        "tm_w1": (DATA, None),
        "tm_w2": (None, None, "model"),
        "td_w1": (DATA, None),
        "td_w2": (None, "model"),
        "w0": ("model",),
        # RG-LRU
        "w_x": (DATA, "model"),
        "w_out": ("model", DATA),
        "w_a": ("model", None, None),
        "w_i": ("model", None, None),
        "lam": ("model",),
        "b_a": ("model",),
        "b_i": ("model",),
        "conv_w": (None, "model"),
        "conv_b": ("model",),
    }


def _fit(spec: Tuple[Axis, ...], shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Trailing-dim spec -> full-rank PartitionSpec, dropping non-divisible
    axes."""
    full: list = [None] * (len(shape) - len(spec)) + list(spec)
    out = []
    for dim, ax in zip(shape, full):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        total = int(np.prod([mesh.shape[a] for a in axes]))
        out.append(ax if dim % total == 0 and dim >= total else None)
    return P(*out)


def fit_spec(trailing_spec: Tuple[Axis, ...], shape: Tuple[int, ...],
             mesh: Mesh) -> P:
    """Public helper: trailing-dim spec with divisibility fallback."""
    return _fit(trailing_spec, shape, mesh)


def param_pspecs(params, mesh: Mesh):
    """PartitionSpec pytree matching the params pytree."""
    DATA = data_axes(mesh)
    DATA = DATA if len(DATA) > 1 else (DATA[0] if DATA else None)
    rules = _rules(DATA)

    def spec_for(path, leaf) -> P:
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = str(entry.key)
                break
        shape = np.shape(leaf)
        rule = rules.get(name)
        if rule is None or len(rule) > len(shape):
            return P()
        return _fit(rule, shape, mesh)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def shardings_of(pspecs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_pspecs(batch: Dict[str, Any], mesh: Mesh):
    """Batch dims over the data axes; everything else replicated."""
    DATA = data_axes(mesh)
    DATA = DATA if len(DATA) > 1 else (DATA[0] if DATA else None)

    def spec_for(leaf) -> P:
        shape = np.shape(leaf)
        if len(shape) == 0:
            return P()
        return _fit((DATA,) + (None,) * (len(shape) - 1), shape, mesh)

    return jax.tree.map(spec_for, batch)


def cache_pspecs(model, caches, mesh: Mesh):
    """Specs mirroring Model.init_caches structure (built semantically)."""
    from repro.models.attention import KVCache
    from repro.models.rglru import RGLRUState
    from repro.models.rwkv6 import RWKVState

    DATA = data_axes(mesh)
    DATA = DATA if len(DATA) > 1 else (DATA[0] if DATA else None)

    def kv_spec(cache: KVCache, stacked: bool) -> KVCache:
        lead = (None,) if stacked else ()
        return KVCache(
            k=_fit(lead + (DATA, "model", None, None), _sh(cache.k), mesh),
            v=_fit(lead + (DATA, "model", None, None), _sh(cache.v), mesh),
            slot_pos=P(*((None,) * np.ndim(cache.slot_pos))),
        )

    def rg_spec(st: RGLRUState, stacked: bool) -> RGLRUState:
        lead = (None,) if stacked else ()
        return RGLRUState(
            s=_fit(lead + (DATA, "model"), _sh(st.s), mesh),
            conv=_fit(lead + (DATA, None, "model"), _sh(st.conv), mesh),
        )

    def rwkv_spec(st: RWKVState, stacked: bool) -> RWKVState:
        lead = (None,) if stacked else ()
        return RWKVState(
            tm_x=_fit(lead + (DATA, "model"), _sh(st.tm_x), mesh),
            wkv=_fit(lead + (DATA, "model", None, None), _sh(st.wkv), mesh),
            cm_x=_fit(lead + (DATA, "model"), _sh(st.cm_x), mesh),
        )

    def _sh(x):
        return np.shape(x)

    def spec_one(c, stacked: bool):
        if isinstance(c, KVCache):
            return kv_spec(c, stacked)
        if isinstance(c, RGLRUState):
            return rg_spec(c, stacked)
        if isinstance(c, RWKVState):
            return rwkv_spec(c, stacked)
        raise TypeError(type(c))

    out = {"stack": {}, "tail": {}, "pos": P()}
    for k, c in caches["stack"].items():
        out["stack"][k] = spec_one(c, stacked=True)
    for k, c in caches["tail"].items():
        out["tail"][k] = spec_one(c, stacked=False)
    return out


def zero3_gather_fn(mesh: Mesh):
    """ZeRO-3 weight gathering: inside the layer, constrain each weight to
    its spec *minus the data axes* (keep tensor-parallel 'model' shards).

    GSPMD then all-gathers a layer's FSDP weight shards right before use
    (cheap: one layer's weights) instead of partial-summing activations
    over the data-sharded contraction dim and all-reducing token-scaled
    tensors (ruinously expensive at 1M tokens/step — see EXPERIMENTS.md
    §Perf, mixtral train_4k).
    """
    DATA = data_axes(mesh)
    DATA = DATA if len(DATA) > 1 else (DATA[0] if DATA else None)
    rules = _rules(DATA)
    data_set = {"pod", "data"}

    def strip_data(ax):
        if ax is None:
            return None
        axes = ax if isinstance(ax, tuple) else (ax,)
        kept = tuple(a for a in axes if a not in data_set)
        if not kept:
            return None
        return kept if len(kept) > 1 else kept[0]

    def gather(block_params):
        def spec_for(path, leaf):
            name = None
            for entry in reversed(path):
                if isinstance(entry, jax.tree_util.DictKey):
                    name = str(entry.key)
                    break
            shape = np.shape(leaf)
            rule = rules.get(name)
            if rule is None or len(rule) > len(shape):
                return leaf
            spec = _fit(tuple(strip_data(a) for a in rule), shape, mesh)
            return jax.lax.with_sharding_constraint(
                leaf, NamedSharding(mesh, spec))
        return jax.tree_util.tree_map_with_path(spec_for, block_params)

    return gather


def logical_rules(mesh: Mesh, *, seq_shard: bool = True) -> Dict[str, Any]:
    """Rules for sharding/logical.constrain calls inside model code."""
    DATA = data_axes(mesh)
    DATA = DATA if len(DATA) > 1 else (DATA[0] if DATA else None)
    return {
        "batch": DATA,
        "seq": "model" if seq_shard else None,   # Megatron-SP residual
        "embed": None,
        "mlp": "model",
        "expert": None,
        "vocab": "model",
        "heads": "model",
    }

"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Recurrence (per channel, fp32):
    r_t = sigmoid(W_a h_t + b_a)          # recurrence gate
    i_t = sigmoid(W_i h_t + b_i)          # input gate
    a_t = exp(-c * softplus(Lambda) * r_t),  c = 8
    s_t = a_t * s_{t-1} + sqrt(1 - a_t^2) * (i_t * h_t)

Train/prefill uses ``lax.associative_scan`` (parallel over seq); decode is a
single-step update. The block wraps the recurrence Griffin-style:
    out = W_out( gelu(W_gate x) * RGLRU(conv1d(W_x x)) )
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers

_C = 8.0


class RGLRUState(NamedTuple):
    s: jax.Array      # (B, width) recurrent state, fp32
    conv: jax.Array   # (B, conv_width - 1, width) trailing conv inputs


def init_rglru_state(batch: int, width: int, conv_width: int) -> RGLRUState:
    return RGLRUState(
        s=jnp.zeros((batch, width), jnp.float32),
        conv=jnp.zeros((batch, conv_width - 1, width), jnp.float32),
    )


def _block_linear(w: jax.Array, h: jax.Array) -> jax.Array:
    """Block-diagonal linear (RecurrentGemma's gate structure).

    w: (nb, wb, wb); h: (..., nb*wb) -> (..., nb*wb).
    """
    nb, wb, _ = w.shape
    hb = h.reshape(h.shape[:-1] + (nb, wb))
    out = jnp.einsum("...ni,nij->...nj", hb, w)
    return out.reshape(h.shape)


def _gates(params: dict, h: jax.Array):
    """h: (..., w) -> (a, beta_in) both fp32."""
    hf = h.astype(jnp.float32)
    r = jax.nn.sigmoid(_block_linear(params["w_a"].astype(jnp.float32), hf)
                       + params["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(_block_linear(params["w_i"].astype(jnp.float32), hf)
                       + params["b_i"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) * (i * hf)
    return a, beta


def rglru_scan(params: dict, h: jax.Array, s0: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Parallel linear recurrence. h: (B, S, w), s0: (B, w). Returns (y, s_last)."""
    a, beta = _gates(params, h)   # (B, S, w) fp32
    # Fold the initial state into the first step: s_1 = a_1 s_0 + beta_1.
    beta = beta.at[:, 0].add(a[:, 0] * s0.astype(jnp.float32))

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    a_c, y = jax.lax.associative_scan(combine, (a, beta), axis=1)
    return y.astype(h.dtype), y[:, -1]


def rglru_step(params: dict, h: jax.Array, s: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single-token step. h: (B, w), s: (B, w) fp32."""
    a, beta = _gates(params, h)
    s_new = a * s + beta
    return s_new.astype(h.dtype), s_new


# ---------------------------------------------------------------------------
# Causal depthwise conv1d (width cw, per-channel)
# ---------------------------------------------------------------------------

def conv1d_causal(params: dict, x: jax.Array) -> jax.Array:
    """x: (B, S, w). Depthwise causal conv of width cw."""
    w = params["conv_w"].astype(jnp.float32)     # (cw, width)
    cw = w.shape[0]
    xf = x.astype(jnp.float32)
    out = xf * w[cw - 1]
    for i in range(1, cw):
        shifted = jnp.pad(xf, ((0, 0), (i, 0), (0, 0)))[:, : xf.shape[1]]
        out = out + shifted * w[cw - 1 - i]
    out = out + params["conv_b"].astype(jnp.float32)
    return out.astype(x.dtype)


def conv1d_step(params: dict, x: jax.Array, conv_state: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, w) one token; conv_state: (B, cw-1, w) trailing inputs."""
    w = params["conv_w"].astype(jnp.float32)
    cw = w.shape[0]
    xf = x.astype(jnp.float32)
    window = jnp.concatenate([conv_state, xf[:, None]], axis=1)  # (B, cw, w)
    out = jnp.einsum("bcw,cw->bw", window, w) + params["conv_b"].astype(jnp.float32)
    new_state = window[:, 1:]
    return out.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# The full recurrent block
# ---------------------------------------------------------------------------

def rglru_block(params: dict, x: jax.Array, cfg) -> jax.Array:
    """Train/prefill path. x: (B, S, d) -> (B, S, d)."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["w_gate"]))
    h = jnp.einsum("bsd,dw->bsw", x, params["w_x"])
    h = conv1d_causal(params, h)
    B = x.shape[0]
    s0 = jnp.zeros((B, h.shape[-1]), jnp.float32)
    y, _ = rglru_scan(params, h, s0)
    return jnp.einsum("bsw,wd->bsd", gate * y, params["w_out"])


def rglru_block_prefill(params: dict, x: jax.Array, cfg
                        ) -> Tuple[jax.Array, RGLRUState]:
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["w_gate"]))
    h_in = jnp.einsum("bsd,dw->bsw", x, params["w_x"])
    h = conv1d_causal(params, h_in)
    B, S, W = h.shape
    cw = params["conv_w"].shape[0]
    s0 = jnp.zeros((B, W), jnp.float32)
    y, s_last = rglru_scan(params, h, s0)
    out = jnp.einsum("bsw,wd->bsd", gate * y, params["w_out"])
    # trailing conv inputs (pre-conv h_in), padded if S < cw-1
    tail = h_in.astype(jnp.float32)
    if S >= cw - 1:
        conv_tail = tail[:, S - (cw - 1):]
    else:
        conv_tail = jnp.pad(tail, ((0, 0), (cw - 1 - S, 0), (0, 0)))
    return out, RGLRUState(s=s_last, conv=conv_tail)


def rglru_block_step(params: dict, x: jax.Array, state: RGLRUState, cfg
                     ) -> Tuple[jax.Array, RGLRUState]:
    """Decode path. x: (B, 1, d)."""
    xt = x[:, 0]
    gate = jax.nn.gelu(xt @ params["w_gate"])
    h_in = xt @ params["w_x"]
    h, conv_new = conv1d_step(params, h_in, state.conv)
    y, s_new = rglru_step(params, h, state.s)
    out = (gate * y) @ params["w_out"]
    return out[:, None], RGLRUState(s=s_new, conv=conv_new)


def init_rglru_params(key, cfg, dtype) -> dict:
    d, w, cw = cfg.d_model, cfg.rglru_width, cfg.conv1d_width
    nb = max(1, cfg.n_heads)     # block-diagonal gate blocks (RecurrentGemma)
    wb = w // nb
    ks = jax.random.split(key, 6)
    lam_init = jax.random.uniform(ks[5], (w,), jnp.float32, 0.0, 1.0)
    # Lambda such that a^c ~ uniform(0.9, 0.999) at r=1 (Griffin init)
    lam = jnp.log(jnp.expm1(-jnp.log(0.9 + 0.099 * lam_init) / _C))
    return {
        "w_x": layers.dense_init(ks[0], (d, w), dtype),
        "w_gate": layers.dense_init(ks[1], (d, w), dtype),
        "w_out": layers.dense_init(ks[2], (w, d), dtype, fan_in=w),
        "w_a": layers.dense_init(ks[3], (nb, wb, wb), dtype, fan_in=wb),
        "w_i": layers.dense_init(ks[4], (nb, wb, wb), dtype, fan_in=wb),
        "b_a": jnp.zeros((w,), dtype),
        "b_i": jnp.zeros((w,), dtype),
        "lam": lam.astype(jnp.float32),
        "conv_w": jnp.zeros((cw, w), dtype).at[cw - 1].set(1.0),
        "conv_b": jnp.zeros((w,), dtype),
    }

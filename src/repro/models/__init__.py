from repro.models.model import Model, init_params

__all__ = ["Model", "init_params"]

"""RWKV-6 "Finch" block: data-dependent token-shift + decay WKV recurrence.

Time-mix (per layer, H heads of size D):
    sx_t   = x_{t-1} - x_t                           (token shift delta)
    xxx    = x + sx * mu_x
    deltas = tanh(xxx @ tm_w1) reshaped (5, 32) @ tm_w2   -> per-channel lerp
    x{w,k,v,r,g} = x + sx * (mu_{w,k,v,r,g} + delta_{...})
    r,k,v,g = projections; w = exp(-exp(w0 + tanh(xw @ td_w1) @ td_w2))
    WKV:   o_t = r_t · (S_{t-1} + diag(u) k_t v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    out    = W_o( group_norm_heads(o) * silu(g) )

Channel-mix:
    k  = relu(x_k @ W_ck)^2 ; out = sigmoid(x_r @ W_cr) * (k @ W_cv)

The sequential WKV here is the numerical oracle; the Pallas chunked kernel
lives in repro/kernels/rwkv6_scan.py.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers

_LORA_TM = 32    # token-shift LoRA rank
_LORA_TD = 64    # decay LoRA rank


class RWKVState(NamedTuple):
    tm_x: jax.Array    # (B, d)   last input of time-mix
    wkv: jax.Array     # (B, H, D, D) recurrent state, fp32
    cm_x: jax.Array    # (B, d)   last input of channel-mix


def init_rwkv_state(batch: int, d_model: int, n_heads: int, head_dim: int
                    ) -> RWKVState:
    return RWKVState(
        tm_x=jnp.zeros((batch, d_model), jnp.float32),
        wkv=jnp.zeros((batch, n_heads, head_dim, head_dim), jnp.float32),
        cm_x=jnp.zeros((batch, d_model), jnp.float32),
    )


# ---------------------------------------------------------------------------
# WKV recurrence
# ---------------------------------------------------------------------------

def wkv_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
             u: jax.Array, s0: jax.Array, *, chunk: int = 64
             ) -> Tuple[jax.Array, jax.Array]:
    """Sequential WKV. r,k,v,w: (B, S, H, D); u: (H, D); s0: (B, H, D, D).

    Returns (o: (B, S, H, D), s_last).

    Chunked-remat: a naive scan+autodiff saves the (B, H, D, D) state for
    EVERY timestep (S x state — 34 GB/device for the 1.6B at 4k seq). We
    scan over S/chunk chunks and jax.checkpoint the inner scan, so only
    chunk-boundary states are saved and in-chunk states are recomputed in
    the backward pass — activation traffic drops by ~chunk x for ~1 extra
    in-chunk forward (§Perf iteration log in EXPERIMENTS.md).
    """
    B, S, H, D = r.shape
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp                        # (B, H, D) each
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)    # (B, H, D, D)
        o = jnp.einsum("bhi,bhij->bhj", rt, s + uf[None, :, :, None] * kv)
        s_new = wt[..., None] * s + kv
        return s_new, o

    if S % chunk != 0 or S <= chunk:
        xs = tuple(jnp.swapaxes(a, 0, 1) for a in (rf, kf, vf, wf))
        s_last, o = jax.lax.scan(step, s0, xs)
        return jnp.swapaxes(o, 0, 1).astype(r.dtype), s_last

    n_chunks = S // chunk
    # (n_chunks, chunk, B, H, D)
    xs = tuple(jnp.swapaxes(a, 0, 1).reshape(n_chunks, chunk, B, H, D)
               for a in (rf, kf, vf, wf))

    @jax.checkpoint
    def chunk_step(s, inp):
        s_new, o = jax.lax.scan(step, s, inp)
        return s_new, o

    s_last, o = jax.lax.scan(chunk_step, s0, xs)
    o = o.reshape(S, B, H, D)
    return jnp.swapaxes(o, 0, 1).astype(r.dtype), s_last


def wkv_step(r, k, v, w, u, s):
    """Single token. r,k,v,w: (B, H, D); s: (B, H, D, D) fp32."""
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    kv = jnp.einsum("bhi,bhj->bhij", kf, vf)
    o = jnp.einsum("bhi,bhij->bhj", rf, s + u.astype(jnp.float32)[None, :, :, None] * kv)
    s_new = wf[..., None] * s + kv
    return o.astype(r.dtype), s_new


# ---------------------------------------------------------------------------
# Token shift + projections
# ---------------------------------------------------------------------------

def _ddlerp(params: dict, x: jax.Array, sx: jax.Array):
    """Data-dependent lerp producing the 5 mixed inputs (w,k,v,r,g)."""
    xxx = x + sx * params["mu_x"]
    B = x.shape[:-1]
    lora = jnp.tanh(xxx.astype(jnp.float32) @ params["tm_w1"].astype(jnp.float32))
    lora = lora.reshape(B + (5, _LORA_TM))
    deltas = jnp.einsum("...nk,nkd->...nd", lora, params["tm_w2"].astype(jnp.float32))
    mus = jnp.stack([params["mu_w"], params["mu_k"], params["mu_v"],
                     params["mu_r"], params["mu_g"]]).astype(jnp.float32)
    mixed = x[..., None, :] + sx[..., None, :] * (mus + deltas).astype(x.dtype)
    return [mixed[..., i, :] for i in range(5)]


def _time_mix_core(params: dict, x, sx, cfg):
    """Shared by scan and step paths. x, sx: (..., d)."""
    H, D = x.shape[-1] // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    xw, xk, xv, xr, xg = _ddlerp(params, x, sx)
    shp = x.shape[:-1] + (H, D)
    r = (xr @ params["w_r"]).reshape(shp)
    k = (xk @ params["w_k"]).reshape(shp)
    v = (xv @ params["w_v"]).reshape(shp)
    g = jax.nn.silu(xg @ params["w_g"])
    wlog = params["w0"].astype(jnp.float32).reshape(H, D) + (
        jnp.tanh(xw.astype(jnp.float32) @ params["td_w1"].astype(jnp.float32))
        @ params["td_w2"].astype(jnp.float32)
    ).reshape(shp)
    w = jnp.exp(-jnp.exp(jnp.clip(wlog, -50.0, 10.0)))
    return r, k, v, g, w.astype(jnp.float32)


def time_mix(params: dict, x: jax.Array, cfg, s0=None, x_prev0=None):
    """Train/prefill time-mix. x: (B, S, d). Returns (out, (x_last, s_last))."""
    B, S, d = x.shape
    H, D = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    if x_prev0 is None:
        x_prev0 = jnp.zeros((B, d), x.dtype)
    x_prev = jnp.concatenate([x_prev0[:, None], x[:, :-1]], axis=1)
    sx = x_prev - x
    r, k, v, g, w = _time_mix_core(params, x, sx, cfg)
    if s0 is None:
        s0 = jnp.zeros((B, H, D, D), jnp.float32)
    o, s_last = wkv_scan(r, k, v, w, params["u"], s0)
    o = layers.group_norm_heads(o, params["gn_scale"].reshape(H, D),
                                params["gn_bias"].reshape(H, D))
    out = (o.reshape(B, S, d) * g) @ params["w_o"]
    return out, (x[:, -1].astype(jnp.float32), s_last)


def time_mix_step(params: dict, x: jax.Array, state_x, state_s, cfg):
    """Decode time-mix. x: (B, d)."""
    B, d = x.shape
    H, D = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    sx = state_x.astype(x.dtype) - x
    r, k, v, g, w = _time_mix_core(params, x, sx, cfg)
    o, s_new = wkv_step(r, k, v, w, params["u"], state_s)
    o = layers.group_norm_heads(o, params["gn_scale"].reshape(H, D),
                                params["gn_bias"].reshape(H, D))
    out = (o.reshape(B, d) * g) @ params["w_o"]
    return out, (x.astype(jnp.float32), s_new)


def channel_mix(params: dict, x: jax.Array, x_prev0=None):
    """Train/prefill channel-mix. x: (B, S, d)."""
    B, S, d = x.shape
    if x_prev0 is None:
        x_prev0 = jnp.zeros((B, d), x.dtype)
    x_prev = jnp.concatenate([x_prev0[:, None], x[:, :-1]], axis=1)
    sx = x_prev - x
    xk = x + sx * params["cm_mu_k"]
    xr = x + sx * params["cm_mu_r"]
    kk = jnp.square(jax.nn.relu(xk @ params["w_ck"]))
    out = jax.nn.sigmoid(xr @ params["w_cr"]) * (kk @ params["w_cv"])
    return out, x[:, -1].astype(jnp.float32)


def channel_mix_step(params: dict, x: jax.Array, state_x):
    sx = state_x.astype(x.dtype) - x
    xk = x + sx * params["cm_mu_k"]
    xr = x + sx * params["cm_mu_r"]
    kk = jnp.square(jax.nn.relu(xk @ params["w_ck"]))
    out = jax.nn.sigmoid(xr @ params["w_cr"]) * (kk @ params["w_cv"])
    return out, x.astype(jnp.float32)


def init_rwkv_params(key, cfg, dtype) -> dict:
    d, dff = cfg.d_model, cfg.d_ff
    H = d // cfg.rwkv_head_dim
    D = cfg.rwkv_head_dim
    ks = jax.random.split(key, 12)
    zeros_d = jnp.zeros((d,), dtype)
    return {
        # token-shift mixing
        "mu_x": zeros_d, "mu_w": zeros_d, "mu_k": zeros_d,
        "mu_v": zeros_d, "mu_r": zeros_d, "mu_g": zeros_d,
        "tm_w1": layers.dense_init(ks[0], (d, 5 * _LORA_TM), dtype),
        "tm_w2": (jax.random.normal(ks[1], (5, _LORA_TM, d), jnp.float32)
                  * 0.01).astype(dtype),
        # decay
        "w0": (jnp.linspace(-6.0, -0.5, d)).astype(jnp.float32),
        "td_w1": layers.dense_init(ks[2], (d, _LORA_TD), dtype),
        "td_w2": (jax.random.normal(ks[3], (_LORA_TD, d), jnp.float32)
                  * 0.01).astype(dtype),
        "u": (jax.random.normal(ks[4], (H, D), jnp.float32) * 0.1),
        # projections
        "w_r": layers.dense_init(ks[5], (d, d), dtype),
        "w_k": layers.dense_init(ks[6], (d, d), dtype),
        "w_v": layers.dense_init(ks[7], (d, d), dtype),
        "w_g": layers.dense_init(ks[8], (d, d), dtype),
        "w_o": layers.dense_init(ks[9], (d, d), dtype),
        "gn_scale": jnp.ones((d,), dtype),
        "gn_bias": jnp.zeros((d,), dtype),
        # channel-mix
        "cm_mu_k": zeros_d, "cm_mu_r": zeros_d,
        "w_ck": layers.dense_init(ks[10], (d, dff), dtype),
        "w_cv": layers.dense_init(ks[11], (dff, d), dtype, fan_in=dff),
        "w_cr": layers.dense_init(jax.random.fold_in(key, 99), (d, d), dtype),
    }

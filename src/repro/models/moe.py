"""Mixture-of-Experts FFN: top-k router + capacity-bounded scatter dispatch.

Dispatch strategy (XLA/GSPMD-friendly, memory O(E · C · d)):
  1. router logits -> top-k experts per token, softmax over the chosen k.
  2. each (token, k) assignment gets a *rank* within its expert via a
     cumulative count; assignments whose rank exceeds the expert capacity
     ``C = ceil(cf · T · k / E)`` are dropped (standard GShard semantics).
  3. tokens are scattered into an (E, C, d) buffer, expert FFNs run as one
     batched einsum over E, results gather back weighted by the gate.

FLOPs scale with E·C·d·ff ≈ cf · T · k · d · ff — i.e. with *active* params,
which is what the roofline's 6·N_active·D model expects.

The Pallas grouped-GEMM kernel (repro/kernels/moe_gmm.py) is the TPU hot
path for the expert einsum; this module is the XLA-lowerable reference.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.sharding.logical import constrain

CAPACITY_FACTOR = 1.25


def expert_capacity(n_tokens: int, n_experts: int, top_k: int,
                    cf: float = CAPACITY_FACTOR) -> int:
    c = int(math.ceil(cf * n_tokens * top_k / n_experts))
    return max(8, min(c, n_tokens))


def route(router_w: jax.Array, x: jax.Array, top_k: int
          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (T, d). Returns (gates (T,k) fp32, expert_idx (T,k) int32, logits)."""
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    gate_vals, idx = jax.lax.top_k(logits, top_k)
    gates = jax.nn.softmax(gate_vals, axis=-1)
    return gates, idx.astype(jnp.int32), logits


def load_balancing_loss(logits: jax.Array, idx: jax.Array, n_experts: int
                        ) -> jax.Array:
    """Switch-style aux loss: E * sum_e f_e * p_e."""
    probs = jax.nn.softmax(logits, axis=-1)                    # (T, E)
    onehot = jax.nn.one_hot(idx[:, 0], n_experts, dtype=jnp.float32)
    f = jnp.mean(onehot, axis=0)
    p = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(f * p)


def moe_ffn(params: dict, x: jax.Array, cfg, *,
            capacity_factor: Optional[float] = None
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    Dispatch is *local per batch row* (capacity = ceil(cf·S·K/E) per
    sequence): the rank cumsum runs along S inside each row, never across
    the data-sharded batch dim. A global-token dispatch forces a
    cross-device prefix sum + activation all-reduce per layer — measured
    84 s/step of all-reduce on mixtral train_4k (EXPERIMENTS.md §Perf);
    per-row dispatch keeps all routing local to the shard, which is how
    per-device capacity works on a real cluster anyway.
    """
    B, S, d = x.shape
    if capacity_factor is None:
        capacity_factor = getattr(cfg, "moe_cf", CAPACITY_FACTOR)
    # E read from params (not cfg) so expert pruning needs no config edits
    E = params["router"].shape[-1]
    K = min(cfg.top_k, E)

    gates, idx, logits = route(params["router"], x.reshape(B * S, d), K)
    aux = load_balancing_loss(logits, idx, E)
    gates = gates.reshape(B, S, K)
    idx = idx.reshape(B, S, K)

    C = expert_capacity(S, E, K, capacity_factor)
    flat_e = idx.reshape(B, S * K)                              # (B, S*K)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)         # (B, S*K, E)
    ranks = jnp.cumsum(onehot, axis=1) - onehot                 # exclusive
    rank = jnp.take_along_axis(ranks, flat_e[..., None],
                               axis=2)[..., 0]                  # (B, S*K)
    keep = rank < C

    # scatter tokens into (B, E, C, d); dropped assignments hit a dump slot.
    # vmap over batch (instead of explicit batch indices) lowers to
    # gather/scatter with *batching dims*, which GSPMD partitions locally —
    # explicit b_idx coordinates force it to all-gather the whole batch
    # (3.2 GB/layer on mixtral train_4k, EXPERIMENTS.md §Perf).
    safe_e = jnp.where(keep, flat_e, 0)
    safe_r = jnp.where(keep, rank, C)
    x_rep = jnp.repeat(x, K, axis=1)                            # (B, S*K, d)
    x_rep = jnp.where(keep[..., None], x_rep, 0)
    buf = jnp.zeros((B, E, C + 1, d), x.dtype)
    buf = jax.vmap(lambda bb, ee, rr, xx: bb.at[ee, rr].add(xx))(
        buf, safe_e, safe_r, x_rep)
    buf = buf[:, :, :C]                                         # (B, E, C, d)
    buf = constrain(buf, ("batch", "expert", None, None))

    # expert FFN (batched over B, E)
    act = layers.activation_fn(cfg.activation)
    if layers.is_gated(cfg.activation):
        h = act(jnp.einsum("becd,edf->becf", buf, params["w_gate"])) * \
            jnp.einsum("becd,edf->becf", buf, params["w_up"])
    else:
        h = act(jnp.einsum("becd,edf->becf", buf, params["w_up"]))
    h = constrain(h, ("batch", "expert", None, "mlp"))
    out_e = jnp.einsum("becf,efd->becd", h, params["w_down"])
    out_e = constrain(out_e, ("batch", "expert", None, None))

    # gather back: each assignment reads its slot, weighted by its gate
    out_e = jnp.pad(out_e, ((0, 0), (0, 0), (0, 1), (0, 0)))    # dump = 0
    picked = jax.vmap(lambda oe, ee, rr: oe[ee, rr])(
        out_e, safe_e, safe_r)                                  # (B, S*K, d)
    picked = jnp.where(keep[..., None], picked, 0)
    w = gates.reshape(B, S * K, 1).astype(picked.dtype)
    out = jnp.sum((picked * w).reshape(B, S, K, d), axis=2)
    return out, aux


def init_moe_params(key, cfg, dtype) -> dict:
    d, ff, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": layers.dense_init(ks[0], (d, E), jnp.float32),
        "w_up": layers.dense_init(ks[1], (E, d, ff), dtype, fan_in=d),
        "w_down": layers.dense_init(ks[2], (E, ff, d), dtype, fan_in=ff),
    }
    if layers.is_gated(cfg.activation):
        p["w_gate"] = layers.dense_init(ks[3], (E, d, ff), dtype, fan_in=d)
    return p

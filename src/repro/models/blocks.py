"""Block assembly: norm -> mixer -> residual -> norm -> FFN/MoE -> residual.

One ``apply_block_*`` trio (train / prefill / decode) covers all four block
kinds (attn, local_attn, rglru, rwkv). Caches/states are kind-specific
NamedTuples threaded through the prefill/decode paths.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, LOCAL_ATTN, RGLRU, RWKV
from repro.models import attention, layers, moe, rglru, rwkv6


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------

def dense_ffn(params: dict, x: jax.Array, cfg) -> jax.Array:
    act = layers.activation_fn(cfg.activation)
    if layers.is_gated(cfg.activation):
        h = act(jnp.einsum("bsd,df->bsf", x, params["w_gate"])) * \
            jnp.einsum("bsd,df->bsf", x, params["w_up"])
    else:
        h = act(jnp.einsum("bsd,df->bsf", x, params["w_up"]))
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"])


def init_ffn_params(key, cfg, dtype) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w_up": layers.dense_init(ks[0], (d, ff), dtype),
        "w_down": layers.dense_init(ks[1], (ff, d), dtype, fan_in=ff),
    }
    if layers.is_gated(cfg.activation):
        p["w_gate"] = layers.dense_init(ks[2], (d, ff), dtype)
    return p


def init_channel_mix_params(key, cfg, dtype) -> dict:
    # RWKV channel-mix params live inside init_rwkv_params; the ffn slot for
    # RWKV blocks references the same dict (handled in model.init).
    raise NotImplementedError


# ---------------------------------------------------------------------------
# Per-kind init of one layer's params
# ---------------------------------------------------------------------------

def init_block_params(key, kind: str, cfg, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p: dict = {"norm1": layers.init_norm(cfg.norm, cfg.d_model, dtype),
               "norm2": layers.init_norm(cfg.norm, cfg.d_model, dtype)}
    if kind in (ATTN, LOCAL_ATTN):
        p["mixer"] = attention.init_attention_params(k1, cfg, dtype)
    elif kind == RGLRU:
        p["mixer"] = rglru.init_rglru_params(k1, cfg, dtype)
    elif kind == RWKV:
        rp = rwkv6.init_rwkv_params(k1, cfg, dtype)
        cm_keys = ("cm_mu_k", "cm_mu_r", "w_ck", "w_cv", "w_cr")
        p["mixer"] = {k: v for k, v in rp.items() if k not in cm_keys}
        p["ffn"] = {k: rp[k] for k in cm_keys}
        return p
    if cfg.n_experts > 0:
        p["ffn"] = moe.init_moe_params(k2, cfg, dtype)
    else:
        p["ffn"] = init_ffn_params(k2, cfg, dtype)
    return p


def _window_for(kind: str, cfg) -> int:
    if kind == LOCAL_ATTN:
        return cfg.sliding_window
    if kind == ATTN:
        return cfg.sliding_window  # 0 = full attention
    return 0


def _apply_ffn_train(bp: dict, kind: str, h: jax.Array, cfg):
    if kind == RWKV:
        out, _ = rwkv6.channel_mix(bp["ffn"], h)
        return out, jnp.float32(0.0)
    if cfg.n_experts > 0:
        return moe.moe_ffn(bp["ffn"], h, cfg)
    return dense_ffn(bp["ffn"], h, cfg), jnp.float32(0.0)


# ---------------------------------------------------------------------------
# Train path
# ---------------------------------------------------------------------------

def apply_block_train(kind: str, bp: dict, x: jax.Array, cfg, positions
                      ) -> Tuple[jax.Array, jax.Array]:
    h = layers.apply_norm(cfg.norm, bp["norm1"], x)
    if kind in (ATTN, LOCAL_ATTN):
        mix = attention.attention_block(bp["mixer"], h, cfg,
                                        positions=positions,
                                        window=_window_for(kind, cfg))
    elif kind == RGLRU:
        mix = rglru.rglru_block(bp["mixer"], h, cfg)
    else:  # RWKV
        mix, _ = rwkv6.time_mix(bp["mixer"], h, cfg)
    x = x + mix
    h = layers.apply_norm(cfg.norm, bp["norm2"], x)
    ff, aux = _apply_ffn_train(bp, kind, h, cfg)
    return x + ff, aux


# ---------------------------------------------------------------------------
# Prefill path (returns per-block cache)
# ---------------------------------------------------------------------------

def init_block_cache(kind: str, cfg, batch: int, max_seq: int, dtype):
    if kind in (ATTN, LOCAL_ATTN):
        clen = attention.cache_len_for(max_seq, _window_for(kind, cfg))
        return attention.KVCache.init(batch, clen, cfg.n_kv_heads,
                                      cfg.head_dim, dtype)
    if kind == RGLRU:
        return rglru.init_rglru_state(batch, cfg.rglru_width, cfg.conv1d_width)
    if kind == RWKV:
        return rwkv6.init_rwkv_state(batch, cfg.d_model,
                                     cfg.d_model // cfg.rwkv_head_dim,
                                     cfg.rwkv_head_dim)
    raise ValueError(kind)


def apply_block_prefill(kind: str, bp: dict, x: jax.Array, cfg, positions,
                        max_seq: int) -> Tuple[jax.Array, Any]:
    h = layers.apply_norm(cfg.norm, bp["norm1"], x)
    if kind in (ATTN, LOCAL_ATTN):
        w = _window_for(kind, cfg)
        clen = attention.cache_len_for(max_seq, w)
        mix, cache = attention.attention_prefill(
            bp["mixer"], h, cfg, positions=positions, window=w, cache_len=clen)
    elif kind == RGLRU:
        mix, cache = rglru.rglru_block_prefill(bp["mixer"], h, cfg)
    else:  # RWKV
        mix, (tm_x, wkv) = rwkv6.time_mix(bp["mixer"], h, cfg)
        cache = (tm_x, wkv)
    x = x + mix
    h = layers.apply_norm(cfg.norm, bp["norm2"], x)
    if kind == RWKV:
        ff, cm_x = rwkv6.channel_mix(bp["ffn"], h)
        cache = rwkv6.RWKVState(tm_x=cache[0], wkv=cache[1], cm_x=cm_x)
        aux = jnp.float32(0.0)
    else:
        ff, aux = _apply_ffn_train(bp, kind, h, cfg)
    return x + ff, cache, aux


# ---------------------------------------------------------------------------
# Decode path (single token)
# ---------------------------------------------------------------------------

def apply_block_decode_paged(bp: dict, x: jax.Array, pool, cfg,
                             pos: jax.Array, positions, table: jax.Array
                             ) -> Tuple[jax.Array, Any]:
    """ATTN-only decode block over paged KV (``paged_compatible`` gates
    the other kinds to the contiguous path)."""
    h = layers.apply_norm(cfg.norm, bp["norm1"], x)
    mix, pool = attention.attention_decode_paged(
        bp["mixer"], h, pool, cfg, pos=pos, positions=positions, table=table)
    x = x + mix
    h = layers.apply_norm(cfg.norm, bp["norm2"], x)
    if cfg.n_experts > 0:
        ff, _ = moe.moe_ffn(bp["ffn"], h, cfg)
    else:
        ff = dense_ffn(bp["ffn"], h, cfg)
    return x + ff, pool


def apply_block_decode_paged_gathered(bp: dict, x: jax.Array,
                                      kg: jax.Array, vg: jax.Array, cfg,
                                      pos: jax.Array, positions
                                      ) -> Tuple[jax.Array, Any]:
    """Decode block over pre-gathered paged KV (the XLA path: pools stay
    outside the layer scan; this returns the layer's new K/V row for one
    post-scan scatter instead of a rewritten pool)."""
    h = layers.apply_norm(cfg.norm, bp["norm1"], x)
    mix, kv = attention.attention_decode_paged_gathered(
        bp["mixer"], h, kg, vg, cfg, pos=pos, positions=positions)
    x = x + mix
    h = layers.apply_norm(cfg.norm, bp["norm2"], x)
    if cfg.n_experts > 0:
        ff, _ = moe.moe_ffn(bp["ffn"], h, cfg)
    else:
        ff = dense_ffn(bp["ffn"], h, cfg)
    return x + ff, kv


def apply_block_chunk_paged_gathered(bp: dict, x: jax.Array,
                                     kg: jax.Array, vg: jax.Array, cfg,
                                     start: jax.Array, positions
                                     ) -> Tuple[jax.Array, Any]:
    """Chunked-prefill block over pre-gathered paged KV (returns the
    chunk's K/V for the caller's post-scan scatter)."""
    h = layers.apply_norm(cfg.norm, bp["norm1"], x)
    mix, kv = attention.attention_prefill_chunk_paged_gathered(
        bp["mixer"], h, kg, vg, cfg, start=start, positions=positions)
    x = x + mix
    h = layers.apply_norm(cfg.norm, bp["norm2"], x)
    if cfg.n_experts > 0:
        ff, _ = moe.moe_ffn(bp["ffn"], h, cfg)
    else:
        ff = dense_ffn(bp["ffn"], h, cfg)
    return x + ff, kv


def apply_block_chunk_paged(bp: dict, x: jax.Array, pool, cfg,
                            start: jax.Array, positions, table: jax.Array
                            ) -> Tuple[jax.Array, Any]:
    """ATTN-only chunked-prefill block over paged KV."""
    h = layers.apply_norm(cfg.norm, bp["norm1"], x)
    mix, pool = attention.attention_prefill_chunk_paged(
        bp["mixer"], h, pool, cfg, start=start, positions=positions,
        table=table)
    x = x + mix
    h = layers.apply_norm(cfg.norm, bp["norm2"], x)
    if cfg.n_experts > 0:
        ff, _ = moe.moe_ffn(bp["ffn"], h, cfg)
    else:
        ff = dense_ffn(bp["ffn"], h, cfg)
    return x + ff, pool


def apply_block_decode(kind: str, bp: dict, x: jax.Array, cache, cfg,
                       pos: jax.Array, positions) -> Tuple[jax.Array, Any]:
    h = layers.apply_norm(cfg.norm, bp["norm1"], x)
    if kind in (ATTN, LOCAL_ATTN):
        mix, cache = attention.attention_decode(
            bp["mixer"], h, cache, cfg, pos=pos, positions=positions,
            window=_window_for(kind, cfg))
    elif kind == RGLRU:
        mix, cache = rglru.rglru_block_step(bp["mixer"], h, cache, cfg)
    else:  # RWKV
        mix1, (tm_x, wkv) = rwkv6.time_mix_step(
            bp["mixer"], h[:, 0], cache.tm_x, cache.wkv, cfg)
        mix = mix1[:, None]
        cache = rwkv6.RWKVState(tm_x=tm_x, wkv=wkv, cm_x=cache.cm_x)
    x = x + mix
    h = layers.apply_norm(cfg.norm, bp["norm2"], x)
    if kind == RWKV:
        ff1, cm_x = rwkv6.channel_mix_step(bp["ffn"], h[:, 0], cache.cm_x)
        ff = ff1[:, None]
        cache = rwkv6.RWKVState(tm_x=cache.tm_x, wkv=cache.wkv, cm_x=cm_x)
    elif cfg.n_experts > 0:
        ff, _ = moe.moe_ffn(bp["ffn"], h, cfg)
    else:
        ff = dense_ffn(bp["ffn"], h, cfg)
    return x + ff, cache

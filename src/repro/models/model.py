"""Model assembly: scanned layer stacks, train/prefill/decode, prune metadata.

Layer layout
------------
``cfg.block_pattern`` repeats across ``n_layers``. Layers are organized as
``n_periods`` full repetitions of the pattern (stacked + lax.scan, keeps the
HLO small enough that 512-device lowering of an 80-layer 110B model is fast)
plus a small unscanned ``tail`` for the remainder layers.

Params pytree::

    params = {
      "embed":      (V, d),
      "lm_head":    (d, V),            # absent when tie_embeddings
      "final_norm": {...},
      "stack":      {"pos0": <block pytree, leaves lead with n_periods>, ...},
      "tail":       {"0": <block pytree>, ...},      # remainder layers
    }

Prune metadata: ``prune_sites(cfg)`` exposes every prunable dimension as a
``PruneSite`` (the paper's *subgraph* groups) for the CPrune core.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, LOCAL_ATTN, RGLRU, RWKV, ModelConfig
from repro.models import attention, blocks, layers


# ---------------------------------------------------------------------------
# Prune-site metadata (consumed by repro.core)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GemmSpec:
    """One GEMM inside a prunable subgraph (per-token shape)."""

    name: str          # up | gate | down | q | o | router | ...
    k: int
    n: int
    prunable: str      # 'n' | 'k' | '-' (which dim the prunable dim maps to)
    batch: int = 1     # leading batched-GEMM dim (experts)
    m_scale: float = 1.0  # M = m_scale * tokens (capacity factor for MoE)


@dataclasses.dataclass(frozen=True)
class PruneSite:
    """One prunable structured dimension shared by `multiplicity` subgraphs.

    param_axes maps param-path (relative to the block pytree, "/"-joined) to
    the axis (in the *unstacked* layer params) sliced when pruning. Stacked
    entries get +1 applied by the applier.
    """

    site_id: str                  # e.g. "stack/pos0:ffn"
    kind: str                     # ffn | moe_ffn | heads | experts
    block_path: str               # "stack/pos0" or "tail/3"
    stacked: bool                 # True when leaves carry a leading layer axis
    dim: int                      # current prunable dimension size
    granularity: int              # minimal semantic prune unit
    multiplicity: int             # number of subgraphs sharing this GEMM shape
    unit_cols: int                # GEMM columns per prunable unit
    param_axes: Tuple[Tuple[str, int], ...]
    gemms: Tuple[GemmSpec, ...]
    op_kind: str = "matmul"       # epilogue/op discriminator for task grouping

    def with_dim(self, new_dim: int) -> "PruneSite":
        """Site after pruning to ``new_dim`` units (GEMM shapes follow)."""
        new_gemms = []
        cols = new_dim * self.unit_cols
        for g in self.gemms:
            if g.prunable == "n":
                new_gemms.append(dataclasses.replace(g, n=cols))
            elif g.prunable == "k":
                new_gemms.append(dataclasses.replace(g, k=cols))
            else:
                new_gemms.append(g)
        return dataclasses.replace(self, dim=new_dim, gemms=tuple(new_gemms))


def _block_sites(cfg: ModelConfig, kind: str, block_path: str, stacked: bool,
                 mult: int) -> List[PruneSite]:
    sites: List[PruneSite] = []
    d = cfg.d_model
    gated = layers.is_gated(cfg.activation)
    # --- FFN / channel-mix / MoE ---
    if kind == RWKV:
        sites.append(PruneSite(
            site_id=f"{block_path}:cmix", kind="ffn", block_path=block_path,
            stacked=stacked, dim=cfg.d_ff, granularity=1, multiplicity=mult,
            unit_cols=1,
            param_axes=(("ffn/w_ck", 1), ("ffn/w_cv", 0)),
            gemms=(GemmSpec("up", d, cfg.d_ff, "n"),
                   GemmSpec("down", cfg.d_ff, d, "k")),
            op_kind="matmul+relu2"))
    elif cfg.n_experts > 0:
        axes = [("ffn/w_up", 2), ("ffn/w_down", 1)]
        gl = [GemmSpec("up", d, cfg.moe_d_ff, "n", batch=cfg.n_experts,
                       m_scale=1.25 * cfg.top_k / cfg.n_experts),
              GemmSpec("down", cfg.moe_d_ff, d, "k", batch=cfg.n_experts,
                       m_scale=1.25 * cfg.top_k / cfg.n_experts)]
        if gated:
            axes.append(("ffn/w_gate", 2))
            gl.append(GemmSpec("gate", d, cfg.moe_d_ff, "n",
                               batch=cfg.n_experts,
                               m_scale=1.25 * cfg.top_k / cfg.n_experts))
        sites.append(PruneSite(
            site_id=f"{block_path}:moe_ffn", kind="moe_ffn",
            block_path=block_path, stacked=stacked, dim=cfg.moe_d_ff,
            granularity=1, multiplicity=mult * cfg.n_experts,
            unit_cols=1, param_axes=tuple(axes), gemms=tuple(gl),
            op_kind=f"matmul+{cfg.activation}"))
        sites.append(PruneSite(
            site_id=f"{block_path}:experts", kind="experts",
            block_path=block_path, stacked=stacked, dim=cfg.n_experts,
            granularity=1, multiplicity=mult, unit_cols=1,
            param_axes=(("ffn/w_up", 0), ("ffn/w_down", 0), ("ffn/router", 1))
            + ((("ffn/w_gate", 0),) if gated else ()),
            gemms=(GemmSpec("router", d, cfg.n_experts, "n"),),
            op_kind="router"))
    else:
        axes = [("ffn/w_up", 1), ("ffn/w_down", 0)]
        gl = [GemmSpec("up", d, cfg.d_ff, "n"),
              GemmSpec("down", cfg.d_ff, d, "k")]
        if gated:
            axes.append(("ffn/w_gate", 1))
            gl.append(GemmSpec("gate", d, cfg.d_ff, "n"))
        sites.append(PruneSite(
            site_id=f"{block_path}:ffn", kind="ffn", block_path=block_path,
            stacked=stacked, dim=cfg.d_ff, granularity=1, multiplicity=mult,
            unit_cols=1, param_axes=tuple(axes), gemms=tuple(gl),
            op_kind=f"matmul+{cfg.activation}"))
    # --- attention heads ---
    if kind in (ATTN, LOCAL_ATTN) and cfg.n_heads > cfg.n_kv_heads:
        axes = [("mixer/wq", 1), ("mixer/wo", 0)]
        if cfg.qkv_bias:
            axes.append(("mixer/bq", 0))
        hd = cfg.head_dim
        sites.append(PruneSite(
            site_id=f"{block_path}:heads", kind="heads", block_path=block_path,
            stacked=stacked, dim=cfg.n_heads,
            granularity=cfg.n_kv_heads,      # keep q-per-kv uniform
            multiplicity=mult, unit_cols=hd,
            param_axes=tuple(axes),
            gemms=(GemmSpec("q", d, cfg.n_heads * hd, "n"),
                   GemmSpec("o", cfg.n_heads * hd, d, "k")),
            op_kind="matmul"))
    return sites


def prune_sites(cfg: ModelConfig) -> List[PruneSite]:
    pattern = cfg.block_pattern
    P = len(pattern)
    n_p = cfg.n_layers // P
    tail_kinds = cfg.layer_kinds()[n_p * P:]
    out: List[PruneSite] = []
    for pos, kind in enumerate(pattern):
        if n_p > 0:
            out.extend(_block_sites(cfg, kind, f"stack/pos{pos}", True, n_p))
    for i, kind in enumerate(tail_kinds):
        out.extend(_block_sites(cfg, kind, f"tail/{i}", False, 1))
    return out


# ---------------------------------------------------------------------------
# Positions (RoPE / M-RoPE)
# ---------------------------------------------------------------------------

def _mrope_grid(cfg) -> int:
    return int(round(math.sqrt(max(cfg.frontend_seq, 1))))


def make_positions(cfg: ModelConfig, seq_len: int):
    """Train/prefill position stream(s). (S,) for rope, (3, S) for mrope."""
    if cfg.rope == "mrope":
        F = cfg.frontend_seq
        g = _mrope_grid(cfg)
        i = jnp.arange(seq_len, dtype=jnp.int32)
        vis = i < F
        text = i - F + g
        t = jnp.where(vis, 0, text)
        h = jnp.where(vis, (i // max(g, 1)) % max(g, 1), text)
        w = jnp.where(vis, i % max(g, 1), text)
        return jnp.stack([t, h, w])
    return jnp.arange(seq_len, dtype=jnp.int32)


def decode_positions(cfg: ModelConfig, pos: jax.Array):
    if cfg.rope == "mrope":
        g = _mrope_grid(cfg)
        text = (pos - cfg.frontend_seq + g).astype(jnp.int32)
        return jnp.broadcast_to(text, (3, 1))
    return pos[None].astype(jnp.int32)


# ---------------------------------------------------------------------------
# The model
# ---------------------------------------------------------------------------

def _dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def init_params(key: jax.Array, cfg: ModelConfig) -> Dict[str, Any]:
    dtype = _dtype_of(cfg)
    pattern = cfg.block_pattern
    P = len(pattern)
    n_p = cfg.n_layers // P
    tail_kinds = cfg.layer_kinds()[n_p * P:]

    k_embed, k_head, k_stack, k_tail = jax.random.split(key, 4)
    params: Dict[str, Any] = {
        "embed": layers.dense_init(k_embed, (cfg.vocab_size, cfg.d_model),
                                   dtype, fan_in=cfg.d_model),
        "final_norm": layers.init_norm(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.dense_init(
            k_head, (cfg.d_model, cfg.vocab_size), dtype)

    stack: Dict[str, Any] = {}
    for pos, kind in enumerate(pattern):
        if n_p == 0:
            break
        keys = jax.random.split(jax.random.fold_in(k_stack, pos), n_p)
        per_layer = [blocks.init_block_params(keys[i], kind, cfg, dtype)
                     for i in range(n_p)]
        stack[f"pos{pos}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
    params["stack"] = stack

    tail: Dict[str, Any] = {}
    for i, kind in enumerate(tail_kinds):
        tail[str(i)] = blocks.init_block_params(
            jax.random.fold_in(k_tail, i), kind, cfg, dtype)
    params["tail"] = tail
    return params


def _remat_wrap(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)


class Model:
    """Functional model wrapper bound to a config."""

    def __init__(self, cfg: ModelConfig, shard_fn=None, gather_fn=None):
        self.cfg = cfg
        self.pattern = cfg.block_pattern
        self.P = len(self.pattern)
        self.n_periods = cfg.n_layers // self.P
        self.tail_kinds = cfg.layer_kinds()[self.n_periods * self.P:]
        # optional residual-stream sharding constraint (set by launch/)
        self.shard_fn = shard_fn or (lambda x: x)
        # optional ZeRO-3 per-layer weight gathering (set by launch/)
        self.gather_fn = gather_fn or (lambda p: p)

    # -- embedding / head ---------------------------------------------------

    def embed(self, params, tokens: jax.Array) -> jax.Array:
        return jnp.take(params["embed"], tokens, axis=0)

    def unembed(self, params, x: jax.Array) -> jax.Array:
        # gather the (small) head weight over the data axes so the
        # contraction dim d is unsharded — otherwise GSPMD all-gathers the
        # (tokens x d) activations per CE chunk (EXPERIMENTS.md §Perf)
        if self.cfg.tie_embeddings:
            w = self.gather_fn({"embed": params["embed"]})["embed"]
            logits = jnp.einsum("...d,vd->...v", x, w)
        else:
            w = self.gather_fn({"lm_head": params["lm_head"]})["lm_head"]
            logits = jnp.einsum("...d,dv->...v", x, w)
        return layers.softcap(logits, self.cfg.logits_softcap)

    def _input_x(self, params, batch: Dict[str, jax.Array]) -> jax.Array:
        cfg = self.cfg
        if cfg.frontend == "audio_frames":
            return batch["frames"].astype(_dtype_of(cfg))
        x = self.embed(params, batch["tokens"])
        if cfg.frontend == "vision_patches" and "patch_embeds" in batch:
            F = batch["patch_embeds"].shape[1]
            x = jnp.concatenate(
                [batch["patch_embeds"].astype(x.dtype), x[:, F:]], axis=1)
        return x

    # -- train forward ------------------------------------------------------

    def backbone_train(self, params, x: jax.Array, positions
                       ) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        aux_total = jnp.float32(0.0)

        if self.n_periods > 0:
            def body(carry, p_params):
                x, aux = carry
                for pos, kind in enumerate(self.pattern):
                    bp = self.gather_fn(p_params[f"pos{pos}"])
                    x, a = blocks.apply_block_train(
                        kind, bp, x, cfg, positions)
                    aux = aux + a
                return (self.shard_fn(x), aux), None
            body = _remat_wrap(body, cfg)
            (x, aux_total), _ = jax.lax.scan(
                body, (self.shard_fn(x), aux_total), params["stack"])

        for i, kind in enumerate(self.tail_kinds):
            x, a = blocks.apply_block_train(
                kind, self.gather_fn(params["tail"][str(i)]), x, cfg,
                positions)
            x = self.shard_fn(x)
            aux_total = aux_total + a

        x = layers.apply_norm(cfg.norm, params["final_norm"], x)
        return x, aux_total

    def loss_fn(self, params, batch: Dict[str, jax.Array], *,
                vocab_chunk: int = 0) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Causal LM (or masked-prediction for encoder-only) loss + metrics."""
        cfg = self.cfg
        x = self._input_x(params, batch)
        positions = make_positions(cfg, x.shape[1])
        x, aux = self.backbone_train(params, x, positions)

        if cfg.is_encoder_only:
            labels = batch["labels"]
            mask = batch["mask"].astype(jnp.float32)
        else:
            tokens = batch["tokens"]
            labels = jnp.concatenate(
                [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
            mask = jnp.concatenate(
                [jnp.ones_like(tokens[:, 1:], jnp.float32),
                 jnp.zeros_like(tokens[:, :1], jnp.float32)], axis=1)
            if "loss_mask" in batch:
                mask = mask * batch["loss_mask"].astype(jnp.float32)

        ce, acc = _chunked_ce(self, params, x, labels, mask,
                              chunk=vocab_chunk)
        loss = ce + 0.01 * aux
        return loss, {"ce": ce, "aux": aux, "acc": acc}

    # -- prefill ------------------------------------------------------------

    def prefill(self, params, batch: Dict[str, jax.Array], max_seq: int):
        """Run the full prompt; returns (last-position logits, caches)."""
        cfg = self.cfg
        x = self._input_x(params, batch)
        B, S = x.shape[0], x.shape[1]
        positions = make_positions(cfg, S)
        caches_stack: Dict[str, Any] = {}

        if self.n_periods > 0:
            def body(x, p_params):
                new_c = {}
                for pos, kind in enumerate(self.pattern):
                    bp = self.gather_fn(p_params[f"pos{pos}"])
                    x, c, _ = blocks.apply_block_prefill(
                        kind, bp, x, cfg, positions, max_seq)
                    new_c[f"pos{pos}"] = c
                return self.shard_fn(x), new_c
            x, caches_stack = jax.lax.scan(body, x, params["stack"])

        caches_tail: Dict[str, Any] = {}
        for i, kind in enumerate(self.tail_kinds):
            x, c, _ = blocks.apply_block_prefill(
                kind, self.gather_fn(params["tail"][str(i)]), x, cfg,
                positions, max_seq)
            caches_tail[str(i)] = c

        x = layers.apply_norm(cfg.norm, params["final_norm"], x)
        logits = self.unembed(params, x[:, -1:])
        caches = {"stack": caches_stack, "tail": caches_tail,
                  "pos": jnp.int32(S)}
        return logits, caches

    def init_caches(self, batch_size: int, max_seq: int) -> Dict[str, Any]:
        """Empty caches for pure-decode lowering (dry-run decode cells)."""
        cfg = self.cfg
        dtype = _dtype_of(cfg)
        stack: Dict[str, Any] = {}
        if self.n_periods > 0:
            for pos, kind in enumerate(self.pattern):
                one = blocks.init_block_cache(kind, cfg, batch_size, max_seq,
                                              dtype)
                stack[f"pos{pos}"] = jax.tree.map(
                    lambda a: jnp.broadcast_to(
                        a, (self.n_periods,) + a.shape), one)
        tail = {str(i): blocks.init_block_cache(k, cfg, batch_size, max_seq,
                                                dtype)
                for i, k in enumerate(self.tail_kinds)}
        return {"stack": stack, "tail": tail, "pos": jnp.int32(0)}

    # -- decode -------------------------------------------------------------

    def decode_step(self, params, token: jax.Array, caches: Dict[str, Any]
                    ) -> Tuple[jax.Array, Dict[str, Any]]:
        """token: (B, 1) int32 (or (B,1,d) frames). Returns (logits, caches)."""
        cfg = self.cfg
        pos = caches["pos"]
        positions = decode_positions(cfg, pos)
        if token.ndim == 2:
            x = self.embed(params, token)
        else:
            x = token.astype(_dtype_of(cfg))

        new_stack: Dict[str, Any] = {}
        if self.n_periods > 0:
            def body(x, inp):
                p_params, p_cache = inp
                new_c = {}
                for p, kind in enumerate(self.pattern):
                    bp = self.gather_fn(p_params[f"pos{p}"])
                    x, c = blocks.apply_block_decode(
                        kind, bp, x, p_cache[f"pos{p}"],
                        cfg, pos, positions)
                    new_c[f"pos{p}"] = c
                return x, new_c
            x, new_stack = jax.lax.scan(
                body, x, (params["stack"], caches["stack"]))

        new_tail: Dict[str, Any] = {}
        for i, kind in enumerate(self.tail_kinds):
            x, c = blocks.apply_block_decode(
                kind, params["tail"][str(i)], x, caches["tail"][str(i)],
                cfg, pos, positions)
            new_tail[str(i)] = c

        x = layers.apply_norm(cfg.norm, params["final_norm"], x)
        logits = self.unembed(params, x)
        return logits, {"stack": new_stack, "tail": new_tail, "pos": pos + 1}

    # -- paged decode / chunked prefill (block-table KV) --------------------

    def decode_step_paged(self, params, token: jax.Array, pools: Dict[str, Any],
                          table: jax.Array, pos: jax.Array
                          ) -> Tuple[jax.Array, Dict[str, Any]]:
        """One decode step over block pools from ``init_paged_pools``.

        token: (B, 1) int32; table: (B, nc) int32 block table shared by
        every layer; pos: scalar int32 absolute position. Position state
        lives on the host (the slot group), not in the cache pytree.

        Two lowerings of the same math: the Pallas path writes then
        attends inside the layer scan (the kernel reads through the
        table, and TPU scans don't pay for the pool carry); the XLA path
        gathers every layer's KV through the table *before* the scan and
        scatters the new rows *after* it, because a ``lax.scan``-carried
        pool is double-buffered — a full pool copy per layer per step."""
        from repro.models.attention import _use_paged_kernel
        from repro.models.paged_cache import PagedKVCache
        cfg = self.cfg
        positions = decode_positions(cfg, pos)
        if token.ndim == 2:
            x = self.embed(params, token)
        else:
            x = token.astype(_dtype_of(cfg))

        if _use_paged_kernel():
            new_stack: Dict[str, Any] = {}
            if self.n_periods > 0:
                def body(x, inp):
                    p_params, p_pool = inp
                    new_p = {}
                    for p, _ in enumerate(self.pattern):
                        bp = self.gather_fn(p_params[f"pos{p}"])
                        x, c = blocks.apply_block_decode_paged(
                            bp, x, p_pool[f"pos{p}"], cfg, pos, positions,
                            table)
                        new_p[f"pos{p}"] = c
                    return x, new_p
                x, new_stack = jax.lax.scan(
                    body, x, (params["stack"], pools["stack"]))

            new_tail: Dict[str, Any] = {}
            for i, _ in enumerate(self.tail_kinds):
                x, c = blocks.apply_block_decode_paged(
                    params["tail"][str(i)], x, pools["tail"][str(i)], cfg,
                    pos, positions, table)
                new_tail[str(i)] = c

            x = layers.apply_norm(cfg.norm, params["final_norm"], x)
            logits = self.unembed(params, x)
            return logits, {"stack": new_stack, "tail": new_tail}

        B = table.shape[0]
        some_pool = (next(iter(pools["stack"].values())) if pools["stack"]
                     else next(iter(pools["tail"].values())))
        bs = some_pool.k.shape[-3]
        col = (pos // bs).astype(jnp.int32)
        off = (pos % bs).astype(jnp.int32)
        bids = jax.lax.dynamic_index_in_dim(table, col, axis=1,
                                            keepdims=False)  # (B,)

        new_stack = {}
        if self.n_periods > 0:
            gathered = {}
            for p, _ in enumerate(self.pattern):
                pc = pools["stack"][f"pos{p}"]
                kg = pc.k[:, table]  # (n_p, B, nc, bs, Hkv, D)
                vg = pc.v[:, table]
                gathered[f"pos{p}"] = (
                    kg.reshape(kg.shape[0], B, -1, *pc.k.shape[-2:]),
                    vg.reshape(vg.shape[0], B, -1, *pc.v.shape[-2:]))

            def body(x, inp):
                p_params, p_g = inp
                kvs = {}
                for p, _ in enumerate(self.pattern):
                    bp = self.gather_fn(p_params[f"pos{p}"])
                    kg, vg = p_g[f"pos{p}"]
                    x, kv = blocks.apply_block_decode_paged_gathered(
                        bp, x, kg, vg, cfg, pos, positions)
                    kvs[f"pos{p}"] = kv
                return x, kvs
            x, kvs = jax.lax.scan(body, x, (params["stack"], gathered))
            for p, _ in enumerate(self.pattern):
                pc = pools["stack"][f"pos{p}"]
                k1, v1 = kvs[f"pos{p}"]  # (n_p, B, Hkv, D)
                new_stack[f"pos{p}"] = PagedKVCache(
                    k=pc.k.at[:, bids, off].set(k1),
                    v=pc.v.at[:, bids, off].set(v1))

        new_tail = {}
        for i, _ in enumerate(self.tail_kinds):
            pc = pools["tail"][str(i)]
            kg = pc.k[table].reshape(B, -1, *pc.k.shape[-2:])
            vg = pc.v[table].reshape(B, -1, *pc.v.shape[-2:])
            x, (k1, v1) = blocks.apply_block_decode_paged_gathered(
                params["tail"][str(i)], x, kg, vg, cfg, pos, positions)
            new_tail[str(i)] = PagedKVCache(k=pc.k.at[bids, off].set(k1),
                                            v=pc.v.at[bids, off].set(v1))

        x = layers.apply_norm(cfg.norm, params["final_norm"], x)
        logits = self.unembed(params, x)
        return logits, {"stack": new_stack, "tail": new_tail}

    def prefill_chunk_paged(self, params, tokens: jax.Array,
                            pools: Dict[str, Any], table: jax.Array,
                            start: jax.Array, last_index: jax.Array
                            ) -> Tuple[jax.Array, Dict[str, Any]]:
        """One fixed-size chunk of a paged prefill.

        tokens: (B, C) int32 (chunk-padded past the prompt); start: scalar
        block-multiple absolute position of the chunk; last_index: index
        *within the chunk* whose logits to return (the prompt's final
        token on the last chunk — meaningless earlier, cheap either way).
        Text-only (no mrope / frontends — the engine enforces this).

        Like the XLA decode path, KV is gathered through the table before
        the layer scan and the chunk's blocks are scattered after it, so
        the pools never ride the scan carry (which would double-buffer a
        full pool copy per layer per chunk)."""
        from repro.models.paged_cache import PagedKVCache
        cfg = self.cfg
        B, C = tokens.shape
        positions = (start + jnp.arange(C)).astype(jnp.int32)
        x = self.embed(params, tokens)

        some_pool = (next(iter(pools["stack"].values())) if pools["stack"]
                     else next(iter(pools["tail"].values())))
        bs = some_pool.k.shape[-3]
        ncb = C // bs
        c0 = (start // bs).astype(jnp.int32)
        bids = jax.lax.dynamic_slice_in_dim(table, c0, ncb, axis=1)  # (B,ncb)

        new_stack: Dict[str, Any] = {}
        if self.n_periods > 0:
            gathered = {}
            for p, _ in enumerate(self.pattern):
                pc = pools["stack"][f"pos{p}"]
                kg = pc.k[:, table]
                vg = pc.v[:, table]
                gathered[f"pos{p}"] = (
                    kg.reshape(kg.shape[0], B, -1, *pc.k.shape[-2:]),
                    vg.reshape(vg.shape[0], B, -1, *pc.v.shape[-2:]))

            def body(x, inp):
                p_params, p_g = inp
                kvs = {}
                for p, _ in enumerate(self.pattern):
                    bp = self.gather_fn(p_params[f"pos{p}"])
                    kg, vg = p_g[f"pos{p}"]
                    x, kv = blocks.apply_block_chunk_paged_gathered(
                        bp, x, kg, vg, cfg, start, positions)
                    kvs[f"pos{p}"] = kv
                return self.shard_fn(x), kvs
            x, kvs = jax.lax.scan(body, x, (params["stack"], gathered))
            for p, _ in enumerate(self.pattern):
                pc = pools["stack"][f"pos{p}"]
                kc, vc = kvs[f"pos{p}"]  # (n_p, B, C, Hkv, D)
                n_p = kc.shape[0]
                new_stack[f"pos{p}"] = PagedKVCache(
                    k=pc.k.at[:, bids].set(
                        kc.reshape(n_p, B, ncb, bs, *pc.k.shape[-2:])),
                    v=pc.v.at[:, bids].set(
                        vc.reshape(n_p, B, ncb, bs, *pc.v.shape[-2:])))

        new_tail: Dict[str, Any] = {}
        for i, _ in enumerate(self.tail_kinds):
            pc = pools["tail"][str(i)]
            kg = pc.k[table].reshape(B, -1, *pc.k.shape[-2:])
            vg = pc.v[table].reshape(B, -1, *pc.v.shape[-2:])
            x, (kc, vc) = blocks.apply_block_chunk_paged_gathered(
                params["tail"][str(i)], x, kg, vg, cfg, start, positions)
            new_tail[str(i)] = PagedKVCache(
                k=pc.k.at[bids].set(
                    kc.reshape(B, ncb, bs, *pc.k.shape[-2:])),
                v=pc.v.at[bids].set(
                    vc.reshape(B, ncb, bs, *pc.v.shape[-2:])))

        x = layers.apply_norm(cfg.norm, params["final_norm"], x)
        xi = jax.lax.dynamic_index_in_dim(x, last_index, axis=1,
                                          keepdims=True)
        logits = self.unembed(params, xi)
        return logits, {"stack": new_stack, "tail": new_tail}


# ---------------------------------------------------------------------------
# Chunked cross-entropy (never materializes full (B, S, V) logits)
# ---------------------------------------------------------------------------

def _chunked_ce(model: Model, params, x: jax.Array, labels: jax.Array,
                mask: jax.Array, chunk: int = 0
                ) -> Tuple[jax.Array, jax.Array]:
    B, S, d = x.shape
    if chunk <= 0:
        chunk = S if S <= 512 else 512
    n = S // chunk if S % chunk == 0 else None
    if n is None:                       # ragged: fall back to single shot
        logits = model.unembed(params, x).astype(jnp.float32)
        return _ce_from_logits(logits, labels, mask)

    xc = x.reshape(B, n, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(B, n, chunk).swapaxes(0, 1)
    mc = mask.reshape(B, n, chunk).swapaxes(0, 1)

    def body(carry, inp):
        tot, cnt, correct = carry
        xi, li, mi = inp
        # chunk stays batch-sharded only; the model axis carries the vocab
        # shard of the head (seq-sharding here would force GSPMD to gather
        # the whole residual per chunk — see EXPERIMENTS.md §Perf)
        from repro.sharding.logical import constrain as _constrain
        xi = _constrain(xi, ("batch", None, None))
        logits = model.unembed(params, xi).astype(jnp.float32)
        logits = _constrain(logits, ("batch", None, "vocab"))
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, li[..., None].astype(jnp.int32),
                                     axis=-1)[..., 0]
        tot = tot + jnp.sum((lse - picked) * mi)
        cnt = cnt + jnp.sum(mi)
        hit = (jnp.argmax(logits, axis=-1) == li).astype(jnp.float32)
        correct = correct + jnp.sum(hit * mi)
        return (tot, cnt, correct), None

    (tot, cnt, correct), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0), jnp.float32(0.0)),
        (xc, lc, mc))
    cnt = jnp.maximum(cnt, 1.0)
    return tot / cnt, correct / cnt


def _ce_from_logits(logits, labels, mask):
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(
        logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    ce = jnp.sum((lse - picked) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    hit = (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
    acc = jnp.sum(hit * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return ce, acc

"""Block-paged KV storage for the serving engine (vLLM-style).

The contiguous serve path gives every decode slot a private
``(max_seq, n_kv, head_dim)`` cache per layer, so admission copies the
prefill cache in, compaction physically gathers rows, and short requests
pay for ``max_seq`` keys on every decode step. This module replaces that
with an indirection the attention kernel reads through:

* one shared **pool** of fixed-size blocks per attention stack — block
  ``b`` of every layer belongs to the same logical block, so a single
  per-slot **block table** (host-side ``(width, n_cols)`` int32) covers
  the whole model;
* admission/refill/compaction rewrite the table (pointer moves +
  refcount updates) instead of gathering cache rows;
* requests with a common prompt head share their full prefix blocks
  copy-on-write: blocks are refcounted, freed at zero, and the *frontier*
  (partially filled) block is always private per row — so the "write"
  half of copy-on-write never has to copy.

Two block ids are reserved pool-wide:

``ZERO_BLOCK`` (0)
    never written; padded table columns point here so a power-of-two
    padded device table stays valid (reads are masked by position).
``SCRATCH_BLOCK`` (1)
    the pad-row sink: rows left behind by power-of-two compaction still
    execute the decode kernel, and their writes land here (reads of the
    resulting garbage are discarded with the pad row's output).
"""
from __future__ import annotations

from collections import deque
from typing import Any, Dict, Hashable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTN

#: ids below this are never allocated: 0 = zero/dummy, 1 = pad scratch
RESERVED_BLOCKS = 2
ZERO_BLOCK = 0
SCRATCH_BLOCK = 1


class PagedKVCache(NamedTuple):
    """One attention stack's block pool.

    k, v: ``(n_blocks, block_size, n_kv, head_dim)`` — or with a leading
    ``n_periods`` axis for scanned (stacked) layers. Block ``b`` holds
    ``block_size`` consecutive token positions of whichever row the block
    table maps to it; absolute positions are implicit (column ``c``,
    offset ``o`` is position ``c * block_size + o``)."""

    k: jax.Array
    v: jax.Array


class BlockAllocator:
    """Host-side free list + refcounts + prefix-share registry.

    The registry maps a hashable prefix key to a block id so cohorts with
    a common prompt head reuse blocks instead of recomputing/storing
    them; ``decref`` to zero returns the block to the free list and
    unpublishes it. Purely host-side bookkeeping — device pools are only
    ever *indexed* by the ids this hands out."""

    def __init__(self, n_blocks: int):
        if n_blocks <= RESERVED_BLOCKS:
            raise ValueError(f"need more than {RESERVED_BLOCKS} blocks "
                             f"(got {n_blocks}); ids 0/1 are reserved")
        self.n_blocks = n_blocks
        self._free: deque = deque(range(RESERVED_BLOCKS, n_blocks))
        self._ref = np.zeros(n_blocks, np.int64)
        self._registry: Dict[Hashable, int] = {}
        self._block_key: Dict[int, Hashable] = {}
        self.peak_blocks = 0
        self.shared_hits = 0

    @property
    def blocks_in_use(self) -> int:
        return self.n_blocks - RESERVED_BLOCKS - len(self._free)

    @property
    def blocks_free(self) -> int:
        return len(self._free)

    def alloc(self) -> int:
        """A fresh private block (refcount 1)."""
        if not self._free:
            raise RuntimeError(
                f"KV block pool exhausted ({self.n_blocks} blocks); size "
                f"the engine's pool for max_batch x ceil(max_seq/page_size)")
        bid = self._free.popleft()
        self._ref[bid] = 1
        self.peak_blocks = max(self.peak_blocks, self.blocks_in_use)
        return bid

    def incref(self, bid: int, *, shared: bool = False) -> None:
        """Add a reference. ``shared=True`` also counts a shared hit —
        intra-cohort dedup increfs directly (no registry round-trip) but
        is prefix sharing all the same."""
        self._ref[bid] += 1
        if shared:
            self.shared_hits += 1

    def decref(self, bid: int) -> None:
        self._ref[bid] -= 1
        if self._ref[bid] == 0:
            key = self._block_key.pop(bid, None)
            if key is not None:
                self._registry.pop(key, None)
            self._free.append(bid)
        elif self._ref[bid] < 0:
            raise RuntimeError(f"block {bid} decref'd below zero")

    def refcount(self, bid: int) -> int:
        return int(self._ref[bid])

    def share(self, key: Hashable) -> Optional[int]:
        """Reuse the block published under ``key``: bumps its refcount
        and the shared-hit counter. None when nothing is published."""
        bid = self._registry.get(key)
        if bid is None:
            return None
        self._ref[bid] += 1
        self.shared_hits += 1
        return bid

    def publish(self, key: Hashable, bid: int) -> None:
        """Make ``bid`` reusable by later cohorts under ``key`` (the
        registry holds no refcount of its own — the entry dies with the
        block's last reference)."""
        self._registry[key] = bid
        self._block_key[bid] = key

    def reset_stats(self) -> None:
        """Restart peak/shared accounting from the current occupancy
        (benchmarks call this between a warmup drain and a timed one)."""
        self.peak_blocks = self.blocks_in_use
        self.shared_hits = 0


def paged_compatible(cfg) -> bool:
    """Whether this model can serve from paged KV: every mixer is global
    causal attention (recurrent states and rolling sliding-window caches
    have no block-table analogue here — those configs keep the
    contiguous layout)."""
    return (all(k == ATTN for k in cfg.layer_kinds())
            and cfg.sliding_window == 0 and cfg.causal)


def init_paged_pools(model, n_blocks: int, block_size: int
                     ) -> Dict[str, Any]:
    """Zeroed block pools shaped like the model's cache pytree: one
    :class:`PagedKVCache` per scanned pattern position (leading
    ``n_periods`` axis, so ``lax.scan`` can carry it) plus one per tail
    layer. Block ``b`` in every pool belongs to the same logical block."""
    cfg = model.cfg
    dtype = jnp.dtype(cfg.dtype)
    shape = (n_blocks, block_size, cfg.n_kv_heads, cfg.head_dim)

    def pool(lead=()):
        # distinct zero buffers: the engine donates the pools into jitted
        # steps, and aliased k/v would be the same donated buffer twice
        return PagedKVCache(k=jnp.zeros(lead + shape, dtype),
                            v=jnp.zeros(lead + shape, dtype))

    stack: Dict[str, Any] = {}
    if model.n_periods > 0:
        for p, _ in enumerate(model.pattern):
            stack[f"pos{p}"] = pool((model.n_periods,))
    tail = {str(i): pool() for i, _ in enumerate(model.tail_kinds)}
    return {"stack": stack, "tail": tail}


def scatter_prefill_blocks(pools: Dict[str, Any], caches: Dict[str, Any],
                           rows: Sequence[int], cols: Sequence[int],
                           bids: Sequence[int], *, block_size: int
                           ) -> Dict[str, Any]:
    """Copy whole blocks out of a dense prefill cache into the pools.

    ``caches`` comes from ``Model.prefill`` run at a block-multiple cache
    length; entry ``m`` copies block ``cols[m]`` of prefill row
    ``rows[m]`` into pool block ``bids[m]`` — in every layer at once
    (one block table serves the whole model). Shared (registry-hit)
    blocks simply don't appear in the worklist."""
    if not len(bids):
        return pools
    rows = jnp.asarray(rows, jnp.int32)
    cols = jnp.asarray(cols, jnp.int32)
    bids = jnp.asarray(bids, jnp.int32)
    bs = block_size

    new_stack: Dict[str, Any] = {}
    for name, pc in pools["stack"].items():
        cc = caches["stack"][name]
        n_p, U, S, H, D = cc.k.shape
        kb = cc.k.reshape(n_p, U, S // bs, bs, H, D)
        vb = cc.v.reshape(n_p, U, S // bs, bs, H, D)
        new_stack[name] = PagedKVCache(
            k=pc.k.at[:, bids].set(kb[:, rows, cols].astype(pc.k.dtype)),
            v=pc.v.at[:, bids].set(vb[:, rows, cols].astype(pc.v.dtype)))
    new_tail: Dict[str, Any] = {}
    for name, pc in pools["tail"].items():
        cc = caches["tail"][name]
        U, S, H, D = cc.k.shape
        kb = cc.k.reshape(U, S // bs, bs, H, D)
        vb = cc.v.reshape(U, S // bs, bs, H, D)
        new_tail[name] = PagedKVCache(
            k=pc.k.at[bids].set(kb[rows, cols].astype(pc.k.dtype)),
            v=pc.v.at[bids].set(vb[rows, cols].astype(pc.v.dtype)))
    return {"stack": new_stack, "tail": new_tail}

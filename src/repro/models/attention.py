"""Attention: GQA / MQA / MHA, causal / bidirectional / sliding-window.

Two execution paths:

* ``blockwise_attention`` — memory-efficient online-softmax attention written
  in pure jnp + lax.scan (never materializes the (S, S) score matrix). This is
  the XLA path used by the distributed dry-run (Pallas does not lower to the
  CPU backend) and the numerical oracle for the Pallas flash kernel.
* ``repro.kernels.flash_attention`` — the Pallas TPU kernel (same math).

KV cache layout (decode): a *rolling* cache of ``cache_len`` slots with an
absolute-position side array, which unifies full attention
(cache_len == seq_len, never wraps) and sliding-window attention
(cache_len == window, wraps) in one code path.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers

NEG_INF = -1e30


class KVCache(NamedTuple):
    """Rolling KV cache for one attention stack.

    k, v: (B, cache_len, n_kv, head_dim) — written at slot ``pos % cache_len``.
    slot_pos: (cache_len,) int32 absolute position held by each slot (-1 empty).
    """

    k: jax.Array
    v: jax.Array
    slot_pos: jax.Array

    @staticmethod
    def init(batch: int, cache_len: int, n_kv: int, head_dim: int, dtype) -> "KVCache":
        return KVCache(
            k=jnp.zeros((batch, cache_len, n_kv, head_dim), dtype),
            v=jnp.zeros((batch, cache_len, n_kv, head_dim), dtype),
            slot_pos=jnp.full((cache_len,), -1, jnp.int32),
        )


def cache_len_for(seq_len: int, window: int) -> int:
    return seq_len if window <= 0 else min(window, seq_len)


# ---------------------------------------------------------------------------
# Masking
# ---------------------------------------------------------------------------

def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, *, causal: bool,
               window: int) -> jax.Array:
    """Additive mask bias (0 or NEG_INF). q_pos: (..., Sq), k_pos: (..., Sk)."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    ok = kp >= 0
    if causal:
        ok &= kp <= qp
    if window > 0:
        ok &= kp > qp - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention in pure jnp — the XLA path + kernel oracle
# ---------------------------------------------------------------------------

def blockwise_attention(
    q: jax.Array,               # (B, Sq, Hq, D)
    k: jax.Array,               # (B, Sk, Hkv, D)
    v: jax.Array,               # (B, Sk, Hkv, D)
    *,
    causal: bool,
    window: int = 0,
    q_positions: Optional[jax.Array] = None,   # (Sq,) absolute positions
    k_positions: Optional[jax.Array] = None,   # (Sk,)
    q_block: int = 512,
    k_block: int = 512,
    scale: Optional[float] = None,
) -> jax.Array:
    """Online-softmax attention, O(Sq·D + block²) memory. Returns (B, Sq, Hq, D)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5

    if q_positions is None:
        q_positions = jnp.arange(Sq, dtype=jnp.int32)
    if k_positions is None:
        k_positions = jnp.arange(Sk, dtype=jnp.int32)

    q_block = min(q_block, Sq)
    k_block = min(k_block, Sk)
    # Pad sequence dims to block multiples.
    pq = (-Sq) % q_block
    pk = (-Sk) % k_block
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, (0, pq), constant_values=-(10 ** 9))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        k_positions = jnp.pad(k_positions, (0, pk), constant_values=-1)
    nq, nk = (Sq + pq) // q_block, (Sk + pk) // k_block

    # (B, nq, bq, Hkv, g, D) — inputs stay in model dtype (bf16); blocks are
    # upcast inside the scan body only (keeps the big resharded/gathered
    # arrays half-width; the f32 math happens on block-sized tiles).
    qb = q.reshape(B, nq, q_block, Hkv, g, D)
    kb = k.reshape(B, nk, k_block, Hkv, D)
    vb = v.reshape(B, nk, k_block, Hkv, D)
    qpb = q_positions.reshape(nq, q_block)
    kpb = k_positions.reshape(nk, k_block)

    # jax.checkpoint = flash-attention backward: nothing from the inner
    # online-softmax scan is saved between fwd and bwd; the kv sweep is
    # recomputed per q-chunk during the backward pass. Without it, autodiff
    # saves the (B, bq, H, g, D) accumulator for EVERY kv block.
    @jax.checkpoint
    def q_chunk(qi, qp):
        # qi: (B, bq, Hkv, g, D); qp: (bq,)
        m0 = jnp.full((B, q_block, Hkv, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, q_block, Hkv, g), jnp.float32)
        a0 = jnp.zeros((B, q_block, Hkv, g, D), jnp.float32)

        def kv_step(carry, inp):
            m, l, acc = carry
            kj, vj, kp = inp                      # (B, bk, Hkv, D), (bk,)
            s = jnp.einsum("bqhgd,bkhd->bqhgk", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            s = s + _mask_bias(qp, kp, causal=causal, window=window)[
                None, :, None, None, :]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bqhgk,bkhd->bqhgd", p.astype(vj.dtype), vj,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kb.swapaxes(0, 1), vb.swapaxes(0, 1), kpb))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    out = jax.lax.map(lambda args: q_chunk(*args), (qb.swapaxes(0, 1), qpb))
    out = out.swapaxes(0, 1).reshape(B, nq * q_block, Hq, D)[:, :Sq]
    return out.astype(q.dtype)


def direct_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool, window: int = 0,
    q_positions: Optional[jax.Array] = None,
    k_positions: Optional[jax.Array] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """Materialized-score attention (decode path, Sq small)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    if q_positions is None:
        q_positions = jnp.arange(Sq, dtype=jnp.int32)
    if k_positions is None:
        k_positions = jnp.arange(Sk, dtype=jnp.int32)
    qf = q.reshape(B, Sq, Hkv, g, D).astype(jnp.float32) * scale
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, k.astype(jnp.float32))
    s = s + _mask_bias(q_positions, k_positions, causal=causal, window=window)[
        None, :, None, None, :]
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention layer (projections + rope + qk-norm) — train/prefill and decode
# ---------------------------------------------------------------------------

def qkv_project(params: dict, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, params["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if cfg.qk_norm:
        q = layers.rms_norm(q, params["q_scale"])
        k = layers.rms_norm(k, params["k_scale"])
    return q, k, v


def attention_block(params: dict, x: jax.Array, cfg, *, positions,
                    window: int) -> jax.Array:
    """Full-sequence attention (train / prefill). x: (B, S, d)."""
    q, k, v = qkv_project(params, x, cfg)
    if cfg.rope != "none":
        q = layers.apply_positional(cfg.rope, q, positions, cfg.rope_theta)
        k = layers.apply_positional(cfg.rope, k, positions, cfg.rope_theta)
    seq_pos = None  # blockwise uses iota positions; mrope handled in projections
    out = blockwise_attention(q, k, v, causal=cfg.causal, window=window)
    return jnp.einsum("bshe,hed->bsd", out, params["wo"])


def attention_decode(params: dict, x: jax.Array, cache: KVCache, cfg, *,
                     pos: jax.Array, positions, window: int
                     ) -> Tuple[jax.Array, KVCache]:
    """One-token decode. x: (B, 1, d); pos: scalar int32 absolute position."""
    q, k, v = qkv_project(params, x, cfg)
    if cfg.rope != "none":
        q = layers.apply_positional(cfg.rope, q, positions, cfg.rope_theta)
        k = layers.apply_positional(cfg.rope, k, positions, cfg.rope_theta)
    cache_len = cache.k.shape[1]
    slot = (pos % cache_len).astype(jnp.int32)
    k_new = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                         (0, slot, 0, 0))
    v_new = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                         (0, slot, 0, 0))
    slot_pos = cache.slot_pos.at[slot].set(pos.astype(jnp.int32))
    out = direct_attention(
        q, k_new, v_new, causal=cfg.causal, window=window,
        q_positions=pos[None].astype(jnp.int32),
        k_positions=slot_pos)
    out = jnp.einsum("bshe,hed->bsd", out, params["wo"])
    return out, KVCache(k_new, v_new, slot_pos)


def fill_cache_from_prefill(k: jax.Array, v: jax.Array, cache_len: int,
                            dtype) -> KVCache:
    """Build the rolling cache holding the last ``cache_len`` of S tokens.

    Slot s holds token t(s) = s + cache_len * floor((S-1-s)/cache_len) —
    the last token whose index ≡ s (mod cache_len). Deterministic gather.
    """
    B, S, Hkv, D = k.shape
    s_idx = jnp.arange(cache_len, dtype=jnp.int32)
    t_idx = s_idx + cache_len * ((S - 1 - s_idx) // cache_len)
    valid = t_idx < S  # always true when cache_len <= S
    t_gather = jnp.clip(t_idx, 0, S - 1)
    return KVCache(
        k=jnp.take(k, t_gather, axis=1).astype(dtype),
        v=jnp.take(v, t_gather, axis=1).astype(dtype),
        slot_pos=jnp.where(valid, t_idx, -1),
    )


def attention_prefill(params: dict, x: jax.Array, cfg, *, positions,
                      window: int, cache_len: int
                      ) -> Tuple[jax.Array, KVCache]:
    """Full-sequence attention that also returns the rolling cache."""
    q, k, v = qkv_project(params, x, cfg)
    if cfg.rope != "none":
        q = layers.apply_positional(cfg.rope, q, positions, cfg.rope_theta)
        k = layers.apply_positional(cfg.rope, k, positions, cfg.rope_theta)
    out = blockwise_attention(q, k, v, causal=cfg.causal, window=window)
    out = jnp.einsum("bshe,hed->bsd", out, params["wo"])
    cache = fill_cache_from_prefill(k, v, cache_len, k.dtype)
    return out, cache


# ---------------------------------------------------------------------------
# Paged attention layer — decode and chunked prefill through a block table
# ---------------------------------------------------------------------------

def _use_paged_kernel() -> bool:
    """Pallas on TPU, XLA gather+``direct_attention`` elsewhere (the same
    math; Pallas does not lower to the CPU backend). Overridable with
    REPRO_PAGED_BACKEND=pallas|xla for kernel testing."""
    import os
    forced = os.environ.get("REPRO_PAGED_BACKEND", "auto")
    if forced == "pallas":
        return True
    if forced == "xla":
        return False
    return jax.default_backend() == "tpu"


def _paged_attend(q: jax.Array, pool: "PagedKVCache", table: jax.Array,
                  pos: jax.Array) -> jax.Array:
    """Decode attention over pool blocks. q: (B, 1, Hq, D); table: (B, nc)
    int32; pos: scalar absolute position of the (already written) query
    token. Slot (c, o) of a row holds absolute position c*bs + o, so the
    causal mask alone rejects every not-yet-written slot — including the
    zero block behind padded table columns."""
    B, _, Hq, D = q.shape
    bs = pool.k.shape[-3]
    nc = table.shape[1]
    if _use_paged_kernel():
        from repro.kernels.paged_attention import paged_attention
        lens = jnp.broadcast_to(pos.astype(jnp.int32) + 1, (B,))
        out = paged_attention(q[:, 0], pool.k, pool.v, table, lens)
        return out[:, None]
    kg = pool.k[table].reshape(B, nc * bs, *pool.k.shape[-2:])
    vg = pool.v[table].reshape(B, nc * bs, *pool.v.shape[-2:])
    return direct_attention(
        q, kg, vg, causal=True,
        q_positions=pos[None].astype(jnp.int32),
        k_positions=jnp.arange(nc * bs, dtype=jnp.int32))


def attention_decode_paged(params: dict, x: jax.Array, pool: "PagedKVCache",
                           cfg, *, pos: jax.Array, positions,
                           table: jax.Array):
    """One-token decode writing/reading KV through the block table.

    x: (B, 1, d); pool k/v: (n_blocks, bs, Hkv, D) shared across rows;
    table: (B, nc) int32. The new K/V lands in block ``table[b, pos//bs]``
    at offset ``pos % bs`` (pad rows' tables point that column at the
    scratch block)."""
    from repro.models.paged_cache import PagedKVCache
    q, k, v = qkv_project(params, x, cfg)
    if cfg.rope != "none":
        q = layers.apply_positional(cfg.rope, q, positions, cfg.rope_theta)
        k = layers.apply_positional(cfg.rope, k, positions, cfg.rope_theta)
    bs = pool.k.shape[1]
    col = (pos // bs).astype(jnp.int32)
    off = (pos % bs).astype(jnp.int32)
    bids = jax.lax.dynamic_index_in_dim(table, col, axis=1, keepdims=False)
    k_new = pool.k.at[bids, off].set(k[:, 0].astype(pool.k.dtype))
    v_new = pool.v.at[bids, off].set(v[:, 0].astype(pool.v.dtype))
    new_pool = PagedKVCache(k_new, v_new)
    out = _paged_attend(q, new_pool, table, pos)
    out = jnp.einsum("bshe,hed->bsd", out, params["wo"])
    return out, new_pool


def attention_decode_paged_gathered(params: dict, x: jax.Array,
                                    kg: jax.Array, vg: jax.Array, cfg, *,
                                    pos: jax.Array, positions):
    """One-token decode over *pre-gathered* paged KV (XLA fallback path).

    kg/vg: (B, nc*bs, Hkv, D) — the row's table-gathered KV as of *before*
    this step. Carrying whole pools through ``lax.scan`` double-buffers
    them (a full pool copy per layer per step), so on the XLA path the
    caller gathers once outside the scan and this layer only *reads*: the
    fresh K/V is appended at attend time (its stale pool slot masked with
    position -1, which ``_mask_bias`` always rejects) and returned so the
    caller can scatter every layer's new row with one post-scan update."""
    q, k, v = qkv_project(params, x, cfg)
    if cfg.rope != "none":
        q = layers.apply_positional(cfg.rope, q, positions, cfg.rope_theta)
        k = layers.apply_positional(cfg.rope, k, positions, cfg.rope_theta)
    k1 = k[:, 0].astype(kg.dtype)
    v1 = v[:, 0].astype(vg.dtype)
    iota = jnp.arange(kg.shape[1], dtype=jnp.int32)
    kpos = jnp.where(iota == pos, jnp.int32(-1), iota)
    out = direct_attention(
        q, jnp.concatenate([kg, k1[:, None]], axis=1),
        jnp.concatenate([vg, v1[:, None]], axis=1), causal=True,
        q_positions=pos[None].astype(jnp.int32),
        k_positions=jnp.concatenate([kpos, pos[None].astype(jnp.int32)]))
    out = jnp.einsum("bshe,hed->bsd", out, params["wo"])
    return out, (k1, v1)


def attention_prefill_chunk_paged_gathered(params: dict, x: jax.Array,
                                           kg: jax.Array, vg: jax.Array,
                                           cfg, *, start: jax.Array,
                                           positions):
    """One chunk of a paged prefill over pre-gathered KV (same pool-copy
    avoidance as :func:`attention_decode_paged_gathered`). Gathered slots
    at/after ``start`` are this chunk's own stale storage — masked with
    position -1 — and the chunk's fresh K/V is appended at positions
    ``start + [0, C)``; the caller scatters the returned chunk K/V into
    the pools after the layer scan."""
    q, k, v = qkv_project(params, x, cfg)
    if cfg.rope != "none":
        q = layers.apply_positional(cfg.rope, q, positions, cfg.rope_theta)
        k = layers.apply_positional(cfg.rope, k, positions, cfg.rope_theta)
    C = x.shape[1]
    kc = k.astype(kg.dtype)
    vc = v.astype(vg.dtype)
    iota = jnp.arange(kg.shape[1], dtype=jnp.int32)
    kpos = jnp.where(iota < start, iota, jnp.int32(-1))
    qpos = (start + jnp.arange(C)).astype(jnp.int32)
    out = direct_attention(
        q, jnp.concatenate([kg, kc], axis=1),
        jnp.concatenate([vg, vc], axis=1), causal=True,
        q_positions=qpos, k_positions=jnp.concatenate([kpos, qpos]))
    out = jnp.einsum("bshe,hed->bsd", out, params["wo"])
    return out, (kc, vc)


def attention_prefill_chunk_paged(params: dict, x: jax.Array,
                                  pool: "PagedKVCache", cfg, *,
                                  start: jax.Array, positions,
                                  table: jax.Array):
    """One chunk of a paged prefill. x: (B, C, d) with C a multiple of the
    block size and ``start`` (the chunk's first absolute position) a
    block multiple; writes the chunk's C/bs blocks through the table and
    attends causally over everything written so far. Chunk-padding tokens
    past the prompt land at positions the causal mask hides from every
    real query, and decode overwrites them before they become visible."""
    from repro.models.paged_cache import PagedKVCache
    q, k, v = qkv_project(params, x, cfg)
    if cfg.rope != "none":
        q = layers.apply_positional(cfg.rope, q, positions, cfg.rope_theta)
        k = layers.apply_positional(cfg.rope, k, positions, cfg.rope_theta)
    B, C = x.shape[0], x.shape[1]
    bs = pool.k.shape[1]
    nc = table.shape[1]
    ncb = C // bs
    c0 = (start // bs).astype(jnp.int32)
    bids = jax.lax.dynamic_slice_in_dim(table, c0, ncb, axis=1)  # (B, ncb)
    k_new = pool.k.at[bids].set(
        k.reshape(B, ncb, bs, *k.shape[-2:]).astype(pool.k.dtype))
    v_new = pool.v.at[bids].set(
        v.reshape(B, ncb, bs, *v.shape[-2:]).astype(pool.v.dtype))
    kg = k_new[table].reshape(B, nc * bs, *k_new.shape[-2:])
    vg = v_new[table].reshape(B, nc * bs, *v_new.shape[-2:])
    out = direct_attention(
        q, kg, vg, causal=True,
        q_positions=(start + jnp.arange(C)).astype(jnp.int32),
        k_positions=jnp.arange(nc * bs, dtype=jnp.int32))
    out = jnp.einsum("bshe,hed->bsd", out, params["wo"])
    return out, PagedKVCache(k_new, v_new)


def init_attention_params(key, cfg, dtype) -> dict:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers.dense_init(ks[0], (d, hq, hd), dtype, fan_in=d),
        "wk": layers.dense_init(ks[1], (d, hkv, hd), dtype, fan_in=d),
        "wv": layers.dense_init(ks[2], (d, hkv, hd), dtype, fan_in=d),
        "wo": layers.dense_init(ks[3], (hq, hd, d), dtype, fan_in=hq * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq, hd), dtype)
        p["bk"] = jnp.zeros((hkv, hd), dtype)
        p["bv"] = jnp.zeros((hkv, hd), dtype)
    if cfg.qk_norm:
        p["q_scale"] = jnp.zeros((hd,), dtype)
        p["k_scale"] = jnp.zeros((hd,), dtype)
    return p

"""Shared layer primitives: norms, activations, RoPE / M-RoPE, embeddings."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def activation_fn(name: str):
    if name == "gelu":
        return jax.nn.gelu
    if name == "silu":
        return jax.nn.silu
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    if name in ("swiglu", "geglu"):
        # gated variants handled in the FFN itself; this is the gate nonlinearity
        return jax.nn.silu if name == "swiglu" else jax.nn.gelu
    raise ValueError(f"unknown activation {name!r}")


def is_gated(name: str) -> bool:
    return name in ("swiglu", "geglu")


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def apply_norm(kind: str, params: dict, x: jax.Array) -> jax.Array:
    if kind == "rmsnorm":
        return rms_norm(x, params["scale"])
    return layer_norm(x, params["scale"], params["bias"])


def init_norm(kind: str, d: int, dtype) -> dict:
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def group_norm_heads(x: jax.Array, scale: jax.Array, bias: jax.Array,
                     eps: float = 64e-5) -> jax.Array:
    """Per-head group norm used by RWKV-6 on the WKV output.

    x: (..., H, D). Normalizes over D within each head.
    """
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE and Qwen2-VL M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (head_dim // 2,) in float32."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Standard RoPE.

    x: (..., S, H, D); positions: broadcastable to (..., S) int32.
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]                   # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# Qwen2-VL M-RoPE: head_dim sections rotate with (t, h, w) position streams.
# Section split follows the released config: [16, 24, 24] pairs for D=128
# (scaled proportionally for other head dims).
def mrope_sections(head_dim: int) -> Tuple[int, int, int]:
    half = head_dim // 2
    t = round(half * 16 / 64)
    h = round(half * 24 / 64)
    w = half - t - h
    return t, h, w


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float) -> jax.Array:
    """M-RoPE. x: (..., S, H, D); positions3: (3, ..., S) int32 (t,h,w streams)."""
    d = x.shape[-1]
    half = d // 2
    freqs = rope_freqs(d, theta)                       # (D/2,)
    sec = mrope_sections(d)
    # For each frequency slot choose which position stream drives it.
    stream_id = jnp.concatenate([
        jnp.zeros((sec[0],), jnp.int32),
        jnp.ones((sec[1],), jnp.int32),
        jnp.full((sec[2],), 2, jnp.int32),
    ])                                                  # (D/2,)
    # positions3: (3, ..., S) -> (..., S, D/2) by gathering per-slot stream
    pos = jnp.moveaxis(positions3, 0, -1)               # (..., S, 3)
    pos_slot = jnp.take(pos.astype(jnp.float32), stream_id, axis=-1)  # (..., S, D/2)
    ang = pos_slot * freqs                              # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_positional(rope_kind: str, x: jax.Array, positions, theta: float):
    if rope_kind == "rope":
        return apply_rope(x, positions, theta)
    if rope_kind == "mrope":
        return apply_mrope(x, positions, theta)
    return x


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------

def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0.0:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def dense_init(key, shape, dtype, fan_in: Optional[int] = None):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)

"""`repro.api` — the public front door: target-aware pruning sessions.

    from repro.api import PruningSession, Workload, CPruneConfig, TrainHooks

    session = PruningSession(cfg, target="tpu_v5e",
                             workload=Workload(tokens_global=65536),
                             hooks=hooks, pcfg=CPruneConfig(a_g=0.5))
    result = session.prune(strategy="cprune")   # netadapt | uniform_l1 | fpgm
    engine = session.serve(max_batch=8)
    session.save("ckpt/");  PruningSession.resume("ckpt/", hooks=hooks)

Targets (`targets.py`): registry of :class:`TargetSpec` device profiles —
``tpu_v5e`` (the seed cost model, bit-identical), ``tpu_v4``, ``edge`` —
threaded through the tuner, the tuning-cache fingerprints, the latency
model, and CPrune, so one prune loop produces per-target architectures.

Oracles (`repro.core.oracle`, re-exported here): pluggable scoring
backends — ``analytic`` (the closed-form model, default), ``measured``
(times the repo's Pallas kernels), ``replay`` (deterministic playback of
a recorded measurement log) — selected per session
(``PruningSession(oracle=...)``), per run (``session.prune(oracle=...)``),
and recorded with ``session.calibrate()``.

Strategies (`strategies.py`): registry unifying Algorithm 1 and the
baselines behind one call with a common :class:`PruneResult`.

Artifacts (`artifact.py`): the pipeline's exit — ``session.export(path)``
emits a versioned, self-contained :class:`DeploymentArtifact` (params,
config, target constants, tuned program table, oracle/replay log,
metadata, fingerprints) that ``DeploymentArtifact.load`` validates and
``ServeEngine.from_artifact`` serves with no session and no warm caches.

Planning (`planner.py`): the constraint front door —
``plan(cfg, accuracy_floor=..., latency_budget_s=..., targets=[...],
strategies=[...])`` sweeps strategy x target, returns a :class:`Plan`
with the Pareto frontier and a constraint-satisfying ``best``, and
``Plan.export(path)`` emits the winning artifact.
``Plan.export_catalog(path)`` emits the whole frontier as an
``ArtifactCatalog`` that ``repro.serve.router.Router`` dispatches
per-request SLOs over (``Request(latency_budget_s=...,
accuracy_floor=...)``).

The `repro.core` modules remain importable as before; this package only
composes them.
"""
from repro.api.artifact import (ArtifactError, DeploymentArtifact,
                                GenerationStore)
from repro.api.planner import (Plan, PlanCandidate, PlanError, PlanInputs,
                               plan, replan)
from repro.api.session import PruningSession
from repro.api.strategies import (PruneResult, get_strategy, list_strategies,
                                  register_strategy)
from repro.api.targets import (Target, TargetSpec, get_target, list_targets,
                               register_target)
from repro.core.cprune import CPruneConfig, TrainHooks
from repro.core.oracle import (AnalyticOracle, LatencyOracle, MeasuredOracle,
                               MeasurementConfig, MeasurementLog,
                               ReplayOracle, get_oracle, use_oracle)
from repro.core.tasks import Workload

__all__ = [
    "PruningSession", "PruneResult", "get_strategy", "list_strategies",
    "register_strategy", "Target", "TargetSpec", "get_target",
    "list_targets", "register_target", "CPruneConfig", "TrainHooks",
    "Workload", "AnalyticOracle", "LatencyOracle", "MeasuredOracle",
    "MeasurementConfig", "MeasurementLog", "ReplayOracle", "get_oracle",
    "use_oracle", "ArtifactError", "DeploymentArtifact", "GenerationStore",
    "Plan", "PlanCandidate", "PlanError", "PlanInputs", "plan", "replan",
]

"""Constraint-driven deployment planning — serve a *requirement*, not a
mechanism.

The paper's framing is "support an application with a required target
accuracy"; the user-facing contract is therefore a pair of constraints
(accuracy floor, latency budget), not a strategy name. :func:`plan`
sweeps every registered strategy against every requested target, scores
each candidate with the session machinery, and returns a :class:`Plan`:

    pl = plan(cfg, accuracy_floor=0.6, latency_budget_s=2e-3,
              targets=["tpu_v5e", "edge"], strategies=["cprune", "fpgm"],
              workload=Workload(tokens_global=65536), hooks=hooks)
    pl.frontier            # Pareto-optimal (accuracy up, latency down)
    pl.best                # cheapest candidate satisfying the constraints
    art = pl.export(path)  # the winning DeploymentArtifact
    cat = pl.export_catalog(path)   # the whole frontier, router-servable

The sweep is cheap by construction: all candidates on one target share
the process-wide ProgramCache (keys carry the target+oracle
fingerprints, so targets never cross-contaminate), and CPrune's own
iterations reuse the incremental task-table carry-over — the second
strategy on a target tunes almost nothing.

Every candidate keeps its finished :class:`PruningSession`, so exporting
any of them (not just the winner) is one call; the exported artifact's
latency metadata is the exact number the plan ranked it by (enforced by
tests/test_planner.py).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Sequence, Union

import jax

from repro.api.artifact import DeploymentArtifact
from repro.api.session import PruningSession
from repro.api.strategies import PruneResult
from repro.api.targets import TargetSpec, get_target
from repro.configs.base import ModelConfig
from repro.core.cprune import CPruneConfig, TrainHooks
from repro.core.oracle import LatencyOracle
from repro.core.tasks import Workload
from repro.models.model import init_params


class PlanError(ValueError):
    """No plan candidate satisfies the requested constraints."""


@dataclasses.dataclass
class PlanCandidate:
    """One (strategy, target) arm of the sweep, with its finished session
    kept alive so :meth:`export` can emit the artifact directly."""

    strategy: str
    target: str
    accuracy: float
    latency_s: float
    fps_increase: float
    meets_floor: bool
    meets_budget: bool
    session: PruningSession
    result: PruneResult
    # tensor-parallel degree this arm was priced (and exports) at; the
    # session's workload carries the same value, so ``export`` stamps it
    tp: int = 1

    @property
    def feasible(self) -> bool:
        return self.meets_floor and self.meets_budget

    @property
    def name(self) -> str:
        """Catalog entry name: ``<strategy>@<target>``, qualified by the
        tp degree for sharded arms so tp variants never collide."""
        base = f"{self.strategy}@{self.target}"
        return base if self.tp == 1 else f"{base}@tp{self.tp}"

    def export(self, path: str, **kw) -> DeploymentArtifact:
        """Emit this candidate's :class:`DeploymentArtifact` at ``path``."""
        return self.session.export(path, **kw)

    def describe(self) -> str:
        flag = "ok" if self.feasible else (
            "acc<floor" if not self.meets_floor else "lat>budget")
        shard = "" if self.tp == 1 else f" tp={self.tp}"
        return (f"{self.strategy:>10s} @ {self.target:<8s}{shard} "
                f"acc={self.accuracy:.3f}  latency={self.latency_s*1e3:.3f}ms"
                f"  fps_x={self.fps_increase:.2f}  [{flag}]")


@dataclasses.dataclass
class PlanInputs:
    """Everything :func:`plan` needs to run the same sweep again —
    stashed on the returned :class:`Plan` so :func:`replan` can re-score
    the identical strategy×target arms under a different oracle (the
    autopilot's recalibrated one) without the caller re-supplying hooks,
    params, or constraints."""

    cfg: ModelConfig
    accuracy_floor: float
    latency_budget_s: Optional[float]
    targets: Sequence[Union[str, TargetSpec]]
    strategies: Sequence[str]
    workload: Optional[Workload]
    hooks: Optional[TrainHooks]
    pcfg: Optional[CPruneConfig]
    params: Optional[Dict]
    strategy_kwargs: Optional[Dict[str, Dict]]
    seed: int
    tp: Union[int, Sequence[int], None] = None


@dataclasses.dataclass
class Plan:
    """The sweep's outcome: every candidate, the Pareto frontier, and the
    best constraint-satisfying choice."""

    accuracy_floor: float
    latency_budget_s: Optional[float]
    candidates: List[PlanCandidate]
    inputs: Optional[PlanInputs] = None

    @property
    def frontier(self) -> List[PlanCandidate]:
        """Pareto-optimal candidates (no other candidate is at least as
        accurate AND at least as fast, with one strictly better), sorted
        fastest-first."""
        front = []
        for c in self.candidates:
            dominated = any(
                o.accuracy >= c.accuracy and o.latency_s <= c.latency_s
                and (o.accuracy > c.accuracy or o.latency_s < c.latency_s)
                for o in self.candidates if o is not c)
            if not dominated:
                front.append(c)
        return sorted(front, key=lambda c: (c.latency_s, -c.accuracy))

    @property
    def best(self) -> Optional[PlanCandidate]:
        """Fastest candidate meeting the accuracy floor (and the latency
        budget, when one was given); ties break toward higher accuracy.
        None when nothing satisfies the constraints."""
        feasible = [c for c in self.candidates if c.feasible]
        if not feasible:
            return None
        return min(feasible, key=lambda c: (c.latency_s, -c.accuracy))

    def export(self, path: str, candidate: Optional[PlanCandidate] = None,
               **kw) -> DeploymentArtifact:
        """Emit the winning artifact (or an explicit ``candidate``'s)."""
        cand = candidate or self.best
        if cand is None:
            budget = ("" if self.latency_budget_s is None else
                      f" and latency_budget_s={self.latency_budget_s!r}")
            raise PlanError(
                f"no candidate satisfies accuracy_floor="
                f"{self.accuracy_floor!r}{budget}; candidates:\n"
                + "\n".join(c.describe() for c in self.candidates))
        return cand.export(path, **kw)

    def export_catalog(self, path: str,
                       candidates: Optional[List[PlanCandidate]] = None, *,
                       max_batch: int = 8, max_seq: int = 512):
        """Emit the whole Pareto ``frontier`` (or an explicit candidate
        list) as an :class:`~repro.serve.router.ArtifactCatalog` at
        ``path``: one validated ``DeploymentArtifact`` directory per
        candidate (named ``<strategy>@<target>``) plus a ``catalog.json``
        manifest whose routing numbers — accuracy, ranked latency, and
        the oracle's decode-step prediction at the serve defaults — are
        exactly the artifacts' own metadata. The returned catalog is
        re-loaded from disk, so what you get is what a serving fleet
        (``repro.serve.router.Router``) will read."""
        from repro.serve.router import (ArtifactCatalog, CATALOG_NAME,
                                        CATALOG_VERSION)
        cands = list(candidates) if candidates is not None else self.frontier
        if not cands:
            raise PlanError("no candidates to export as a catalog")
        os.makedirs(path, exist_ok=True)
        entries = []
        for c in cands:
            name = c.name
            art = c.export(os.path.join(path, name), max_batch=max_batch,
                           max_seq=max_seq)
            entries.append({
                "name": name, "path": name,
                "strategy": c.strategy, "target": c.target,
                "accuracy": c.accuracy, "latency_s": c.latency_s,
                "predicted_step_s": art.metadata.get("predicted_step_s"),
                "tuned_digest": art.tuned_digest,
                # the export-time static-analysis stamp, surfaced so a
                # router can see a whole fleet's check status in one read
                "checks": art.checks,
                # tensor-parallel degree (partition stamp) — 1 when the
                # artifact is unsharded, so old manifests parse unchanged
                "tp": art.tp,
            })
        blob = {"version": CATALOG_VERSION,
                "accuracy_floor": self.accuracy_floor,
                "latency_budget_s": self.latency_budget_s,
                "entries": entries}
        tmp = os.path.join(path, CATALOG_NAME + ".tmp")
        with open(tmp, "w") as f:
            json.dump(blob, f, indent=1)
        os.replace(tmp, os.path.join(path, CATALOG_NAME))
        # verification re-read: a catalog is routinely exported on a
        # smaller host than the pod it targets, so skip only the
        # device-availability check of any tp > 1 members
        return ArtifactCatalog.load(path, check_devices=False)

    def summary(self) -> str:
        lines = [c.describe() for c in self.candidates]
        best = self.best
        lines.append(f"best: {best.describe() if best else '<none feasible>'}")
        return "\n".join(lines)


def plan(cfg: ModelConfig, *, accuracy_floor: float,
         latency_budget_s: Optional[float] = None,
         targets: Sequence[Union[str, TargetSpec]] = ("tpu_v5e",),
         strategies: Sequence[str] = ("cprune",),
         workload: Optional[Workload] = None,
         hooks: Optional[TrainHooks] = None,
         pcfg: Optional[CPruneConfig] = None,
         params: Optional[Dict] = None,
         oracle: Union[str, LatencyOracle, None] = None,
         strategy_kwargs: Optional[Dict[str, Dict]] = None,
         seed: int = 0, verbose: bool = False,
         tp: Union[int, Sequence[int], None] = None) -> Plan:
    """Sweep strategy x target under one set of constraints.

    Every arm starts from the *same* initial params (``params``, or a
    fresh ``seed``-keyed init), so accuracy/latency are comparable across
    arms. ``strategy_kwargs`` maps a strategy name to extra ``prune``
    kwargs (e.g. ``{"uniform_l1": {"ratio": 0.25}}``). Latencies are each
    target's own cost-model estimate — comparable within a target and a
    deploy-time budget check across targets.

    ``tp`` adds tensor-parallel degrees to the sweep (``tp=[1, 2]`` runs
    every strategy x target arm at both): sharded arms are priced as
    per-shard GEMMs plus the analytic all-reduce term, so sharding
    competes with pruning on the same latency axis, and their exported
    artifacts carry the partition stamp. ``None`` inherits ``workload``'s
    degree (default 1).

    The floor is threaded into the search itself, not just checked after
    the fact: when no ``pcfg`` is given, the sessions run with
    ``CPruneConfig(a_g=accuracy_floor)`` so CPrune's accuracy gate stops
    at the requirement instead of pruning past it. An explicit ``pcfg``
    wins verbatim (e.g. to deliberately let the loop prune deeper).
    """
    if params is None:
        params = init_params(jax.random.PRNGKey(seed), cfg)
    if pcfg is None:
        pcfg = CPruneConfig(a_g=accuracy_floor)
    if tp is None:
        tps = (workload.tp if workload is not None else 1,)
    elif isinstance(tp, int):
        tps = (tp,)
    else:
        tps = tuple(int(t) for t in tp)
    if any(t < 1 for t in tps):
        raise PlanError(f"tp degrees must be >= 1, got {tps}")
    kwargs = strategy_kwargs or {}
    candidates: List[PlanCandidate] = []
    for target in targets:
        tspec = get_target(target)
        for strategy in strategies:
            for t in tps:
                if workload is None:
                    wl_arm = None if t == 1 \
                        else Workload(tokens_global=65536, tp=t)
                else:
                    wl_arm = workload if workload.tp == t \
                        else dataclasses.replace(workload, tp=t)
                session = PruningSession(cfg, params=params, target=tspec,
                                         oracle=oracle, workload=wl_arm,
                                         hooks=hooks, pcfg=pcfg)
                result = session.prune(strategy=strategy,
                                       **kwargs.get(strategy, {}))
                lat = result.final_latency.total_s
                acc = result.final_acc
                cand = PlanCandidate(
                    strategy=strategy, target=tspec.name, accuracy=acc,
                    latency_s=lat, fps_increase=result.fps_increase,
                    meets_floor=acc >= accuracy_floor,
                    meets_budget=(latency_budget_s is None
                                  or lat <= latency_budget_s),
                    session=session, result=result, tp=t)
                candidates.append(cand)
                if verbose:
                    print(cand.describe())
    inputs = PlanInputs(cfg=cfg, accuracy_floor=accuracy_floor,
                        latency_budget_s=latency_budget_s,
                        targets=tuple(targets), strategies=tuple(strategies),
                        workload=workload, hooks=hooks, pcfg=pcfg,
                        params=params, strategy_kwargs=strategy_kwargs,
                        seed=seed, tp=tp)
    return Plan(accuracy_floor=accuracy_floor,
                latency_budget_s=latency_budget_s, candidates=candidates,
                inputs=inputs)


def replan(prior: Plan, *, oracle: Union[str, LatencyOracle, None],
           accuracy_floor: Optional[float] = None,
           latency_budget_s: Optional[float] = None,
           verbose: bool = False) -> Plan:
    """Run ``prior``'s exact sweep again under a different oracle — the
    replan half of the plan → serve → replan loop.

    ``oracle`` is typically a serve-recalibrated replay backend
    (:meth:`DeploymentArtifact.recalibrated_oracle`); the sweep restarts
    from the *same* initial params, strategies, targets, hooks, and
    constraints recorded in ``prior.inputs``, so the only variable is
    what the oracle believes about the target. The re-sweep is warm: the
    process-wide ProgramCache keys carry the oracle fingerprint, so
    tunings scored by the stale oracle are never reused, while everything
    oracle-independent (model build, task decomposition) carries over.
    Constraint overrides let a replan also tighten/relax the floor or
    budget in the same pass."""
    ins = prior.inputs
    if ins is None:
        raise PlanError(
            "this Plan records no inputs (it was not produced by plan() "
            "in this process); run plan() directly instead of replan()")
    return plan(ins.cfg,
                accuracy_floor=(ins.accuracy_floor if accuracy_floor is None
                                else accuracy_floor),
                latency_budget_s=(ins.latency_budget_s
                                  if latency_budget_s is None
                                  else latency_budget_s),
                targets=ins.targets, strategies=ins.strategies,
                workload=ins.workload, hooks=ins.hooks, pcfg=ins.pcfg,
                params=ins.params, oracle=oracle,
                strategy_kwargs=ins.strategy_kwargs, seed=ins.seed,
                verbose=verbose, tp=ins.tp)

"""Pruning-strategy registry: one calling convention for CPrune and every
baseline, so ``session.prune(strategy=...)`` swaps the *search policy*
while target, workload, training hooks, and applier stay fixed — exactly
how the paper's Table 1 isolates policies (every row shares the tuner).

Built-in strategies:
  cprune      Algorithm 1 (compiler-informed selective search)
  netadapt    hardware-aware exhaustive search (paper's main comparison)
  uniform_l1  L1-magnitude structured pruning at a uniform ratio
  fpgm        geometric-median ranking at a uniform ratio

Register custom policies with :func:`register_strategy`; they receive the
session and must return a :class:`PruneResult`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import baselines, latency, tuner
from repro.core.cprune import CPrune, IterationRecord
from repro.models.model import PruneSite


@dataclasses.dataclass
class PruneResult:
    """What every strategy returns — the common currency of the API."""

    strategy: str
    target: str
    params: Dict
    sites: List[PruneSite]
    final_latency: latency.LatencyReport
    original_latency: latency.LatencyReport
    final_acc: float
    candidates_evaluated: int
    history: List[IterationRecord] = dataclasses.field(default_factory=list)
    tuner_stats: Optional[tuner.TunerStats] = None

    @property
    def fps_increase(self) -> float:
        return self.original_latency.total_s / self.final_latency.total_s

    def history_digest(self, *, include_latency: bool = False) -> List[Tuple]:
        """Hashable digest of the *accepted* prune trajectory — the quantity
        that differs between targets (paper Fig. 7/8) and must not differ
        between tuning engines (tuner_bench). ``include_latency`` adds the
        measured l_m per record for exact-value identity checks (the
        measured-vs-replay acceptance in measured_smoke)."""
        if include_latency:
            return [(h.task_kind, h.prune_units, h.dim_before, h.dim_after,
                     h.l_m, h.accepted) for h in self.history]
        return [(h.task_kind, h.prune_units, h.dim_before, h.dim_after,
                 h.accepted) for h in self.history]


StrategyFn = Callable[..., PruneResult]

_STRATEGIES: Dict[str, StrategyFn] = {}


def register_strategy(name: str, *, overwrite: bool = False):
    """Decorator: ``@register_strategy("mine")`` over ``fn(session, **kw)``."""

    def deco(fn: StrategyFn) -> StrategyFn:
        if name in _STRATEGIES and not overwrite:
            raise ValueError(f"strategy {name!r} already registered "
                             f"(pass overwrite=True to replace)")
        _STRATEGIES[name] = fn
        return fn

    return deco


def get_strategy(name: str) -> StrategyFn:
    try:
        return _STRATEGIES[name]
    except KeyError:
        raise KeyError(f"unknown strategy {name!r}; registered strategies: "
                       f"{sorted(_STRATEGIES)}") from None


def list_strategies() -> List[str]:
    return sorted(_STRATEGIES)


# ---------------------------------------------------------------------------
# Built-in strategies. Each runs under the session's already-activated
# target (PruningSession.prune wraps the call in target.activate()).
# ---------------------------------------------------------------------------

@register_strategy("cprune")
def _cprune(session, *, verbose: bool = False, **pcfg_over) -> PruneResult:
    pcfg = dataclasses.replace(session.pcfg, **pcfg_over) if pcfg_over \
        else session.pcfg
    cp = CPrune(session.cfg, session.sites, session.workload, session.hooks,
                pcfg)
    res = cp.run(session.params, verbose=verbose)
    return PruneResult(
        strategy="cprune", target=session.target.name, params=res.params,
        sites=res.sites, final_latency=res.final_latency,
        original_latency=res.original_latency, final_acc=res.final_acc,
        candidates_evaluated=res.tuner_stats.candidates_evaluated,
        history=res.history, tuner_stats=res.tuner_stats)


def _uniform(session, method: str, name: str, *, ratio: float) -> PruneResult:
    res = baselines.uniform_prune(
        session.cfg, session.params, session.sites, session.workload,
        session.hooks, session.pcfg, ratio=ratio, method=method, name=name)
    # after the baseline: session.sites is still the original model, the
    # ProgramCache is warm, and the baseline's eval accounting stays
    # identical to a standalone run (no front-door pre-tune)
    rep0 = session.latency_report()
    return PruneResult(
        strategy=name, target=session.target.name, params=res.params,
        sites=res.sites, final_latency=res.latency, original_latency=rep0,
        final_acc=res.acc, candidates_evaluated=res.candidates_evaluated)


@register_strategy("uniform_l1")
def _uniform_l1(session, *, ratio: float = 0.5) -> PruneResult:
    return _uniform(session, "l1", "uniform_l1", ratio=ratio)


@register_strategy("fpgm")
def _fpgm(session, *, ratio: float = 0.5) -> PruneResult:
    return _uniform(session, "fpgm", "fpgm", ratio=ratio)


@register_strategy("netadapt")
def _netadapt(session, *, latency_decay: float = 0.97,
              max_iterations: int = 30) -> PruneResult:
    res = baselines.netadapt_prune(
        session.cfg, session.params, session.sites, session.workload,
        session.hooks, session.pcfg, latency_decay=latency_decay,
        max_iterations=max_iterations)
    # measured after the run (session.sites is untouched until prune()
    # adopts the result): the baseline pays its own cold start, so its
    # candidates_evaluated matches a standalone netadapt run, and this
    # report is served almost entirely from the warmed ProgramCache
    rep0 = session.latency_report()
    return PruneResult(
        strategy="netadapt", target=session.target.name, params=res.params,
        sites=res.sites, final_latency=res.latency, original_latency=rep0,
        final_acc=res.acc, candidates_evaluated=res.candidates_evaluated)

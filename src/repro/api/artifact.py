"""Deployment artifacts — the pipeline's exit, decoupled from the session.

CPrune's expensive half is the prune -> tune search; the cheap half is
serving the result. NPAS and PatDNN both ship compiler-aware pruning as a
*deployable artifact* pipeline, and this module does the same for the
repro: :class:`DeploymentArtifact` is a versioned, self-contained
directory holding everything the serve path needs —

    artifact/
      artifact.json     schema version, model config, TargetSpec fields,
                        workload, site dims, the tuned program table, the
                        oracle identity, fingerprints, and accuracy/latency
                        metadata (the commit record — written last)
      params.npz        the pruned parameter pytree, flattened
      replay_log.json   the oracle calibration log (replay-backed
                        artifacts only)

Produced by :meth:`PruningSession.export`, loaded by
:meth:`DeploymentArtifact.load`, served by
:meth:`repro.serve.engine.ServeEngine.from_artifact` — no live
``PruningSession`` (and no warm process caches) required. ``load``
validates the schema version and every fingerprint: the params digest,
the target constants, the oracle identity, and the tuned table's
``tuned_fingerprint`` must all agree, so a table tuned for a different
target or scored by a different oracle is refused with a clear
:class:`ArtifactError` instead of silently served.

A session whose oracle is a *recording* :class:`MeasuredOracle` exports a
``replay`` artifact: the export measures everything the artifact needs,
snapshots the log, and re-expresses the table under a deterministic
:class:`ReplayOracle` — the artifact then replays identically on any
machine, which is how measured tunings ship from the device that timed
them to the fleet that serves them.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import warnings
from typing import Any, Dict, List, Optional, Tuple, Union

import numpy as np

from repro.api.targets import TargetSpec
from repro.configs.base import ModelConfig
from repro.core import latency, tuner, tuning_cache
from repro.core import oracle as oracle_mod
from repro.core.oracle import (AnalyticOracle, LatencyOracle, MeasuredOracle,
                               MeasurementConfig, MeasurementLog, ReplayOracle)
from repro.core.tasks import TaskTable, Workload
from repro.models.model import PruneSite, prune_sites

SCHEMA_VERSION = 1
_LOG_NAME = "replay_log.json"


class ArtifactError(ValueError):
    """A deployment artifact is missing, malformed, or fails validation."""


# -- param pytree <-> flat npz (shared with the session checkpoint) ---------

def _flatten_params(tree: Dict[str, Any], prefix: str = ""
                    ) -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    for k, v in tree.items():
        path = f"{prefix}/{k}" if prefix else k
        if isinstance(v, dict):
            out.update(_flatten_params(v, path))
        else:
            out[path] = np.asarray(v)
    return out


def _unflatten_params(flat: Dict[str, np.ndarray]) -> Dict[str, Any]:
    tree: Dict[str, Any] = {}
    for path, arr in flat.items():
        node = tree
        parts = path.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return tree


def _params_digest(flat: Dict[str, np.ndarray]) -> str:
    """Order-independent content hash of a flattened param tree."""
    h = hashlib.sha256()
    for k in sorted(flat):
        a = np.ascontiguousarray(flat[k])
        h.update(k.encode())
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()[:16]


# -- fingerprint (de)serialization ------------------------------------------
# Fingerprints are nested tuples of ints/floats/strings/None. JSON turns
# tuples into lists; these two helpers make the round trip exact (Python's
# json writes floats via repr, which round-trips binary64 losslessly).

def _listify(x):
    if isinstance(x, (list, tuple)):
        return [_listify(v) for v in x]
    return x


def _canon(x):
    if isinstance(x, (list, tuple)):
        return tuple(_canon(v) for v in x)
    return x


def _decode_step_report(cfg: ModelConfig, sites, wl: Workload,
                        max_batch: int, max_seq: int, *,
                        kv_layout: str = "contiguous"
                        ) -> latency.LatencyReport:
    """One decode step of this model at ``max_batch``: per-token GEMMs for
    ``max_batch`` tokens plus attention against a ``max_seq``-deep KV
    cache — under the *already active* target and oracle. Returns the
    full report (the task/fixed split parameterizes serve-time
    recalibration, not just the total). ``kv_layout="paged"`` prices the
    attention term through the paged-decode kernel when the oracle can
    measure it. The decode workload inherits ``wl``'s tensor-parallel
    degree: a tp=2 artifact is priced as per-shard GEMMs plus the
    analytic all-reduce term, not as one big chip."""
    wl_d = Workload(tokens_global=max_batch, dp=1, tp=wl.tp,
                    dtype_bytes=wl.dtype_bytes)
    table = tuner.build_tuned_table(sites, wl_d)
    return latency.model_latency(cfg, sites, table, seq_len=1,
                                 decode_kv_len=max_seq,
                                 kv_layout=kv_layout)


def _partition_blob(params: Dict[str, Any], tp: int) -> Dict[str, Any]:
    """The artifact's ``PartitionSpec`` section for a ``tp``-way model
    mesh: the per-param named-axis layout resolved from
    :mod:`repro.sharding.rules` against a ``{"data": 1, "model": tp}``
    spec mesh (pure spec math — no devices touched at export time)."""
    from repro.sharding import rules

    mesh_axes = {"data": 1, "model": int(tp)}
    pspecs = rules.param_pspecs(params, rules.SpecMesh(mesh_axes))

    def flatten(tree, prefix=""):
        out: Dict[str, Any] = {}
        for k, v in tree.items():
            p = f"{prefix}/{k}" if prefix else k
            if isinstance(v, dict):
                out.update(flatten(v, p))
            else:
                out[p] = [list(ax) if isinstance(ax, tuple) else ax
                          for ax in tuple(v)]
        return out

    return {"tp": int(tp), "mesh_axes": mesh_axes,
            "params": flatten(pspecs)}


@dataclasses.dataclass
class DeploymentArtifact:
    """A self-contained, restartable serve package for one pruned model on
    one target, scored by one oracle. See the module docstring for the
    on-disk layout; in memory the tuned table is a live :class:`TaskTable`
    and ``oracle`` is the reconstructed backend instance."""

    cfg: ModelConfig
    params: Dict[str, Any]
    sites: List[PruneSite]
    target: TargetSpec
    oracle: LatencyOracle
    workload: Workload
    seq_len: int
    table: Optional[TaskTable]
    metadata: Dict[str, Any]
    path: Optional[str] = None
    schema_version: int = SCHEMA_VERSION
    # export-time static-analysis stamp ({"passed": bool, "codes": [...]});
    # None for in-memory artifacts not yet saved and for pre-stamp files
    checks: Optional[Dict[str, Any]] = None
    # optional PartitionSpec section ({"tp", "mesh_axes", "params"}),
    # present only for tensor-parallel (tp > 1) exports — tp=1 artifacts
    # stay byte-identical to the pre-partition schema (still version 1)
    partition: Optional[Dict[str, Any]] = None

    # -- identity -----------------------------------------------------------

    @property
    def tuned_fingerprint(self) -> Optional[Tuple]:
        """The tuned table's full identity: target constants + VMEM
        override + oracle fingerprint, exactly as the tuner stamped it."""
        return getattr(self.table, "tuned_fingerprint", None) \
            if self.table is not None else None

    @property
    def tuned_digest(self) -> Optional[str]:
        """Short stable hash of :attr:`tuned_fingerprint` — the value two
        processes compare to prove they hold the same tuning."""
        fp = self.tuned_fingerprint
        if fp is None:
            return None
        blob = json.dumps(_listify(fp))
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    # -- construction -------------------------------------------------------

    @classmethod
    def from_session(cls, session, *, max_batch: int = 8, max_seq: int = 512,
                     predict_step: bool = True, include_table: bool = True,
                     tp: Optional[int] = None) -> "DeploymentArtifact":
        """Snapshot a session's current (pruned) model as an artifact.

        With ``include_table`` (the deployable form), the tuned program
        table and the latency report are computed under the session's
        target + oracle and embedded; a recording measured oracle is
        first drained into a replay log so the artifact is deterministic.
        ``include_table=False`` builds a lightweight serving snapshot
        (params + decode-step prediction only) that cannot be saved —
        it is what :meth:`PruningSession.serve` rides on.

        ``tp`` overrides the session workload's tensor-parallel degree:
        the tuned table and every latency number are then priced as
        per-shard GEMMs + collectives, and (for tp > 1) the artifact
        carries a ``PartitionSpec`` section deriving the per-param
        named-axis layout from the sharding rules. ``None`` inherits the
        session workload; tp=1 artifacts are byte-identical to exports
        from before partitioning existed.
        """
        target, orc = session.target, session.oracle
        tp = session.workload.tp if tp is None else int(tp)
        if tp < 1:
            raise ArtifactError(f"tp must be >= 1, got {tp}")
        wl = session.workload if tp == session.workload.tp \
            else dataclasses.replace(session.workload, tp=tp)
        export_oracle = orc
        if include_table:
            if not dataclasses.is_dataclass(target):
                raise ArtifactError(
                    f"cannot export a session whose target is not a "
                    f"TargetSpec-style dataclass: {type(target).__name__}")
            if isinstance(orc, MeasuredOracle) and orc.record is not None:
                # phase 1: measure (into the record) everything the
                # artifact will need, then re-express deterministically
                with target.activate(), oracle_mod.use_oracle(orc):
                    t0 = tuner.build_tuned_table(session.sites, wl)
                    latency.model_latency(session.cfg, session.sites, t0,
                                          seq_len=session.pcfg.seq_len)
                    if predict_step:
                        _decode_step_report(session.cfg, session.sites,
                                            wl, max_batch, max_seq)
                export_oracle = ReplayOracle(orc.record.copy())
            elif not isinstance(orc, (AnalyticOracle, MeasuredOracle,
                                      ReplayOracle)):
                raise ArtifactError(
                    f"cannot export a session whose oracle "
                    f"({type(orc).__name__}) is not one of the serializable "
                    f"backends (analytic/measured/replay)")
        table = report = None
        step_rep: Optional[latency.LatencyReport] = None
        with tuner.target_activation(target), \
                oracle_mod.use_oracle(export_oracle):
            if include_table:
                table = tuner.build_tuned_table(session.sites, wl)
                report = latency.model_latency(session.cfg, session.sites,
                                               table,
                                               seq_len=session.pcfg.seq_len)
            if predict_step:
                try:
                    step_rep = _decode_step_report(session.cfg,
                                                   session.sites, wl,
                                                   max_batch, max_seq)
                except KeyError:
                    # a replay log recorded for another workload cannot
                    # score the decode shapes; ship without a prediction
                    step_rep = None
        metadata = {
            "strategy": session.last_strategy,
            "final_acc": session.final_acc,
            "latency_total_s": report.total_s if report else None,
            "latency_task_s": report.task_s if report else None,
            "latency_fixed_s": report.fixed_s if report else None,
            "fps": report.fps if report else None,
            "predicted_step_s": step_rep.total_s if step_rep else None,
            # the prediction's task/fixed split: serve-time recalibration
            # scales the measured-kernel (task) half only, so it needs to
            # know how much of the step the fixed ops account for
            "predicted_step_task_s": step_rep.task_s if step_rep else None,
            "predicted_step_fixed_s": step_rep.fixed_s if step_rep else None,
            "serve_defaults": {"max_batch": max_batch, "max_seq": max_seq},
        }
        partition = _partition_blob(session.params, tp) if tp > 1 else None
        return cls(cfg=session.cfg, params=session.params,
                   sites=list(session.sites), target=target,
                   oracle=export_oracle, workload=wl,
                   seq_len=session.pcfg.seq_len, table=table,
                   metadata=metadata, partition=partition)

    # -- persistence --------------------------------------------------------

    def _oracle_blob(self) -> Tuple[Dict, Optional[MeasurementLog]]:
        if isinstance(self.oracle, ReplayOracle):
            return ({"backend": "replay",
                     "config": self.oracle.config.to_dict(),
                     "digest": self.oracle.log.digest(),
                     "log": _LOG_NAME}, self.oracle.log)
        if isinstance(self.oracle, MeasuredOracle):
            if self.oracle.record is not None:
                raise ArtifactError(
                    "a live recording MeasuredOracle cannot be serialized; "
                    "export via DeploymentArtifact.from_session, which "
                    "snapshots the record into a replay artifact")
            return ({"backend": "measured",
                     "config": self.oracle.config.to_dict()}, None)
        if isinstance(self.oracle, AnalyticOracle):
            return ({"backend": "analytic"}, None)
        raise ArtifactError(
            f"cannot serialize oracle backend {type(self.oracle).__name__}")

    def save(self, path: str) -> str:
        """Write the artifact directory. Ordering is crash-safe: params
        (and the bundled log) land first, ``artifact.json`` — the commit
        record — last, each via tmp + atomic rename."""
        if self.table is None:
            raise ArtifactError(
                "this artifact is an in-memory serving snapshot (no tuned "
                "table); create deployable artifacts with "
                "PruningSession.export(path)")
        if not dataclasses.is_dataclass(self.target):
            raise ArtifactError(
                f"cannot save an artifact whose target is not a "
                f"TargetSpec-style dataclass: {type(self.target).__name__}")
        oracle_blob, log = self._oracle_blob()
        checks = self.run_checks()
        os.makedirs(path, exist_ok=True)
        flat = _flatten_params(self.params)
        tmp = os.path.join(path, "params.npz.tmp")
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, os.path.join(path, "params.npz"))
        if log is not None:
            log.save(os.path.join(path, _LOG_NAME))
        blob = {
            "schema_version": SCHEMA_VERSION,
            "config": dataclasses.asdict(self.cfg),
            "target_spec": dataclasses.asdict(self.target),
            "workload": dataclasses.asdict(self.workload),
            # PartitionSpec section only exists for tp > 1 exports, so a
            # tp=1 artifact.json is byte-identical to the pre-partition
            # schema (and old readers never see an unknown key)
            **({"partition": self.partition} if self.partition else {}),
            "seq_len": self.seq_len,
            "site_dims": {s.site_id: s.dim for s in self.sites},
            "oracle": oracle_blob,
            "table": {
                "tuned_fingerprint": _listify(self.table.tuned_fingerprint),
                "tasks": [
                    {"task_id": t.task_id,
                     "signature": _listify(t.signature),
                     "tuned_mode": t.tuned_mode,
                     "programs": {name: tuning_cache.program_to_dict(p)
                                  for name, p in t.programs.items()}}
                    for t in self.table.tasks],
            },
            "fingerprints": {
                "target": _listify(self.target.fingerprint()),
                "oracle": _listify(self.oracle.fingerprint()),
                "params": _params_digest(flat),
            },
            "metadata": self.metadata,
            # export-time static-analysis stamp: the kernel checker run
            # against this artifact's own target + tuned table.
            # load(strict_checks=True) refuses artifacts without it.
            "checks": checks,
        }
        tmp = os.path.join(path, "artifact.json.tmp")
        with open(tmp, "w") as f:
            json.dump(blob, f, indent=1)
        os.replace(tmp, os.path.join(path, "artifact.json"))
        self.path = path
        self.checks = checks
        return path

    def run_checks(self) -> Dict[str, Any]:
        """Run the static kernel checker against this artifact's own
        target + tuned table and return the stamp ``save`` writes:
        ``{"passed": bool, "codes": [...]}`` (distinct diagnostic codes
        seen, warnings included). Pure — no global tuner/oracle state is
        touched and nothing runs on a device."""
        from repro.analysis.kernels import check_artifact_kernels
        diags = check_artifact_kernels(self)
        return {"passed": not any(d.severity == "error" for d in diags),
                "codes": sorted({d.code for d in diags})}

    @classmethod
    def load(cls, path: str, *,
             strict_checks: bool = False,
             check_devices: bool = True) -> "DeploymentArtifact":
        """Read + validate an artifact directory. Refuses (with a clear
        :class:`ArtifactError`) any artifact that is missing, malformed,
        or whose schema version is unknown or whose params/target/oracle/
        table fingerprints do not agree — a table tuned for a different
        target or oracle is never served.

        ``strict_checks=True`` additionally requires the export-time
        static-analysis stamp (``checks: {passed: true}``) — artifacts
        from before the stamp existed, or stamped with errors, are
        refused. The default keeps them loadable with a warning.

        A partition-stamped (tp > 1) artifact is also checked against
        this process's device count — loading for serving on a host that
        cannot build the mesh fails here, by name, instead of deep inside
        a jit. ``check_devices=False`` skips only that check (structure
        and fingerprints still validate): the export-side re-read uses
        it, since exporting *for* a pod from a small host is the normal
        plan-here-deploy-there flow."""
        try:
            return cls._load(path, strict_checks=strict_checks,
                             check_devices=check_devices)
        except ArtifactError:
            raise
        except (OSError, json.JSONDecodeError, KeyError, IndexError,
                TypeError, ValueError) as e:
            raise ArtifactError(
                f"malformed deployment artifact at {path!r}: "
                f"{type(e).__name__}: {e}") from e

    @classmethod
    def _load(cls, path: str, *,
              strict_checks: bool = False,
              check_devices: bool = True) -> "DeploymentArtifact":
        meta_path = os.path.join(path, "artifact.json")
        if not os.path.exists(meta_path):
            raise ArtifactError(f"no deployment artifact at {path!r} "
                                f"(missing artifact.json)")
        with open(meta_path) as f:
            blob = json.load(f)
        ver = blob.get("schema_version")
        if ver != SCHEMA_VERSION:
            raise ArtifactError(
                f"unsupported artifact schema version {ver!r} "
                f"(this build reads version {SCHEMA_VERSION})")
        checks = blob.get("checks")
        if checks is not None and not checks.get("passed", False):
            # a stamp recording errors is refused outright: the exporter
            # knew the kernels cannot launch on the artifact's target
            raise ArtifactError(
                f"artifact at {path!r} is stamped with failing static "
                f"checks (codes {checks.get('codes')}); re-export after "
                f"fixing, or re-plan for a bigger target")
        if checks is None:
            if strict_checks:
                raise ArtifactError(
                    f"strict_checks=True: artifact at {path!r} carries no "
                    f"static-analysis stamp (exported before "
                    f"repro.analysis existed) — re-export it, or load "
                    f"with strict_checks=False")
            warnings.warn(
                f"artifact at {path!r} has no static-analysis stamp "
                f"(pre-repro.analysis export); loading anyway — "
                f"re-export to stamp it, or opt into "
                f"load(strict_checks=True) to refuse unstamped artifacts",
                stacklevel=3)
        cfg_d = dict(blob["config"])
        cfg_d["block_pattern"] = tuple(cfg_d["block_pattern"])
        cfg = ModelConfig(**cfg_d)
        target = TargetSpec(**blob["target_spec"])
        workload = Workload(**blob["workload"])
        fps = blob["fingerprints"]

        partition = blob.get("partition")
        if partition is not None:
            part_tp = int(partition.get("tp", 0))
            if part_tp < 2:
                raise ArtifactError(
                    f"artifact at {path!r} carries a partition section "
                    f"with tp={partition.get('tp')!r}; partitioned "
                    f"artifacts must declare an integer tp >= 2 (tp=1 "
                    f"artifacts carry no partition section at all)")
            if part_tp != workload.tp:
                raise ArtifactError(
                    f"artifact at {path!r} is partitioned for tp="
                    f"{part_tp} but its workload records tp="
                    f"{workload.tp} — the artifact was modified after "
                    f"export")
            # availability check mirrors launch/mesh.make_test_mesh: the
            # model axis needs part_tp devices, so refuse (clearly) here
            # rather than deep inside a jit with a sharding error
            import jax
            if check_devices and (avail := len(jax.devices())) < part_tp:
                raise ArtifactError(
                    f"artifact at {path!r} requires a mesh with tp="
                    f"{part_tp} model shards but only {avail} device(s) "
                    f"are available — run under >= {part_tp} devices "
                    f"(e.g. XLA_FLAGS=--xla_force_host_platform_device_"
                    f"count={part_tp} for a host-device test mesh)")

        with np.load(os.path.join(path, "params.npz")) as z:
            flat = {k: z[k] for k in z.files}
        if _params_digest(flat) != fps["params"]:
            raise ArtifactError(
                f"params.npz does not match the artifact's params "
                f"fingerprint ({fps['params']}) — the artifact was modified "
                f"after export")
        params = _unflatten_params(flat)

        ob = blob["oracle"]
        backend = ob.get("backend")
        if backend == "analytic":
            orc: LatencyOracle = oracle_mod.ANALYTIC
        elif backend == "measured":
            orc = MeasuredOracle(MeasurementConfig(**ob["config"]))
        elif backend == "replay":
            log = MeasurementLog.load(os.path.join(path, ob["log"]))
            if log.digest() != ob["digest"]:
                raise ArtifactError(
                    f"bundled replay log {ob['log']!r} does not match its "
                    f"recorded digest ({ob['digest']}) — the log was "
                    f"modified after export")
            orc = ReplayOracle(log)
        else:
            raise ArtifactError(f"unknown oracle backend {backend!r}")

        if _canon(fps["oracle"]) != orc.fingerprint():
            raise ArtifactError(
                f"oracle fingerprint mismatch: artifact records "
                f"{_canon(fps['oracle'])!r} but the reconstructed "
                f"{backend!r} backend fingerprints as {orc.fingerprint()!r}")
        if _canon(fps["target"]) != target.fingerprint():
            raise ArtifactError(
                "target fingerprint mismatch: artifact.json's target_spec "
                "was modified after export")
        stored_fp = _canon(blob["table"]["tuned_fingerprint"])
        with target.activate():
            expected = tuning_cache.target_fingerprint() + (None,) \
                + orc.fingerprint()
        if stored_fp != expected:
            raise ArtifactError(
                f"refusing to serve: the tuned program table was produced "
                f"under a different target/oracle (table fingerprint "
                f"{stored_fp!r} != this artifact's target+oracle "
                f"{expected!r})")

        dims = blob["site_dims"]
        sites = [s.with_dim(dims[s.site_id]) if s.site_id in dims else s
                 for s in prune_sites(cfg)]
        table = TaskTable(sites, workload)
        stored_tasks = blob["table"]["tasks"]
        if len(stored_tasks) != len(table.tasks):
            raise ArtifactError(
                f"task decomposition mismatch: artifact has "
                f"{len(stored_tasks)} tasks, the reconstructed model has "
                f"{len(table.tasks)}")
        for tb in stored_tasks:
            t = table.tasks[tb["task_id"]]
            if _canon(tb["signature"]) != t.signature:
                raise ArtifactError(
                    f"task {tb['task_id']} signature mismatch: the "
                    f"reconstructed model does not reproduce the artifact's "
                    f"task decomposition")
            t.programs = {name: tuning_cache.program_from_dict(d)
                          for name, d in tb["programs"].items()}
            t.tuned_mode = tb.get("tuned_mode", "tuned")
        table.tuned_fingerprint = stored_fp

        return cls(cfg=cfg, params=params, sites=sites, target=target,
                   oracle=orc, workload=workload,
                   seq_len=blob.get("seq_len", 128), table=table,
                   metadata=blob.get("metadata", {}), path=path,
                   schema_version=ver, checks=checks, partition=partition)

    # -- serving / inspection ----------------------------------------------

    @property
    def params_digest(self) -> str:
        """Content hash of the (pruned) params — the value ``load``
        validates against ``params.npz``. Computed once and cached."""
        if getattr(self, "_params_digest_cache", None) is None:
            self._params_digest_cache = _params_digest(
                _flatten_params(self.params))
        return self._params_digest_cache

    @property
    def measurement_tag(self) -> str:
        """Identity under which engines serving this artifact record their
        observed decode steps (``MeasurementLog.step_key``): the model
        name qualified by the params digest, so two pruned variants of
        the same architecture never collide in one log (the tuned digest
        hashes target+oracle identity, which frontier siblings share)."""
        return f"{self.cfg.name}@{self.params_digest}"

    @property
    def tp(self) -> int:
        """Tensor-parallel degree this artifact was exported for (1 for
        unpartitioned artifacts)."""
        if self.partition is not None:
            return int(self.partition["tp"])
        return int(self.workload.tp)

    def predict_step_s(self, max_batch: int, max_seq: int, *,
                       oracle: Optional[LatencyOracle] = None,
                       kv_layout: str = "contiguous",
                       tp: Optional[int] = None) -> Optional[float]:
        """Oracle-predicted seconds per decode step at ``max_batch`` with a
        ``max_seq``-deep KV cache (None when a replay log cannot score the
        decode shapes). ``oracle`` overrides the artifact's own backend —
        e.g. a recalibrated replay oracle. ``kv_layout="paged"`` predicts
        the paged-decode step — a measuring oracle times the paged kernel
        itself, so the prediction tracks the engine's actual layout.
        ``tp`` overrides the tensor-parallel degree (default: the
        artifact's own) — per-shard GEMMs plus the analytic all-reduce
        term, so sharding and pruning are priced on the same axis."""
        wl = self.workload if tp is None \
            else dataclasses.replace(self.workload, tp=int(tp))
        with tuner.target_activation(self.target), \
                oracle_mod.use_oracle(oracle or self.oracle):
            try:
                return _decode_step_report(self.cfg, self.sites, wl,
                                           max_batch, max_seq,
                                           kv_layout=kv_layout).total_s
            except KeyError:
                return None

    def recalibrated_oracle(self, measured: Union[float, MeasurementLog], *,
                            max_batch: Optional[int] = None,
                            max_seq: Optional[int] = None) -> ReplayOracle:
        """Close the plan -> serve -> replan loop: fold a serve run's
        *measured* decode step back into the replay oracle that planned
        this artifact.

        ``measured`` is either the observed seconds per decode step or a
        :class:`MeasurementLog` an engine recorded into
        (``ServeEngine(..., measurements=log)``), which is looked up
        under this artifact's :attr:`measurement_tag` at
        ``max_batch``/``max_seq`` (default: the artifact's serve
        defaults). Every recorded kernel seconds in the bundled log is
        scaled by measured/predicted, so the returned
        :class:`ReplayOracle` predicts (approximately) what serving
        observed — hand it to ``plan(oracle=...)`` or
        ``PruningSession(oracle=...)`` to replan against reality.
        Replay-backed artifacts only."""
        if not isinstance(self.oracle, ReplayOracle):
            raise ArtifactError(
                f"recalibration needs a replay-backed artifact (this one "
                f"is {self.oracle.name!r}): only a recorded log can be "
                f"rescaled deterministically")
        kernel_keys = [k for k in self.oracle.log.entries
                       if k.startswith("gemm:")]
        if not kernel_keys:
            raise ArtifactError(
                "the bundled replay log records no kernel (gemm:*) "
                "measurements, so there is nothing to rescale — re-export "
                "the artifact from a session with a recording "
                "MeasuredOracle")
        if len(kernel_keys) == 1:
            # a single kernel entry makes the rescale degenerate: the
            # factor is fully aliased with that one measurement, so the
            # "recalibrated" oracle cannot generalize beyond it
            warnings.warn(
                f"replay log has a single kernel measurement "
                f"({kernel_keys[0]!r}); the rescale would be degenerate — "
                f"returning the original oracle unscaled",
                RuntimeWarning, stacklevel=2)
            return self.oracle
        defaults = self.metadata.get("serve_defaults") or {}
        mb = max_batch if max_batch is not None \
            else defaults.get("max_batch", 8)
        ms = max_seq if max_seq is not None else defaults.get("max_seq", 512)
        if isinstance(measured, MeasurementLog):
            key = MeasurementLog.step_key(self.measurement_tag, mb, ms)
            found = measured.lookup(key)
            if found is None:
                raise ArtifactError(
                    f"measurement log has no {key!r} entry — serve this "
                    f"artifact with ServeEngine(..., measurements=log) at "
                    f"max_batch={mb}, max_seq={ms} first")
            measured = found
        if measured <= 0.0:
            raise ArtifactError(
                f"measured decode step must be positive, got {measured!r}")
        if (mb, ms) == (defaults.get("max_batch"), defaults.get("max_seq")):
            total = self.metadata.get("predicted_step_s")
            task = self.metadata.get("predicted_step_task_s")
            fixed = self.metadata.get("predicted_step_fixed_s")
        else:
            with tuner.target_activation(self.target), \
                    oracle_mod.use_oracle(self.oracle):
                try:
                    rep = _decode_step_report(self.cfg, self.sites,
                                              self.workload, mb, ms)
                except KeyError:
                    rep = None
            total = rep.total_s if rep else None
            task = rep.task_s if rep else None
            fixed = rep.fixed_s if rep else None
        if not total:
            raise ArtifactError(
                f"this artifact records no decode-step prediction at "
                f"max_batch={mb}, max_seq={ms}; nothing to recalibrate "
                f"against")
        # scaling touches only the recorded kernel seconds, so solve for
        # the factor on the task half alone: fixed + factor*task = measured
        # (the fixed-op estimates stay analytic in a replay backend). When
        # the hardware beats even the fixed-op estimate, fall back to the
        # total ratio — the factor must stay positive.
        if task and fixed is not None and measured > fixed:
            factor = (measured - fixed) / task
        else:
            factor = measured / total
        return ReplayOracle(self.oracle.log.scaled(factor))

    def latency_report(self) -> latency.LatencyReport:
        """Whole-model latency recomputed from the embedded table under the
        artifact's own target + oracle — must reproduce
        ``metadata['latency_total_s']`` for deterministic backends."""
        if self.table is None:
            raise ArtifactError("serving snapshot has no tuned table")
        with tuner.target_activation(self.target), \
                oracle_mod.use_oracle(self.oracle):
            return latency.model_latency(self.cfg, self.sites, self.table,
                                         seq_len=self.seq_len)

    def serve(self, *, max_batch: Optional[int] = None,
              max_seq: Optional[int] = None, seed: int = 0,
              predict_step: bool = True):
        """A :class:`~repro.serve.engine.ServeEngine` over this artifact —
        no session, no warm caches required."""
        from repro.serve.engine import ServeEngine
        return ServeEngine.from_artifact(self, max_batch=max_batch,
                                         max_seq=max_seq, seed=seed,
                                         predict_step=predict_step)


# ---------------------------------------------------------------------------
# Catalog generations — crash-safe, reversible hot-swap storage
# ---------------------------------------------------------------------------

GENERATIONS_DIR = "generations"
CURRENT_NAME = "CURRENT"
_GEN_PREFIX = "gen-"
_CATALOG_MANIFEST = "catalog.json"   # mirrors serve.router.CATALOG_NAME


class GenerationStore:
    """Side-by-side catalog generations under one root, with an atomic
    pointer flip as the only commit operation.

    Layout::

        root/
          catalog.json ...          generation 0: the flat layout
                                    ``Plan.export_catalog`` writes
          generations/gen-0001/     a complete catalog directory
          generations/gen-0002/     (member artifacts + catalog.json)
          CURRENT                   JSON {"generation": N, "path": rel}

    ``CURRENT`` is replaced via tmp + ``os.replace``, so a kill at any
    point of a swap leaves either the old or the new generation fully
    current — never a torn catalog: a staged generation is invisible
    until its manifest exists *and* the pointer names it, and the
    previous generation's files are untouched by the flip.
    ``ArtifactCatalog.load`` resolves the pointer transparently; a root
    with no ``CURRENT`` is simply generation 0, so pre-generation
    catalogs keep loading unchanged. Generation 0 is never deleted —
    ``rollback`` can always reach it.

    ``faults`` (a :class:`repro.util.faults.FaultInjector`) fires the
    ``swap_commit`` point immediately before the pointer flip, which is
    how tests kill a swap mid-flight.
    """

    def __init__(self, root: str, *, keep_last: int = 3, faults=None):
        self.root = root
        self.keep_last = keep_last
        self.faults = faults

    # -- pointer ------------------------------------------------------------

    @staticmethod
    def read_pointer(root: str) -> Optional[Dict[str, Any]]:
        """The raw ``CURRENT`` pointer, or ``None`` when the root is a
        plain generation-0 catalog. A malformed pointer is refused loudly
        (``os.replace`` makes a torn write impossible, so damage means
        tampering)."""
        p = os.path.join(root, CURRENT_NAME)
        if not os.path.exists(p):
            return None
        try:
            with open(p) as f:
                blob = json.load(f)
            return {"generation": int(blob["generation"]),
                    "path": str(blob["path"])}
        except (OSError, json.JSONDecodeError, KeyError, TypeError,
                ValueError) as e:
            raise ArtifactError(
                f"malformed generation pointer at {p!r}: "
                f"{type(e).__name__}: {e}") from e

    @classmethod
    def resolve(cls, root: str) -> Tuple[int, str]:
        """``(generation, directory)`` the catalog at ``root`` currently
        serves from — ``(0, root)`` when no pointer exists."""
        ptr = cls.read_pointer(root)
        if ptr is None:
            return 0, root
        path = os.path.normpath(os.path.join(root, ptr["path"]))
        if not os.path.exists(os.path.join(path, _CATALOG_MANIFEST)):
            raise ArtifactError(
                f"generation pointer at {root!r} names generation "
                f"{ptr['generation']} ({path!r}) but no catalog manifest "
                f"exists there")
        return ptr["generation"], path

    @property
    def current(self) -> Tuple[int, str]:
        return self.resolve(self.root)

    def gen_path(self, gen_id: int) -> str:
        if gen_id == 0:
            return self.root
        return os.path.join(self.root, GENERATIONS_DIR,
                            f"{_GEN_PREFIX}{gen_id:04d}")

    def generations(self) -> Dict[int, str]:
        """Every *complete* generation on disk (its manifest exists),
        keyed by id. Staged-but-uncommitted directories are excluded."""
        out: Dict[int, str] = {}
        if os.path.exists(os.path.join(self.root, _CATALOG_MANIFEST)):
            out[0] = self.root
        gdir = os.path.join(self.root, GENERATIONS_DIR)
        if os.path.isdir(gdir):
            for name in sorted(os.listdir(gdir)):
                if not name.startswith(_GEN_PREFIX):
                    continue
                try:
                    gid = int(name[len(_GEN_PREFIX):])
                except ValueError:
                    continue
                path = os.path.join(gdir, name)
                if os.path.exists(os.path.join(path, _CATALOG_MANIFEST)):
                    out[gid] = path
        return out

    def _all_gen_ids(self) -> List[int]:
        """Ids of every generation directory, complete or orphaned."""
        ids = [0]
        gdir = os.path.join(self.root, GENERATIONS_DIR)
        if os.path.isdir(gdir):
            for name in os.listdir(gdir):
                if name.startswith(_GEN_PREFIX):
                    try:
                        ids.append(int(name[len(_GEN_PREFIX):]))
                    except ValueError:
                        pass
        return ids

    # -- swap lifecycle -----------------------------------------------------

    def stage(self) -> Tuple[int, str]:
        """An empty directory for the next generation (id is monotonic
        past every directory on disk *and* the current pointer, so retired
        ids are never reused). A crashed previous stage at the same id is
        cleared — an uncommitted stage is invisible, hence disposable."""
        cur, _ = self.current
        gid = max(self._all_gen_ids() + [cur]) + 1
        path = self.gen_path(gid)
        if os.path.exists(path):
            shutil.rmtree(path)
        os.makedirs(path)
        return gid, path

    def commit(self, gen_id: int) -> str:
        """Atomically make ``gen_id`` the current generation. Refuses a
        stage with no manifest (``export_catalog`` into it first)."""
        path = self.gen_path(gen_id)
        if not os.path.exists(os.path.join(path, _CATALOG_MANIFEST)):
            raise ArtifactError(
                f"cannot commit generation {gen_id}: no catalog manifest "
                f"at {path!r} — export a catalog into the staged "
                f"directory first")
        self._flip(gen_id)
        return path

    def rollback(self) -> Tuple[int, str]:
        """Flip back to the newest complete generation older than the
        current one (the rolled-back generation's files stay on disk for
        post-mortem until :meth:`retire`)."""
        cur, _ = self.current
        prior = [g for g in self.generations() if g < cur]
        if not prior:
            raise ArtifactError(
                f"cannot roll back: generation {cur} has no prior "
                f"generation on disk")
        gid = max(prior)
        self._flip(gid)
        return gid, self.gen_path(gid)

    def retire(self, keep_last: Optional[int] = None) -> List[int]:
        """Delete old generations, keeping the current one, generation 0
        (always), and the ``keep_last`` most recent others. Returns the
        retired ids."""
        keep = self.keep_last if keep_last is None else keep_last
        cur, _ = self.current
        gens = self.generations()
        candidates = sorted(g for g in gens if g not in (0, cur))
        kept = set(candidates[-keep:]) if keep > 0 else set()
        removed = []
        for g in candidates:
            if g not in kept:
                shutil.rmtree(gens[g])
                removed.append(g)
        return removed

    def _flip(self, gen_id: int) -> None:
        rel = "." if gen_id == 0 else \
            f"{GENERATIONS_DIR}/{_GEN_PREFIX}{gen_id:04d}"
        if self.faults is not None:
            self.faults.fire("swap_commit", f"gen{gen_id}")
        p = os.path.join(self.root, CURRENT_NAME)
        tmp = p + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"generation": gen_id, "path": rel}, f)
        os.replace(tmp, p)

"""Pluggable target backends — the "compiler-informed" half of CPrune.

The paper's thesis is that pruning decisions must consult the *target
device's* compiler/tuner: the same network pruned for two processors ends
up with two different architectures (paper Fig. 7/8). This module turns
the previously hardcoded v5e constants in :mod:`repro.core.cost_model`
into swappable :class:`TargetSpec` profiles behind a registry, so the
whole prune -> tune -> serve stack (tuner, tuning cache, latency, CPrune)
runs against any registered target.

Design: ``cost_model``'s module globals remain the single *active-target*
storage — the tuning cache fingerprints them at lookup time, existing
tests mutate them directly, and the scalar/vectorized cost kernels read
them. ``TargetSpec.activate()`` installs a profile into those globals
(restoring the prior values on exit, exceptions included), which makes a
target swap automatically invalidate every cache through the existing
``target_fingerprint`` contract. The built-in ``tpu_v5e`` profile holds
exactly the seed constants, so activating it is bit-identical to the
pre-registry behavior.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import (Dict, Iterator, List, Protocol, Tuple, Union,
                    runtime_checkable)

import numpy as np

from repro.core import cost_model


@runtime_checkable
class Target(Protocol):
    """The full target-backend surface. The tuner/latency stack itself
    consumes only ``activate()`` (via ``tuner.target_activation``) plus the
    dataclass constants; the cost methods exist for direct, out-of-loop
    queries (e.g. comparing one GEMM across targets) — inside a tuning
    loop, activate once instead of paying per-call activation."""

    name: str
    vmem_bytes: int

    def fingerprint(self) -> Tuple: ...

    def activate(self): ...

    def matmul_cost(self, m: int, k: int, n: int, block, **kw) -> float: ...

    def matmul_cost_grid(self, m: int, k: int, n: int, bm, bk, bn,
                         **kw) -> np.ndarray: ...


# (cost_model global, TargetSpec field) — the full active-target state
_CONSTS: Tuple[Tuple[str, str], ...] = (
    ("PEAK_FLOPS_BF16", "peak_flops_bf16"),
    ("PEAK_FLOPS_F32", "peak_flops_f32"),
    ("HBM_BW", "hbm_bw"),
    ("ICI_BW", "ici_bw"),
    ("VMEM_BYTES", "vmem_bytes"),
    ("LANE", "lane"),
    ("SUBLANE", "sublane"),
    ("MXU", "mxu"),
    ("BLOCK_OVERHEAD_S", "block_overhead_s"),
    ("CALL_OVERHEAD_S", "call_overhead_s"),
    ("VPU_THROUGHPUT", "vpu_throughput"),
)


@dataclasses.dataclass(frozen=True)
class TargetSpec:
    """One emulated device: the roofline + layout constants the cost model,
    tuner, and cache fingerprint depend on."""

    name: str
    peak_flops_bf16: float
    peak_flops_f32: float
    hbm_bw: float
    ici_bw: float
    vmem_bytes: int
    lane: int = 128
    sublane: int = 8
    mxu: int = 128
    block_overhead_s: float = 0.4e-6
    call_overhead_s: float = 2e-6
    vpu_throughput: float = 4e12
    description: str = ""
    # which latency-oracle backend a PruningSession on this target uses
    # when the caller does not pass one ("analytic" | "measured"); the
    # analytic profiles (tpu_v5e/tpu_v4/edge) all stay analytic — their
    # constants ARE the device. Not part of fingerprint(): the oracle
    # identity is keyed separately by the active backend itself.
    default_oracle: str = "analytic"

    def fingerprint(self) -> Tuple:
        """Constants a tuned program depends on, in the exact order of
        :func:`repro.core.tuning_cache.target_fingerprint` (ICI_BW is not
        part of GEMM cost, hence not part of the fingerprint)."""
        return (self.peak_flops_bf16, self.peak_flops_f32, self.hbm_bw,
                self.vmem_bytes, self.block_overhead_s, self.call_overhead_s,
                self.vpu_throughput, self.lane, self.sublane, self.mxu)

    @contextlib.contextmanager
    def activate(self) -> Iterator["TargetSpec"]:
        """Install this target into ``cost_model``; restore the previous
        one on exit — including exception paths."""
        old = [getattr(cost_model, g) for g, _ in _CONSTS]
        for g, f in _CONSTS:
            setattr(cost_model, g, getattr(self, f))
        try:
            yield self
        finally:
            for (g, _), v in zip(_CONSTS, old):
                setattr(cost_model, g, v)

    # -- cost protocol ------------------------------------------------------

    def matmul_cost(self, m: int, k: int, n: int, block, **kw) -> float:
        """Scalar GEMM latency under *this* target (same kernel as the
        active-target free function)."""
        with self.activate():
            return cost_model.matmul_cost(m, k, n, block, **kw)

    def matmul_cost_grid(self, m: int, k: int, n: int, bm, bk, bn,
                         **kw) -> np.ndarray:
        """Vectorized GEMM latency grid under *this* target."""
        with self.activate():
            return cost_model.matmul_cost_grid(m, k, n, bm, bk, bn, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_TARGETS: Dict[str, TargetSpec] = {}


def register_target(spec: TargetSpec, *, overwrite: bool = False
                    ) -> TargetSpec:
    if spec.name in _TARGETS and not overwrite:
        raise ValueError(f"target {spec.name!r} already registered "
                         f"(pass overwrite=True to replace)")
    _TARGETS[spec.name] = spec
    return spec


def get_target(target: Union[str, Target, None]) -> TargetSpec:
    """Resolve a target name (or pass a spec / any :class:`Target`
    implementation through). ``None`` resolves to the default ``tpu_v5e``
    profile."""
    if target is None:
        return _TARGETS["tpu_v5e"]
    if not isinstance(target, str):
        if hasattr(target, "activate"):    # duck-typed Target passthrough
            return target
        raise TypeError(f"target must be a registered name or implement "
                        f"the Target protocol, got {type(target).__name__}")
    try:
        return _TARGETS[target]
    except KeyError:
        raise KeyError(f"unknown target {target!r}; registered targets: "
                       f"{sorted(_TARGETS)}") from None


def list_targets() -> List[str]:
    return sorted(_TARGETS)


# ---------------------------------------------------------------------------
# Built-in profiles
# ---------------------------------------------------------------------------

# The seed repo's hardcoded target, captured verbatim from cost_model so
# activating it is a no-op — tuner selections stay bit-identical to the
# pre-registry code (enforced by tests/test_api.py and tuner_bench.py).
TPU_V5E = register_target(TargetSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12, peak_flops_f32=197e12 / 4,
    hbm_bw=819e9, ici_bw=50e9, vmem_bytes=64 * 1024 * 1024,
    description="analytic TPU v5e shard — the seed cost model"))

# A v4-like profile: more compute and HBM bandwidth, smaller usable VMEM
# working-set budget — tuned blocks grow, prune quanta shift accordingly.
TPU_V4 = register_target(TargetSpec(
    name="tpu_v4",
    peak_flops_bf16=275e12, peak_flops_f32=275e12 / 4,
    hbm_bw=1228e9, ici_bw=100e9, vmem_bytes=32 * 1024 * 1024,
    description="analytic TPU v4-like chip (compute/bandwidth-rich, "
                "tighter VMEM budget)"))

# A bandwidth-skewed edge accelerator: compute-poor, narrow memory bus,
# tiny on-chip buffer, expensive dispatch. GEMMs are memory-bound almost
# everywhere, so the tuner picks small blocks and CPrune's accepted prune
# history diverges from the TPU targets on the same workload (the paper's
# Fig. 7/8 target-specificity claim).
EDGE = register_target(TargetSpec(
    name="edge",
    peak_flops_bf16=8e12, peak_flops_f32=2e12,
    hbm_bw=68e9, ici_bw=5e9, vmem_bytes=2 * 1024 * 1024,
    block_overhead_s=1.0e-6, call_overhead_s=5e-6,
    vpu_throughput=0.5e12,
    description="bandwidth-skewed edge accelerator (memory-bound regime)"))

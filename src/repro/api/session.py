"""`PruningSession` — the repo's single front door for prune -> tune -> serve.

One object owns the pieces users previously hand-wired across every
example and benchmark (Model + params + PruneSite list + Workload +
TrainHooks + CPruneConfig + tuner + ServeEngine) and threads the selected
:class:`~repro.api.targets.TargetSpec` *and* the selected
:class:`~repro.core.oracle.LatencyOracle` backend through all of them:

    session = PruningSession(cfg, target="edge", oracle="analytic",
                             workload=Workload(tokens_global=65536),
                             hooks=my_hooks, pcfg=CPruneConfig(a_g=0.5))
    result = session.prune(strategy="cprune")     # or netadapt/uniform_l1/...
    engine = session.serve(max_batch=8)           # serves the pruned params
    art = session.export("artifact/")             # deployable serve package
    log = session.calibrate("replay.json")        # record measured timings
    session.save("ckpt/")                         # prune-loop checkpoint
    session = PruningSession.resume("ckpt/", hooks=my_hooks)

``prune`` runs entirely under ``target.activate()`` and
``use_oracle(session.oracle)``, so the tuner, the tuning-cache
fingerprints, and the latency model all see the session's target and
scoring backend — the same loop provably produces different pruned
architectures per target (tests/test_api.py, benchmarks/session_targets.py)
and a ``replay`` oracle reproduces a ``measured`` run's history exactly
(tests/test_oracle.py, benchmarks/measured_smoke.py).
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import warnings
from typing import Any, Dict, List, Optional, Union

import jax
import numpy as np

from repro.api.artifact import (DeploymentArtifact, _flatten_params,
                                _unflatten_params)
from repro.api.strategies import PruneResult, get_strategy, list_strategies
from repro.api.targets import TargetSpec, get_target
from repro.configs.base import ModelConfig
from repro.core import latency, tuner
from repro.core import oracle as oracle_mod
from repro.core.cprune import CPruneConfig, IterationRecord, TrainHooks
from repro.core.oracle import (LatencyOracle, MeasuredOracle,
                               MeasurementConfig, MeasurementLog,
                               ReplayOracle)
from repro.core.tasks import TaskTable, Workload
from repro.models.model import Model, init_params, prune_sites
from repro.serve.engine import ServeEngine

_CKPT_VERSION = 1


def _null_hooks() -> TrainHooks:
    """Hooks for tune/serve-only sessions: no training, perfect accuracy."""
    hooks = TrainHooks(short_term_train=lambda p, s: p,
                       eval_acc=lambda p, s: 1.0)
    hooks._is_null = True      # lets prune() warn that accuracy is a stub
    return hooks


class PruningSession:
    """Facade over the prune -> tune -> serve pipeline for one model on one
    target. Mutable: ``prune`` advances ``params``/``sites`` to the pruned
    model, so subsequent ``tune``/``serve``/``save`` (or another ``prune``
    round) operate on the current state.
    """

    def __init__(self, cfg: ModelConfig, *,
                 params: Optional[Dict[str, Any]] = None,
                 target: Union[str, TargetSpec, None] = "tpu_v5e",
                 oracle: Union[str, LatencyOracle, None] = None,
                 workload: Optional[Workload] = None,
                 hooks: Optional[TrainHooks] = None,
                 pcfg: Optional[CPruneConfig] = None,
                 seed: int = 0):
        self.cfg = cfg
        self.target = get_target(target)
        # None -> the target's declared default backend (analytic for all
        # built-in profiles); a name or LatencyOracle instance overrides
        self.oracle = oracle_mod.get_oracle(
            oracle if oracle is not None
            else getattr(self.target, "default_oracle", "analytic"))
        self.model = Model(cfg)
        self.params = params if params is not None \
            else init_params(jax.random.PRNGKey(seed), cfg)
        self.sites = prune_sites(cfg)
        self.workload = workload or Workload(tokens_global=65536)
        self.hooks = hooks or _null_hooks()
        self.pcfg = pcfg or CPruneConfig(a_g=0.0)
        self.result: Optional[PruneResult] = None
        # accumulated across prune() rounds and survives save()/resume()
        self.history: List[IterationRecord] = []
        self.final_acc: Optional[float] = None
        self.last_strategy: Optional[str] = None

    # -- target + oracle activation ----------------------------------------

    @contextlib.contextmanager
    def _active(self, oracle: Union[str, LatencyOracle, None] = None):
        """Everything the session runs happens in here: the target's
        constants installed AND the session's (or an override) oracle
        active, so tuner, cache fingerprints, and latency agree on both."""
        orc = self.oracle if oracle is None else oracle_mod.get_oracle(oracle)
        with self.target.activate(), oracle_mod.use_oracle(orc):
            yield orc

    # -- prune --------------------------------------------------------------

    def prune(self, strategy: str = "cprune",
              oracle: Union[str, LatencyOracle, None] = None,
              **kwargs) -> PruneResult:
        """Run a registered pruning strategy under the session's target and
        adopt the pruned model as the session state. ``oracle`` overrides
        the session's scoring backend for this run only (e.g.
        ``session.prune(oracle="measured")``)."""
        fn = get_strategy(strategy)
        if getattr(self.hooks, "_is_null", False):
            warnings.warn(
                "pruning with default (no-op) hooks: accuracy is stubbed to "
                "1.0, so every candidate passes the accuracy gate and "
                "final_acc is meaningless — pass hooks=TrainHooks(...) for "
                "real accuracy-gated pruning", stacklevel=2)
        with self._active(oracle):
            result = fn(self, **kwargs)
        self.params = result.params
        # strategies filter to pcfg.prunable_kinds and return only that
        # subset; merge it back so the session keeps the full site list
        # (tune/latency_report/save must still see the untouched sites)
        by_id = {s.site_id: s for s in result.sites}
        self.sites = [by_id.get(s.site_id, s) for s in self.sites]
        self.result = result
        self.history.extend(result.history)
        self.final_acc = result.final_acc
        self.last_strategy = result.strategy
        return result

    @staticmethod
    def strategies() -> List[str]:
        return list_strategies()

    # -- tune / measure -----------------------------------------------------

    def tune(self, *, use_tuning: bool = True,
             stats: Optional[tuner.TunerStats] = None,
             oracle: Union[str, LatencyOracle, None] = None) -> TaskTable:
        """Tuned task table (the paper's C) for the current sites under the
        session's target and oracle."""
        with self._active(oracle):
            return tuner.build_tuned_table(
                self.sites, self.workload, use_tuning=use_tuning, stats=stats)

    def latency_report(self, *, use_tuning: bool = True,
                       oracle: Union[str, LatencyOracle, None] = None
                       ) -> latency.LatencyReport:
        """Whole-model latency of the current (possibly pruned) model on the
        session's target, costed by the session's (or an override) oracle."""
        with self._active(oracle):
            table = tuner.build_tuned_table(self.sites, self.workload,
                                            use_tuning=use_tuning)
            return latency.model_latency(
                self.cfg, self.sites, table, seq_len=self.pcfg.seq_len,
                use_tuning=use_tuning)

    def calibrate(self, path: Optional[str] = None, *,
                  config: Optional[MeasurementConfig] = None
                  ) -> MeasurementLog:
        """Record a measured-execution replay log for the current model.

        Tunes the current task table and the fixed ops with the measured
        backend while recording every kernel timing; the returned
        :class:`MeasurementLog` (also written to ``path`` when given)
        drives a deterministic ``ReplayOracle`` later. If the session's
        own oracle is already a recording :class:`MeasuredOracle`, its log
        is extended/reused — so calling ``calibrate`` after a measured
        ``prune`` snapshots everything that run measured.
        """
        if isinstance(self.oracle, MeasuredOracle) \
                and self.oracle.record is not None \
                and (config is None or config == self.oracle.config):
            orc = self.oracle
        else:
            # inherit a measured session's protocol so the recorded log
            # matches the backend the session actually scores with
            cfg_m = config or (self.oracle.config
                               if isinstance(self.oracle, MeasuredOracle)
                               else MeasurementConfig())
            orc = MeasuredOracle(cfg_m, record=MeasurementLog(cfg_m))
        with self._active(orc):
            table = tuner.build_tuned_table(self.sites, self.workload)
            latency.model_latency(self.cfg, self.sites, table,
                                  seq_len=self.pcfg.seq_len)
        if path is not None:
            orc.record.save(path)
        return orc.record

    # -- export / serve -----------------------------------------------------

    def export(self, path: str, *, max_batch: int = 8,
               max_seq: int = 512,
               tp: Optional[int] = None) -> DeploymentArtifact:
        """Package the current (pruned) model as a self-contained
        :class:`~repro.api.artifact.DeploymentArtifact` at ``path``:
        params, model config, target constants, the tuned program table,
        the oracle identity (a recording measured session ships its
        calibration log as a replay artifact), accuracy/latency metadata,
        and fingerprints. The artifact serves without this session —
        ``DeploymentArtifact.load(path).serve()`` or
        ``ServeEngine.from_artifact(path)`` in a fresh process.

        ``max_batch``/``max_seq`` become the artifact's serve defaults and
        parameterize the recorded decode-step prediction. Returns the
        artifact re-read from disk, so what you get is exactly what was
        persisted (validation included).

        ``tp`` exports for a tensor-parallel mesh: the tuned table and
        latency metadata are priced per shard (plus collectives) and the
        artifact carries a ``PartitionSpec`` section the sharded engine
        loads against a real mesh. ``None`` inherits the session
        workload's degree; tp=1 artifacts are byte-identical to before
        partitioning existed.
        """
        DeploymentArtifact.from_session(
            self, max_batch=max_batch, max_seq=max_seq, tp=tp).save(path)
        # the verification re-read skips only the device-availability
        # check: exporting *for* a pod from a small host is the normal
        # plan-here-deploy-there flow (serving still re-checks at load)
        return DeploymentArtifact.load(path, check_devices=False)

    def serve(self, *, params: Optional[Dict[str, Any]] = None,
              max_batch: int = 8, max_seq: int = 512,
              seed: int = 0, predict_step: bool = True,
              scheduler=None, measurements=None) -> ServeEngine:
        """A :class:`ServeEngine` over the current (pruned) params — or an
        explicit ``params`` override, e.g. the dense baseline.

        Built on the artifact path: the session snapshots itself as an
        in-memory :class:`DeploymentArtifact` (no tuned table, no disk)
        and hands it to :meth:`ServeEngine.from_artifact`, so session
        serving and artifact serving are the same code. With
        ``predict_step`` (default), the engine is handed the oracle's
        predicted per-decode-step latency for this model at ``max_batch``
        (per-token GEMMs for ``max_batch`` tokens, attention against a
        ``max_seq``-deep KV cache), and its ``run()`` stats report
        predicted vs measured step time — the observable oracle error the
        paper's compiler feedback loop closes. The prediction describes
        the *session's* model, so serving a ``params`` override (e.g. the
        dense baseline) gets no prediction. ``scheduler`` (a
        ``SchedulerConfig`` or policy name) and ``measurements`` (a
        ``MeasurementLog`` the engine records its observed decode step
        into) pass through to the engine.
        """
        if params is not None:
            return ServeEngine(self.cfg, params, max_batch=max_batch,
                               max_seq=max_seq, seed=seed,
                               scheduler=scheduler,
                               measurements=measurements)
        art = DeploymentArtifact.from_session(
            self, max_batch=max_batch, max_seq=max_seq,
            predict_step=predict_step, include_table=False)
        return ServeEngine.from_artifact(art, max_batch=max_batch,
                                         max_seq=max_seq, seed=seed,
                                         predict_step=predict_step,
                                         scheduler=scheduler,
                                         measurements=measurements)

    # -- checkpointing ------------------------------------------------------

    def save(self, path: str) -> None:
        """Checkpoint the prune-loop state: config, target, workload, current
        (pruned) params + site dims, and the iteration history."""
        if not dataclasses.is_dataclass(self.target):
            raise ValueError(
                f"cannot checkpoint a session whose target is not a "
                f"TargetSpec-style dataclass: {type(self.target).__name__}")
        os.makedirs(path, exist_ok=True)
        meta = {
            "version": _CKPT_VERSION,
            "config": dataclasses.asdict(self.cfg),
            "target": self.target.name,
            # full spec fields so custom/unregistered targets round-trip
            "target_spec": dataclasses.asdict(self.target),
            "workload": dataclasses.asdict(self.workload),
            "oracle": self.oracle.name,
            "pcfg": dataclasses.asdict(self.pcfg),
            "site_dims": {s.site_id: s.dim for s in self.sites},
            "strategy": self.last_strategy,
            "final_acc": self.final_acc,
            "history": [dataclasses.asdict(h) for h in self.history],
        }
        # a replay session records where its log lives (plus a digest) so
        # resume() can reattach the exact artifact instead of silently
        # falling back to the target's default backend
        if isinstance(self.oracle, ReplayOracle) \
                and self.oracle.log.path is not None:
            meta["oracle_log"] = os.path.abspath(self.oracle.log.path)
            meta["oracle_log_digest"] = self.oracle.log.digest()
        # params first, metadata last: session.json is the commit record, so
        # a crash mid-save can never pair new metadata with missing/stale
        # params (both writes are tmp + atomic rename)
        tmp = os.path.join(path, "params.npz.tmp")
        with open(tmp, "wb") as f:
            np.savez(f, **_flatten_params(self.params))
        os.replace(tmp, os.path.join(path, "params.npz"))
        tmp = os.path.join(path, "session.json.tmp")
        with open(tmp, "w") as f:
            json.dump(meta, f, indent=1)
        os.replace(tmp, os.path.join(path, "session.json"))

    @classmethod
    def resume(cls, path: str, *,
               hooks: Optional[TrainHooks] = None,
               target: Union[str, TargetSpec, None] = None,
               workload: Optional[Workload] = None,
               pcfg: Optional[CPruneConfig] = None) -> "PruningSession":
        """Rebuild a session from :meth:`save`. Training hooks are live
        objects and cannot be serialized — pass them again to continue
        pruning; tune/serve work without them. A further ``prune`` call
        re-enters Algorithm 1 from the checkpointed model (the loop's
        ``l_t``/``a_p`` are re-derived from the restored state).
        """
        with open(os.path.join(path, "session.json")) as f:
            meta = json.load(f)
        if meta.get("version") != _CKPT_VERSION:
            raise ValueError(f"unsupported session checkpoint version: "
                             f"{meta.get('version')!r}")
        cfg_d = dict(meta["config"])
        cfg_d["block_pattern"] = tuple(cfg_d["block_pattern"])
        cfg = ModelConfig(**cfg_d)
        with np.load(os.path.join(path, "params.npz")) as z:
            params = _unflatten_params({k: z[k] for k in z.files})
        if target is None:
            # prefer the checkpointed spec fields: a customized spec whose
            # name shadows a registry entry must not be silently replaced
            # by the stock profile
            spec_d = meta.get("target_spec")
            target = TargetSpec(**spec_d) if spec_d \
                else get_target(meta["target"])
        # stateless backends round-trip by name; a replay session
        # round-trips through its checkpointed log path (digest-checked,
        # so a silently edited log cannot impersonate the original run)
        oracle: Union[str, LatencyOracle, None] = meta.get("oracle")
        if oracle == "replay":
            log_path = meta.get("oracle_log")
            if log_path and os.path.exists(log_path):
                log = MeasurementLog.load(log_path)
                want = meta.get("oracle_log_digest")
                if want and log.digest() != want:
                    raise ValueError(
                        f"replay log {log_path!r} changed since the session "
                        f"was saved (digest {log.digest()} != {want}); "
                        f"re-point the session at the original log via "
                        f"PruningSession(oracle=ReplayOracle(path))")
                oracle = ReplayOracle(log)
            else:
                if log_path:
                    warnings.warn(
                        f"replay log {log_path!r} is missing; resuming with "
                        f"the target's default oracle", stacklevel=2)
                oracle = None
        elif oracle not in ("analytic", "measured"):
            oracle = None
        session = cls(
            cfg, params=params, target=target, oracle=oracle,
            workload=workload or Workload(**meta["workload"]),
            hooks=hooks, pcfg=pcfg or CPruneConfig(**meta["pcfg"]))
        dims = meta["site_dims"]
        session.sites = [s.with_dim(dims[s.site_id]) if s.site_id in dims
                         else s for s in session.sites]
        session.history = [IterationRecord(**h) for h in meta["history"]]
        session.final_acc = meta.get("final_acc")
        session.last_strategy = meta.get("strategy")
        return session

"""Table 2 + Fig. 9 + Fig. 10 reproductions — the three CPrune ablations:

  * w/o tuning        (Fig. 10 / Table 2 row 3): the loop consults untuned
                      default programs for ordering and prune steps; the
                      FINAL model is still tuned (paper Line 17), so the
                      reported FPS isolates decision quality.
  * single-subgraph   (Fig. 9  / Table 2 row 4): prune one subgraph per
                      iteration instead of all associated subgraphs.
  * full CPrune       (reference row)

Each variant is one `PruningSession.prune("cprune", **ablation)` call —
the ablation switches are CPruneConfig overrides forwarded by the
strategy.

Arch: the hybrid (RecurrentGemma-family) bench config — its FFN task spans
three stack positions, so "associated subgraphs" is a real set, as in the
paper's ResNet graph (Fig. 4).

Expected ordering (paper): FPS(cprune) >= FPS(single) > FPS(w/o tuning).

Note on ``evals``: with the memoized tuning engine the counter reports
*true grid work* (cache hits and carried-over tasks cost nothing). The
single-subgraph ablation masks channels instead of slicing (shapes are
preserved for the scanned stack), so its candidates legitimately re-tune
less than CPrune's — per-unit-of-FPS-gained it is still far costlier,
which is the paper's Fig. 9 point; the selective-vs-exhaustive search
cost comparison lives in fig11_search_cost.py.
"""
from __future__ import annotations

from benchmarks import common
from repro.api import PruningSession


def _run_variant(name: str, **pcfg_over):
    common.reset_tuning_caches()   # per-arm cold start: evals comparable
    # d_ff=4096: VMEM forces mid-size tuned blocks, so the tuned prune step
    # (512) beats the default program's lane quantum (128) — without tuning
    # "pruning does not proceed sufficiently" (paper §4.6) under the same
    # iteration budget.
    setup = common.make_setup("recurrentgemma_9b", n_layers=3, d_model=256,
                              d_ff=4096, n_heads=4, n_kv_heads=1,
                              head_dim=64, rglru_width=256,
                              max_iterations=6, alpha=0.8, beta=0.99)
    common.pretrain(setup, steps=36)
    session = PruningSession(setup.cfg, params=setup.params,
                             workload=setup.wl, hooks=setup.hooks,
                             pcfg=setup.pcfg)
    base_fps = session.latency_report().fps
    res = session.prune(strategy="cprune", **pcfg_over)
    # paper Line 17: the final model is tuned regardless of the ablation
    # (the session's latency_report always consults the tuned table)
    final_fps = session.latency_report().fps
    return {
        "rate": final_fps / base_fps,
        "acc": res.final_acc,
        "evals": res.candidates_evaluated,
        "accepted": sum(h.accepted for h in res.history),
        "iters": len(res.history),
    }


def run():
    t = common.Timer()
    rows = {
        "cprune": _run_variant("cprune"),
        "wo_tuning": _run_variant("wo_tuning", use_tuning=False),
        "single_subgraph": _run_variant("single_subgraph",
                                        associated_subgraphs=False),
    }
    derived = ";".join(
        f"{k}:rate={v['rate']:.2f},acc={v['acc']:.3f},evals={v['evals']},"
        f"accepted={v['accepted']}" for k, v in rows.items())
    common.emit("table2_ablations", t.us(), derived)
    return rows


if __name__ == "__main__":
    run()

"""Render the §Roofline-table markdown from the final dry-run artifacts
and splice it into EXPERIMENTS.md (idempotent: replaces the table block).

    PYTHONPATH=src python -m benchmarks.render_roofline
"""
from __future__ import annotations

import re
from pathlib import Path

from benchmarks.roofline import analyze_cell
from repro.configs import ARCH_IDS, SHAPES

MARK_BEGIN = "<!-- ROOFLINE-TABLE:BEGIN -->"
MARK_END = "<!-- ROOFLINE-TABLE:END -->"


def render(mesh: str = "single") -> str:
    lines = [
        MARK_BEGIN,
        "",
        f"Per-device roofline terms, {mesh}-pod mesh "
        "(final artifacts; seconds per step):",
        "",
        "| arch | shape | compute_s | memory_s | collective_s | dominant |"
        " useful | frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_IDS:
        for shape in SHAPES:
            r = analyze_cell(arch, shape, mesh)
            if r is None:
                continue
            if r.get("status") == "skipped":
                lines.append(
                    f"| {arch} | {shape} | — | — | — | skipped |"
                    f" {r['reason'][:36]} | — |")
                continue
            if r.get("status") != "ok":
                continue
            lines.append(
                f"| {arch} | {shape} | {r['compute_s']:.3g} |"
                f" {r['memory_s']:.3g} | {r['collective_s']:.3g} |"
                f" {r['dominant']} | {r['useful_ratio']:.2f} |"
                f" {r['roofline_fraction']:.4f} |")
    lines += ["", MARK_END]
    return "\n".join(lines)


def main():
    exp = Path(__file__).resolve().parents[1] / "EXPERIMENTS.md"
    text = exp.read_text()
    table = render("single")
    if MARK_BEGIN in text:
        text = re.sub(
            re.escape(MARK_BEGIN) + r".*?" + re.escape(MARK_END),
            table, text, flags=re.S)
    else:
        text += "\n\n" + table + "\n"
    exp.write_text(text)
    print(f"wrote roofline table ({table.count(chr(10))} lines) into "
          f"{exp}")


if __name__ == "__main__":
    main()

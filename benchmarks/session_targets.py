"""Target-aware session benchmark — the acceptance check for the
`PruningSession` target registry:

  * under the ``tpu_v5e`` backend the accepted prune history is identical
    to the default (active-constants) run — the registry is bit-identical
    to the seed cost model;
  * under the ``edge`` backend the same quickstart-shaped workload yields
    a *different* accepted history (different prune quanta / trajectory) —
    the compiler-informed loop actually listens to the target.

Training hooks are stubbed so the digest isolates the compiler/tuner side.
"""
from __future__ import annotations

import jax

from benchmarks import common
from repro.api import CPruneConfig, PruningSession, TrainHooks, list_targets
from repro.models.model import init_params

_QUICKSTART_KW = dict(n_layers=4, d_model=128, d_ff=1024, n_heads=8,
                      n_kv_heads=2, head_dim=16, vocab_size=256)


def _hooks_pcfg():
    return (TrainHooks(short_term_train=lambda p, s: p,
                       eval_acc=lambda p, s: 0.9),
            CPruneConfig(a_g=0.5, alpha=0.5, beta=0.9999, max_iterations=8,
                         seq_len=common.BENCH_SEQ))


def _prune_on(target, cfg, params):
    common.reset_tuning_caches()
    hooks, pcfg = _hooks_pcfg()
    session = PruningSession(
        cfg, params=params, target=target, workload=common.bench_workload(),
        hooks=hooks, pcfg=pcfg)
    return session.prune(strategy="cprune")


def _prune_raw_core(cfg, params):
    """The pre-registry path: CPrune directly on the active (seed) target
    constants — the baseline the ``tpu_v5e`` backend must reproduce."""
    from repro.core import CPrune
    from repro.models.model import prune_sites
    common.reset_tuning_caches()
    hooks, pcfg = _hooks_pcfg()
    res = CPrune(cfg, prune_sites(cfg), common.bench_workload(), hooks,
                 pcfg).run(params)
    return [(h.task_kind, h.prune_units, h.dim_before, h.dim_after,
             h.accepted) for h in res.history]


def run():
    t = common.Timer()
    cfg = common.bench_config("qwen3_1_7b", **_QUICKSTART_KW)
    params = init_params(jax.random.PRNGKey(0), cfg)

    digests = {tgt: tuple(_prune_on(tgt, cfg, params).history_digest())
               for tgt in list_targets()}
    v5e_default_identical = digests["tpu_v5e"] == tuple(
        _prune_raw_core(cfg, params))
    edge_differs = digests["edge"] != digests["tpu_v5e"]

    derived = (f"v5e_matches_default={v5e_default_identical};"
               f"edge_differs_from_v5e={edge_differs};"
               + ";".join(f"{k}_accepted={len(v)}"
                          for k, v in sorted(digests.items())))
    common.emit("session_targets", t.us(), derived)
    if not v5e_default_identical:
        raise AssertionError("tpu_v5e target drifted from the seed model")
    if not edge_differs:
        raise AssertionError("edge target did not change the prune history")
    return {"digests": digests, "v5e_default": v5e_default_identical,
            "edge_differs": edge_differs}


if __name__ == "__main__":
    run()

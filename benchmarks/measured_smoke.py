"""Measured-oracle smoke: a full CPrune run scored by *executing* the
repo's Pallas kernels (interpret mode on CPU), recorded to a replay log,
then replayed to prove the log reproduces the identical prune history.

This is the CI `measured-smoke` job: it must finish a small config
end-to-end inside a 10-minute budget and leaves the recorded log at
``MEASURED_SMOKE_LOG`` (default ``measured_replay.json``) as the build
artifact — the same calibrate -> replay workflow a user runs against a
real TPU.
"""
from __future__ import annotations

import os

from benchmarks import common
from repro.api import (MeasuredOracle, MeasurementConfig, MeasurementLog,
                       PruningSession, ReplayOracle)
from repro.core import CPruneConfig, Workload, clear_tuning_caches

# CPU-interpret-friendly measurement protocol: tiny shortlist, one
# measured grid step per dim, median of 3 unwarmed repeats
MEASURE = MeasurementConfig(warmup=0, repeats=3, trim=0, measure_top_k=2,
                            max_grid_steps=1)


def _session(setup, oracle):
    return PruningSession(setup.cfg, params=setup.params, target="tpu_v5e",
                          oracle=oracle, workload=setup.wl,
                          hooks=setup.hooks, pcfg=setup.pcfg)


def run():
    t = common.Timer()
    log_path = os.environ.get("MEASURED_SMOKE_LOG", "measured_replay.json")
    setup = common.make_setup(n_layers=2, d_model=64, d_ff=256, n_heads=4,
                              n_kv_heads=2, head_dim=16, vocab_size=128,
                              max_iterations=3, alpha=0.5, beta=0.999)
    setup.wl = Workload(tokens_global=1024)
    common.pretrain(setup, steps=10)

    # measured run, recording every kernel timing
    log = MeasurementLog(MEASURE)
    clear_tuning_caches()
    res_m = _session(setup, MeasuredOracle(MEASURE, record=log)) \
        .prune(strategy="cprune")
    n_saved = log.save(log_path)
    stats = res_m.tuner_stats

    # replay run from the saved artifact: identical history required
    clear_tuning_caches()
    res_r = _session(setup, ReplayOracle.from_file(log_path)) \
        .prune(strategy="cprune")
    identical = res_r.history_digest(include_latency=True) \
        == res_m.history_digest(include_latency=True)
    clear_tuning_caches()

    derived = (f"identical_history={identical}"
               f";accepted={sum(h.accepted for h in res_m.history)}"
               f";measured_programs={stats.measured_programs}"
               f";measure_wall_s={stats.measure_wall_s:.1f}"
               f";replay_hits={res_r.tuner_stats.replay_hits}"
               f";log_entries={n_saved}")
    common.emit("measured_smoke", t.us(), derived)
    if not identical:
        # RuntimeError (not SystemExit) so benchmarks/run.py's harness can
        # record the failure row and keep running the remaining figures
        raise RuntimeError("replay history diverged from the measured run")
    return {"log_path": log_path, "identical": identical}


if __name__ == "__main__":
    run()

"""Distributed-serving bench (CI ``distributed-smoke``): tensor-parallel
bit-identity plus the replica fleet balancer.

Three arms:

  * ``dist_tp2_identity`` — a subprocess under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` greedy-decodes
    the same reduced model once on a single device and once sharded tp=2
    over a ``(1, 2)`` (data, model) mesh, for both KV layouts. The token
    streams must be **bit-identical**: GSPMD partitions the very jaxpr
    the single-device engine traced, so sharding is an execution detail,
    never a math change.
  * ``dist_fleet_vs_solo`` — a 2-replica :class:`ReplicaSet` draining the
    interleaved serve workload vs one replica alone, both warmed.
    Least-loaded outstanding-token dispatch must sustain >= the single
    replica (``SERVE_DIST_MIN_RATIO``, default 1.0): on one host the
    replicas share the CPU, so the fleet's win is batching reach (2x the
    slots), and the gate catches any balancer overhead regression.
  * ``dist_fleet_crash`` — the same fleet with one injected mid-decode
    crash on replica 0. Gates: **zero lost requests** (every request
    completes), outputs bit-identical to the fault-free drain, and at
    least one re-queue must land on the *surviving* replica
    (``requeued_to_survivor`` — recovery does not wait for the cold
    rebuild of the replica that died).

Run: ``PYTHONPATH=src:. python benchmarks/distributed_bench.py``
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np

from benchmarks import common
from repro.models.model import init_params
from repro.serve.engine import Request, ServeEngine
from repro.serve.fleet import ReplicaSet, RetryPolicy
from repro.util.faults import FaultInjector, crash_at

N_REQUESTS = 16
MAX_BATCH = 4
MAX_SEQ = 40


def _bench_cfg():
    return common.bench_config(n_layers=2, d_model=64, d_ff=512, n_heads=4,
                               n_kv_heads=2, head_dim=16, vocab_size=128)


def _workload(cfg):
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(N_REQUESTS):
        plen = 8 if i % 2 == 0 else 12
        n_new = 4 if i % 4 < 2 else 24
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            max_new_tokens=n_new))
    return reqs


# ---------------------------------------------------------------------------
# arm 1: tp=2 sharded decode is bit-identical to single-device decode
# ---------------------------------------------------------------------------

# The parent process already initialised jax with however many devices the
# host has, and XLA_FLAGS is read once at import — so the tp=2 arm runs in
# a fresh interpreter where the flag can still take effect.
_TP2_CODE = textwrap.dedent("""
    import jax, numpy as np
    from benchmarks import common
    from repro.launch.mesh import make_test_mesh
    from repro.models.model import init_params
    from repro.serve.distributed import ShardedServeEngine
    from repro.serve.engine import Request, ServeEngine
    from repro.serve.scheduler import SchedulerConfig

    assert len(jax.devices()) == 4, jax.devices()
    cfg = common.bench_config(n_layers=2, d_model=64, d_ff=512, n_heads=4,
                              n_kv_heads=2, head_dim=16, vocab_size=128)
    params = init_params(jax.random.PRNGKey(0), cfg)

    def reqs():
        rng = np.random.default_rng(0)
        return [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            8 if i % 2 == 0 else 12
                                            ).astype(np.int32),
                        max_new_tokens=4 if i % 4 < 2 else 24)
                for i in range(16)]

    def drain(eng):
        for r in reqs():
            eng.submit(r)
        eng.run()
        return {r.rid: list(r.output) for r in eng.done}

    mesh = make_test_mesh(n_devices=2, model=2)   # (1, 2) (data, model)
    for layout in ("contiguous", "paged"):
        sched = SchedulerConfig(kv_layout=layout, page_size=8)
        want = drain(ServeEngine(cfg, params, max_batch=4, max_seq=40,
                                 scheduler=sched))
        got = drain(ShardedServeEngine(cfg, params, mesh=mesh, max_batch=4,
                                       max_seq=40, scheduler=sched))
        assert got == want, (
            f"tp=2 {layout} decode diverged for rids "
            f"{[r for r in want if got.get(r) != want[r]][:8]}")
        print(f"IDENTICAL {layout} tokens="
              f"{sum(len(v) for v in want.values())}")
""")


def run_tp2_identity():
    t = common.Timer()
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = "src:."
    proc = subprocess.run([sys.executable, "-c", _TP2_CODE],
                          capture_output=True, text=True, timeout=900,
                          env=env)
    if proc.returncode != 0:
        raise RuntimeError(
            f"tp=2 identity subprocess failed:\n{proc.stdout}\n{proc.stderr}")
    lines = [ln for ln in proc.stdout.splitlines()
             if ln.startswith("IDENTICAL")]
    if len(lines) != 2:
        raise RuntimeError(f"expected 2 IDENTICAL lines, got:\n{proc.stdout}")
    common.emit("dist_tp2_identity", t.us(),
                "identical=contiguous,paged;devices=4;mesh=1x2;"
                + lines[0].split()[-1])
    return lines


# ---------------------------------------------------------------------------
# arms 2+3: the fleet balancer
# ---------------------------------------------------------------------------

def _fleet(cfg, params, *, replicas, faults=None):
    def factory(i):
        return ServeEngine(cfg, params, max_batch=MAX_BATCH, max_seq=MAX_SEQ,
                           faults=faults if i == 0 else None,
                           fault_tag=f"bench#r{i}")
    return ReplicaSet(factory, replicas=replicas, name="bench",
                      retry=RetryPolicy(max_retries=2, backoff_s=60.0))


def _drain(sup, cfg):
    for r in _workload(cfg):
        sup.submit(r)
    sup.run()
    stats = sup.stats()
    outputs = {r.rid: list(r.output) for r in sup.completed}
    sup.reset_stats()
    return stats, outputs


def run_fleet():
    min_ratio = float(os.environ.get("SERVE_DIST_MIN_RATIO", "1.0"))
    cfg = _bench_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)

    # -- arm 2: 2-replica least-loaded dispatch vs one replica --------------
    t = common.Timer()
    solo = _fleet(cfg, params, replicas=1)
    duo = _fleet(cfg, params, replicas=2)
    _drain(solo, cfg)                      # warmup: compile every shape
    _drain(duo, cfg)
    solo_stats, solo_out = _drain(solo, cfg)
    duo_stats, duo_out = _drain(duo, cfg)
    for _ in range(2):                     # best-of-3 to dampen host noise
        s, _ = _drain(solo, cfg)
        if s["tokens_per_s"] > solo_stats["tokens_per_s"]:
            solo_stats = s
        s, _ = _drain(duo, cfg)
        if s["tokens_per_s"] > duo_stats["tokens_per_s"]:
            duo_stats = s
    assert duo_out == solo_out, "replica count changed greedy outputs"
    hist = duo_stats["dispatch_histogram"]
    ratio = duo_stats["tokens_per_s"] / max(solo_stats["tokens_per_s"], 1e-9)
    common.emit(
        "dist_fleet_vs_solo", t.us(),
        f"tokens_per_s={duo_stats['tokens_per_s']:.1f}"
        f";solo_tokens_per_s={solo_stats['tokens_per_s']:.1f}"
        f";ratio={ratio:.2f}"
        f";dispatch_histogram={hist}")
    if not all(hist):
        raise RuntimeError(
            f"least-loaded dispatch starved a replica: histogram {hist}")
    if ratio < min_ratio:
        raise RuntimeError(
            f"2-replica fleet fell below the single replica: "
            f"ratio {ratio:.2f} < {min_ratio}")

    # -- arm 3: one injected crash — zero lost, survivor absorbs ------------
    t = common.Timer()
    inj = FaultInjector(specs=[crash_at("decode:bench#r0", 3)])
    fleet = _fleet(cfg, params, replicas=2, faults=inj)
    chaos_stats, chaos_out = _drain(fleet, cfg)
    if chaos_out != solo_out:
        bad = [rid for rid in solo_out if chaos_out.get(rid) != solo_out[rid]]
        raise RuntimeError(
            f"re-queued outputs diverged from the fault-free drain "
            f"for rids {bad[:8]}")
    acct = chaos_stats["accounting"]
    common.emit(
        "dist_fleet_crash", t.us(),
        f"crashes={chaos_stats['crashes']}"
        f";requeued={chaos_stats['requeued']}"
        f";requeued_to_survivor={chaos_stats['requeued_to_survivor']}"
        f";requests={chaos_stats['requests']}"
        f";failed={chaos_stats['failed']}"
        f";dispatch_histogram={chaos_stats['dispatch_histogram']}")
    if chaos_stats["requests"] != N_REQUESTS or chaos_stats["failed"] \
            or acct["in_flight"]:
        raise RuntimeError(
            f"lost requests under the crash: completed "
            f"{chaos_stats['requests']}/{N_REQUESTS} "
            f"(failed={chaos_stats['failed']}, "
            f"in_flight={acct['in_flight']})")
    if not (chaos_stats["crashes"] >= 1
            and chaos_stats["requeued_to_survivor"] >= 1):
        raise RuntimeError(
            f"the crash did not exercise survivor re-queue: "
            f"crashes={chaos_stats['crashes']} "
            f"requeued_to_survivor={chaos_stats['requeued_to_survivor']}")
    return {"solo": solo_stats, "duo": duo_stats, "chaos": chaos_stats}


def run():
    run_tp2_identity()
    return run_fleet()


if __name__ == "__main__":
    run()

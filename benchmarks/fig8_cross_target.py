"""Fig. 7/8 reproduction: target-specificity of CPrune models.

The paper prunes for one processor and shows the FPS increase is
significantly higher on the pruning target than when the same pruned model
runs on a different processor. We emulate two TPU "targets" with different
roofline balances (v5e-like vs a bandwidth-rich/compute-poor variant):
CPrune tuned against target A should beat, on A, the model that was pruned
for target B — and vice versa.
"""
from __future__ import annotations

import contextlib

from benchmarks import common
from repro.core import CPrune, tuner
from repro.core import cost_model
from repro.core.latency import model_latency

# (peak_flops, hbm_bw, vmem_bytes) per emulated target — the VMEM budget
# changes which blocks tune fastest, hence the structure-preserving steps
TARGETS = {
    "v5e": (197e12, 819e9, 64 * 2 ** 20),
    "bw_rich": (60e12, 1600e9, 4 * 2 ** 20),   # compute-poor, tiny VMEM
}


@contextlib.contextmanager
def _target(name: str):
    peak, bw, vmem = TARGETS[name]
    old = (cost_model.PEAK_FLOPS_BF16, cost_model.HBM_BW,
           cost_model.VMEM_BYTES)
    cost_model.PEAK_FLOPS_BF16 = peak
    cost_model.HBM_BW = bw
    cost_model.VMEM_BYTES = vmem
    try:
        yield
    finally:
        (cost_model.PEAK_FLOPS_BF16, cost_model.HBM_BW,
         cost_model.VMEM_BYTES) = old


def _fps(cfg, sites, wl, seq_len):
    table = tuner.build_tuned_table(sites, wl)
    return model_latency(cfg, sites, table, seq_len=seq_len).fps


def run():
    t = common.Timer()
    pruned = {}
    base_fps = {}
    # prune one model per target
    for tgt in TARGETS:
        setup = common.make_setup(d_model=256, d_ff=2048, n_heads=8,
                                  n_kv_heads=2, head_dim=32, n_layers=4,
                                  max_iterations=6, alpha=0.8, beta=0.99)
        common.pretrain(setup, steps=36)
        with _target(tgt):
            base_fps[tgt] = _fps(setup.cfg, setup.sites, setup.wl,
                                 setup.pcfg.seq_len)
            cp = CPrune(setup.cfg, setup.sites, setup.wl, setup.hooks,
                        setup.pcfg)
            res = cp.run(setup.params)
        pruned[tgt] = (setup.cfg, res.sites)

    # cross matrix: FPS increase of model pruned-for-row measured on col
    rates = {}
    for made_for, (cfg, sites) in pruned.items():
        for run_on in TARGETS:
            with _target(run_on):
                wl = common.bench_workload()
                rates[(made_for, run_on)] = (
                    _fps(cfg, sites, wl, common.BENCH_SEQ)
                    / base_fps[run_on])

    own = [rates[(t, t)] for t in TARGETS]
    cross = [rates[(a, b)] for a in TARGETS for b in TARGETS if a != b]
    derived = ";".join(
        f"{a}_on_{b}={rates[(a,b)]:.2f}" for a in TARGETS for b in TARGETS)
    derived += (f";own_mean={sum(own)/len(own):.2f}"
                f";cross_mean={sum(cross)/len(cross):.2f}")
    common.emit("fig8_cross_target", t.us(), derived)
    return rates


if __name__ == "__main__":
    run()

"""Fig. 7/8 reproduction: target-specificity of CPrune models.

The paper prunes for one processor and shows the FPS increase is
significantly higher on the pruning target than when the same pruned model
runs on a different processor. We emulate two TPU "targets" with different
roofline balances — the registered `tpu_v5e` backend vs a custom
`bw_rich` :class:`TargetSpec` (compute-poor, bandwidth-rich, tiny VMEM):
CPrune run against target A should beat, on A, the model that was pruned
for target B — and vice versa.
"""
from __future__ import annotations

from benchmarks import common
from repro.api import PruningSession, TargetSpec, get_target
from repro.core import tuner
from repro.core.latency import model_latency

# the VMEM budget changes which blocks tune fastest, hence the
# structure-preserving prune steps (custom spec: not in the registry)
BW_RICH = TargetSpec(
    name="bw_rich", peak_flops_bf16=60e12, peak_flops_f32=60e12 / 4,
    hbm_bw=1600e9, ici_bw=50e9, vmem_bytes=4 * 2 ** 20,
    description="compute-poor, bandwidth-rich, tiny VMEM")

TARGETS = {"tpu_v5e": get_target("tpu_v5e"), "bw_rich": BW_RICH}


def _fps_on(target, cfg, sites, wl, seq_len):
    table = tuner.build_tuned_table(sites, wl, target=target)
    return model_latency(cfg, sites, table, seq_len=seq_len,
                         target=target).fps


def run():
    t = common.Timer()
    pruned = {}
    base_fps = {}
    # prune one model per target — same seed/pretraining, different backend
    for tgt, spec in TARGETS.items():
        setup = common.make_setup(d_model=256, d_ff=2048, n_heads=8,
                                  n_kv_heads=2, head_dim=32, n_layers=4,
                                  max_iterations=6, alpha=0.8, beta=0.99)
        common.pretrain(setup, steps=36)
        session = PruningSession(setup.cfg, params=setup.params, target=spec,
                                 workload=setup.wl, hooks=setup.hooks,
                                 pcfg=setup.pcfg)
        base_fps[tgt] = session.latency_report().fps
        res = session.prune(strategy="cprune")
        pruned[tgt] = (setup.cfg, res.sites)

    # cross matrix: FPS increase of model pruned-for-row measured on col
    rates = {}
    for made_for, (cfg, sites) in pruned.items():
        for run_on, spec in TARGETS.items():
            wl = common.bench_workload()
            rates[(made_for, run_on)] = (
                _fps_on(spec, cfg, sites, wl, common.BENCH_SEQ)
                / base_fps[run_on])

    own = [rates[(t, t)] for t in TARGETS]
    cross = [rates[(a, b)] for a in TARGETS for b in TARGETS if a != b]
    derived = ";".join(
        f"{a}_on_{b}={rates[(a,b)]:.2f}" for a in TARGETS for b in TARGETS)
    derived += (f";own_mean={sum(own)/len(own):.2f}"
                f";cross_mean={sum(cross)/len(cross):.2f}")
    common.emit("fig8_cross_target", t.us(), derived)
    return rates


if __name__ == "__main__":
    run()

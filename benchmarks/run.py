"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  fig1_correlation   paper Fig. 1  (pruned-vs-tuned non-correlation)
  fig6_iterations    paper Fig. 6  (iterative FPS rate + accuracy)
  table1_methods     paper Table 1 (CPrune vs L1/FPGM/NetAdapt)
  table2_ablations   paper Table 2 + Fig. 9 + Fig. 10 (tuning,
                     associated-subgraph ablations)
  fig11_search_cost  paper Fig. 11 (selective vs exhaustive search)
  session_targets    PruningSession target registry: tpu_v5e bit-identical
                     to the seed model, edge yields a different history
  measured_smoke     measured-execution oracle: CPrune scored by timing
                     the Pallas kernels, replay-log determinism check
  artifact_smoke     deployment artifact: export in this process, serve
                     from a second interpreter, fingerprints must match
  serve_bench        scheduler-core serving vs the legacy wave engine on
                     an interleaved workload, plus the SLO router over a
                     two-artifact catalog (throughput gates)
  serve_chaos        supervised fleet under injected crashes/stragglers
                     + one tampered catalog member (zero lost requests,
                     bit-identical re-queued outputs, goodput gate)
  serve_autopilot    drift-triggered autopilot: injected decode drift ->
                     recalibrated replan -> atomic hot-swap (swap must
                     happen, violation rate must drop, zero dropped)
  serve_distributed  tensor-parallel serving: tp=2 sharded greedy decode
                     bit-identical to single-device (subprocess, 4 host
                     devices), 2-replica least-loaded fleet >= solo
                     throughput, zero lost requests across one injected
                     crash (re-queues land on the surviving replica)
  serve_paged        paged KV cache vs the contiguous layout at batch 64
                     on a heavy-tailed mix (throughput + strict peak-KV
                     gates, zero compaction copies, bit-identical greedy
                     outputs, prefix sharing must cut prefill work)
  tuner_bench        vectorized+memoized tuning engine vs the scalar
                     reference engine (identical histories, wall-clock)
  kernel_*           Pallas kernel microbenches (interpret + v5e cost)
  roofline[*]        deliverable (g): per-cell roofline terms from the
                     dry-run artifacts (run launch/dryrun.py first)
"""
import sys
import traceback


def main() -> None:
    from benchmarks import (artifact_smoke, distributed_bench,
                            fig1_correlation, fig6_iterations,
                            fig8_cross_target, fig11_search_cost,
                            kernels_bench, measured_smoke, roofline,
                            serve_bench, session_targets, table1_methods,
                            table2_ablations, tuner_bench)
    from benchmarks import common

    print("name,us_per_call,derived")
    mods = [
        ("fig1_correlation", fig1_correlation.run),
        ("fig6_iterations", fig6_iterations.run),
        ("table1_methods", table1_methods.run),
        ("table2_ablations", table2_ablations.run),
        ("fig8_cross_target", fig8_cross_target.run),
        ("session_targets", session_targets.run),
        ("measured_smoke", measured_smoke.run),
        ("artifact_smoke", artifact_smoke.run),
        ("serve_bench", serve_bench.run),
        ("serve_chaos", serve_bench.run_chaos),
        ("serve_autopilot", serve_bench.run_autopilot),
        ("serve_distributed", distributed_bench.run),
        ("serve_paged", serve_bench.run_paged),
        ("fig11_search_cost", fig11_search_cost.run),
        ("tuner_bench", tuner_bench.run),
        ("kernels", kernels_bench.run),
        ("roofline", roofline.run),
    ]
    failures = []
    for name, fn in mods:
        try:
            fn()
        except Exception as e:
            failures.append(name)
            print(f"{name},0,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == '__main__':
    main()

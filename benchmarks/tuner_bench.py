"""Tuning-engine benchmark: vectorized + memoized vs the pre-PR tuner.

Runs the *same* 10-iteration CPrune loop on a qwen3_1_7b-family config
under ``tuner.engine_mode("reference")`` (the original scalar candidate
loop, no ProgramCache, no incremental retuning, no fixed-op memo) and
under the default engine — interleaved repeats, cold caches each time —
and checks that the accepted iteration histories are identical (same
tasks, dims, and latencies). The reported speedup is the median of the
per-pair wall-clock ratios (robust to one-off machine-load spikes); the
per-engine seconds are minima over the repeats.

Training/accuracy hooks are stubbed (accuracy never gates) and the param
tensors carry a skinny non-prunable axis, so wall-clock isolates the
compiler/tuner side — the quantity the two engines differ in. Both engines
run the identical CPrune code path over identical inputs.

Note on counters: ``candidates_evaluated`` now also counts fixed-op
(kv/unembed/...) tuning — work the pre-PR code performed per candidate but
never counted. The reference engine's total therefore reflects its true
per-candidate work, which is exactly what the vectorized engine's cache
and memo remove.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core import CPrune, CPruneConfig, TrainHooks, tuner
from repro.models.model import prune_sites

# Dims chosen so every GEMM uses a near-maximal candidate grid (~900
# configs) — the regime the pre-PR tuner pays for on every candidate.
_ARCH_KW = dict(n_layers=2, d_model=2048, d_ff=8192, n_heads=16,
                n_kv_heads=4, head_dim=128, vocab_size=16384)
_ROWS = 4          # skinny stand-in for the d_model axis of param tensors


def _make_params(cfg) -> dict:
    """Numpy param tree holding exactly the site-referenced leaves.

    Prunable axes match the real model (ranking/surgery operate on them);
    the non-prunable d_model axis is ``_ROWS`` wide so candidate surgery
    costs microseconds and the tuner dominates the run.
    """
    rng = np.random.default_rng(0)
    L, F = cfg.n_layers, cfg.d_ff
    H, hd = cfg.n_heads, cfg.head_dim

    def w(*shape):
        return rng.standard_normal(shape).astype(np.float32)

    return {"stack": {"pos0": {
        "ffn": {"w_up": w(L, _ROWS, F), "w_gate": w(L, _ROWS, F),
                "w_down": w(L, F, _ROWS)},
        "mixer": {"wq": w(L, _ROWS, H, hd), "wo": w(L, H * hd, _ROWS)},
    }}}


def _run_cprune():
    cfg = common.bench_config("qwen3_1_7b", **_ARCH_KW)
    sites = prune_sites(cfg)
    params = _make_params(cfg)
    hooks = TrainHooks(short_term_train=lambda p, s: p,
                       eval_acc=lambda p, s: 0.9)
    # beta ~ 1: any real latency win is accepted, so the loop runs all 10
    # iterations and the engines face the maximal retuning load
    pcfg = CPruneConfig(a_g=0.5, alpha=0.5, beta=0.9999, max_iterations=10,
                        seq_len=common.BENCH_SEQ)
    cp = CPrune(cfg, sites, common.bench_workload(), hooks, pcfg)
    t0 = time.time()
    res = cp.run(params)
    return time.time() - t0, res


def _history_key(res):
    return [(h.iteration, h.task_kind, h.prune_units, h.dim_before,
             h.dim_after, h.l_m, h.accepted) for h in res.history]


_REPEATS = 5


def _timed(engine: str):
    # cold caches per repeat: the speedup claim is within-run reuse,
    # not residue from a previous run
    common.reset_tuning_caches()
    with tuner.engine_mode(engine):
        return _run_cprune()


def run():
    t = common.Timer()
    # interleave the engines so both sample the same machine-load regime;
    # the median of per-pair ratios is robust to one-off load spikes
    ratios = []
    ref_res = new_res = None
    ref_s = new_s = float("inf")
    for _ in range(_REPEATS):
        r_s, ref_res = _timed("reference")
        n_s, new_res = _timed("vectorized")
        ratios.append(r_s / max(n_s, 1e-9))
        ref_s, new_s = min(ref_s, r_s), min(new_s, n_s)
    speedup = sorted(ratios)[len(ratios) // 2]
    identical = _history_key(ref_res) == _history_key(new_res)
    st = new_res.tuner_stats
    common.emit(
        "tuner_bench", t.us(),
        f"speedup={speedup:.1f}x;reference_s={ref_s:.3f};"
        f"vectorized_s={new_s:.3f};identical_history={identical};"
        f"accepted={sum(h.accepted for h in new_res.history)};"
        f"ref_candidates={ref_res.tuner_stats.candidates_evaluated};"
        f"new_candidates={st.candidates_evaluated};"
        f"cache_hits={st.cache_hits};cache_misses={st.cache_misses};"
        f"tasks_reused={st.tasks_reused}")
    if not identical:
        raise AssertionError("engines disagree on the accepted history")
    return {"speedup": speedup, "identical_history": identical,
            "reference_s": ref_s, "vectorized_s": new_s}


if __name__ == "__main__":
    import os

    out = run()
    # 20x is the local acceptance bar; CI sets a looser tripwire because
    # shared runners have different CPUs and noisy neighbors
    floor = float(os.environ.get("TUNER_BENCH_MIN_SPEEDUP", "20"))
    assert out["speedup"] >= floor, \
        f"speedup {out['speedup']:.1f}x < {floor:g}x"

"""Fig. 1 reproduction: the fastest pruned model BEFORE compiler tuning is
usually NOT the fastest AFTER tuning (and correlation is weak).

Protocol (paper §3, adapted to the TPU target): generate variants that
spend a similar total prune budget but allocate it differently between
attention heads and FFN channels. The bench dims sit at the
compute<->memory roofline boundary, so:

  * the untuned default program (128-cube blocks) inflates memory traffic
    via panel re-reads and mis-ranks variants that tuned programs handle
    well — ``spearman(naive, tuned)`` is weak and argmins mismatch
    (the paper's Fig. 1);
  * FLOPs-based ranking (the indirect metric pruning methods optimize) is
    equally weakly correlated with tuned latency (the paper's §4.4 point).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import applier, tuner
from repro.core.latency import model_latency


def _latency(cfg, sites, wl, *, use_tuning: bool, seq_len: int) -> float:
    table = tuner.build_tuned_table(sites, wl, use_tuning=use_tuning)
    return model_latency(cfg, sites, table, seq_len=seq_len,
                         use_tuning=use_tuning).total_s


def _spearman(a, b):
    ra = np.argsort(np.argsort(a)).astype(float)
    rb = np.argsort(np.argsort(b)).astype(float)
    ra -= ra.mean()
    rb -= rb.mean()
    return float((ra * rb).sum() /
                 np.sqrt((ra ** 2).sum() * (rb ** 2).sum()))


def run(n_variants: int = 16, seed: int = 0):
    t = common.Timer()
    setup = common.make_setup(d_model=512, d_ff=2048, n_heads=8,
                              n_kv_heads=2, head_dim=64, n_layers=4)
    rng = np.random.default_rng(seed)
    naive, tuned, flops = [], [], []
    for i in range(n_variants):
        sites = list(setup.sites)
        params = setup.params
        pruned = {}
        budget = int(0.45 * 2048)
        head_units = int(rng.uniform(0, 1) * 6) // 2 * 2   # 0..6 heads
        for site in sites:
            if site.kind == "experts":
                continue
            if site.kind == "heads":
                n_units = head_units
            else:
                n_units = budget - head_units * 64 + int(
                    rng.integers(-64, 64))
                n_units = max(1, min(n_units, site.dim - 16))
            if n_units <= 0:
                continue
            scores = rng.random(site.dim)   # random pruning (paper Fig. 1)
            params, new_site = applier.prune_site_by_rank(
                params, site, n_units, scores)
            pruned[site.site_id] = new_site
        sites = applier.refresh_sites(sites, pruned)
        naive.append(_latency(setup.cfg, sites, setup.wl, use_tuning=False,
                              seq_len=64))
        tuned.append(_latency(setup.cfg, sites, setup.wl, use_tuning=True,
                              seq_len=64))
        flops.append(sum(g.k * g.n * g.batch * g.m_scale
                         for s in sites for g in s.gemms))
    naive, tuned, flops = map(np.array, (naive, tuned, flops))
    rho_nt = _spearman(naive, tuned)
    rho_ft = _spearman(flops, tuned)
    mismatch = int(np.argmin(naive) != np.argmin(tuned))
    common.emit("fig1_correlation", t.us(),
                f"spearman_naive_tuned={rho_nt:.3f};"
                f"spearman_flops_tuned={rho_ft:.3f};"
                f"argmin_mismatch={mismatch};n={n_variants};"
                f"best_naive_fps={1/naive.min():.1f};"
                f"best_tuned_fps={1/tuned.min():.1f}")
    return {"rho": rho_nt, "rho_flops": rho_ft, "mismatch": mismatch,
            "naive": naive, "tuned": tuned}


if __name__ == "__main__":
    run()

"""Artifact smoke: export a deployment artifact in THIS process, then
serve it from a SECOND ``python -c`` interpreter (fresh process, no
shared tuning caches), and assert the two processes agree on the tuned
fingerprint while the serve stats are non-empty.

This is the CI ``artifact-smoke`` job — the export -> load -> serve
separation the artifact layer exists for: the expensive prune/tune
session lives and dies in process one; process two restarts the serve
path from disk alone.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

from benchmarks import common
from repro.api import CPruneConfig, PruningSession, TrainHooks, Workload
from repro.core import clear_tuning_caches

# runs in a second interpreter: cold caches, no PruningSession, artifact
# directory as argv[1]; prints one JSON line the parent asserts on
_CHILD = """
import json, sys
import numpy as np
from repro.api.artifact import DeploymentArtifact
from repro.serve.engine import Request, ServeEngine

art = DeploymentArtifact.load(sys.argv[1])
eng = ServeEngine.from_artifact(art, max_batch=2, max_seq=24)
rng = np.random.default_rng(0)
for i in range(2):
    eng.submit(Request(rid=i,
                       prompt=rng.integers(0, art.cfg.vocab_size,
                                           8).astype(np.int32),
                       max_new_tokens=4))
stats = eng.run()
print(json.dumps({"tuned_digest": art.tuned_digest,
                  "requests": stats["requests"],
                  "total_new_tokens": stats["total_new_tokens"],
                  "p95_ttft_s": stats["p95_ttft_s"],
                  "p95_step_s": stats["p95_step_s"],
                  "predicted_step_s": stats["predicted_step_s"],
                  "outputs": [r.output for r in eng.done]}))
"""


def _child_env() -> dict:
    import repro
    # repro is a namespace package (__file__ is None): locate src via
    # __path__ so the child resolves the same tree regardless of cwd
    src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


def run():
    t = common.Timer()
    clear_tuning_caches()
    cfg = common.bench_config(n_layers=2, d_model=64, d_ff=512, n_heads=4,
                              n_kv_heads=2, head_dim=16, vocab_size=128)
    session = PruningSession(
        cfg, workload=Workload(tokens_global=2048),
        hooks=TrainHooks(short_term_train=lambda p, s: p,
                         eval_acc=lambda p, s: 1.0),
        pcfg=CPruneConfig(a_g=0.0, seq_len=64, max_iterations=2))
    session.prune(strategy="uniform_l1", ratio=0.5)

    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "artifact")
        art = session.export(path, max_batch=2, max_seq=24)
        proc = subprocess.run([sys.executable, "-c", _CHILD, path],
                              capture_output=True, text=True,
                              env=_child_env(), timeout=480)
        if proc.returncode != 0:
            raise RuntimeError(f"artifact serve subprocess failed:\n"
                               f"{proc.stderr[-2000:]}")
        blob = json.loads(proc.stdout.strip().splitlines()[-1])

    fingerprints_match = blob["tuned_digest"] == art.tuned_digest
    stats_nonempty = (blob["requests"] == 2
                      and blob["total_new_tokens"] == 8
                      and blob["p95_ttft_s"] > 0.0
                      and blob["p95_step_s"] > 0.0
                      and all(blob["outputs"]))
    derived = (f"fingerprints_match={fingerprints_match}"
               f";stats_nonempty={stats_nonempty}"
               f";requests={blob['requests']}"
               f";tokens={blob['total_new_tokens']}"
               f";p95_ttft_s={blob['p95_ttft_s']:.3f}"
               f";predicted_step_s={blob['predicted_step_s']}")
    common.emit("artifact_smoke", t.us(), derived)
    clear_tuning_caches()
    if not (fingerprints_match and stats_nonempty):
        # RuntimeError (not SystemExit) so benchmarks/run.py's harness can
        # record the failure row and keep running the remaining figures
        raise RuntimeError(f"artifact smoke failed: {derived}")
    return blob


if __name__ == "__main__":
    run()

"""Kernel microbenchmarks: interpret-mode wall time (CPU correctness path)
plus the analytic v5e latency of the tuned program for the same shape —
the number the CPrune loop actually optimizes."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import tuner
from repro.core.cost_model import Block
from repro.kernels.flash_attention import flash_attention
from repro.kernels.matmul import matmul
from repro.kernels.moe_gmm import moe_gmm
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.rwkv6_scan import rwkv6_scan


def _time(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6


def run():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)

    # matmul: the tuner's target program
    m, k, n = 512, 512, 1024
    a = jax.random.normal(ks[0], (m, k))
    b = jax.random.normal(ks[1], (k, n))
    prog = tuner.tune_gemm(m, k, n, dtype_bytes=4)
    us = _time(lambda x, y: matmul(x, y, block=prog.block, interpret=True),
               a, b)
    common.emit("kernel_matmul", us,
                f"shape={m}x{k}x{n};block={prog.block};"
                f"v5e_cost_us={prog.latency*1e6:.2f}")

    # flash attention
    B, S, Hq, Hkv, D = 1, 256, 4, 2, 64
    q = jax.random.normal(ks[2], (B, S, Hq, D))
    kk = jax.random.normal(ks[3], (B, S, Hkv, D))
    v = jax.random.normal(ks[4], (B, S, Hkv, D))
    us = _time(lambda *x: flash_attention(*x, causal=True, bq=64, bk=64,
                                          interpret=True), q, kk, v)
    from repro.core.cost_model import attention_cost
    common.emit("kernel_flash_attention", us,
                f"BSHD={B}x{S}x{Hq}x{D};"
                f"v5e_cost_us={attention_cost(B,S,S,Hq,D)*1e6:.2f}")

    # paged decode attention (block-table KV, the serve engine's kernel)
    import numpy as np
    from repro.kernels.paged_attention import paged_attention
    B, ncols, bs, Hq, Hkv, D = 4, 4, 16, 4, 2, 64
    n_blocks = B * ncols + 2  # + reserved zero/scratch ids
    kp = jax.random.normal(ks[5], (n_blocks, bs, Hkv, D))
    vp = jax.random.normal(ks[6], (n_blocks, bs, Hkv, D))
    tbl = jnp.asarray(
        np.random.default_rng(0).permutation(np.arange(2, n_blocks))
        .reshape(B, ncols), jnp.int32)
    qd = jax.random.normal(ks[7], (B, Hq, D))
    lens = jnp.full((B,), ncols * bs, jnp.int32)
    us = _time(lambda *x: paged_attention(*x, interpret=True),
               qd, kp, vp, tbl, lens)
    common.emit("kernel_paged_attention", us,
                f"B={B};kv_len={ncols * bs};bs={bs};HqHkvD={Hq}x{Hkv}x{D};"
                f"v5e_cost_us={attention_cost(B, 1, ncols * bs, Hq, D)*1e6:.2f}")

    # rglru scan
    aa = jax.nn.sigmoid(jax.random.normal(ks[5], (2, 256, 128)))
    xx = jax.random.normal(ks[6], (2, 256, 128))
    us = _time(lambda *x: rglru_scan(*x, bs=64, bw=128, interpret=True),
               aa, xx)
    from repro.core.cost_model import scan_cost
    common.emit("kernel_rglru_scan", us,
                f"BSW=2x256x128;v5e_cost_us={scan_cost(2,256,128,0)*1e6:.2f}")

    # rwkv6 scan
    r = jax.random.normal(ks[7], (1, 128, 2, 32))
    w = jax.nn.sigmoid(jax.random.normal(ks[0], (1, 128, 2, 32)))
    u = jax.random.normal(ks[1], (2, 32)) * 0.1
    us = _time(lambda: rwkv6_scan(r, r, r, w, u, bs=32, interpret=True)[0])
    common.emit("kernel_rwkv6_scan", us, "BSHD=1x128x2x32")

    # moe grouped GEMM
    x = jax.random.normal(ks[2], (4, 128, 128))
    wgt = jax.random.normal(ks[3], (4, 128, 256))
    us = _time(lambda: moe_gmm(x, wgt, block=Block(64, 128, 128),
                               interpret=True))
    common.emit("kernel_moe_gmm", us, "ECKN=4x128x128x256")


if __name__ == "__main__":
    run()

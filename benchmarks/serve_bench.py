"""Serve bench (CI ``serve-smoke``): scheduler core vs the legacy wave
engine, and the SLO router over a two-artifact catalog.

Workload: interleaved prompt lengths (8/12) x interleaved decode budgets
(4/24 new tokens) — exactly the mix the wave engine is worst at: every
wave drags its finished slots through ``max(max_new_tokens)`` steps. The
scheduler core buckets by prompt length, groups similar decode lengths,
and compacts finished slots away, so the same workload takes ~half the
jitted decode calls.

Two arms, both warmed (a throwaway drain compiles every shape, then
``reset_stats()`` + a timed drain):

  * ``scheduler_vs_wave`` — one engine, same params, policy flipped.
    Asserts the scheduler core sustains *strictly* higher tokens/s
    (``SERVE_BENCH_MIN_RATIO``, default 1.0, tightened locally).
  * ``router_vs_wave`` — ``plan()`` -> ``Plan.export_catalog`` with two
    frontier artifacts (deep uniform prune = fast/less accurate, shallow
    FPGM = slow/more accurate); a mixed-SLO workload (tight budgets ->
    fast artifact, loose -> accurate) through the ``Router`` must sustain
    >= the wave engine serving the accurate artifact alone.

A third arm (CI ``chaos-smoke``, ``--chaos``) serves the same catalog
through the supervised fleet while a ``FaultInjector`` kills engines
mid-decode, crashes one prefill, delays decode ticks (stragglers), and
one catalog member is permanently tampered. Gates: **zero lost
requests** (every request completes or is explicitly rejected),
re-queued outputs **bit-identical** to a fault-free drain, and chaos
goodput (delivered tokens) >= ``SERVE_CHAOS_MIN_GOODPUT`` (default 0.7)
of the fault-free run's.

A fourth arm (CI ``autopilot-smoke``, ``--autopilot``) serves a
replay-backed catalog while an injected decode delay drifts the accurate
entry far past its prediction: the :class:`repro.serve.Autopilot` must —
autonomously — detect the drift, replan under the recalibrated oracle,
and hot-swap the new catalog generation in. Gates: at least one swap, a
post-swap budget-violation rate strictly below pre-swap, and **zero
dropped requests** across the swap.

A fifth arm (CI ``paged-smoke``, ``--paged``) drains a heavy-tailed
batch-64 workload through the paged KV cache and through the legacy
contiguous layout. Gates: paged tokens/s >= contiguous
(``SERVE_PAGED_MIN_RATIO``), paged peak KV bytes strictly lower, zero
compaction cache-row copies, bit-identical greedy outputs — and prefix
sharing must strictly reduce prefill tokens and peak blocks on a
duplicate-heavy workload.

Run: ``PYTHONPATH=src:. python benchmarks/serve_bench.py
[--chaos|--autopilot|--paged]``
"""
from __future__ import annotations

import os
import shutil
import tempfile

import jax
import numpy as np

from benchmarks import common
from repro.api import (CPruneConfig, MeasuredOracle, MeasurementConfig,
                       MeasurementLog, TrainHooks, Workload, plan)
from repro.models.model import init_params
from repro.serve.autopilot import Autopilot, AutopilotConfig
from repro.serve.engine import Request, ServeEngine
from repro.serve.fleet import RetryPolicy, RouteError
from repro.serve.router import ArtifactCatalog, Router
from repro.util.faults import FaultInjector, crash_at, delay_at

N_REQUESTS = 16
MAX_BATCH = 4
MAX_SEQ = 40        # longest prompt (12) + longest decode budget (24) + slack


def _bench_cfg():
    return common.bench_config(n_layers=2, d_model=64, d_ff=512, n_heads=4,
                               n_kv_heads=2, head_dim=16, vocab_size=128)


def _workload(cfg, *, budgets=None):
    """Fresh Request objects for one drain (interleaved lengths + decode
    budgets; ``budgets`` optionally attaches per-request SLOs)."""
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(N_REQUESTS):
        plen = 8 if i % 2 == 0 else 12
        n_new = 4 if i % 4 < 2 else 24
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            max_new_tokens=n_new,
            latency_budget_s=budgets(i, n_new) if budgets else None))
    return reqs


def _drain(submit, run, reset, cfg, *, budgets=None):
    """Warm every compiled shape with one throwaway drain, then time a
    second identical drain from zeroed stats."""
    for r in _workload(cfg, budgets=budgets):
        submit(r)
    run()
    reset()
    for r in _workload(cfg, budgets=budgets):
        submit(r)
    return run()


def _engine_drain(eng, cfg):
    return _drain(eng.submit, eng.run, eng.reset_stats, cfg)


def run():
    min_ratio = float(os.environ.get("SERVE_BENCH_MIN_RATIO", "1.0"))
    cfg = _bench_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)

    # -- arm 1: scheduler core vs legacy wave, same model -------------------
    t = common.Timer()
    wave = _engine_drain(
        ServeEngine(cfg, params, max_batch=MAX_BATCH, max_seq=MAX_SEQ,
                    scheduler="wave"), cfg)
    sched = _engine_drain(
        ServeEngine(cfg, params, max_batch=MAX_BATCH, max_seq=MAX_SEQ), cfg)
    assert sched["total_new_tokens"] == wave["total_new_tokens"]
    ratio = sched["tokens_per_s"] / max(wave["tokens_per_s"], 1e-9)
    common.emit(
        "serve_sched_vs_wave", t.us(),
        f"tokens_per_s={sched['tokens_per_s']:.1f}"
        f";wave_tokens_per_s={wave['tokens_per_s']:.1f}"
        f";ratio={ratio:.2f}"
        f";decode_steps={sched['decode_steps']}"
        f";wave_decode_steps={wave['decode_steps']}"
        f";slot_steps={sched['slot_steps']}"
        f";wave_slot_steps={wave['slot_steps']}"
        f";occupancy={sched['mean_batch_occupancy']:.2f}")

    # -- arm 2: SLO router over a two-artifact catalog ----------------------
    t = common.Timer()
    common.reset_tuning_caches()
    n0 = common.count_params(params)
    hooks = TrainHooks(short_term_train=lambda p, s: p,
                       eval_acc=lambda p, s: common.count_params(p) / n0)
    pl = plan(cfg, accuracy_floor=0.0, targets=["tpu_v5e"],
              strategies=["uniform_l1", "fpgm"],
              workload=Workload(tokens_global=8192), hooks=hooks,
              params=params, pcfg=CPruneConfig(a_g=0.0, seq_len=64),
              strategy_kwargs={"uniform_l1": {"ratio": 0.6},
                               "fpgm": {"ratio": 0.1}})
    with tempfile.TemporaryDirectory() as td:
        catalog = pl.export_catalog(td, max_batch=MAX_BATCH,
                                    max_seq=MAX_SEQ)
        common.reset_tuning_caches()
        fast = min(catalog, key=lambda e: e.predicted_step_s)
        accurate = max(catalog, key=lambda e: e.accuracy)

        def budgets(i, n_new):
            # even rids: tight (only the fast artifact can promise it);
            # odd rids: loose (the budget buys the accurate artifact)
            mid = (fast.predicted_step_s + accurate.predicted_step_s) / 2
            return mid * n_new if i % 2 == 0 \
                else accurate.predicted_step_s * n_new * 100
        router = Router(catalog)
        routed = _drain(router.submit, router.run, router.reset_stats, cfg,
                        budgets=budgets)
        # the deployment the router replaces: the accurate artifact alone,
        # behind the legacy blocking wave engine
        solo = _engine_drain(
            ServeEngine.from_artifact(catalog.artifact(accurate.name),
                                      max_batch=MAX_BATCH, max_seq=MAX_SEQ,
                                      scheduler="wave"), cfg)
    assert routed["total_new_tokens"] == solo["total_new_tokens"]
    assert set(routed["routing"]) == {fast.name, accurate.name}
    r_ratio = routed["tokens_per_s"] / max(solo["tokens_per_s"], 1e-9)
    common.emit(
        "serve_router_vs_wave", t.us(),
        f"tokens_per_s={routed['tokens_per_s']:.1f}"
        f";wave_tokens_per_s={solo['tokens_per_s']:.1f}"
        f";ratio={r_ratio:.2f}"
        f";routing={routed['routing']}"
        f";violation_rate={routed['budget_violation_rate']:.2f}")
    common.reset_tuning_caches()

    if ratio <= min_ratio:
        raise RuntimeError(
            f"scheduler core is not faster than the wave engine on the "
            f"interleaved workload: ratio {ratio:.2f} <= {min_ratio}")
    if r_ratio < min_ratio:
        raise RuntimeError(
            f"router throughput fell below the wave baseline: "
            f"{r_ratio:.2f} < {min_ratio}")
    return {"sched": sched, "wave": wave, "router": routed, "solo": solo}


def _paged_workload(cfg, *, n=96, seed=0, duplicates=1):
    """Heavy-tailed serve mix for the paged arm: mostly short prompts,
    a long tail of deep prompts and long decodes — the shape on which a
    full-depth contiguous reservation wastes the most KV. Decode budgets
    are deep enough (8-16 typical, 48 tail) that the drain spends its
    time in sustained multi-row decode ticks, where the KV layout is
    what's being measured — not in single-row dispatch overhead.
    ``duplicates`` repeats each distinct prompt (the prefix-sharing
    arm's knob)."""
    rng = np.random.default_rng(seed)
    reqs = []
    rid = 0
    while len(reqs) < n:
        u = rng.random()
        plen = 8 if u < 0.7 else (16 if u < 0.95 else 64)
        n_new = int(rng.integers(8, 17)) if rng.random() < 0.85 else 48
        prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
        for _ in range(duplicates):
            if len(reqs) >= n:
                break
            reqs.append(Request(rid=rid, prompt=prompt.copy(),
                                max_new_tokens=n_new))
            rid += 1
    return reqs


def run_paged():
    """CI ``paged-smoke``: the paged KV cache vs the contiguous layout.

    Same scheduler policy, same params, batch 64, heavy-tailed prompts
    and decode budgets. Gates: paged tokens/s >= contiguous
    (``SERVE_PAGED_MIN_RATIO``, default 1.0), paged peak KV bytes
    *strictly* below contiguous, **zero** compaction cache-row copies on
    the paged arm, bit-identical greedy outputs per request — and, on a
    duplicate-heavy workload, prefix sharing must strictly reduce both
    prefill tokens and peak blocks.
    """
    from repro.serve.scheduler import SchedulerConfig

    # under REPRO_DEBUG_KV the paged arm pays an O(pool) sanitizer sweep
    # per quantum that the contiguous arm doesn't, so the throughput gate
    # is replaced by the sanitizer gate (>0 checks, 0 violations)
    debug_kv = os.environ.get("REPRO_DEBUG_KV", "0") not in ("", "0")
    min_ratio = float(os.environ.get("SERVE_PAGED_MIN_RATIO",
                                     "0.0" if debug_kv else "1.0"))
    cfg = _bench_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    max_batch, max_seq = 64, 128     # deepest prompt (64) + longest decode

    def _mk(layout, *, share=True):
        return ServeEngine(
            cfg, params, max_batch=max_batch, max_seq=max_seq,
            scheduler=SchedulerConfig(kv_layout=layout, page_size=16,
                                      share_prefix=share))

    def _round(eng, *, duplicates=1, n=96):
        for r in _paged_workload(cfg, n=n, duplicates=duplicates):
            eng.submit(r)
        stats = eng.run()
        outputs = {r.rid: list(r.output) for r in eng.done}
        eng.reset_stats()
        return stats, outputs

    def drain(layout, *, share=True, duplicates=1, n=96):
        eng = _mk(layout, share=share)
        _round(eng, duplicates=duplicates, n=n)     # warmup/compile
        stats, outputs = _round(eng, duplicates=duplicates, n=n)
        return stats, outputs

    # -- arm 1: throughput + memory, paged vs contiguous --------------------
    # one drain is only a few hundred ms, so a single timed pass is at the
    # mercy of host noise (and of the CPU still cooling off from the
    # compile burst): warm both engines first, then alternate timed rounds
    # and score each arm by its best round.
    t = common.Timer()
    c_eng, p_eng = _mk("contiguous"), _mk("paged")
    _round(c_eng)
    _round(p_eng)
    contig, c_out = _round(c_eng)
    paged, p_out = _round(p_eng)
    for _ in range(2):
        s, _ = _round(c_eng)
        if s["tokens_per_s"] > contig["tokens_per_s"]:
            contig = s
        s, _ = _round(p_eng)
        if s["tokens_per_s"] > paged["tokens_per_s"]:
            paged = s
    assert paged["total_new_tokens"] == contig["total_new_tokens"]
    ratio = paged["tokens_per_s"] / max(contig["tokens_per_s"], 1e-9)
    common.emit(
        "serve_paged_vs_contiguous", t.us(),
        f"tokens_per_s={paged['tokens_per_s']:.1f}"
        f";contig_tokens_per_s={contig['tokens_per_s']:.1f}"
        f";ratio={ratio:.2f}"
        f";peak_kv_mb={paged['peak_kv_bytes']/2**20:.2f}"
        f";contig_peak_kv_mb={contig['peak_kv_bytes']/2**20:.2f}"
        f";kv_blocks_peak={paged['kv_blocks_peak']}"
        f";kv_row_copies={paged['kv_row_copies']}"
        # REPRO_DEBUG_KV=1 runs the paged-KV sanitizer every quantum
        # (repro.analysis.kv_sanitizer); both stay 0 when it's off
        f";kv_debug_checks={paged['kv_debug_checks']}"
        f";kv_debug_violations={paged['kv_debug_violations']}")
    if p_out != c_out:
        bad = [rid for rid in c_out if p_out.get(rid) != c_out[rid]]
        raise RuntimeError(
            f"paged outputs diverged from contiguous for rids {bad[:8]}")
    if paged["kv_row_copies"] != 0:
        raise RuntimeError(
            f"paged compaction copied {paged['kv_row_copies']} cache rows "
            f"(must be a pure block-table rewrite)")
    if not paged["peak_kv_bytes"] < contig["peak_kv_bytes"]:
        raise RuntimeError(
            f"paged peak KV {paged['peak_kv_bytes']} is not strictly below "
            f"contiguous {contig['peak_kv_bytes']}")
    if ratio < min_ratio:
        raise RuntimeError(
            f"paged throughput fell below contiguous: ratio {ratio:.2f} "
            f"< {min_ratio}")
    if debug_kv and not (paged["kv_debug_checks"] > 0
                         and paged["kv_debug_violations"] == 0):
        raise RuntimeError(
            f"paged-KV sanitizer gate: expected >0 quantum-boundary "
            f"checks and 0 violations, got "
            f"checks={paged['kv_debug_checks']} "
            f"violations={paged['kv_debug_violations']}")

    # -- arm 2: prefix sharing on a duplicate-heavy workload ----------------
    t = common.Timer()
    solo, solo_out = drain("paged", share=False, duplicates=4, n=32)
    shared, shared_out = drain("paged", share=True, duplicates=4, n=32)
    common.emit(
        "serve_paged_sharing", t.us(),
        f"prefill_tokens={shared['prefill_tokens']}"
        f";unshared_prefill_tokens={solo['prefill_tokens']}"
        f";kv_blocks_peak={shared['kv_blocks_peak']}"
        f";unshared_kv_blocks_peak={solo['kv_blocks_peak']}"
        f";shared_blocks={shared['kv_shared_blocks']}")
    if shared_out != solo_out:
        raise RuntimeError("prefix sharing changed greedy outputs")
    if not (shared["prefill_tokens"] < solo["prefill_tokens"]
            and shared["kv_blocks_peak"] < solo["kv_blocks_peak"]
            and shared["kv_shared_blocks"] > 0):
        raise RuntimeError(
            f"prefix sharing did not reduce prefill work: "
            f"prefill_tokens {shared['prefill_tokens']} vs "
            f"{solo['prefill_tokens']}, blocks {shared['kv_blocks_peak']} "
            f"vs {solo['kv_blocks_peak']} "
            f"(shared={shared['kv_shared_blocks']})")
    return {"paged": paged, "contiguous": contig, "shared": shared,
            "unshared": solo}


def _export_catalog(td, cfg, params):
    common.reset_tuning_caches()
    n0 = common.count_params(params)
    hooks = TrainHooks(short_term_train=lambda p, s: p,
                       eval_acc=lambda p, s: common.count_params(p) / n0)
    pl = plan(cfg, accuracy_floor=0.0, targets=["tpu_v5e"],
              strategies=["uniform_l1", "fpgm"],
              workload=Workload(tokens_global=8192), hooks=hooks,
              params=params, pcfg=CPruneConfig(a_g=0.0, seq_len=64),
              strategy_kwargs={"uniform_l1": {"ratio": 0.6},
                               "fpgm": {"ratio": 0.1}})
    catalog = pl.export_catalog(td, max_batch=MAX_BATCH, max_seq=MAX_SEQ)
    common.reset_tuning_caches()
    return catalog


def _tamper_member(root, name):
    """Bump one member's manifest accuracy so the catalog refuses it
    (the permanently-failing entry of the chaos arm)."""
    import json
    man = os.path.join(root, "catalog.json")
    with open(man) as f:
        blob = json.load(f)
    for d in blob["entries"]:
        if d["name"] == name:
            d["accuracy"] += 0.5
    with open(man, "w") as f:
        json.dump(blob, f)


def run_chaos():
    """CI ``chaos-smoke``: the supervised fleet under injected faults.

    Failure mix: two mid-decode engine crashes (replica torn down, cold
    rebuild, in-flight re-queued), one prefill crash (admission-time
    OOM), two decode delays (stragglers), and one catalog member whose
    manifest is tampered (permanent load failure -> quarantine).
    """
    min_goodput = float(os.environ.get("SERVE_CHAOS_MIN_GOODPUT", "0.7"))
    cfg = _bench_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    t = common.Timer()
    with tempfile.TemporaryDirectory() as td:
        clean = os.path.join(td, "clean")
        broken = os.path.join(td, "broken")
        catalog = _export_catalog(clean, cfg, params)
        fast = min(catalog, key=lambda e: e.predicted_step_s)
        accurate = max(catalog, key=lambda e: e.accuracy)
        shutil.copytree(clean, broken)
        _tamper_member(broken, accurate.name)

        # -- fault-free reference: the surviving artifact, no faults ----
        ref_eng = ServeEngine.from_artifact(catalog.artifact(fast.name))
        for r in _workload(cfg):
            ref_eng.submit(r)
        ref = ref_eng.run()
        ref_outputs = {r.rid: list(r.output) for r in ref_eng.done}
        assert len(ref_outputs) == N_REQUESTS

        # -- chaos arm: tampered member + injected engine faults --------
        inj = FaultInjector(specs=[
            crash_at(f"decode:{fast.name}#r0", 3, 25),   # engine crashes
            crash_at("prefill", 1),                      # admission OOM
            delay_at("decode", 0.05, 12),                # stragglers
            delay_at("decode", 0.05, 30),
        ])
        router = Router(ArtifactCatalog.load(broken, lazy=True),
                        faults=inj, retry=RetryPolicy(max_retries=4))
        submitted = rejected = 0
        for r in _workload(cfg):
            submitted += 1
            try:
                router.submit(r)
            except RouteError:
                rejected += 1
        chaos = router.run()

    # -- gates --------------------------------------------------------------
    # 1. zero silent loss: every request completed or was explicitly
    #    rejected/failed, and nothing is still in flight
    accounted = chaos["requests"] + rejected + chaos["failed"]
    in_flight = sum(s["in_flight"] for s in chaos["per_artifact"].values())
    if accounted != submitted or in_flight:
        raise RuntimeError(
            f"lost requests under chaos: submitted {submitted} != "
            f"{chaos['requests']} completed + {rejected} rejected + "
            f"{chaos['failed']} failed (in_flight={in_flight})")
    # 2. bit-identical greedy outputs through crashes and re-queues
    chaos_outputs = {r.rid: list(r.output)
                     for sup in router._fleets.values()
                     for r in sup.completed}
    if chaos_outputs != ref_outputs:
        bad = [rid for rid in ref_outputs
               if chaos_outputs.get(rid) != ref_outputs[rid]]
        raise RuntimeError(
            f"re-queued outputs diverged from the fault-free drain "
            f"for rids {bad}")
    # 3. goodput: delivered tokens vs the fault-free drain
    goodput = chaos["total_new_tokens"] / max(ref["total_new_tokens"], 1)
    # 4. the faults actually happened (the arm must not silently no-op)
    fleet = chaos["per_artifact"][fast.name]
    if not (chaos["crashes"] >= 2 and fleet["rebuilds"] >= 1
            and fleet["requeued"] >= 1
            and accurate.name in chaos["quarantined"]):
        raise RuntimeError(
            f"chaos faults did not land: crashes={chaos['crashes']} "
            f"rebuilds={fleet['rebuilds']} requeued={fleet['requeued']} "
            f"quarantined={list(chaos['quarantined'])}")
    common.emit(
        "serve_chaos", t.us(),
        f"goodput={goodput:.2f}"
        f";crashes={chaos['crashes']}"
        f";rebuilds={chaos['rebuilds']}"
        f";requeued={chaos['requeued']}"
        f";retried={fleet['retried_requests']}"
        f";stragglers={fleet['straggler_steps']}"
        f";failed={chaos['failed']}"
        f";rejected={rejected}"
        f";quarantined={list(chaos['quarantined'])}")
    if goodput < min_goodput:
        raise RuntimeError(
            f"chaos goodput {goodput:.2f} < {min_goodput} of the "
            f"fault-free drain")
    return {"chaos": chaos, "ref": ref, "goodput": goodput}


class _DeterministicMeasuredOracle(MeasuredOracle):
    """Per-kernel timing as a deterministic function of problem size —
    the real recording/replay/rescale code path, but the frontier
    ordering (more pruning => faster) cannot be inverted by interpret-
    mode timing noise (see tests/test_autopilot.py)."""

    def _time_kernel(self, m, k, n, batch, dtype_bytes, block) -> float:
        return float(m * k * n * batch) * 1e-12 + 5e-7


def run_autopilot():
    """CI ``autopilot-smoke``: drift -> replan -> hot-swap, no human.

    Phase 1 serves budgeted requests on the accurate entry while an
    injected decode delay drifts its observed step to >= 5x the oracle
    prediction — every budget is violated. The autopilot detects the
    drift through the measurement window, recalibrates the entry's
    replay oracle, re-runs the plan's own sweep under it, and atomically
    swaps the new catalog generation in. Phase 2 serves budgets spoken
    in the *new* catalog's language and must (after a warmup drain of
    the fresh engines) violate none of them.
    """
    cfg = _bench_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    t = common.Timer()
    common.reset_tuning_caches()
    n0 = common.count_params(params)
    hooks = TrainHooks(short_term_train=lambda p, s: p,
                       eval_acc=lambda p, s: common.count_params(p) / n0)
    mcfg = MeasurementConfig(warmup=0, repeats=1, trim=0, measure_top_k=1,
                             max_grid_steps=1)
    pl = plan(cfg, accuracy_floor=0.0, targets=["tpu_v5e"],
              strategies=["uniform_l1", "fpgm"],
              workload=Workload(tokens_global=8192), hooks=hooks,
              params=params,
              oracle=_DeterministicMeasuredOracle(
                  mcfg, record=MeasurementLog(mcfg)),
              pcfg=CPruneConfig(a_g=0.0, seq_len=64),
              strategy_kwargs={"uniform_l1": {"ratio": 0.6},
                               "fpgm": {"ratio": 0.1}})
    with tempfile.TemporaryDirectory() as td:
        catalog = pl.export_catalog(td, max_batch=2, max_seq=24)
        common.reset_tuning_caches()
        fast = min(catalog, key=lambda e: e.predicted_step_s)
        accurate = max(catalog, key=lambda e: e.accuracy)

        # synthetic drift: the accurate entry's decode step inflates to
        # >= 5x its oracle prediction, every tick
        delay = max(0.08, 5 * accurate.predicted_step_s)
        inj = FaultInjector(specs=[
            delay_at(f"decode:{accurate.name}#r0", delay, *range(4000))])
        router = Router(catalog, faults=inj)
        ap = Autopilot(router, replan=pl, faults=inj,
                       config=AutopilotConfig(
                           check_every=4, rel_error_threshold=1.0,
                           min_window=2, min_budgeted=999,
                           probation_steps=25, cooldown_steps=50,
                           max_swaps=1))

        rng = np.random.default_rng(0)

        def _req(rid, budget):
            return Request(rid=rid, prompt=rng.integers(
                0, cfg.vocab_size, 8).astype(np.int32),
                max_new_tokens=4, latency_budget_s=budget)

        # phase 1: budgets the pre-drift oracle promises easily
        phase1 = [_req(i, delay) for i in range(4)]
        for r in phase1:
            router.submit(r)
        ap.run(deadline_s=600)
        st = ap.stats()
        if st["swaps"] < 1:
            raise RuntimeError(
                f"autopilot never swapped: {st['events']}")
        pre_rate = sum(r.t_done - r.t_submit > delay
                       for r in phase1) / len(phase1)
        if not all(r.done and not r.failed for r in phase1):
            raise RuntimeError("pre-swap requests lost across the swap")

        # phase 2: budgets in the recalibrated catalog's language
        new_fast = min(router.catalog, key=lambda e: e.predicted_step_s)
        new_acc = max(router.catalog, key=lambda e: e.accuracy)
        b2 = (new_fast.predicted_step_s + new_acc.predicted_step_s) / 2 * 4
        for i in range(2):              # warm the fresh engines
            router.submit(_req(10 + i, b2))
        ap.run(deadline_s=600)
        phase2 = [_req(20 + i, b2) for i in range(2)]
        for r in phase2:
            router.submit(r)
        ap.run(deadline_s=600)
        post_rate = sum(r.t_done - r.t_submit > b2
                        for r in phase2) / len(phase2)
        rst = router.stats()

    # -- gates --------------------------------------------------------------
    if rst["submitted"] != rst["requests"] or rst["failed"] \
            or rst["shed"] or rst["rejected"]:
        raise RuntimeError(
            f"requests dropped across the swap: submitted "
            f"{rst['submitted']} != {rst['requests']} completed "
            f"(failed={rst['failed']} shed={rst['shed']} "
            f"rejected={rst['rejected']})")
    if post_rate >= pre_rate:
        raise RuntimeError(
            f"hot-swap did not improve the budget-violation rate: "
            f"post {post_rate:.2f} >= pre {pre_rate:.2f}")
    common.emit(
        "serve_autopilot", t.us(),
        f"swaps={st['swaps']}"
        f";replans={st['replans']}"
        f";rollbacks={st['rollbacks']}"
        f";generation={rst['generation']}"
        f";pre_violation_rate={pre_rate:.2f}"
        f";post_violation_rate={post_rate:.2f}"
        f";submitted={rst['submitted']}"
        f";completed={rst['requests']}"
        f";retired_fleets={rst['retired_fleets']}")
    common.reset_tuning_caches()
    return {"stats": st, "router": rst, "pre_rate": pre_rate,
            "post_rate": post_rate}


if __name__ == "__main__":
    import sys
    if "--chaos" in sys.argv:
        run_chaos()
    elif "--autopilot" in sys.argv:
        run_autopilot()
    elif "--paged" in sys.argv:
        run_paged()
    else:
        run()

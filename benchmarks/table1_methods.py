"""Table 1 reproduction: CPrune vs model-based pruning (L1, FPGM) and
hardware-aware pruning (NetAdapt-style), all through the same tuner.

Columns: FPS (increase rate), FLOPs, params, accuracy — mirroring the
paper's Mobile CPU/GPU table on our v5e cost-model target.
"""
from __future__ import annotations

import jax

from benchmarks import common
from repro.core import CPrune, baselines, tuner
from repro.core.latency import model_latency


def _fps(cfg, sites, wl, seq_len):
    table = tuner.build_tuned_table(sites, wl)
    return model_latency(cfg, sites, table, seq_len=seq_len).fps


def run():
    t = common.Timer()
    rows = {}

    # Original (tuned only — the "TVM auto-tune" row)
    setup = common.make_setup(max_iterations=8, alpha=0.8, beta=0.99)
    common.pretrain(setup, steps=30)
    base_fps = _fps(setup.cfg, setup.sites, setup.wl, setup.pcfg.seq_len)
    base_acc = setup.hooks.eval_acc(setup.params, setup.sites)
    rows["original"] = dict(
        fps=base_fps, rate=1.0, acc=base_acc,
        params=common.count_params(setup.params),
        flops=common.model_flops_per_token(setup.cfg))

    p0 = setup.params   # shared pretrained start for every method

    # L1 / FPGM uniform baselines
    for method, name in (("l1", "l1_uniform"), ("fpgm", "fpgm")):
        res = baselines.uniform_prune(
            setup.cfg, p0, setup.sites, setup.wl, setup.hooks, setup.pcfg,
            ratio=0.375, method=method, name=name)
        rows[name] = dict(fps=res.latency.fps,
                          rate=res.latency.fps / base_fps, acc=res.acc,
                          params=common.count_params(res.params),
                          flops=0)

    # NetAdapt-style exhaustive hardware-aware
    common.reset_tuning_caches()   # per-arm cold start: evals comparable
    res = baselines.netadapt_prune(
        setup.cfg, p0, setup.sites, setup.wl, setup.hooks, setup.pcfg,
        latency_decay=0.96, max_iterations=4)
    rows["netadapt"] = dict(fps=res.latency.fps,
                            rate=res.latency.fps / base_fps, acc=res.acc,
                            params=common.count_params(res.params),
                            flops=0, evals=res.candidates_evaluated)

    # CPrune
    common.reset_tuning_caches()
    cp = CPrune(setup.cfg, setup.sites, setup.wl, setup.hooks, setup.pcfg)
    cres = cp.run(p0)
    rows["cprune"] = dict(fps=cres.final_latency.fps,
                          rate=cres.fps_increase, acc=cres.final_acc,
                          params=common.count_params(cres.params),
                          flops=0)

    derived = ";".join(
        f"{k}:rate={v['rate']:.2f},acc={v['acc']:.3f},"
        f"params={v['params']}" for k, v in rows.items())
    common.emit("table1_methods", t.us(), derived)
    return rows


if __name__ == "__main__":
    run()

"""Table 1 reproduction: CPrune vs model-based pruning (L1, FPGM) and
hardware-aware pruning (NetAdapt-style), all through the same tuner — one
`PruningSession` per method, strategies swapped by name.

Columns: FPS (increase rate), FLOPs, params, accuracy — mirroring the
paper's Mobile CPU/GPU table on our v5e cost-model target.
"""
from __future__ import annotations

from benchmarks import common
from repro.api import PruningSession


def _session(setup) -> PruningSession:
    return PruningSession(setup.cfg, params=setup.params, target="tpu_v5e",
                          workload=setup.wl, hooks=setup.hooks,
                          pcfg=setup.pcfg)


def run():
    t = common.Timer()
    rows = {}

    # Original (tuned only — the "TVM auto-tune" row)
    setup = common.make_setup(max_iterations=8, alpha=0.8, beta=0.99)
    common.pretrain(setup, steps=30)
    base = _session(setup)
    base_fps = base.latency_report().fps
    base_acc = setup.hooks.eval_acc(setup.params, setup.sites)
    rows["original"] = dict(
        fps=base_fps, rate=1.0, acc=base_acc,
        params=common.count_params(setup.params),
        flops=common.model_flops_per_token(setup.cfg))

    # One strategy registry, one calling convention per method row.
    arms = [
        ("l1_uniform", "uniform_l1", dict(ratio=0.375)),
        ("fpgm", "fpgm", dict(ratio=0.375)),
        ("netadapt", "netadapt", dict(latency_decay=0.96, max_iterations=4)),
        ("cprune", "cprune", {}),
    ]
    for row_name, strategy, kw in arms:
        common.reset_tuning_caches()   # per-arm cold start: evals comparable
        res = _session(setup).prune(strategy=strategy, **kw)
        rows[row_name] = dict(fps=res.final_latency.fps,
                              rate=res.final_latency.fps / base_fps,
                              acc=res.final_acc,
                              params=common.count_params(res.params),
                              flops=0, evals=res.candidates_evaluated)

    derived = ";".join(
        f"{k}:rate={v['rate']:.2f},acc={v['acc']:.3f},"
        f"params={v['params']}" for k, v in rows.items())
    common.emit("table1_methods", t.us(), derived)
    return rows


if __name__ == "__main__":
    run()

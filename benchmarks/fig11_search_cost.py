"""Fig. 11 reproduction: CPrune's selective (priority-ordered) search vs a
NetAdapt-style exhaustive search — relative time cost in the Main step.

The paper reports ~90% search-cost reduction at similar final performance.
Cost here = candidate evaluations + short-term trainings (the quantities
the paper's wall-clock is made of)."""
from __future__ import annotations

from benchmarks import common
from repro.core import CPrune, baselines


_ARCH_KW = dict(n_layers=3, d_model=256, d_ff=4096, n_heads=4,
                n_kv_heads=1, head_dim=64, rglru_width=256)


def run():
    t = common.Timer()
    # CPrune (selective) — hybrid arch: 4 prunable sites, so exhaustive
    # search trains 4 candidates/iteration where CPrune trains ~1
    common.reset_tuning_caches()   # per-arm cold start: evals comparable
    setup = common.make_setup("recurrentgemma_9b", max_iterations=6,
                              alpha=0.8, beta=0.99, **_ARCH_KW)
    common.pretrain(setup, steps=48)
    trainings = {"n": 0}
    orig_train = setup.hooks.short_term_train

    def counting_train(p, s):
        trainings["n"] += 1
        return orig_train(p, s)

    setup.hooks.short_term_train = counting_train
    cp = CPrune(setup.cfg, setup.sites, setup.wl, setup.hooks, setup.pcfg)
    res = cp.run(setup.params)
    cprune_cost = res.tuner_stats.candidates_evaluated
    cprune_trainings = trainings["n"]

    # NetAdapt-style exhaustive
    common.reset_tuning_caches()
    setup2 = common.make_setup("recurrentgemma_9b", max_iterations=6,
                               alpha=0.8, beta=0.99, **_ARCH_KW)
    common.pretrain(setup2, steps=48)
    trainings2 = {"n": 0}
    orig2 = setup2.hooks.short_term_train

    def counting2(p, s):
        trainings2["n"] += 1
        return orig2(p, s)

    setup2.hooks.short_term_train = counting2
    bres = baselines.netadapt_prune(
        setup2.cfg, setup2.params, setup2.sites, setup2.wl, setup2.hooks,
        setup2.pcfg, latency_decay=0.96,
        max_iterations=sum(h.accepted for h in res.history) or 3)
    exh_cost = bres.candidates_evaluated
    exh_trainings = trainings2["n"]

    cprune_acc = sum(h.accepted for h in res.history) or 1
    exh_iters = max(1, exh_trainings // max(len(setup2.sites), 1))
    per_iter_cprune = cprune_trainings / max(cprune_acc, 1)
    per_iter_exh = exh_trainings / exh_iters
    saving = 1.0 - per_iter_cprune / max(per_iter_exh, 1e-9)
    common.emit(
        "fig11_search_cost", t.us(),
        f"cprune_trainings_per_iter={per_iter_cprune:.1f};"
        f"exhaustive_trainings_per_iter={per_iter_exh:.1f};"
        f"per_iter_training_saving={saving:.2f};"
        f"cprune_tuner_evals={cprune_cost};exhaustive_tuner_evals={exh_cost};"
        f"cprune_rate={res.fps_increase:.2f};"
        f"exhaustive_fps={bres.latency.fps:.1f}")
    return {"saving": saving}


if __name__ == "__main__":
    run()

"""Roofline analysis (deliverable g): three terms per (arch x shape) cell.

    compute term    = HLO_FLOPs / (chip peak 197 TFLOP/s bf16)
    memory term     = HLO_bytes / (chip HBM 819 GB/s)
    collective term = collective wire bytes / (chip ICI ~50 GB/s/link)

Inputs: the dry-run artifacts (benchmarks/dryrun_artifacts/*.json), whose
``hlo_stats`` are loop-corrected per-device numbers parsed from the
post-SPMD HLO (launch/hlo_stats.py) — raw ``cost_analysis`` is retained in
the artifacts but undercounts scan bodies (trip counts not applied).

Also reported per cell: MODEL_FLOPS = 6·N·D (train) or 2·N_active·D
(decode/prefill), the useful-compute ratio MODEL_FLOPS / HLO_FLOPs, the
dominant term, and a one-line "what would move it" note.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

from benchmarks import common
from repro.configs import ARCH_IDS, SHAPES, get_config

PEAK = 197e12
HBM = 819e9
ICI = 50e9

ART = Path(__file__).resolve().parent / "dryrun_artifacts"


def model_flops_per_device(arch: str, shape_name: str, n_devices: int
                           ) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens / n_devices
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens / n_devices
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch / n_devices


def _bottleneck_note(dom: str, arch: str, shape: str) -> str:
    return {
        "compute": "raise MXU occupancy: larger fused GEMM tiles / fewer "
                   "recompute passes (remat policy)",
        "memory": "cut HBM traffic: bf16 intermediates, fuse converts, "
                  "larger attention blocks, save fewer activations",
        "collective": "reshard: move all-gathers off the critical axis / "
                      "overlap with compute / hierarchical reduction",
    }[dom]


def load_cell(arch: str, shape: str, mesh: str = "single") -> Optional[dict]:
    p = ART / f"{arch}__{shape}__{mesh}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def analyze_cell(arch: str, shape: str, mesh: str = "single"
                 ) -> Optional[Dict]:
    rec = load_cell(arch, shape, mesh)
    if rec is None:
        return None
    if rec["status"] == "skipped":
        return {"arch": arch, "shape": shape, "status": "skipped",
                "reason": rec["reason"]}
    if rec["status"] != "ok" or "hlo_stats" not in rec:
        return {"arch": arch, "shape": shape, "status": rec["status"]}
    st = rec["hlo_stats"]
    n_dev = rec["n_devices"]
    t_c = st["flops"] / PEAK
    t_m = st["bytes"] / HBM
    t_x = st["collective_bytes"] / ICI
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    mf = model_flops_per_device(arch, shape, n_dev)
    step_s = max(t_c, t_m, t_x)
    mfu = mf / PEAK / max(step_s, 1e-12)      # roofline-fraction proxy
    return {
        "arch": arch, "shape": shape, "mesh": mesh, "status": "ok",
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom,
        "model_flops": mf,
        "useful_ratio": mf / max(st["flops"], 1.0),
        "roofline_fraction": mfu,
        "temp_bytes": rec.get("memory_analysis", {}).get(
            "temp_size_in_bytes", 0),
        "note": _bottleneck_note(dom, arch, shape),
    }


def run(mesh: str = "single"):
    t = common.Timer()
    rows: List[Dict] = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            r = analyze_cell(arch, shape, mesh)
            if r is not None:
                rows.append(r)
    ok = [r for r in rows if r.get("status") == "ok"]
    if ok:
        worst = min(ok, key=lambda r: r["roofline_fraction"])
        coll = max(ok, key=lambda r: r.get("collective_s", 0))
        common.emit(
            "roofline_summary", t.us(),
            f"cells_ok={len(ok)};"
            f"worst_cell={worst['arch']}/{worst['shape']}"
            f"({worst['roofline_fraction']:.3f});"
            f"most_collective={coll['arch']}/{coll['shape']}"
            f"({coll['collective_s']*1e3:.2f}ms)")
    for r in ok:
        common.emit(
            f"roofline[{r['arch']}/{r['shape']}]",
            max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
            f"c={r['compute_s']*1e3:.2f}ms;m={r['memory_s']*1e3:.2f}ms;"
            f"x={r['collective_s']*1e3:.2f}ms;dom={r['dominant']};"
            f"useful={r['useful_ratio']:.3f};"
            f"frac={r['roofline_fraction']:.3f}")
    return rows


if __name__ == "__main__":
    run()

"""Fig. 6 reproduction: FPS increase rate and short-term accuracy across
CPrune iterations (real short-term training on the synthetic task)."""
from __future__ import annotations

from benchmarks import common
from repro.core import CPrune


def run():
    t = common.Timer()
    setup = common.make_setup(max_iterations=10, alpha=0.85, beta=0.99)
    common.pretrain(setup, steps=30)
    cp = CPrune(setup.cfg, setup.sites, setup.wl, setup.hooks, setup.pcfg)
    res = cp.run(setup.params)
    curve = [(h.iteration, round(h.fps_rate, 3), round(h.a_s, 3),
              h.accepted) for h in res.history]
    accepted = [h for h in res.history if h.accepted]
    common.emit(
        "fig6_iterations", t.us(),
        f"iters={len(res.history)};accepted={len(accepted)};"
        f"final_fps_rate={res.fps_increase:.3f};"
        f"final_acc={res.final_acc:.3f};"
        f"curve={curve}")
    return res


if __name__ == "__main__":
    run()

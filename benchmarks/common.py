"""Shared benchmark infrastructure.

The paper's experiments target a phone; ours target one v5e shard (the
analytic cost model). The benchmark model is a reduced transformer with
*compute-meaningful* dims (so the cost model is not overhead-dominated) but
CPU-trainable sizes; accuracy comes from real short-term training on the
synthetic Markov task.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced_config
from repro.core import CPruneConfig, TrainHooks, Workload
from repro.data.pipeline import DataPipeline
from repro.models.model import Model, init_params, prune_sites

BENCH_TOKENS = 65536          # target-workload tokens for the cost model
BENCH_SEQ = 256


def reset_tuning_caches() -> None:
    """Cold-start the process-wide tuning caches.

    Benchmarks that compare search cost (candidates_evaluated) across
    arms must call this per arm — otherwise the second arm warms up on
    the first arm's ProgramCache and the counters become order-dependent.
    """
    from repro.core import clear_tuning_caches
    clear_tuning_caches()


def bench_config(arch: str = "qwen3_1_7b", **over):
    base = dict(n_layers=4, d_model=128, d_ff=1024, n_heads=8, n_kv_heads=2,
                head_dim=16, vocab_size=256)
    base.update(over)
    return get_reduced_config(arch).with_overrides(**base)


def bench_workload(tp: int = 1) -> Workload:
    return Workload(tokens_global=BENCH_TOKENS, dp=1, tp=tp)


@dataclasses.dataclass
class BenchSetup:
    cfg: object
    model: Model
    params: Dict
    sites: List
    pipe: DataPipeline
    hooks: TrainHooks
    pcfg: CPruneConfig
    wl: Workload


def make_setup(arch: str = "qwen3_1_7b", *, short_steps: int = 4,
               long_steps: int = 16, lr: float = 0.05, a_g: float = 0.0,
               alpha: float = 0.9, beta: float = 0.98,
               max_iterations: int = 8, seed: int = 0, **cfg_over
               ) -> BenchSetup:
    from repro.optim.optimizers import sgd_init, sgd_update

    cfg = bench_config(arch, **cfg_over)
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    sites = prune_sites(cfg)
    pipe = DataPipeline(cfg, global_batch=8, seq_len=64, seed=seed)
    val = pipe.batch(10 ** 6)
    jloss = jax.jit(model.loss_fn)

    @jax.jit
    def jstep(p, o, b):
        # SGD + momentum — the paper trains pruned models with SGD
        (_, m), g = jax.value_and_grad(
            lambda pp: model.loss_fn(pp, b), has_aux=True)(p)
        p2, o2 = sgd_update(p, g, o, lr=lr, momentum=0.9)
        return p2, o2, m

    counter = {"step": 0}

    def train(p, sites, n):
        o = sgd_init(p)    # fresh momentum after each pruning surgery
        for _ in range(n):
            counter["step"] += 1
            p, o, _ = jstep(p, o, pipe.batch(counter["step"]))
        return p

    def eval_acc(p, sites):
        _, m = jloss(p, val)
        return float(m["acc"])

    hooks = TrainHooks(
        short_term_train=lambda p, s: train(p, s, short_steps),
        eval_acc=eval_acc,
        long_term_train=lambda p, s: train(p, s, long_steps))
    pcfg = CPruneConfig(a_g=a_g, alpha=alpha, beta=beta,
                        max_iterations=max_iterations, seq_len=BENCH_SEQ)
    return BenchSetup(cfg=cfg, model=model, params=params, sites=sites,
                      pipe=pipe, hooks=hooks, pcfg=pcfg, wl=bench_workload())


def pretrain(setup: BenchSetup, steps: int = 48, lr: float = 0.05) -> None:
    """Give the benchmark model real (above-chance) accuracy to protect.

    One contiguous momentum-SGD run (the CPrune hooks re-init momentum per
    call, which is right after surgery but too slow for pretraining)."""
    from repro.optim.optimizers import sgd_init, sgd_update
    model = setup.model

    @jax.jit
    def jstep(p, o, b):
        (_, m), g = jax.value_and_grad(
            lambda pp: model.loss_fn(pp, b), has_aux=True)(p)
        return (*sgd_update(p, g, o, lr=lr, momentum=0.9), m)

    p, o = setup.params, sgd_init(setup.params)
    for i in range(steps):
        p, o, _ = jstep(p, o, setup.pipe.batch(i))
    setup.params = p


def count_params(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


def model_flops_per_token(cfg, sites=None) -> float:
    """2 * N_active per token (forward)."""
    n = cfg.active_param_count()
    return 2.0 * n


class Timer:
    def __init__(self):
        self.t0 = time.time()

    def us(self) -> float:
        return (time.time() - self.t0) * 1e6


_ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    _ROWS.append(row)
    print(row, flush=True)


def all_rows() -> List[str]:
    return list(_ROWS)

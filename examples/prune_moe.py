"""MoE pruning demo: CPrune on a Mixtral-family model, where the prunable
units are expert FFN channels (all 4 experts x all layers = one task, the
paper's associated-subgraph set) and whole experts — driven through the
`PruningSession` front door.

    PYTHONPATH=src python examples/prune_moe.py
"""
import jax

from repro.api import CPruneConfig, PruningSession, TrainHooks, Workload
from repro.configs import get_reduced_config
from repro.data.pipeline import DataPipeline
from repro.models.model import Model, init_params
from repro.optim.optimizers import sgd_init, sgd_update


def main():
    cfg = get_reduced_config("mixtral_8x22b").with_overrides(
        n_layers=2, d_model=128, d_ff=1024, moe_d_ff=1024, n_experts=4,
        top_k=2, n_heads=8, n_kv_heads=2, head_dim=16, vocab_size=256)
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)

    pipe = DataPipeline(cfg, global_batch=8, seq_len=64)
    val = pipe.batch(10 ** 6)
    jloss = jax.jit(model.loss_fn)

    @jax.jit
    def jstep(p, o, b):
        (_, m), g = jax.value_and_grad(
            lambda pp: model.loss_fn(pp, b), has_aux=True)(p)
        return (*sgd_update(p, g, o, lr=0.05, momentum=0.9), m)

    state = {"i": 0}

    def train(p, _s, n):
        o = sgd_init(p)
        for _ in range(n):
            state["i"] += 1
            p, o, _ = jstep(p, o, pipe.batch(state["i"]))
        return p

    print("pretraining ...")
    params = train(params, None, 40)

    session = PruningSession(
        cfg, params=params, workload=Workload(tokens_global=65536),
        hooks=TrainHooks(
            short_term_train=lambda p, s: train(p, s, 4),
            eval_acc=lambda p, s: float(jloss(p, val)[1]["acc"])),
        pcfg=CPruneConfig(a_g=0.4, alpha=0.88, beta=0.98, max_iterations=8,
                          seq_len=256))

    print("prunable sites:")
    for s in session.sites:
        print(f"  {s.site_id:26s} kind={s.kind:8s} dim={s.dim} "
              f"subgraphs={s.multiplicity}")

    table = session.tune()
    print("\ntask table (C) — impact = latency x #subgraphs (paper §3.3):")
    for t in table.ordered():
        print(f"  task{t.task_id} {t.sites[0].kind:8s} "
              f"lat={t.latency*1e6:8.1f}us x {t.n_subgraphs:2d} subgraphs "
              f"-> impact {t.pruning_impact*1e6:9.1f}")

    res = session.prune(strategy="cprune", verbose=True)

    print(f"\nFPS increase {res.fps_increase:.2f}x, acc {res.final_acc:.3f}")
    for s in res.sites:
        print(f"  {s.site_id:26s} dim {s.dim}")
    E = res.params["stack"]["pos0"]["ffn"]["router"].shape[-1]
    print(f"experts remaining: {E} (started with {cfg.n_experts})")


if __name__ == "__main__":
    main()

"""Constraint-driven deployment: "accuracy floor, latency budget" as the
front door, per the paper's framing ("support an application with a
required target accuracy").

    PYTHONPATH=src python examples/plan_deploy.py [--fast]

`plan()` sweeps strategy x target (the sweep rides the shared tuning
caches, so extra arms are cheap), prints the Pareto frontier, exports the
best constraint-satisfying candidate as a deployment artifact, and then
serves that artifact from disk — the prune/tune machinery is out of the
loop by the time requests arrive. To keep the constraint language alive
per *request* instead of freezing it here, export the whole frontier with
`pl.export_catalog(dir)` and serve it through the SLO router — see
`examples/route_slo.py`.
"""
import argparse
import os
import tempfile

import jax
import numpy as np

from repro.api import CPruneConfig, TrainHooks, Workload, plan
from repro.configs import get_reduced_config
from repro.data.pipeline import DataPipeline
from repro.models.model import Model, init_params
from repro.optim.optimizers import sgd_init, sgd_update
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced iteration counts for smoke runs")
    ap.add_argument("--accuracy-floor", type=float, default=None,
                    help="required eval accuracy (default: 90%% of the "
                         "pretrained accuracy)")
    args = ap.parse_args()

    # 1. model + data + real training hooks (as in quickstart)
    cfg = get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=2, d_model=128, d_ff=1024, n_heads=8, n_kv_heads=2,
        head_dim=16, vocab_size=256)
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    pipe = DataPipeline(cfg, global_batch=8, seq_len=64)
    val = pipe.batch(10 ** 6)
    jloss = jax.jit(model.loss_fn)

    @jax.jit
    def jstep(p, o, b):
        (_, m), g = jax.value_and_grad(
            lambda pp: model.loss_fn(pp, b), has_aux=True)(p)
        return (*sgd_update(p, g, o, lr=0.05, momentum=0.9), m)

    state = {"i": 0}

    def train(p, _sites, n):
        o = sgd_init(p)
        for _ in range(n):
            state["i"] += 1
            p, o, _ = jstep(p, o, pipe.batch(state["i"]))
        return p

    def eval_acc(p, _sites):
        _, m = jloss(p, val)
        return float(m["acc"])

    print("pretraining on the synthetic Markov task ...")
    params = train(params, None, 16 if args.fast else 48)
    acc0 = eval_acc(params, None)
    floor = args.accuracy_floor if args.accuracy_floor is not None \
        else round(0.9 * acc0, 3)
    print(f"  pretrained accuracy: {acc0:.3f} -> accuracy floor {floor}")

    # 2. the constraint front door: sweep strategies across two targets
    pl = plan(
        cfg, accuracy_floor=floor,
        targets=["tpu_v5e", "edge"],
        strategies=["cprune", "uniform_l1"],
        workload=Workload(tokens_global=65536),
        hooks=TrainHooks(
            short_term_train=lambda p, s: train(p, s, 2 if args.fast else 4),
            eval_acc=eval_acc),
        pcfg=CPruneConfig(a_g=floor, alpha=0.7 if args.fast else 0.9,
                          beta=0.98, max_iterations=2 if args.fast else 6,
                          seq_len=256),
        params=params,
        strategy_kwargs={"uniform_l1": {"ratio": 0.25}},
        verbose=True)

    print("\nPareto frontier (accuracy up, latency down):")
    for c in pl.frontier:
        print(f"  {c.describe()}")
    best = pl.best
    if best is None:
        print("no candidate satisfies the constraints — relax the floor")
        return
    print(f"\nbest: {best.describe()}")

    # 3. export the winner, then serve it from disk alone
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "artifact")
        art = pl.export(path, max_batch=4, max_seq=48)
        print(f"exported {path}: tuned_digest={art.tuned_digest}, "
              f"planned latency {art.metadata['latency_total_s']*1e3:.3f} ms")
        engine = ServeEngine.from_artifact(path)
        rng = np.random.default_rng(0)
        for i in range(4):
            engine.submit(Request(
                rid=i,
                prompt=rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
                max_new_tokens=8))
        stats = engine.run()
        print(f"served {stats['requests']} reqs: "
              f"{stats['tokens_per_s']:.1f} tok/s, "
              f"TTFT p50/p95 {stats['p50_ttft_s']*1e3:.0f}/"
              f"{stats['p95_ttft_s']*1e3:.0f} ms, "
              f"step p95 {stats['p95_step_s']*1e3:.2f} ms")


if __name__ == "__main__":
    main()

"""Distributed serving demo: tensor-parallel sharded decode + the
replica fleet balancer, end to end.

Walks the whole path: prune in a session, `export(tp=2)` a
partition-stamped artifact, load it back (`ServeEngine.from_artifact`
returns a `ShardedServeEngine` automatically), serve sharded over a
(1, 2) (data, model) mesh — and check the sharded token stream is
**bit-identical** to the single-device one, because GSPMD partitions
the identical jaxpr rather than changing the math. Then a 2-replica
`ReplicaSet` drains the same workload with least-loaded
outstanding-token dispatch and survives an injected mid-decode crash.

Runs anywhere: re-execs itself with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` so a plain CPU
host presents 4 devices.

    PYTHONPATH=src python examples/serve_sharded.py
"""
import os
import sys

# XLA reads this once at import, so fan the host out to 4 devices
# *before* jax loads — re-exec if the flag is not already set
if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4"
                               ).strip()
    os.execv(sys.executable, [sys.executable] + sys.argv)

import tempfile

import jax
import numpy as np

from repro.api import CPruneConfig, PruningSession, TrainHooks, Workload
from repro.configs import get_reduced_config
from repro.launch.mesh import make_test_mesh
from repro.serve.distributed import ShardedServeEngine
from repro.serve.engine import Request, ServeEngine
from repro.serve.fleet import ReplicaSet, RetryPolicy
from repro.util.faults import FaultInjector, crash_at


def requests(cfg, n=8):
    rng = np.random.default_rng(0)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        16 if i % 2 else 8).astype(np.int32),
                    max_new_tokens=24 if i % 4 == 3 else 6)
            for i in range(n)]


def drain(engine, cfg):
    for r in requests(cfg):
        engine.submit(r)
    stats = engine.run()
    return stats, {r.rid: list(r.output) for r in engine.done}


def main():
    print(f"devices: {len(jax.devices())} ({jax.devices()[0].platform})")
    cfg = get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=2, d_model=64, d_ff=512, n_heads=8, n_kv_heads=2,
        head_dim=16, vocab_size=512)

    # prune once; the hooks skip training — this demo measures the
    # serving path, not model quality
    session = PruningSession(
        cfg, workload=Workload(tokens_global=65536),
        hooks=TrainHooks(short_term_train=lambda p, s: p,
                         eval_acc=lambda p, s: float("nan")),
        pcfg=CPruneConfig(a_g=0.0, seq_len=64, prunable_kinds=("ffn",)))
    session.prune(strategy="uniform_l1", ratio=0.5)

    with tempfile.TemporaryDirectory() as td:
        # -- export: tp=2 stamps a partition section ------------------------
        solo_art = session.export(os.path.join(td, "tp1"), max_batch=4,
                                  max_seq=48)
        shard_art = session.export(os.path.join(td, "tp2"), max_batch=4,
                                   max_seq=48, tp=2)
        print(f"exported tp=1 (partition stamp: "
              f"{solo_art.partition is not None}) and tp=2 "
              f"(tp={shard_art.tp}, "
              f"mesh_axes={shard_art.partition['mesh_axes']})")

        # -- serve: the stamped artifact comes back sharded -----------------
        solo = ServeEngine.from_artifact(solo_art)
        shard = ServeEngine.from_artifact(shard_art)   # ShardedServeEngine
        assert isinstance(shard, ShardedServeEngine)
        _, want = drain(solo, cfg)
        st, got = drain(shard, cfg)
        assert got == want, "sharding changed the math!"
        print(f"tp={st['tp']} over mesh {st['mesh']}: "
              f"{st['requests']} reqs, {st['total_new_tokens']} tokens — "
              f"bit-identical to the single-device decode")

        # -- fleet: 2 replicas, least-loaded dispatch, one crash ------------
        inj = FaultInjector(specs=[crash_at("decode:demo#r0", 2)])
        mesh = make_test_mesh(n_devices=2, model=2)

        def factory(i):
            return ShardedServeEngine.for_artifact(
                shard_art, mesh=mesh,
                faults=inj if i == 0 else None, fault_tag=f"demo#r{i}")

        fleet = ReplicaSet(factory, replicas=2, name="demo",
                           retry=RetryPolicy(max_retries=2, backoff_s=60.0))
        for r in requests(cfg):
            fleet.submit(r)
        fs = fleet.run()
        assert {r.rid: list(r.output) for r in fleet.completed} == want
        print(f"fleet: dispatch_histogram={fs['dispatch_histogram']} "
              f"crashes={fs['crashes']} requeued={fs['requeued']} "
              f"(to survivor: {fs['requeued_to_survivor']}) "
              f"failed={fs['failed']} — all {fs['requests']} completed, "
              f"outputs still bit-identical through the crash")
        for occ in fs["per_replica_occupancy"]:
            print(f"  replica {occ['replica']}: live={occ['live']} "
                  f"dispatched={occ['dispatched']} crashes={occ['crashes']}")


if __name__ == "__main__":
    main()

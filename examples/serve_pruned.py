"""Serving demo: batched decode of a pruned vs unpruned model through the
continuous-batching engine (prefill + per-token decode with KV caches).

    PYTHONPATH=src python examples/serve_pruned.py
"""
import time

import jax
import numpy as np

from repro.configs import get_reduced_config
from repro.core import applier, ranking
from repro.models.model import init_params, prune_sites
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=4, d_model=128, d_ff=1024, n_heads=8, n_kv_heads=2,
        head_dim=16, vocab_size=512)
    params = init_params(jax.random.PRNGKey(0), cfg)
    sites = prune_sites(cfg)

    # structured 50% FFN prune (L1 ranking)
    site = next(s for s in sites if s.kind == "ffn")
    scores = ranking.rank_units(params, site, "l1")
    pruned_params, _ = applier.prune_site_by_rank(params, site, 512, scores)

    rng = np.random.default_rng(0)

    def bench(p, label):
        eng = ServeEngine(cfg, p, max_batch=8, max_seq=64)
        for i in range(8):
            eng.submit(Request(
                rid=i,
                prompt=rng.integers(0, cfg.vocab_size, 32).astype(np.int32),
                max_new_tokens=16,
                temperature=0.7 if i % 2 else 0.0))
        stats = eng.run()
        print(f"{label:10s} {stats['requests']} reqs in "
              f"{stats['wall_s']:.2f}s -> {stats['tokens_per_s']:.1f} tok/s "
              f"(TTFT {stats['mean_ttft_s']*1e3:.0f} ms)")
        return stats

    print("serving dense vs 50%-FFN-pruned model (same engine):")
    bench(params, "dense")
    bench(pruned_params, "pruned")


if __name__ == "__main__":
    main()

"""Serving demo: batched decode of a pruned vs unpruned model through the
continuous-batching engine, wired through the deployment-artifact flow —
prune once, `session.export()` the artifact, then serve it from disk via
`ServeEngine.from_artifact` exactly as a fresh serving process would
(the session is not needed on the serve path).

    PYTHONPATH=src python examples/serve_pruned.py
"""
import os
import tempfile

import numpy as np

from repro.api import (CPruneConfig, MeasurementLog, PruningSession,
                       TrainHooks, Workload)
from repro.configs import get_reduced_config
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=4, d_model=128, d_ff=1024, n_heads=8, n_kv_heads=2,
        head_dim=16, vocab_size=512)

    # one session: 50% structured L1 prune of the FFN sites only
    # (prunable_kinds keeps the demo's "50%-FFN-pruned" comparison honest),
    # then export the pruned model as a deployment artifact. This demo
    # measures *serving throughput*, not model quality, so the hooks
    # deliberately skip training — explicit stubs rather than the
    # defaults, which would warn about it.
    session = PruningSession(
        cfg, workload=Workload(tokens_global=65536),
        hooks=TrainHooks(short_term_train=lambda p, s: p,
                         eval_acc=lambda p, s: float("nan")),
        pcfg=CPruneConfig(a_g=0.0, seq_len=256, prunable_kinds=("ffn",)))
    dense_params = session.params
    session.prune(strategy="uniform_l1", ratio=0.5)

    rng = np.random.default_rng(0)

    def bench(engine, label):
        for i in range(8):
            engine.submit(Request(
                rid=i,
                prompt=rng.integers(0, cfg.vocab_size, 32).astype(np.int32),
                max_new_tokens=16,
                temperature=0.7 if i % 2 else 0.0))
        stats = engine.run()
        print(f"{label:10s} {stats['requests']} reqs in "
              f"{stats['wall_s']:.2f}s -> {stats['tokens_per_s']:.1f} tok/s "
              f"(TTFT p50 {stats['p50_ttft_s']*1e3:.0f} ms / "
              f"p95 {stats['p95_ttft_s']*1e3:.0f} ms, "
              f"step p95 {stats['p95_step_s']*1e3:.1f} ms)")
        if stats.get("oracle_rel_error") is not None:
            # the latency oracle predicts a v5e shard; this CPU run makes
            # the prediction error observable (the gap the measured
            # backend closes on real hardware)
            print(f"{'':10s} decode step: predicted "
                  f"{stats['predicted_step_s']*1e3:.3f} ms vs measured "
                  f"{stats['measured_step_s']*1e3:.1f} ms "
                  f"(rel err {stats['oracle_rel_error']:+.1%})")
        return stats

    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "artifact")
        art = session.export(path, max_batch=8, max_seq=64)
        print(f"exported artifact: target={art.target.name} "
              f"strategy={art.metadata['strategy']} "
              f"tuned_digest={art.tuned_digest}")
        print("serving dense (in-session) vs 50%-FFN-pruned (artifact):")
        bench(session.serve(params=dense_params, max_batch=8, max_seq=64),
              "dense")
        # the pruned model serves from the artifact directory alone — the
        # same call a freshly restarted serving process would make; the
        # attached MeasurementLog records the observed decode step, the
        # raw material for DeploymentArtifact.recalibrated_oracle
        log = MeasurementLog()
        stats = bench(ServeEngine.from_artifact(path, measurements=log),
                      "pruned")
        key = MeasurementLog.step_key(art.measurement_tag, 8, 64)
        print(f"{'':10s} recorded observed decode step "
              f"{log.lookup(key)*1e3:.1f} ms into the measurement log "
              f"({key}) — feed it back with art.recalibrated_oracle(log) "
              f"on a replay-backed artifact")
        assert stats["requests"] == 8


if __name__ == "__main__":
    main()

"""Serving demo: batched decode of a pruned vs unpruned model through the
continuous-batching engine (prefill + per-token decode with KV caches),
wired through `PruningSession.prune -> serve`.

    PYTHONPATH=src python examples/serve_pruned.py
"""
import numpy as np

from repro.api import CPruneConfig, PruningSession, TrainHooks, Workload
from repro.configs import get_reduced_config
from repro.serve.engine import Request


def main():
    cfg = get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=4, d_model=128, d_ff=1024, n_heads=8, n_kv_heads=2,
        head_dim=16, vocab_size=512)

    # one session: 50% structured L1 prune of the FFN sites only
    # (prunable_kinds keeps the demo's "50%-FFN-pruned" comparison honest),
    # then serve both models. This demo measures *serving throughput*, not
    # model quality, so the hooks deliberately skip training — explicit
    # stubs rather than the defaults, which would warn about it.
    session = PruningSession(
        cfg, workload=Workload(tokens_global=65536),
        hooks=TrainHooks(short_term_train=lambda p, s: p,
                         eval_acc=lambda p, s: float("nan")),
        pcfg=CPruneConfig(a_g=0.0, seq_len=256, prunable_kinds=("ffn",)))
    dense_params = session.params
    session.prune(strategy="uniform_l1", ratio=0.5)

    rng = np.random.default_rng(0)

    def bench(engine, label):
        for i in range(8):
            engine.submit(Request(
                rid=i,
                prompt=rng.integers(0, cfg.vocab_size, 32).astype(np.int32),
                max_new_tokens=16,
                temperature=0.7 if i % 2 else 0.0))
        stats = engine.run()
        print(f"{label:10s} {stats['requests']} reqs in "
              f"{stats['wall_s']:.2f}s -> {stats['tokens_per_s']:.1f} tok/s "
              f"(TTFT {stats['mean_ttft_s']*1e3:.0f} ms)")
        if stats.get("oracle_rel_error") is not None:
            # the latency oracle predicts a v5e shard; this CPU run makes
            # the prediction error observable (the gap the measured
            # backend closes on real hardware)
            print(f"{'':10s} decode step: predicted "
                  f"{stats['predicted_step_s']*1e3:.3f} ms vs measured "
                  f"{stats['measured_step_s']*1e3:.1f} ms "
                  f"(rel err {stats['oracle_rel_error']:+.1%})")
        return stats

    print("serving dense vs 50%-FFN-pruned model (same engine):")
    bench(session.serve(params=dense_params, max_batch=8, max_seq=64),
          "dense")
    bench(session.serve(max_batch=8, max_seq=64), "pruned")


if __name__ == "__main__":
    main()

"""SLO routing demo: one plan, a catalog of frontier artifacts, and a
router that gives every *request* its own constraint language.

    plan() -> Plan.export_catalog() -> Router(Request(latency_budget_s=...))

The plan sweeps two pruning strategies into a real accuracy/latency
trade-off (deep uniform prune = fast but less accurate, shallow FPGM =
slower but more accurate), exports the whole Pareto frontier as an
ArtifactCatalog, and then serves a mixed-SLO workload: requests with a
tight latency budget land on the fast artifact, requests with a loose
budget spend it on accuracy. Finally the serve run's *measured* decode
step is folded back into the story: the oracle's per-artifact prediction
vs what the hardware actually did.

    PYTHONPATH=src python examples/route_slo.py
"""
import os
import tempfile

import jax
import numpy as np

from repro.api import CPruneConfig, TrainHooks, Workload, plan
from repro.configs import get_reduced_config
from repro.models.model import init_params
from repro.serve.engine import Request
from repro.serve.router import Router


def _count(p):
    return sum(int(np.prod(np.asarray(x).shape)) for x in jax.tree.leaves(p))


def main():
    cfg = get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=2, d_model=128, d_ff=1024, n_heads=8, n_kv_heads=2,
        head_dim=16, vocab_size=256)
    params = init_params(jax.random.PRNGKey(0), cfg)
    n0 = _count(params)
    # accuracy = remaining-parameter fraction: deterministic, and it makes
    # the frontier's accuracy/latency trade-off real without training
    hooks = TrainHooks(short_term_train=lambda p, s: p,
                       eval_acc=lambda p, s: _count(p) / n0)

    pl = plan(cfg, accuracy_floor=0.0, targets=["tpu_v5e"],
              strategies=["uniform_l1", "fpgm"],
              workload=Workload(tokens_global=8192), hooks=hooks,
              params=params, pcfg=CPruneConfig(a_g=0.0, seq_len=64),
              strategy_kwargs={"uniform_l1": {"ratio": 0.6},
                               "fpgm": {"ratio": 0.1}})
    print("plan:")
    print(pl.summary())

    with tempfile.TemporaryDirectory() as td:
        fleet = os.path.join(td, "fleet")
        catalog = pl.export_catalog(fleet, max_batch=4, max_seq=48)
        print(f"\ncatalog ({fleet}):")
        print(catalog.summary())

        fast = min(catalog, key=lambda e: e.predicted_step_s)
        accurate = max(catalog, key=lambda e: e.accuracy)
        router = Router(catalog, on_unroutable="flag")
        rng = np.random.default_rng(0)
        n_new = 16
        mid = (fast.predicted_step_s + accurate.predicted_step_s) / 2
        for i in range(8):
            # even requests: a budget only the fast artifact can promise;
            # odd requests: a loose budget that buys accuracy instead
            budget = mid * n_new if i % 2 == 0 \
                else accurate.predicted_step_s * n_new * 100
            name = router.submit(Request(
                rid=i,
                prompt=rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
                max_new_tokens=n_new, latency_budget_s=budget))
            print(f"request {i}: budget {budget*1e3:.3f} ms -> {name}")

        stats = router.run()
        print(f"\nrouted {stats['requests']} requests "
              f"({stats['tokens_per_s']:.1f} tok/s): {stats['routing']}")
        for name, sub in stats["per_artifact"].items():
            line = (f"  {name}: {sub['requests']} reqs, "
                    f"step p50 {sub['p50_step_s']*1e3:.2f} ms")
            if sub.get("predicted_step_s"):
                line += (f" (oracle predicted "
                         f"{sub['predicted_step_s']*1e3:.4f} ms — the CPU "
                         f"vs v5e sim-to-real gap)")
            print(line)
        print(f"budget violations: {stats['budget_violations']}"
              f"/{stats['budgeted_requests']} (budgets were priced from "
              f"v5e-oracle predictions; on real v5e hardware this is the "
              f"number the recalibration loop drives down)")

        # -- act 2: kill an engine mid-decode, watch the fleet recover ---
        # The same catalog behind a fresh router, but a FaultInjector
        # crashes the accurate entry's engine on its 5th decode tick.
        # The ReplicaSupervisor contains the crash: the engine is rebuilt
        # cold from the artifact, its in-flight requests are re-queued
        # (same SLO clock), and greedy decode reproduces the exact
        # tokens the fault-free run would have produced.
        from repro.serve.fleet import RetryPolicy
        from repro.util.faults import FaultInjector, crash_at
        print("\n--- kill-and-recover ---")
        inj = FaultInjector(
            specs=[crash_at(f"decode:{accurate.name}#r0", 4)])
        chaos = Router(catalog, faults=inj,
                       retry=RetryPolicy(max_retries=2))
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            16).astype(np.int32),
                        max_new_tokens=n_new)
                for i in range(4)]
        for r in reqs:
            chaos.submit(r)        # unconstrained -> the accurate entry
        cstats = chaos.run()
        sup = cstats["per_artifact"][accurate.name]
        print(f"injected crash on {accurate.name}#r0 at decode tick 5: "
              f"{cstats['crashes']} crash, {sup['rebuilds']} cold "
              f"rebuild, {sup['requeued']} requests re-queued "
              f"({sup['retried_requests']} finished on retry)")
        acc = sup["accounting"]
        assert all(r.done for r in reqs) and cstats["failed"] == 0
        assert acc["submitted"] == acc["completed"] == len(reqs)
        print(f"all {acc['completed']}/{acc['submitted']} requests "
              f"completed — nothing lost, outputs bit-identical to a "
              f"fault-free greedy run")


if __name__ == "__main__":
    main()

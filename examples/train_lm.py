"""End-to-end driver: pretrain a ~100M-class LM, CPrune it, final-train,
and compare served throughput before/after — stages 2 and 3 ride the
`PruningSession` front door (prune -> save -> serve).

Default is a CPU-friendly ~3M model so the script finishes in minutes;
``--full`` scales the same family to ~100M params (6·N·D per step grows
~30x — expect ~1 h on this 1-core container, minutes on a real host).

    PYTHONPATH=src python examples/train_lm.py [--full] [--steps 300]

The run exercises the production path: data pipeline -> Trainer (with
checkpointing + straggler monitor) -> CPrune loop -> final training ->
session checkpoint -> ServeEngine throughput measurement.
"""
import argparse
import time

import jax
import numpy as np

from repro.api import CPruneConfig, PruningSession, TrainHooks, Workload
from repro.configs import get_reduced_config
from repro.data.pipeline import DataPipeline
from repro.serve.engine import Request
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params instead of the quick ~3M default")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.full:
        over = dict(n_layers=8, d_model=768, d_ff=3072, n_heads=12,
                    n_kv_heads=4, head_dim=64, vocab_size=8192)
    else:
        over = dict(n_layers=4, d_model=192, d_ff=768, n_heads=6,
                    n_kv_heads=2, head_dim=32, vocab_size=512)
    cfg = get_reduced_config("qwen3_1_7b").with_overrides(**over)
    print(f"arch family: qwen3 (dense GQA), params ~"
          f"{cfg.param_count()/1e6:.1f}M")

    # --- stage 1: pretraining with the production Trainer ---------------
    pipe = DataPipeline(cfg, global_batch=16, seq_len=128)
    tcfg = TrainerConfig(lr=3e-3, optimizer="adamw", ckpt_dir=args.ckpt_dir,
                         ckpt_every=100, log_every=max(args.steps // 10, 1))
    trainer = Trainer(cfg, tcfg, pipe)
    t0 = time.time()
    stats = trainer.run(args.steps)
    print(f"pretrain: {args.steps} steps in {time.time()-t0:.1f}s "
          f"(median step {stats['median_step_s']*1e3:.0f} ms, "
          f"restarts {stats['restarts']}, stragglers {stats['stragglers']})")
    print(f"eval: {trainer.eval_batch()}")

    # --- stage 2: CPrune through the session front door -------------------
    model = trainer.model
    val = pipe.batch(10 ** 6)
    jloss = jax.jit(model.loss_fn)

    def short_train(p, s):
        tr = Trainer(cfg, TrainerConfig(lr=1e-3, log_every=10 ** 9), pipe,
                     params=p, model=model)
        tr.run(4)
        return tr.params

    def eval_acc(p, s):
        _, m = jloss(p, val)
        return float(m["acc"])

    session = PruningSession(
        cfg, params=trainer.params,
        workload=Workload(tokens_global=262144, dp=1, tp=1),
        hooks=TrainHooks(short_term_train=short_train, eval_acc=eval_acc,
                         long_term_train=lambda p, s: short_train(p, s)),
        pcfg=CPruneConfig(a_g=0.3, alpha=0.9, beta=0.98, max_iterations=6,
                          seq_len=2048))
    res = session.prune(strategy="cprune", verbose=True)
    print(f"CPrune: {res.fps_increase:.2f}x target FPS, "
          f"acc {res.final_acc:.3f}")
    session.save(args.ckpt_dir + "/pruned_session")
    print(f"session checkpoint -> {args.ckpt_dir}/pruned_session")

    # --- stage 3: serve both models, measure real tokens/s ----------------
    rng = np.random.default_rng(0)

    def throughput(engine):
        for i in range(8):
            engine.submit(Request(rid=i, prompt=rng.integers(
                0, cfg.vocab_size, 64).astype(np.int32), max_new_tokens=16))
        return engine.run()["tokens_per_s"]

    tps_before = throughput(
        session.serve(params=trainer.params, max_batch=8, max_seq=96))
    tps_after = throughput(session.serve(max_batch=8, max_seq=96))
    print(f"serving throughput (CPU, interpret-free XLA path): "
          f"{tps_before:.1f} -> {tps_after:.1f} tokens/s "
          f"({tps_after/tps_before:.2f}x)")


if __name__ == "__main__":
    main()

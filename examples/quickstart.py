"""Quickstart: compiler-informed pruning of a small LM in ~2 minutes.

    PYTHONPATH=src python examples/quickstart.py

Walks the whole public API: build a model from an assigned-architecture
config, pretrain briefly on the synthetic task, run CPrune (tune ->
task-order -> structure-preserving prune -> accept/reject), and report the
FPS gain on the v5e cost-model target.
"""
import jax
import jax.numpy as jnp

from repro.configs import get_reduced_config
from repro.core import CPrune, CPruneConfig, TrainHooks, Workload
from repro.data.pipeline import DataPipeline
from repro.models.model import Model, init_params, prune_sites
from repro.optim.optimizers import sgd_init, sgd_update


def main():
    # 1. model + data
    cfg = get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=4, d_model=128, d_ff=1024, n_heads=8, n_kv_heads=2,
        head_dim=16, vocab_size=256)
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    sites = prune_sites(cfg)
    pipe = DataPipeline(cfg, global_batch=8, seq_len=64)
    val = pipe.batch(10 ** 6)

    # 2. training hooks (SGD+momentum, as in the paper)
    jloss = jax.jit(model.loss_fn)

    @jax.jit
    def jstep(p, o, b):
        (_, m), g = jax.value_and_grad(
            lambda pp: model.loss_fn(pp, b), has_aux=True)(p)
        return (*sgd_update(p, g, o, lr=0.05, momentum=0.9), m)

    state = {"i": 0}

    def train(p, _sites, n):
        o = sgd_init(p)
        for _ in range(n):
            state["i"] += 1
            p, o, _ = jstep(p, o, pipe.batch(state["i"]))
        return p

    def eval_acc(p, _sites):
        _, m = jloss(p, val)
        return float(m["acc"])

    print("pretraining on the synthetic Markov task ...")
    params = train(params, sites, 48)
    print(f"  pretrained accuracy: {eval_acc(params, sites):.3f}")

    # 3. CPrune: target = one v5e shard serving 64k tokens/step
    hooks = TrainHooks(
        short_term_train=lambda p, s: train(p, s, 4),
        eval_acc=eval_acc,
        long_term_train=lambda p, s: train(p, s, 16))
    pcfg = CPruneConfig(a_g=0.5, alpha=0.9, beta=0.98, max_iterations=8,
                        seq_len=256)
    cp = CPrune(cfg, sites, Workload(tokens_global=65536), hooks, pcfg)
    res = cp.run(params, verbose=True)

    print(f"\nFPS increase     : {res.fps_increase:.2f}x")
    print(f"final accuracy   : {res.final_acc:.3f} (required > {pcfg.a_g})")
    print("final prunable dims:")
    for s in res.sites:
        print(f"  {s.site_id:24s} {s.kind:8s} dim={s.dim}")


if __name__ == "__main__":
    main()

"""Quickstart: compiler-informed pruning of a small LM in ~2 minutes.

    PYTHONPATH=src python examples/quickstart.py [--target edge] [--fast]

Walks the public API front door (`repro.api.PruningSession`): build a
model from an assigned-architecture config, pretrain briefly on the
synthetic task, run CPrune against a registered target backend (tune ->
task-order -> structure-preserving prune -> accept/reject), and report
the FPS gain on that target's cost model. ``--target`` swaps the device
profile (tpu_v5e | tpu_v4 | edge) — the same loop produces a different
pruned architecture per target. ``--fast`` shrinks the run for CI smoke.
"""
import argparse

import jax

from repro.api import CPruneConfig, PruningSession, TrainHooks, Workload
from repro.api import list_targets
from repro.configs import get_reduced_config
from repro.data.pipeline import DataPipeline
from repro.models.model import Model, init_params
from repro.optim.optimizers import sgd_init, sgd_update


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--target", default="tpu_v5e", choices=list_targets())
    ap.add_argument("--fast", action="store_true",
                    help="reduced iteration counts for the CI smoke job")
    args = ap.parse_args()

    # 1. model + data
    cfg = get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=4, d_model=128, d_ff=1024, n_heads=8, n_kv_heads=2,
        head_dim=16, vocab_size=256)
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    pipe = DataPipeline(cfg, global_batch=8, seq_len=64)
    val = pipe.batch(10 ** 6)

    # 2. training hooks (SGD+momentum, as in the paper)
    jloss = jax.jit(model.loss_fn)

    @jax.jit
    def jstep(p, o, b):
        (_, m), g = jax.value_and_grad(
            lambda pp: model.loss_fn(pp, b), has_aux=True)(p)
        return (*sgd_update(p, g, o, lr=0.05, momentum=0.9), m)

    state = {"i": 0}

    def train(p, _sites, n):
        o = sgd_init(p)
        for _ in range(n):
            state["i"] += 1
            p, o, _ = jstep(p, o, pipe.batch(state["i"]))
        return p

    def eval_acc(p, _sites):
        _, m = jloss(p, val)
        return float(m["acc"])

    print("pretraining on the synthetic Markov task ...")
    params = train(params, None, 16 if args.fast else 48)
    print(f"  pretrained accuracy: {eval_acc(params, None):.3f}")

    # 3. one front door: target = a registered device profile serving 64k
    #    tokens/step; CPrune runs entirely under that target's cost model
    session = PruningSession(
        cfg, params=params, target=args.target,
        workload=Workload(tokens_global=65536),
        hooks=TrainHooks(
            short_term_train=lambda p, s: train(p, s, 2 if args.fast else 4),
            eval_acc=eval_acc,
            long_term_train=lambda p, s: train(p, s, 4 if args.fast else 16)),
        # --fast pretrains too briefly to clear the full accuracy bar, so
        # the smoke run lowers a_g enough for the loop to actually prune
        pcfg=CPruneConfig(a_g=0.05 if args.fast else 0.5,
                          alpha=0.7 if args.fast else 0.9, beta=0.98,
                          max_iterations=3 if args.fast else 8, seq_len=256))
    res = session.prune(strategy="cprune", verbose=True)

    print(f"\ntarget           : {session.target.name}")
    print(f"FPS increase     : {res.fps_increase:.2f}x")
    print(f"final accuracy   : {res.final_acc:.3f} "
          f"(required > {session.pcfg.a_g})")
    print("final prunable dims:")
    for s in res.sites:
        print(f"  {s.site_id:24s} {s.kind:8s} dim={s.dim}")


if __name__ == "__main__":
    main()

"""Autopilot control plane: drift-triggered replanning + hot-swap.

Acceptance contract: with a drift injected at serve time (the accurate
entry's decode step slowed well past its prediction), the autopilot —
with no human in the loop — detects the drift through the router's
health signals, replans under the drift source's recalibrated oracle,
exports the winner as a new catalog generation, and hot-swaps it in
with zero dropped requests and zero lost in-flight work (every request
admitted before the swap completes on the old generation); the
post-swap budget-violation rate is strictly lower than pre-swap. A kill
injected mid-swap (``crash_at``) leaves a loadable, validated catalog;
a failed probation rolls the swap back to the prior generation.
"""
import dataclasses
import json
import os
import shutil

import jax
import numpy as np
import pytest

from repro.api import (CPruneConfig, DeploymentArtifact, MeasuredOracle,
                       MeasurementConfig, MeasurementLog, ReplayOracle,
                       TrainHooks, Workload, plan)
from repro.api.artifact import ArtifactError, GenerationStore
from repro.configs import get_reduced_config
from repro.core import clear_tuning_caches
from repro.models.model import init_params
from repro.serve.autopilot import Autopilot, AutopilotConfig
from repro.serve.engine import Request
from repro.serve.fleet import ReplicaSupervisor, RouteError
from repro.serve.router import ArtifactCatalog, Router
from repro.util.faults import FaultInjector, InjectedFault, crash_at, delay_at


@pytest.fixture(autouse=True)
def _cold_caches():
    clear_tuning_caches()
    yield
    clear_tuning_caches()


def _cfg():
    return get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=2, d_model=64, d_ff=512, n_heads=8, n_kv_heads=2,
        head_dim=8, vocab_size=128)


def _count(p):
    return sum(int(np.prod(np.asarray(x).shape)) for x in jax.tree.leaves(p))


_FAST = MeasurementConfig(warmup=0, repeats=1, trim=0, measure_top_k=1,
                          max_grid_steps=1)


class _DeterministicMeasuredOracle(MeasuredOracle):
    """A measured oracle whose per-kernel timing is a deterministic
    function of the problem size instead of a wall clock. Everything
    else — recording, replay bundling, rescaling — is the real code
    path, but the frontier ordering (more pruning => faster) cannot be
    inverted by single-repeat interpret-mode timing noise."""

    def _time_kernel(self, m, k, n, batch, dtype_bytes, block) -> float:
        return float(m * k * n * batch) * 1e-12 + 5e-7


@pytest.fixture(scope="module")
def fleet_plan(tmp_path_factory):
    """One measured-oracle plan whose two frontier artifacts are
    replay-backed (so ``recalibrated_oracle`` — and therefore the
    autopilot's replan — works), exported as a catalog."""
    clear_tuning_caches()
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    n0 = _count(params)
    hooks = TrainHooks(short_term_train=lambda p, s: p,
                       eval_acc=lambda p, s: _count(p) / n0)
    pl = plan(cfg, accuracy_floor=0.0, targets=["tpu_v5e"],
              strategies=["uniform_l1", "fpgm"],
              workload=Workload(tokens_global=8192), hooks=hooks,
              params=params,
              oracle=_DeterministicMeasuredOracle(
                  _FAST, record=MeasurementLog(_FAST)),
              pcfg=CPruneConfig(a_g=0.0, seq_len=64),
              strategy_kwargs={"uniform_l1": {"ratio": 0.6},
                               "fpgm": {"ratio": 0.1}})
    assert len(pl.frontier) == 2
    path = tmp_path_factory.mktemp("autopilot")
    cat = pl.export_catalog(str(path), max_batch=2, max_seq=24)
    assert len(cat) == 2
    clear_tuning_caches()
    return str(path), cfg, pl


def _clone(root, tmp_path, name="cat"):
    dst = str(tmp_path / name)
    shutil.copytree(root, dst)
    return dst


def _entries(cat):
    fast = min(cat, key=lambda e: e.predicted_step_s)
    accurate = max(cat, key=lambda e: e.accuracy)
    return fast, accurate


def _req(rng, cfg, rid, **kw):
    return Request(rid=rid, prompt=rng.integers(
        0, cfg.vocab_size, size=8).astype(np.int32), max_new_tokens=4, **kw)


def _stage_copy(store):
    """Stage the next generation as a byte-identical copy of the current
    root catalog (the cheap way to make a real, loadable generation
    without re-running a plan)."""
    gid, staged = store.stage()
    for item in os.listdir(store.root):
        if item in ("generations", "CURRENT") or item.endswith(".tmp"):
            continue
        src = os.path.join(store.root, item)
        dst = os.path.join(staged, item)
        if os.path.isdir(src):
            shutil.copytree(src, dst)
        else:
            shutil.copy2(src, dst)
    return gid, staged


# -- GenerationStore: the atomic-swap substrate (no jax needed) -------------


def _fake_gen(path):
    os.makedirs(path, exist_ok=True)
    with open(os.path.join(path, "catalog.json"), "w") as f:
        json.dump({"version": 1, "entries": []}, f)


def test_generation_store_lifecycle(tmp_path):
    root = str(tmp_path / "cat")
    _fake_gen(root)
    store = GenerationStore(root, keep_last=1)
    assert GenerationStore.read_pointer(root) is None
    assert GenerationStore.resolve(root) == (0, root)

    gid, staged = store.stage()
    assert gid == 1 and os.path.isdir(staged)
    # a stage with no manifest cannot become current
    with pytest.raises(ArtifactError, match="no catalog manifest"):
        store.commit(gid)
    assert store.current[0] == 0        # refused commit changed nothing
    _fake_gen(staged)
    store.commit(gid)
    assert store.current == (1, staged)
    assert GenerationStore.resolve(root) == (1, staged)

    gid2, staged2 = store.stage()
    assert gid2 == 2
    _fake_gen(staged2)
    store.commit(gid2)
    assert store.current[0] == 2
    assert sorted(store.generations()) == [0, 1, 2]

    # rollback walks back one complete generation at a time, down to the
    # never-deleted generation 0
    assert store.rollback()[0] == 1
    assert store.rollback()[0] == 0
    with pytest.raises(ArtifactError, match="no prior generation"):
        store.rollback()

    # retire keeps generation 0, the current one, and keep_last others
    store.commit(2)
    assert store.retire() == []         # keep_last=1 retains gen 1
    removed = store.retire(keep_last=0)
    assert removed == [1] and sorted(store.generations()) == [0, 2]
    # retired ids are never reused
    gid3, _ = store.stage()
    assert gid3 == 3

    # a malformed pointer is refused loudly, not silently ignored
    with open(os.path.join(root, "CURRENT"), "w") as f:
        f.write("not json{")
    with pytest.raises(ArtifactError, match="malformed generation pointer"):
        GenerationStore.resolve(root)
    # a pointer naming a missing generation is refused too
    with open(os.path.join(root, "CURRENT"), "w") as f:
        json.dump({"generation": 99, "path": "generations/gen-0099"}, f)
    with pytest.raises(ArtifactError, match="no catalog manifest"):
        GenerationStore.resolve(root)


def test_generation_store_crash_at_commit_is_atomic(tmp_path):
    """A kill immediately before the pointer flip (the only commit
    point) leaves the old generation current; retrying the commit
    afterwards completes the swap."""
    root = str(tmp_path / "cat")
    _fake_gen(root)
    inj = FaultInjector(specs=[crash_at("swap_commit")])
    store = GenerationStore(root, faults=inj)
    gid, staged = store.stage()
    _fake_gen(staged)
    with pytest.raises(InjectedFault):
        store.commit(gid)
    assert GenerationStore.read_pointer(root) is None
    assert store.current[0] == 0        # old generation fully current
    # the crash fired once; the retried commit goes through
    store.commit(gid)
    assert store.current[0] == gid


# -- MeasurementLog edge cases behind recalibration -------------------------


def test_recalibrated_oracle_empty_and_single_entry_logs():
    """An artifact whose bundled replay log records no kernel
    measurements cannot be rescaled (clear error, not a zero-division);
    a single-entry log warns and returns the original oracle unscaled."""
    art = DeploymentArtifact(
        cfg=None, params={}, sites=[], target=None,
        oracle=ReplayOracle(MeasurementLog()), workload=None,
        seq_len=0, table=None, metadata={})
    with pytest.raises(ArtifactError, match="no kernel"):
        art.recalibrated_oracle(1e-3)

    log = MeasurementLog()
    log.record("gemm:1:1:1:1:2:8:8:8", 1e-3)
    art2 = dataclasses.replace(art, oracle=ReplayOracle(log))
    with pytest.warns(RuntimeWarning, match="single kernel measurement"):
        out = art2.recalibrated_oracle(1e-3)
    assert out is art2.oracle


# -- drain + drift signals at the fleet/router layer ------------------------


def test_fleet_drain_sheds_new_work_and_finishes_admitted(fleet_plan):
    path, cfg, _ = fleet_plan
    cat = ArtifactCatalog.load(path)
    fast, _ = _entries(cat)
    sup = ReplicaSupervisor.from_artifact(
        lambda: cat.artifact(fast.name), name=fast.name,
        engine_kwargs=dict(max_batch=2, max_seq=24))
    rng = np.random.default_rng(0)
    r0 = _req(rng, cfg, 0)
    sup.submit(r0)
    sup.drain()
    assert sup.draining and not sup.idle
    with pytest.raises(RouteError, match="draining"):
        sup.submit(_req(rng, cfg, 1))
    st = sup.run()
    assert r0.done and not r0.failed
    assert sup.idle
    assert st["draining"] and st["shed"] == 1
    assert st["accounting"]["submitted"] == 1 and st["requests"] == 1


def test_router_stats_expose_drift_signals(fleet_plan, tmp_path):
    path, cfg, _ = fleet_plan
    cat = ArtifactCatalog.load(_clone(path, tmp_path))
    fast, accurate = _entries(cat)
    router = Router(cat)
    rng = np.random.default_rng(0)
    loose = 60.0                        # wall-clock loose, always met
    router.submit(_req(rng, cfg, 0, latency_budget_s=loose))
    router.submit(_req(rng, cfg, 1))
    st = router.run()
    assert st["generation"] == 0 and st["swaps"] == 0
    assert st["submitted"] == 2 and st["requests"] == 2
    per = st["per_artifact"][accurate.name]
    # the autopilot's inputs: predicted-vs-measured drift and the
    # per-entry budget-violation record, straight from stats()
    assert per["measurement_window"] > 0
    assert isinstance(per["oracle_rel_error"], float)
    assert per["budgeted_requests"] == 1
    assert per["budget_violations"] == 0
    assert per["budget_violation_rate"] == 0.0
    assert per["draining"] is False


# -- hot swap: zero loss, bit-identical drain -------------------------------


def test_swap_drains_in_flight_bit_identical(fleet_plan, tmp_path):
    """A request admitted before the swap completes on the old
    generation with the exact output it would have produced without the
    swap; a request submitted after routes on the new generation; the
    accounting stays zero-loss across the swap."""
    path, cfg, _ = fleet_plan
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)

    ref_router = Router(ArtifactCatalog.load(_clone(path, tmp_path, "ref")))
    ref = Request(rid=0, prompt=prompt.copy(), max_new_tokens=4)
    ref_router.submit(ref)
    ref_router.run()
    assert ref.done

    root = _clone(path, tmp_path, "live")
    router = Router(ArtifactCatalog.load(root))
    r_old = Request(rid=0, prompt=prompt.copy(), max_new_tokens=4)
    router.submit(r_old)
    for _ in range(3):                  # prefill + partial decode
        router.step()
    assert not r_old.done

    store = GenerationStore(root)
    gid, _ = _stage_copy(store)
    store.commit(gid)
    cat1 = ArtifactCatalog.load(root, lazy=True)
    assert cat1.generation == gid == 1
    info = router.swap(cat1)
    assert info["generation"] == 1
    assert r_old.routed_to in info["draining"]

    r_new = Request(rid=1, prompt=prompt.copy(), max_new_tokens=4)
    router.submit(r_new)
    st = router.run()
    assert r_old.done and r_old.output == ref.output    # bit-identical
    assert r_old.retries == 0           # never re-routed or re-prefilled
    assert r_new.done
    assert st["submitted"] == 2 and st["requests"] == 2
    assert st["failed"] == 0 and st["shed"] == 0 and st["rejected"] == 0
    assert st["generation"] == 1 and st["swaps"] == 1
    assert st["retired_fleets"] >= 1 and st["retiring"] == []


# -- the autopilot loop -----------------------------------------------------


def _autopilot_cfg(**over):
    base = dict(check_every=4, rel_error_threshold=1.0,
                violation_threshold=0.5, min_window=2, min_budgeted=1,
                probation_steps=25, cooldown_steps=50, max_swaps=1)
    base.update(over)
    return AutopilotConfig(**base)


def test_autopilot_contains_replan_failure(fleet_plan, tmp_path):
    """A replan that blows up must never take serving down: the trigger
    is recorded as a skip, the old generation keeps serving."""
    path, cfg, _ = fleet_plan
    root = _clone(path, tmp_path)
    cat = ArtifactCatalog.load(root)
    _, accurate = _entries(cat)
    router = Router(cat)

    def exploding_replan(trigger, oracle):
        raise ValueError("planner exploded")

    # check_every=0: sweeps only when the test calls them, so the
    # trigger below is the only one
    ap = Autopilot(router, replan=exploding_replan,
                   config=_autopilot_cfg(check_every=0))
    rng = np.random.default_rng(0)
    router.submit(_req(rng, cfg, 0))    # builds the accurate fleet
    ap.run(deadline_s=120)
    # fake a drifted observation window: measured 10x the prediction
    art = cat.artifact(accurate.name)
    key = MeasurementLog.step_key(art.measurement_tag, 2, 24)
    for _ in range(2):
        ap.log.record(key, accurate.predicted_step_s * 10)
    trigger = ap.sweep()
    assert trigger is not None and trigger["name"] == accurate.name
    st = ap.stats()
    assert st["skips"].get("replan") == 1
    assert st["swaps"] == 0 and st["generation"] == 0
    assert st["replans"] == 1
    # serving is unharmed
    r = _req(rng, cfg, 1)
    router.submit(r)
    router.run()
    assert r.done


def test_autopilot_probation_rollback_restores_prior_generation(
        fleet_plan, tmp_path):
    """The judge half of the loop: a new generation whose budget
    violations are strictly worse than pre-swap fails probation and is
    rolled back — pointer, router, and serving all return to the prior
    generation."""
    path, cfg, pl = fleet_plan
    root = _clone(path, tmp_path)
    inj = FaultInjector()
    cat = ArtifactCatalog.load(root)
    fast, accurate = _entries(cat)
    router = Router(cat, faults=inj)
    ap = Autopilot(router, replan=pl, faults=inj,
                   config=_autopilot_cfg(cooldown_steps=10))

    # install generation 1 by hand and put it on probation against a
    # clean pre-swap record
    gid, _ = _stage_copy(ap.store)
    ap.store.commit(gid)
    cat1 = ArtifactCatalog.load(root, lazy=True)
    router.swap(cat1)
    ap._probation = {"until": ap._steps + 30,
                     "pre": {"budgeted": 1, "violations": 0, "rate": 0.0},
                     "generation": cat1.generation, "trigger": "manual"}

    # generation 1 violates its budgets: every decode tick is delayed
    pred_f = fast.predicted_step_s
    pred_a = accurate.predicted_step_s
    delay = max(0.05, 4 * pred_a)
    inj.specs.append(delay_at("decode", delay, *range(4000)))
    r = _req(np.random.default_rng(0), cfg, 0,
             latency_budget_s=pred_f * 4 * 1.2)
    router.submit(r)
    for _ in range(400):
        ap.step()
        if ap.stats()["probation"] is None and not router.has_work:
            break
    assert r.done
    assert r.t_done - r.t_submit > r.latency_budget_s   # it did violate
    st = ap.stats()
    assert st["rollbacks"] == 1
    assert st["generation"] == 0 and router.generation == 0
    assert ap.store.current[0] == 0
    assert st["cooldown_until"] > st["steps"]           # backed off hard
    # the rolled-back fleet still serves
    r2 = _req(np.random.default_rng(1), cfg, 1)
    router.submit(r2)
    router.run()
    assert r2.done
    rst = router.stats()
    assert rst["submitted"] == 2 and rst["requests"] == 2
    assert rst["failed"] == 0


def test_autopilot_crash_mid_swap_leaves_loadable_catalog(
        fleet_plan, tmp_path):
    """The chaos half of the acceptance test: a kill injected at the
    commit point of a real (exported) staged generation leaves the old
    generation loadable and validated; the retried commit completes."""
    path, _, _ = fleet_plan
    root = _clone(path, tmp_path)
    inj = FaultInjector(specs=[crash_at("swap_commit")])
    store = GenerationStore(root, faults=inj)
    gid, _ = _stage_copy(store)
    with pytest.raises(InjectedFault):
        store.commit(gid)
    # the kill left the old generation fully current — eager load
    # validates every member artifact
    cat = ArtifactCatalog.load(root)
    assert cat.generation == 0 and len(cat) == 2
    # recovery: the same staged generation commits cleanly afterwards
    store.commit(gid)
    cat1 = ArtifactCatalog.load(root)
    assert cat1.generation == gid and len(cat1) == 2


def test_autopilot_end_to_end_drift_replan_hot_swap(fleet_plan, tmp_path):
    """The acceptance test: inject a decode-step drift on the accurate
    entry, let the autopilot run the whole loop autonomously —
    detect → recalibrate → background replan → export generation →
    atomic commit → hot-swap — with zero dropped requests, and verify
    the post-swap budget-violation rate is strictly lower."""
    path, cfg, pl = fleet_plan
    root = _clone(path, tmp_path)
    cat = ArtifactCatalog.load(root)
    fast, accurate = _entries(cat)

    # the accurate entry's decode step drifts to >= 5x its prediction
    delay = max(0.08, 5 * accurate.predicted_step_s)
    inj = FaultInjector(specs=[
        delay_at(f"decode:{accurate.name}#r0", delay, *range(4000))])
    router = Router(cat, faults=inj)
    # min_budgeted=999: the violation-rate signal cannot fire with only
    # 4 budgeted requests, so the trigger must be the windowed
    # predicted-vs-measured oracle drift
    ap = Autopilot(router, replan=pl, faults=inj, background=True,
                   config=_autopilot_cfg(min_budgeted=999))

    # phase 1: budgets the (pre-drift) oracle says the accurate entry
    # satisfies easily — the drift makes every one of them violate
    rng = np.random.default_rng(0)
    b1 = delay
    assert accurate.predicted_step_s * 4 < b1   # routable pre-drift
    phase1 = [_req(rng, cfg, i, latency_budget_s=b1) for i in range(4)]
    for r in phase1:
        assert router.submit(r) == accurate.name
    ap.run(deadline_s=600)

    st = ap.stats()
    assert st["replans"] >= 1 and st["swaps"] == 1, st["events"]
    assert st["rollbacks"] == 0
    assert st["last_trigger"]["name"] == accurate.name
    assert any("oracle_rel_error" in why
               for why in st["last_trigger"]["reasons"])
    assert router.generation == 1
    # zero loss: every pre-swap request completed on the old generation
    assert all(r.done and not r.failed for r in phase1)
    assert all(r.routed_to == accurate.name for r in phase1)
    pre_rate = sum(r.t_done - r.t_submit > b1 for r in phase1) / len(phase1)
    assert pre_rate == 1.0

    # the swap is durable: an eager reload from disk validates the new
    # generation, whose accurate entry absorbed the observed drift
    cat1 = ArtifactCatalog.load(root)
    assert cat1.generation == 1 and len(cat1) == 2
    new_fast, new_acc = _entries(cat1)
    assert new_acc.predicted_step_s > accurate.predicted_step_s

    # phase 2: budgets in the *new* catalog's language — the recalibrated
    # predictions route them to the fast entry, which actually meets them
    est_f = new_fast.predicted_step_s * 4
    est_a = new_acc.predicted_step_s * 4
    assert est_f < est_a
    b2 = (est_f + est_a) / 2
    warm = [_req(rng, cfg, 10 + i, latency_budget_s=b2) for i in range(2)]
    for r in warm:                      # compile the new engines
        assert router.submit(r) == new_fast.name
    ap.run(deadline_s=600)
    phase2 = [_req(rng, cfg, 20 + i, latency_budget_s=b2) for i in range(2)]
    for r in phase2:
        assert router.submit(r) == new_fast.name
    ap.run(deadline_s=600)

    assert all(r.done and not r.failed for r in phase2)
    post_rate = sum(r.t_done - r.t_submit > b2 for r in phase2) / len(phase2)
    assert post_rate < pre_rate

    # zero loss across the whole run, swap included
    rst = router.stats()
    assert rst["submitted"] == 8 and rst["requests"] == 8
    assert rst["failed"] == 0 and rst["shed"] == 0 and rst["rejected"] == 0
    assert rst["swaps"] == 1 and rst["retired_fleets"] >= 1

    # probation resolves in the new generation's favor (its violation
    # rate cannot exceed the pre-swap 1.0)
    for _ in range(200):
        if ap.stats()["probation"] is None:
            break
        ap.step()
    st = ap.stats()
    assert st["probation"] is None and st["rollbacks"] == 0
    assert st["generation"] == 1

"""Baseline pruners (Table 1 rows): valid models out, expected behaviours."""
import jax
import numpy as np
import pytest

from conftest import make_batch
from repro.configs import get_reduced_config
from repro.core import CPruneConfig, TrainHooks, Workload, baselines
from repro.models.model import Model, init_params, prune_sites


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced_config("qwen3_1_7b").with_overrides(
        d_model=128, d_ff=1024, n_heads=8, n_kv_heads=2, head_dim=16,
        n_layers=4)
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    sites = prune_sites(cfg)
    batch = make_batch(cfg)
    jloss = jax.jit(model.loss_fn)
    hooks = TrainHooks(
        short_term_train=lambda p, s: p,
        eval_acc=lambda p, s: float(jloss(p, batch)[1]["acc"]) + 0.5)
    pcfg = CPruneConfig(a_g=0.0, seq_len=64)
    wl = Workload(tokens_global=65536)
    return cfg, model, params, sites, hooks, pcfg, wl, batch, jloss


def test_uniform_l1_prunes_by_ratio(setup):
    cfg, model, params, sites, hooks, pcfg, wl, batch, jloss = setup
    res = baselines.uniform_prune(cfg, params, sites, wl, hooks, pcfg,
                                  ratio=0.5, method="l1")
    ffn = next(s for s in res.sites if s.kind == "ffn")
    assert ffn.dim == 512
    assert np.isfinite(float(jloss(res.params, batch)[0]))


def test_fpgm_ranking_differs_from_l1(setup):
    cfg, model, params, sites, hooks, pcfg, wl, batch, jloss = setup
    from repro.core.ranking import rank_units
    site = next(s for s in sites if s.kind == "ffn")
    l1 = rank_units(params, site, "l1")
    fpgm = rank_units(params, site, "fpgm")
    assert l1.shape == fpgm.shape
    # different criteria -> different orderings (with random init weights)
    assert not np.array_equal(np.argsort(l1[0]), np.argsort(fpgm[0]))


def test_netadapt_reduces_latency_and_counts_evals(setup):
    cfg, model, params, sites, hooks, pcfg, wl, batch, jloss = setup
    from repro.core import tuner
    from repro.core.latency import model_latency
    table0 = tuner.build_tuned_table(sites, wl)
    lat0 = model_latency(cfg, sites, table0, seq_len=pcfg.seq_len).total_s
    res = baselines.netadapt_prune(cfg, params, sites, wl, hooks, pcfg,
                                   latency_decay=0.95, max_iterations=3)
    assert res.latency.total_s < lat0
    assert res.candidates_evaluated > 0
    assert np.isfinite(float(jloss(res.params, batch)[0]))

"""Data pipeline: determinism, shard disjointness, elastic re-sharding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.data.pipeline import DataPipeline


def _pipe(n_shards=1, shard_id=0, arch="qwen3_1_7b"):
    cfg = get_reduced_config(arch)
    return DataPipeline(cfg, global_batch=16, seq_len=32,
                        n_shards=n_shards, shard_id=shard_id, seed=3)


def test_restart_determinism():
    p1, p2 = _pipe(), _pipe()
    for step in (0, 7, 1234):
        b1, b2 = p1.batch(step), p2.batch(step)
        np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                      np.asarray(b2["tokens"]))


def test_steps_differ():
    p = _pipe()
    assert not np.array_equal(np.asarray(p.batch(0)["tokens"]),
                              np.asarray(p.batch(1)["tokens"]))


def test_shards_are_disjoint_slices_of_global_batch():
    g = _pipe(1, 0).batch(5)["tokens"]
    shards = [np.asarray(_pipe(4, i).batch(5)["tokens"]) for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(shards), np.asarray(g))


def test_elastic_reshard_preserves_global_stream():
    """4 shards -> 2 shards: the union of shard batches is unchanged."""
    four = [np.asarray(_pipe(4, i).batch(9)["tokens"]) for i in range(4)]
    two = [np.asarray(_pipe(4, 0).reshard(2, i).batch(9)["tokens"])
           for i in range(2)]
    np.testing.assert_array_equal(np.concatenate(four), np.concatenate(two))


def test_markov_task_is_learnable_structure():
    """The next token follows perm[token] 90% of the time."""
    p = _pipe()
    toks = np.asarray(p.global_batch_at(0)["tokens"])
    vocab = p.cfg.vocab_size
    perm = np.asarray(jax.random.permutation(jax.random.PRNGKey(1234), vocab))
    follows = (perm[toks[:, :-1]] == toks[:, 1:]).mean()
    assert follows > 0.8


@pytest.mark.parametrize("arch", ["hubert_xlarge", "qwen2_vl_2b"])
def test_frontend_batches(arch):
    cfg = get_reduced_config(arch)
    p = DataPipeline(cfg, global_batch=4, seq_len=32)
    b = p.batch(0)
    if arch == "hubert_xlarge":
        assert b["frames"].shape == (4, 32, cfg.d_model)
        assert b["labels"].shape == (4, 32)
        assert b["mask"].dtype == jnp.bool_
    else:
        assert "patch_embeds" in b

"""Tensor-parallel sharded serving (PR 10): partition-stamped artifacts,
the mesh-sharded engine, TP-honest oracle pricing, and the replica fleet
balancer.

Acceptance contract: tp=2 sharded greedy decode (contiguous AND paged
KV) is bit-identical to tp=1 on the granite reduced config under a
4-host-device mesh; tp=1 artifacts stay byte-identical to the pre-PR
schema (no ``partition`` key, schema v1); loading a tp=2 artifact on a
1-device host fails with an error naming both device counts; the
planner's ``tp=[1,2]`` sweep prices per-shard GEMMs plus an analytic
all-reduce term; and the fleet balancer dispatches by outstanding-token
count and re-queues a crashed replica's in-flight work onto survivors.

Mesh-requiring tests spawn subprocesses with forced host devices —
conftest must NOT set XLA_FLAGS globally.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.api import (ArtifactError, CPruneConfig, DeploymentArtifact,
                       PruningSession, TrainHooks, Workload, plan)
from repro.configs import all_configs, get_config, get_reduced_config
from repro.core import clear_tuning_caches
from repro.core.cost_model import (CALL_OVERHEAD_S, ICI_BW, collective_cost)
from repro.core.latency import fixed_latency
from repro.core.oracle import AnalyticOracle, MeasuredOracle
from repro.core.tasks import Workload as CoreWorkload
from repro.launch.mesh import (MeshError, make_production_mesh,
                               make_test_mesh, required_devices)
from repro.models.model import init_params
from repro.serve.distributed import validate_mesh
from repro.serve.engine import Request, ServeEngine
from repro.serve.fleet import (ReplicaSet, ReplicaSupervisor, RetryPolicy,
                               outstanding_tokens)
from repro.sharding import rules
from repro.util.faults import FaultInjector, crash_at

REPO = Path(__file__).resolve().parents[1]
ENV = {**os.environ, "PYTHONPATH": str(REPO / "src")}


def _run(code: str, devices: int = 4, timeout: int = 600):
    env = {**ENV, "XLA_FLAGS":
           f"--xla_force_host_platform_device_count={devices}"}
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)


@pytest.fixture(autouse=True)
def _cold_caches():
    clear_tuning_caches()
    yield
    clear_tuning_caches()


def _cfg():
    return get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=2, d_model=64, d_ff=512, n_heads=8, n_kv_heads=2,
        head_dim=8, vocab_size=128)


def _hooks(acc=0.9):
    return TrainHooks(short_term_train=lambda p, s: p,
                      eval_acc=lambda p, s: acc)


def _session(cfg, **kw):
    kw.setdefault("workload", Workload(tokens_global=8192))
    kw.setdefault("hooks", _hooks())
    kw.setdefault("pcfg", CPruneConfig(a_g=0.5, alpha=0.5, beta=0.9999,
                                       max_iterations=2, seq_len=64))
    return PruningSession(cfg, **kw)


def _req(rng, cfg, rid, n_new=4, **kw):
    return Request(rid=rid, prompt=rng.integers(
        0, cfg.vocab_size, size=8).astype(np.int32),
        max_new_tokens=n_new, **kw)


# ---------------------------------------------------------------------------
# Collective cost model + TP-honest fixed latency
# ---------------------------------------------------------------------------

def test_collective_cost_ring_formula_and_degenerate_cases():
    n = 1 << 20
    # ring all-reduce moves 2(tp-1)/tp * n bytes over the ICI
    want = 2 * (4 - 1) * n / 4 / ICI_BW + CALL_OVERHEAD_S
    assert collective_cost(n, 4) == pytest.approx(want)
    ag = collective_cost(n, 4, op="all_gather")
    rs = collective_cost(n, 4, op="reduce_scatter")
    assert ag == rs == pytest.approx((4 - 1) * n / 4 / ICI_BW
                                     + CALL_OVERHEAD_S)
    assert ag < collective_cost(n, 4)           # half the wire bytes
    # tp=1 and empty payloads cost exactly zero — never an overhead floor
    assert collective_cost(n, 1) == 0.0
    assert collective_cost(0, 8) == 0.0
    with pytest.raises(ValueError, match="unknown collective op"):
        collective_cost(n, 2, op="broadcast")


def test_every_oracle_backend_prices_collectives():
    n = 1 << 16
    want = AnalyticOracle().collective_cost(n, 2)
    assert want == collective_cost(n, 2)
    # measurement-backed oracles delegate the (unmeasurable-on-host)
    # collective term to the analytic model
    assert MeasuredOracle().collective_cost(n, 2) == want
    # fingerprints unchanged: the analytic backend stays ("analytic",)
    assert AnalyticOracle().fingerprint() == ("analytic",)


def test_fixed_latency_adds_collective_term_only_above_tp1():
    cfg = _cfg()
    wl1 = CoreWorkload(tokens_global=4096, tp=1)
    wl2 = CoreWorkload(tokens_global=4096, tp=2)
    t1, bd1 = fixed_latency(cfg, [], wl1, seq_len=64, use_tuning=False)
    t2, bd2 = fixed_latency(cfg, [], wl2, seq_len=64, use_tuning=False)
    assert "collective" not in bd1              # tp=1 prices stay untouched
    assert bd2["collective"] > 0.0
    # 2 all-reduces per layer + 1 logits all-gather, analytically priced
    m = wl2.tokens_local
    want = 2 * cfg.n_layers * collective_cost(
        m * cfg.d_model * wl2.dtype_bytes, 2)
    want += collective_cost(m * (cfg.vocab_size // 2) * wl2.dtype_bytes, 2,
                            op="all_gather")
    assert bd2["collective"] == pytest.approx(want)
    # per-shard GEMMs shrink, the collective term pushes back — both real
    assert t2 != t1


# ---------------------------------------------------------------------------
# Mesh construction errors (satellite: no silent truncation)
# ---------------------------------------------------------------------------

def test_make_test_mesh_errors_name_shape_and_device_shortfall():
    with pytest.raises(MeshError, match=r"model axis 3 does not divide"):
        make_test_mesh(n_devices=4, model=3)
    # this pytest process runs on exactly one CPU device
    with pytest.raises(MeshError, match=r"needs 4 devices \(2x2.*but only 1"):
        make_test_mesh(n_devices=4, model=2)
    err = None
    try:
        make_test_mesh(n_devices=4, model=2)
    except MeshError as e:
        err = str(e)
    assert "--xla_force_host_platform_device_count=4" in err


def test_make_production_mesh_refuses_undersized_host():
    with pytest.raises(MeshError, match=r"needs 256 devices.*16x16.*only 1"):
        make_production_mesh()
    with pytest.raises(MeshError, match=r"needs 512 devices"):
        make_production_mesh(multi_pod=True)
    assert required_devices(False) == 256 and required_devices(True) == 512


def test_validate_mesh_names_axes_and_tp_mismatch():
    with pytest.raises(MeshError, match=r"must carry a 'model' axis"):
        validate_mesh(rules.SpecMesh({"data": 4}))
    with pytest.raises(MeshError,
                       match=r"tp=4 model shards but the mesh's model axis "
                             r"is 2"):
        validate_mesh(rules.SpecMesh({"data": 1, "model": 2}), tp=4,
                      what="artifact 'x'")
    assert validate_mesh(rules.SpecMesh({"data": 2, "model": 2}), tp=2) == 2


# ---------------------------------------------------------------------------
# Sharding-rule coverage over every shipped config (satellite)
# ---------------------------------------------------------------------------

# leaves the rule table deliberately leaves replicated at tp=2: MQA KV
# projections (1 KV head), MoE routers (hidden dim over data only), odd
# vocab embeddings, RWKV token-mix bottlenecks
_KNOWN_REPLICATED = {"wk", "wv", "router", "embed", "lm_head", "tm_w1"}


def _model_sharded(spec) -> bool:
    for ax in tuple(spec):
        axes = ax if isinstance(ax, (tuple, list)) else (ax,)
        if "model" in axes:
            return True
    return False


@pytest.mark.parametrize("name", all_configs())
def test_rules_shard_every_shipped_config(name):
    """No silent fallthrough to replicated: at tp=2 the rule table must
    shard >= 95% of each shipped config's parameter bytes over the model
    axis, and any large replicated leaf must be a *known* irregular
    (documented above), not a name the table simply missed."""
    from repro.analysis.jaxpr_audit import audit_param_sharding, param_avals
    cfg = get_config(name)
    avals = param_avals(cfg)
    mesh = rules.SpecMesh({"data": 1, "model": 2})
    specs = rules.param_pspecs(avals, mesh)
    tot = sharded = 0

    def walk(a, s):
        nonlocal tot, sharded
        for k in a:
            if isinstance(a[k], dict):
                walk(a[k], s[k])
                continue
            nb = int(np.prod(a[k].shape)) * np.dtype(a[k].dtype).itemsize
            tot += nb
            if _model_sharded(s[k]):
                sharded += nb

    walk(avals, specs)
    assert sharded > 0, f"{name}: nothing model-sharded at tp=2"
    assert sharded / tot >= 0.95, \
        f"{name}: only {sharded / tot:.1%} of param bytes model-sharded"
    for d in audit_param_sharding(cfg, tp=2, min_mib=8.0):
        leaf = d.site.rsplit("/", 1)[-1]
        assert leaf in _KNOWN_REPLICATED, \
            f"{name}: large replicated leaf {d.site} not a known irregular"


# ---------------------------------------------------------------------------
# Artifact partition stamping + load-time validation
# ---------------------------------------------------------------------------

def test_tp1_export_stays_byte_identical_to_v1_schema(tmp_path):
    cfg = _cfg()
    art = _session(cfg).export(str(tmp_path / "a"), max_batch=2, max_seq=24)
    blob = json.loads((tmp_path / "a" / "artifact.json").read_text())
    assert blob["schema_version"] == 1
    assert "partition" not in blob              # tp=1 writes nothing new
    assert art.partition is None and art.tp == 1
    # and it round-trips + serves exactly as before
    eng = ServeEngine.from_artifact(str(tmp_path / "a"), max_batch=2,
                                    max_seq=24)
    assert type(eng) is ServeEngine


def test_tp2_export_stamps_partition_and_load_checks_devices(tmp_path):
    cfg = _cfg()
    session = _session(cfg)
    art = session.export(str(tmp_path / "a"), max_batch=2, max_seq=24, tp=2)
    assert art.tp == 2 and art.workload.tp == 2
    blob = json.loads((tmp_path / "a" / "artifact.json").read_text())
    part = blob["partition"]
    assert part["tp"] == 2
    assert part["mesh_axes"] == {"data": 1, "model": 2}
    # the layout derives from the rule table: q-projections shard heads
    assert any("model" in str(spec) for name, spec in part["params"].items()
               if name.endswith("wq"))
    # the tp=2 decode-step prediction prices per-shard GEMMs + collectives
    # and differs from the tp=1 price of the same artifact
    p2 = art.predict_step_s(2, 24)
    p1 = art.predict_step_s(2, 24, tp=1)
    assert p2 is not None and p1 is not None and p2 != p1
    # this pytest host has ONE device: loading must refuse, naming both
    with pytest.raises(ArtifactError, match=r"tp=2.*but only 1"):
        DeploymentArtifact.load(str(tmp_path / "a"))


def test_load_rejects_tampered_partition_stamp(tmp_path):
    cfg = _cfg()
    _session(cfg).export(str(tmp_path / "a"), max_batch=2, max_seq=24, tp=2)
    fn = tmp_path / "a" / "artifact.json"
    blob = json.loads(fn.read_text())
    blob["partition"]["tp"] = 1                 # disagree with workload.tp
    fn.write_text(json.dumps(blob))
    with pytest.raises(ArtifactError):
        DeploymentArtifact.load(str(tmp_path / "a"))


def test_export_tp_must_be_positive(tmp_path):
    with pytest.raises(ArtifactError, match="tp"):
        _session(_cfg()).export(str(tmp_path / "a"), tp=0)


# ---------------------------------------------------------------------------
# Planner: sharding competes with pruning on the frontier
# ---------------------------------------------------------------------------

def test_plan_tp_sweep_produces_tp_suffixed_arms(tmp_path):
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    pl = plan(cfg, accuracy_floor=0.0, targets=["tpu_v5e"],
              strategies=["uniform_l1"],
              workload=Workload(tokens_global=8192), hooks=_hooks(),
              params=params, pcfg=CPruneConfig(a_g=0.0, seq_len=64),
              strategy_kwargs={"uniform_l1": {"ratio": 0.5}},
              tp=[1, 2])
    tps = sorted(c.tp for c in pl.candidates)
    assert tps == [1, 2]
    names = {c.name for c in pl.candidates}
    assert any(n.endswith("@tp2") for n in names)
    assert all("@tp1" not in n for n in names)  # tp=1 names unchanged
    by_tp = {c.tp: c for c in pl.candidates}
    assert by_tp[2].latency_s != by_tp[1].latency_s
    # the catalog records each arm's degree (old manifests default tp=1)
    cat_dir = tmp_path / "cat"
    # export the full candidate list, not the frontier: at toy size the
    # collective term outweighs the per-shard GEMM savings, so the tp=2
    # arm is (correctly) dominated and would be skipped
    pl.export_catalog(str(cat_dir), list(pl.candidates),
                      max_batch=2, max_seq=24)
    man = json.loads((cat_dir / "catalog.json").read_text())
    assert sorted(e["tp"] for e in man["entries"]) == [1, 2]
    with pytest.raises(ValueError, match="tp degrees must be >= 1"):
        plan(cfg, accuracy_floor=0.0, targets=["tpu_v5e"],
             strategies=["uniform_l1"], hooks=_hooks(), params=params,
             tp=[0])


# ---------------------------------------------------------------------------
# Fleet balancer: outstanding-token dispatch, histogram, survivor re-queue
# ---------------------------------------------------------------------------

def test_replica_set_is_the_supervisor():
    assert ReplicaSet is ReplicaSupervisor


def test_balancer_dispatches_by_outstanding_tokens(setup=None):
    """One long request loads replica 0 with 12 outstanding tokens; the
    following short ones must all pile onto replica 1 (token-debt
    balancing), where request-count balancing would have split 2/2."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    sup = ReplicaSupervisor(
        lambda i: ServeEngine(cfg, params, max_batch=4, max_seq=24),
        name="tokens", replicas=2)
    rng = np.random.default_rng(0)
    sup.submit(_req(rng, cfg, 0, n_new=12))
    for i in range(1, 4):
        sup.submit(_req(rng, cfg, i, n_new=2))
    stats = sup.run()
    assert stats["dispatch_histogram"] == [1, 3]
    occ = stats["per_replica_occupancy"]
    assert [o["replica"] for o in occ] == [0, 1]
    assert [o["dispatched"] for o in occ] == [1, 3]
    assert all(o["outstanding_tokens"] == 0 for o in occ)   # drained
    assert stats["accounting"]["completed"] == 4
    for eng in sup.engines:
        assert outstanding_tokens(eng) == 0


def test_crashed_replica_requeues_onto_survivor():
    """Replica 0 crashes mid-decode with a long rebuild backoff: its
    in-flight requests must drain through the *surviving* replica 1 —
    counted in requeued_to_survivor — with zero lost requests."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    inj = FaultInjector(specs=[crash_at("decode", 0)])
    sup = ReplicaSupervisor(
        lambda i: ServeEngine(cfg, params, max_batch=4, max_seq=24,
                              faults=inj if i == 0 else None),
        name="survivor", replicas=2,
        retry=RetryPolicy(max_retries=2, backoff_s=60.0))
    rng = np.random.default_rng(1)
    reqs = [_req(rng, cfg, i, n_new=4) for i in range(4)]
    for r in reqs:
        sup.submit(r)
    stats = sup.run()
    assert stats["crashes"] == 1
    assert stats["requeued"] >= 1
    assert stats["requeued_to_survivor"] == stats["requeued"]
    assert stats["live_replicas"] == 1          # 0 still in backoff
    assert all(r.done for r in reqs)            # zero loss
    acc = stats["accounting"]
    assert acc["completed"] == 4 and acc["failed"] == 0
    hist = stats["dispatch_histogram"]
    assert sum(hist) == 4 + stats["requeued"]   # re-dispatches counted


# ---------------------------------------------------------------------------
# tp=2 bit-identity + artifact round trip (subprocesses, 4 host devices)
# ---------------------------------------------------------------------------

def test_tp2_sharded_decode_bit_identical_contiguous_and_paged():
    """The acceptance bar: greedy decode through ShardedServeEngine on a
    (2,2)/(1,2) mesh reproduces the tp=1 token stream exactly, for both
    KV layouts, on the granite (MoE) reduced config."""
    code = """
import jax, numpy as np
from repro.configs import get_reduced_config
from repro.launch.mesh import make_test_mesh
from repro.models.model import init_params
from repro.serve.distributed import ShardedServeEngine
from repro.serve.engine import Request, ServeEngine
from repro.serve.scheduler import SchedulerConfig

cfg = get_reduced_config("granite_moe_1b_a400m")
params = init_params(jax.random.PRNGKey(0), cfg)

def reqs():
    rng = np.random.default_rng(0)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 8 + i).astype(
                        np.int32),
                    max_new_tokens=6) for i in range(4)]

for layout in ("contiguous", "paged"):
    sched = SchedulerConfig(kv_layout=layout, page_size=8)
    ref = ServeEngine(cfg, params, max_batch=4, max_seq=32, scheduler=sched)
    rr = reqs()
    for r in rr: ref.submit(r)
    ref.run()
    assert all(r.done for r in rr)

    mesh = make_test_mesh(n_devices=4, model=2)
    eng = ShardedServeEngine(cfg, params, mesh=mesh, max_batch=4,
                             max_seq=32, scheduler=sched)
    assert eng.tp == 2
    ss = reqs()
    for r in ss: eng.submit(r)
    stats = eng.run()
    assert stats["tp"] == 2 and stats["mesh"] == {"data": 2, "model": 2}
    got = {r.rid: r.output for r in ss}
    want = {r.rid: r.output for r in rr}
    assert got == want, (layout, got, want)
    print("OK", layout)
"""
    r = _run(code)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK contiguous" in r.stdout and "OK paged" in r.stdout


def test_tp2_artifact_round_trip_serves_sharded():
    """export(tp=2) -> load (validated against 4 host devices) ->
    ServeEngine.from_artifact auto-dispatches to the sharded engine on
    the default (1,2) mesh and reproduces the tp=1 artifact's decode."""
    code = """
import tempfile
import jax, numpy as np
from repro.api import CPruneConfig, PruningSession, TrainHooks, Workload
from repro.api.artifact import DeploymentArtifact
from repro.configs import get_reduced_config
from repro.serve.distributed import ShardedServeEngine
from repro.serve.engine import Request, ServeEngine

cfg = get_reduced_config("qwen3_1_7b").with_overrides(
    n_layers=2, d_model=64, d_ff=512, n_heads=8, n_kv_heads=2,
    head_dim=8, vocab_size=128)
hooks = TrainHooks(short_term_train=lambda p, s: p,
                   eval_acc=lambda p, s: 0.9)
session = PruningSession(cfg, workload=Workload(tokens_global=8192),
                         hooks=hooks,
                         pcfg=CPruneConfig(a_g=0.5, seq_len=64))
root = tempfile.mkdtemp()
session.export(root + "/tp1", max_batch=2, max_seq=24)
session.export(root + "/tp2", max_batch=2, max_seq=24, tp=2)

def decode(eng):
    rng = np.random.default_rng(3)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 8).astype(
                np.int32), max_new_tokens=4) for i in range(2)]
    for r in reqs: eng.submit(r)
    eng.run()
    return {r.rid: r.output for r in reqs}

ref = decode(ServeEngine.from_artifact(root + "/tp1", max_batch=2,
                                       max_seq=24))
art = DeploymentArtifact.load(root + "/tp2")
assert art.tp == 2
eng = ServeEngine.from_artifact(art, max_batch=2, max_seq=24)
assert isinstance(eng, ShardedServeEngine)
assert eng.stats()["mesh"] == {"data": 1, "model": 2}
got = decode(eng)
assert got == ref, (got, ref)

# an explicit mesh whose model axis disagrees is refused by name
from repro.launch.mesh import MeshError, make_test_mesh
try:
    ServeEngine.from_artifact(art, mesh=make_test_mesh(n_devices=4, model=4))
except MeshError as e:
    assert "tp=2" in str(e) and "model axis is 4" in str(e), e
else:
    raise AssertionError("mesh mismatch accepted")
print("OK")
"""
    r = _run(code)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout

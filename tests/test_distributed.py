"""Distributed integration tests (subprocesses with forced host devices —
conftest must NOT set XLA_FLAGS globally, so these spawn fresh pythons)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
ENV = {**os.environ, "PYTHONPATH": str(REPO / "src")}


def _run(code: str, devices: int = 8, timeout: int = 600):
    env = {**ENV, "XLA_FLAGS":
           f"--xla_force_host_platform_device_count={devices}"}
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)


def test_sharded_train_step_runs_and_matches_single_device():
    """Loss on a 4x2 mesh == loss on 1 device (same batch, same init)."""
    code = """
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_reduced_config
from repro.data.pipeline import DataPipeline
from repro.launch import steps as S
from repro.models.model import Model, init_params
from repro.optim.optimizers import adamw_init
from repro.sharding import logical, rules

cfg = get_reduced_config("qwen3_1_7b").with_overrides(
    n_layers=2, d_model=64, d_ff=128, vocab_size=256)
pipe = DataPipeline(cfg, global_batch=8, seq_len=32)
batch = pipe.batch(0)
params = init_params(jax.random.PRNGKey(0), cfg)
opt = adamw_init(params)

# single device reference
m0 = Model(cfg)
loss0, _ = jax.jit(m0.loss_fn)(params, batch)

from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((4, 2), ("data", "model"), jax.devices()[:8])
model = S.build_model(cfg, mesh)
step = S.make_train_step(cfg, model)
with mesh, logical.set_rules(mesh, rules.logical_rules(mesh)):
    jitted = S.jit_train_step(step, mesh, jax.eval_shape(lambda: params),
                              jax.eval_shape(lambda: batch), donate=False)
    p2, o2, metrics = jitted(params, opt, batch)
diff = abs(float(metrics["loss"]) - float(loss0))
assert diff < 2e-3, (float(metrics["loss"]), float(loss0))
print("OK", diff)
"""
    r = _run(code)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_sharded_serve_step_matches_single_device():
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_reduced_config
from repro.launch import steps as S
from repro.models.model import Model, init_params
from repro.sharding import logical, rules

cfg = get_reduced_config("qwen3_1_7b").with_overrides(
    n_layers=2, d_model=64, vocab_size=256)
params = init_params(jax.random.PRNGKey(0), cfg)
model0 = Model(cfg)
B, S0 = 8, 16
toks = jax.random.randint(jax.random.PRNGKey(5), (B, S0), 0, cfg.vocab_size)
logits0, caches0 = jax.jit(lambda p, b: model0.prefill(p, b, 32))(
    params, {"tokens": toks})
tok = jnp.argmax(logits0[:, 0], -1).astype(jnp.int32)[:, None]
ref_logits, _ = jax.jit(model0.decode_step)(params, tok, caches0)

from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((4, 2), ("data", "model"), jax.devices()[:8])
model = S.build_model(cfg, mesh)
serve = S.make_serve_step(cfg, model)
with mesh, logical.set_rules(mesh, rules.logical_rules(mesh, seq_shard=False)):
    jitted = S.jit_serve_step(serve, mesh, cfg, model,
                              jax.eval_shape(lambda: params),
                              jax.eval_shape(lambda: caches0),
                              jax.eval_shape(lambda: tok), donate=False)
    logits, caches = jitted(params, tok, caches0)
np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                           rtol=2e-4, atol=2e-4)
print("OK")
"""
    r = _run(code)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_train_driver_with_checkpoint_resume(tmp_path):
    """launch.train runs, checkpoints, and resumes on a different mesh
    (elastic: 4x2 -> 2x2)."""
    ckpt = str(tmp_path / "ck")
    base = [sys.executable, "-m", "repro.launch.train", "--arch",
            "qwen3_1_7b", "--reduced", "--batch", "8", "--seq", "32",
            "--ckpt-dir", ckpt]
    env8 = {**ENV, "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}
    r1 = subprocess.run(base + ["--devices", "8", "--dp", "4", "--tp", "2",
                                "--steps", "10"],
                        env=env8, capture_output=True, text=True, timeout=600)
    assert r1.returncode == 0, r1.stderr[-2000:]
    env4 = {**ENV, "XLA_FLAGS": "--xla_force_host_platform_device_count=4"}
    r2 = subprocess.run(base + ["--devices", "4", "--dp", "2", "--tp", "2",
                                "--steps", "14", "--resume"],
                        env=env4, capture_output=True, text=True, timeout=600)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step 10" in r2.stdout


def test_dryrun_single_cell_smoke():
    """The dry-run entry point works end to end for one cheap cell."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "granite_moe_1b_a400m", "--shape", "decode_32k", "--mesh", "single"],
        env=ENV, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "[OK  ]" in r.stdout

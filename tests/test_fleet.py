"""Fault-tolerant serving fleet (ISSUE 6): supervised engines, serve-time
fault injection, deadline-aware retry/re-queue, graceful degradation.

Acceptance contract: under injected engine crashes (and one permanently
failing catalog member) every submitted request either completes or is
explicitly rejected — nothing is silently lost; re-queued requests
produce bit-identical greedy outputs to an uninterrupted run; a tampered
member is quarantined while the rest of the catalog keeps serving; and
overload sheds at admission instead of queueing past deadlines.
"""
import os
import shutil
import time

import jax
import numpy as np
import pytest

from repro.api import CPruneConfig, TrainHooks, Workload, plan
from repro.api.artifact import ArtifactError
from repro.configs import get_reduced_config
from repro.core import clear_tuning_caches
from repro.models.model import init_params
from repro.serve.engine import Request, ServeEngine
from repro.serve.fleet import ReplicaSupervisor, RetryPolicy, RouteError
from repro.serve.router import ArtifactCatalog, Router
from repro.util.faults import (FaultInjector, FaultSpec, InjectedFault,
                               crash_at, delay_at)


@pytest.fixture(autouse=True)
def _cold_caches():
    clear_tuning_caches()
    yield
    clear_tuning_caches()


def _cfg():
    return get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=2, d_model=64, d_ff=512, n_heads=8, n_kv_heads=2,
        head_dim=8, vocab_size=128)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _req(rng, cfg, rid, n_new=4, **kw):
    return Request(rid=rid, prompt=rng.integers(
        0, cfg.vocab_size, size=8).astype(np.int32),
        max_new_tokens=n_new, **kw)


def _count(p):
    return sum(int(np.prod(np.asarray(x).shape)) for x in jax.tree.leaves(p))


@pytest.fixture(scope="module")
def catalog_dir(tmp_path_factory):
    """One plan, two frontier artifacts (fast/less-accurate vs
    slow/accurate) — the chaos fixture for router-level containment."""
    clear_tuning_caches()
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    n0 = _count(params)
    hooks = TrainHooks(short_term_train=lambda p, s: p,
                       eval_acc=lambda p, s: _count(p) / n0)
    pl = plan(cfg, accuracy_floor=0.0, targets=["tpu_v5e"],
              strategies=["uniform_l1", "fpgm"],
              workload=Workload(tokens_global=8192), hooks=hooks,
              params=params, pcfg=CPruneConfig(a_g=0.0, seq_len=64),
              strategy_kwargs={"uniform_l1": {"ratio": 0.6},
                               "fpgm": {"ratio": 0.1}})
    path = tmp_path_factory.mktemp("chaos")
    cat = pl.export_catalog(str(path), max_batch=2, max_seq=24)
    assert len(cat) == 2
    clear_tuning_caches()
    return str(path), cfg


def _entries(cat):
    fast = min(cat, key=lambda e: e.predicted_step_s)
    accurate = max(cat, key=lambda e: e.accuracy)
    return fast, accurate


def _tamper(root, entry):
    """Flip the manifest's accuracy claim for one member — the artifact's
    own metadata then disagrees, which ArtifactCatalog refuses."""
    import json
    man = os.path.join(root, "catalog.json")
    with open(man) as f:
        blob = json.load(f)
    for d in blob["entries"]:
        if d["name"] == entry:
            d["accuracy"] = d["accuracy"] + 0.5
    with open(man, "w") as f:
        json.dump(blob, f)


# ---------------------------------------------------------------------------
# FaultInjector: named points, tags, occurrence indices, delays
# ---------------------------------------------------------------------------

def test_fault_spec_validates_kind_and_coerces_occurrences():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("decode", kind="explode")
    assert crash_at("decode").at == (0,)
    assert crash_at("decode", 3, 7).at == (3, 7)
    assert delay_at("decode", 0.01, 2).delay_s == 0.01
    assert FaultSpec("decode", at=(np.int64(1),)).at == (1,)


def test_injector_fires_points_by_occurrence_and_tag():
    inj = FaultInjector(specs=[
        crash_at("decode", 2),                  # global: 3rd decode anywhere
        crash_at("prefill:b#r1"),               # tagged: only replica b#r1
        delay_at("decode", 0.0, 0),             # delay on the very first
    ])
    # occurrence 0: delay fires (returns slept), no crash
    assert inj.fire("decode", tag="a#r0") == 0.0
    assert inj.count("decode") == 1 and inj.count("decode:a#r0") == 1
    inj.fire("decode", tag="a#r0")              # occurrence 1: clean
    with pytest.raises(InjectedFault, match="occurrence 2"):
        inj.fire("decode", tag="a#r0")          # occurrence 2: crash
    # counters advanced BEFORE delivery: the crash occurrence is counted
    assert inj.count("decode") == 3
    # tag-targeted spec ignores other tags, hits its own
    inj.fire("prefill", tag="a#r0")
    with pytest.raises(InjectedFault):
        inj.fire("prefill", tag="b#r1")
    assert ("decode", 0, "delay") in inj.fired_log
    assert ("decode", 2, "crash") in inj.fired_log
    assert ("prefill:b#r1", 0, "crash") in inj.fired_log
    # each scheduled occurrence fires at most once: replays are clean
    inj2 = FaultInjector(specs=[crash_at("decode", 0)])
    with pytest.raises(InjectedFault):
        inj2.fire("decode")
    inj2.fire("decode")                         # occurrence 1: clean


def test_injector_legacy_train_interface_unchanged():
    inj = FaultInjector(fail_at_steps=[3])
    inj.maybe_fail(2)
    with pytest.raises(RuntimeError, match="injected fault at step 3"):
        inj.maybe_fail(3)
    inj.maybe_fail(3)                           # fires once


# ---------------------------------------------------------------------------
# Engine-level injection points
# ---------------------------------------------------------------------------

def test_engine_prefill_crash_loses_no_requests(setup):
    """An admission-time crash (injected prefill OOM) must leave the
    popped cohort recoverable: everything is still in in_flight()."""
    cfg, params = setup
    rng = np.random.default_rng(0)
    reqs = [_req(rng, cfg, i) for i in range(2)]
    eng = ServeEngine(cfg, params, max_batch=2, max_seq=16,
                      faults=FaultInjector(specs=[crash_at("prefill")]))
    for r in reqs:
        eng.submit(r)
    with pytest.raises(InjectedFault):
        eng.step()
    assert {r.rid for r in eng.in_flight()} == {0, 1}   # nothing lost
    # the occurrence is consumed — the same engine drains cleanly
    while eng.has_work:
        eng.step()
    assert all(r.done and len(r.output) == 4 for r in reqs)


def test_engine_decode_delay_is_seen_by_straggler_monitor(setup):
    """A delay spec inflates the timed decode step — the attached
    StragglerMonitor (warmup skipped) must flag it."""
    from repro.util.faults import StragglerMonitor
    cfg, params = setup
    rng = np.random.default_rng(1)
    eng = ServeEngine(cfg, params, max_batch=2, max_seq=24,
                      faults=FaultInjector(specs=[delay_at("decode", 0.25,
                                                           10)]),
                      straggler=StragglerMonitor(factor=3.0, skip_first=2))
    eng.submit(_req(rng, cfg, 0, n_new=14))
    stats = eng.run()
    assert stats["straggler_steps"] >= 1
    assert eng.straggler.samples == 13 - 2      # warmup never recorded


# ---------------------------------------------------------------------------
# ReplicaSupervisor: crash recovery, bit-identity, retries, admission
# ---------------------------------------------------------------------------

def test_supervisor_crash_after_compaction_is_bit_identical(setup):
    """Kill the engine on a decode tick *after* SlotGroup pow2 compaction
    (4 rows -> 2) and assert the re-queued requests reproduce the exact
    fault-free greedy outputs through the rebuilt engine."""
    cfg, params = setup
    rng = np.random.default_rng(2)
    mixed = [2, 2, 6, 6]                # two retire together -> 4->2 compact

    def fresh_requests():
        r = np.random.default_rng(2)
        return [_req(r, cfg, i, n_new=n) for i, n in enumerate(mixed)]

    # fault-free reference
    ref_eng = ServeEngine(cfg, params, max_batch=4, max_seq=16)
    ref = fresh_requests()
    for r in ref:
        ref_eng.submit(r)
    ref_eng.run()
    assert all(r.done for r in ref)

    # supervised run: decode occurrence 2 is the first tick after the
    # compaction (occ 0 retires the short pair and compacts the group)
    inj = FaultInjector(specs=[crash_at("decode", 2)])
    sup = ReplicaSupervisor(
        lambda i: ServeEngine(cfg, params, max_batch=4, max_seq=16,
                              faults=inj),
        name="compact-crash", retry=RetryPolicy(max_retries=2))
    for r in fresh_requests():
        sup.submit(r)
    stats = sup.run()

    assert stats["crashes"] == 1 and stats["rebuilds"] == 1
    assert stats["requeued"] == 2               # the two survivors
    assert stats["retried_requests"] == 2
    assert stats["failed"] == 0 and not stats["dead"]
    acc = stats["accounting"]
    assert acc["submitted"] == 4
    assert acc["completed"] == 4 and acc["in_flight"] == 0
    got = {r.rid: r.output for r in sup.completed}
    want = {r.rid: r.output for r in ref}
    assert got == want                          # bit-identical greedy decode
    assert max(r.retries for r in sup.completed) == 1


def test_supervisor_exhausts_retry_budget_explicitly(setup):
    """A poisoned engine (every decode tick crashes) must end in an
    explicit failure with fail_reason='retries' — never a silent loss or
    an infinite rebuild loop."""
    cfg, params = setup
    rng = np.random.default_rng(3)
    inj = FaultInjector(specs=[crash_at("decode", *range(16))])
    sup = ReplicaSupervisor(
        lambda i: ServeEngine(cfg, params, max_batch=2, max_seq=16,
                              faults=inj),
        name="poisoned", retry=RetryPolicy(max_retries=1))
    req = _req(rng, cfg, 0)
    sup.submit(req)
    stats = sup.run()
    assert req.failed and req.fail_reason == "retries"
    assert not req.done and req in sup.failed
    assert stats["failed_by_reason"] == {"retries": 1}
    assert stats["crashes"] == 2                # initial + one retry
    acc = stats["accounting"]
    assert acc == {"submitted": 1, "completed": 0, "failed": 1,
                   "in_flight": 0}


def test_supervisor_admission_sheds_on_overload_and_deadline(setup):
    """Admission control is engine-free: a full queue or an infeasible
    budget sheds with RouteError before any engine is built."""
    cfg, params = setup
    rng = np.random.default_rng(4)

    def no_build(i):
        raise AssertionError("admission must not build engines")

    sup = ReplicaSupervisor(no_build, name="bounded", max_queue=2)
    sup.submit(_req(rng, cfg, 0))
    sup.submit(_req(rng, cfg, 1))
    with pytest.raises(RouteError, match="saturated"):
        sup.submit(_req(rng, cfg, 2))
    assert sup.shed == 1 and sup.submitted == 2

    priced = ReplicaSupervisor(no_build, name="priced", est_step_s=1.0)
    with pytest.raises(RouteError, match="cannot meet its deadline"):
        priced.submit(_req(rng, cfg, 0, n_new=4, latency_budget_s=2.0))
    # a feasible budget is admitted at its full value (t_submit is set
    # in the same clock snapshot as the deadline check)
    priced.submit(_req(rng, cfg, 1, n_new=4, latency_budget_s=10.0))
    # a re-routed request keeps its original submit time — once the
    # elapsed wall clock eats the margin, re-admission sheds explicitly
    stale = _req(rng, cfg, 2, n_new=4, latency_budget_s=5.0)
    stale.t_submit = time.time() - 2.0          # 2s already burned
    with pytest.raises(RouteError, match="cannot meet its deadline"):
        priced.submit(stale)
    assert priced.shed == 2 and priced.submitted == 1


def test_supervisor_dies_after_build_failures_then_probe_revives(setup):
    """A permanently failing factory kills the supervisor (its queue is
    failed explicitly, 'quarantined'); a later successful probe revives
    it for new work — the router's half-open recovery path."""
    cfg, params = setup
    rng = np.random.default_rng(5)
    broken = {"on": True}

    def factory(i):
        if broken["on"]:
            raise ArtifactError("artifact vanished")
        return ServeEngine(cfg, params, max_batch=2, max_seq=16)

    sup = ReplicaSupervisor(factory, name="flaky",
                            retry=RetryPolicy(max_build_failures=1))
    req = _req(rng, cfg, 0)
    sup.submit(req)
    while sup.has_work:
        sup.step()
    assert sup.dead and "build failed" in sup.death_reason
    assert req.failed and req.fail_reason == "quarantined"
    with pytest.raises(RouteError, match="dead"):
        sup.submit(_req(rng, cfg, 1))
    assert not sup.probe()                      # still broken
    broken["on"] = False
    assert sup.probe()                          # half-open success
    assert not sup.dead
    r2 = _req(rng, cfg, 2)
    sup.submit(r2)
    sup.run()
    assert r2.done and len(r2.output) == 4


def test_supervisor_spreads_load_across_replicas(setup):
    """N replicas serve one entry: both engines take work, stats
    aggregate across them, and the zero-loss invariant holds."""
    cfg, params = setup
    rng = np.random.default_rng(6)
    sup = ReplicaSupervisor(
        lambda i: ServeEngine(cfg, params, max_batch=1, max_seq=16,
                              seed=i),
        name="pair", replicas=2)
    reqs = [_req(rng, cfg, i) for i in range(4)]
    for r in reqs:
        sup.submit(r)
    stats = sup.run()
    assert stats["replicas"] == 2 and stats["live_replicas"] == 2
    assert all(r.done for r in reqs)
    assert stats["accounting"]["completed"] == 4
    per_replica = stats["per_replica"]
    assert len(per_replica) == 2
    assert all(s["requests"] >= 1 for s in per_replica)   # both served


# ---------------------------------------------------------------------------
# Router: quarantine, breaker, fallback, overload
# ---------------------------------------------------------------------------

def test_router_quarantines_tampered_member_and_keeps_serving(
        catalog_dir, tmp_path):
    """Satellite regression: one tampered member of a 2-entry catalog is
    quarantined at lazy build time; the other entry keeps serving."""
    path, cfg = catalog_dir
    root = str(tmp_path / "cat")
    shutil.copytree(path, root)
    cat0 = ArtifactCatalog.load(path)
    fast, accurate = _entries(cat0)
    _tamper(root, accurate.name)

    # eager load refuses the whole catalog (the pre-fleet behaviour) ...
    with pytest.raises(ArtifactError, match="does not match"):
        ArtifactCatalog.load(root)
    # ... lazy load defers, so the router can contain the bad member
    cat = ArtifactCatalog.load(root, lazy=True)
    router = Router(cat)
    # Router.engine() on the bad entry: quarantine, then propagate
    with pytest.raises(ArtifactError, match="does not match"):
        router.engine(accurate.name)
    assert accurate.name in router.stats()["quarantined"]

    rng = np.random.default_rng(7)
    reqs = [_req(rng, cfg, i) for i in range(3)]
    for r in reqs:
        # quality policy would prefer the accurate entry — quarantine
        # forces the healthy fast one
        assert router.submit(r) == fast.name
    stats = router.run()
    assert all(r.done for r in reqs)
    assert stats["requests"] == 3
    assert stats["routing"] == {fast.name: 3}
    assert stats["quarantined"] == \
        {accurate.name: stats["quarantined"][accurate.name]}
    assert "ArtifactError" in stats["quarantined"][accurate.name]


def test_router_submit_falls_back_when_preferred_entry_fails_to_build(
        catalog_dir, tmp_path):
    """Same tampered catalog, but the quarantine happens *inside*
    submit() — the caller just sees the request land on the healthy
    entry."""
    path, cfg = catalog_dir
    root = str(tmp_path / "cat")
    shutil.copytree(path, root)
    fast, accurate = _entries(ArtifactCatalog.load(path))
    _tamper(root, accurate.name)
    router = Router(ArtifactCatalog.load(root, lazy=True))
    rng = np.random.default_rng(8)
    req = _req(rng, cfg, 0)
    assert router.submit(req) == fast.name
    assert accurate.name in router.stats()["quarantined"]
    router.run()
    assert req.done and req.routed_to == fast.name


def test_router_breaker_trips_then_probe_restores(catalog_dir):
    """breaker_k consecutive crashes quarantine an entry; the queued
    request still drains (retry on the rebuilt engine), and a manual
    probe restores the entry to dispatch."""
    path, cfg = catalog_dir
    cat = ArtifactCatalog.load(path)
    fast, accurate = _entries(cat)
    # two consecutive crashes on the accurate entry's replica 0
    inj = FaultInjector(specs=[
        crash_at(f"decode:{accurate.name}#r0", 0, 1)])
    router = Router(cat, faults=inj, breaker_k=2, probe_every=0,
                    retry=RetryPolicy(max_retries=3))
    rng = np.random.default_rng(9)
    req = _req(rng, cfg, 0, accuracy_floor=accurate.accuracy)
    assert router.submit(req) == accurate.name
    stats = router.run()
    assert req.done and len(req.output) == 4    # third attempt served
    assert req.retries == 2
    assert stats["crashes"] == 2 and stats["requeued"] == 2
    assert accurate.name in stats["quarantined"]
    assert "circuit breaker" in stats["quarantined"][accurate.name]
    # quarantine redirects new work (floor-less) to the healthy entry
    r2 = _req(rng, cfg, 1)
    assert router.submit(r2) == fast.name
    # a floor only the quarantined entry meets now sheds explicitly
    with pytest.raises(RouteError):
        router.submit(_req(rng, cfg, 2, accuracy_floor=accurate.accuracy))
    # half-open probe: the supervisor is alive again -> restored
    assert router.probe() == [accurate.name]
    assert router.submit(
        _req(rng, cfg, 3, accuracy_floor=accurate.accuracy)) == accurate.name
    router.run()


def test_router_overload_falls_back_then_sheds(catalog_dir):
    """A bounded per-entry queue degrades gracefully: overflow falls to
    the next candidate, and when every fleet is full the request is shed
    with RouteError (explicitly, at submit)."""
    path, cfg = catalog_dir
    cat = ArtifactCatalog.load(path)
    fast, accurate = _entries(cat)
    router = Router(cat, max_queue=1)
    rng = np.random.default_rng(10)
    assert router.submit(_req(rng, cfg, 0)) == accurate.name
    assert router.submit(_req(rng, cfg, 1)) == fast.name   # fallback
    with pytest.raises(RouteError, match="shed"):
        router.submit(_req(rng, cfg, 2))                   # both full
    stats = router.stats()
    # request 1 shed once (on the accurate fleet), request 2 on both
    assert stats["rejected"] == 1 and stats["shed"] == 3
    router.run()
    final = router.stats()
    assert final["requests"] == 2
    assert final["routing"] == {accurate.name: 1, fast.name: 1}

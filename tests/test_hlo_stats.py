"""hlo_stats parser: loop trip counts, dot flops, collective wire bytes."""
import textwrap

from repro.launch.hlo_stats import (_split_op, _type_bytes, parse_hlo,
                                    stats_from_text)

SAMPLE = textwrap.dedent("""\
    HloModule jit_step

    %body.1 (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
      %p = (s32[], f32[128,256]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[128,256]{1,0} get-tuple-element(%p), index=1
      %w = f32[256,256]{1,0} constant({...})
      %dot.1 = f32[128,256]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[128,256]{1,0} all-reduce(%dot.1), replica_groups=[16,16]<=[256], to_apply=%sum
      ROOT %t = (s32[], f32[128,256]) tuple(%i, %ar)
    }

    %cond.1 (p: (s32[], f32[128,256])) -> pred[] {
      %p = (s32[], f32[128,256]) parameter(0)
      ROOT %lt = pred[] constant(true)
    }

    ENTRY %main (a: f32[128,256]) -> f32[128,256] {
      %a = f32[128,256]{1,0} parameter(0)
      %init = (s32[], f32[128,256]) tuple(%a, %a)
      %wh = (s32[], f32[128,256]) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"10"}}
      ROOT %out = f32[128,256]{1,0} get-tuple-element(%wh), index=1
    }
""")


def test_split_op_handles_tuple_types_with_comments():
    line = ('  %wh.2 = (s32[], f32[2,3]{1,0}, /*index=2*/f32[4]) '
            'while(%t), condition=%c, body=%b')
    name, typestr, opcode, rest = _split_op(line)
    assert name == "wh.2"
    assert opcode == "while"
    assert "condition=%c" in rest


def test_type_bytes():
    assert _type_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert _type_bytes("(bf16[2,2], s32[3])") == 2 * 2 * 2 + 3 * 4


def test_while_trip_count_multiplies_body_stats():
    stats = stats_from_text(SAMPLE, n_devices=256)
    # dot: 2*128*256*256 flops, x10 trips
    assert stats["flops"] == 2 * 128 * 256 * 256 * 10
    # all-reduce wire bytes: 2 * result * (g-1)/g, group=16, x10 trips
    result = 128 * 256 * 4
    assert abs(stats["coll_all-reduce"]
               - 10 * 2 * result * 15 / 16) < 1e-6


def test_slice_ops_count_slice_bytes_only():
    hlo = textwrap.dedent("""\
        ENTRY %main (a: f32[4096,1024]) -> f32[1,1024] {
          %a = f32[4096,1024]{1,0} parameter(0)
          %i = s32[] constant(5)
          ROOT %ds = f32[1,1024]{1,0} dynamic-slice(%a, %i, %i), dynamic_slice_sizes={1,1024}
        }
    """)
    stats = stats_from_text(hlo, n_devices=1)
    assert stats["bytes"] == 2 * 1 * 1024 * 4   # slice, not the 16MB operand

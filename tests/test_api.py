"""The repro.api front door: target registry, strategy registry, and the
PruningSession facade (prune -> tune -> serve -> save/resume).

Key contracts:
  * the ``tpu_v5e`` backend is bit-identical to the seed (active-constants)
    cost model — registry threading cannot drift tuner selections;
  * the ``edge`` backend yields a *different* accepted prune history on the
    quickstart workload — the loop is genuinely target-aware;
  * all four registered strategies return a common PruneResult;
  * save()/resume() round-trips the prune-loop state and the loop can
    continue afterwards.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.api import (CPruneConfig, PruneResult, PruningSession, TrainHooks,
                       Workload, get_strategy, get_target, list_strategies,
                       list_targets, register_strategy, register_target)
from repro.configs import get_reduced_config
from repro.core import clear_tuning_caches, cost_model, tuner, tuning_cache
from repro.core.cprune import CPrune
from repro.models.model import init_params, prune_sites


@pytest.fixture(autouse=True)
def _cold_caches():
    clear_tuning_caches()
    yield
    clear_tuning_caches()


def _quickstart_cfg():
    return get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=4, d_model=128, d_ff=1024, n_heads=8, n_kv_heads=2,
        head_dim=16, vocab_size=256)


def _stub_hooks(acc=0.9):
    return TrainHooks(short_term_train=lambda p, s: p,
                      eval_acc=lambda p, s: acc)


def _fast_pcfg(**over):
    base = dict(a_g=0.5, alpha=0.5, beta=0.9999, max_iterations=4,
                seq_len=64)
    base.update(over)
    return CPruneConfig(**base)


def _session(cfg, params, target="tpu_v5e", **pcfg_over):
    return PruningSession(cfg, params=params, target=target,
                          workload=Workload(tokens_global=16384),
                          hooks=_stub_hooks(), pcfg=_fast_pcfg(**pcfg_over))


# ---------------------------------------------------------------------------
# Target registry
# ---------------------------------------------------------------------------

def test_registry_has_required_targets_and_strategies():
    assert {"tpu_v5e", "tpu_v4", "edge"} <= set(list_targets())
    assert {"cprune", "netadapt", "uniform_l1", "fpgm"} \
        <= set(list_strategies())
    with pytest.raises(KeyError, match="unknown target"):
        get_target("no_such_chip")
    with pytest.raises(KeyError, match="unknown strategy"):
        get_strategy("no_such_policy")
    spec = get_target("edge")
    assert get_target(spec) is spec              # spec passthrough
    assert get_target(None).name == "tpu_v5e"    # default


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):
        register_target(get_target("edge"))
    with pytest.raises(ValueError, match="already registered"):
        register_strategy("cprune")(lambda session: None)


def test_tpu_v5e_activation_is_bit_identical():
    v5e = get_target("tpu_v5e")
    # the registered profile IS the seed constants
    with v5e.activate():
        assert tuning_cache.target_fingerprint() == v5e.fingerprint()
    assert tuning_cache.target_fingerprint() == v5e.fingerprint()
    for (m, k, n) in ((65536, 256, 8192), (512, 256, 1024), (64, 64, 64)):
        plain = tuner.tune_gemm(m, k, n)
        via_target = tuner.tune_gemm(m, k, n, target=v5e)
        assert plain == via_target               # Block AND latency float


def test_activation_restores_on_exception():
    before = cost_model.HBM_BW
    with pytest.raises(RuntimeError):
        with get_target("edge").activate():
            assert cost_model.HBM_BW != before
            raise RuntimeError("boom")
    assert cost_model.HBM_BW == before


def test_targets_key_the_program_cache_separately():
    stats = tuner.TunerStats()
    tuner.tune_gemm(2048, 512, 1024, stats=stats, target=get_target("edge"))
    tuner.tune_gemm(2048, 512, 1024, stats=stats,
                    target=get_target("tpu_v5e"))
    assert stats.cache_misses == 2               # different fingerprints
    tuner.tune_gemm(2048, 512, 1024, stats=stats, target=get_target("edge"))
    assert stats.cache_hits == 1                 # edge entry still valid


def test_edge_target_tunes_within_its_vmem_budget():
    edge = get_target("edge")
    prog = tuner.tune_gemm(65536, 1024, 2048, target=edge)
    assert prog.block.vmem_bytes(2) <= edge.vmem_bytes


# ---------------------------------------------------------------------------
# Target-aware pruning: the acceptance criterion
# ---------------------------------------------------------------------------

def _history_via_session(cfg, params, target):
    clear_tuning_caches()
    res = _session(cfg, params, target=target).prune(strategy="cprune")
    return res.history_digest()


def test_same_loop_different_targets_different_architectures():
    """tpu_v5e reproduces the pre-registry CPrune history bit-identically;
    edge yields a different accepted prune history on the same (quickstart)
    workload — the paper's Fig. 7/8 target-specificity claim."""
    cfg = _quickstart_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)

    clear_tuning_caches()
    raw = CPrune(cfg, prune_sites(cfg), Workload(tokens_global=16384),
                 _stub_hooks(), _fast_pcfg()).run(params)
    raw_digest = [(h.task_kind, h.prune_units, h.dim_before, h.dim_after,
                   h.accepted) for h in raw.history]

    v5e = _history_via_session(cfg, params, "tpu_v5e")
    edge = _history_via_session(cfg, params, "edge")
    assert v5e == raw_digest                     # registry == seed model
    assert edge != v5e                           # target changes the result
    assert any(h.accepted for h in raw.history)  # non-degenerate comparison


# ---------------------------------------------------------------------------
# Strategy registry through the session
# ---------------------------------------------------------------------------

def test_all_strategies_return_common_prune_result():
    cfg = get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=2, d_model=64, d_ff=512, n_heads=8, n_kv_heads=2,
        head_dim=8, vocab_size=128)
    params = init_params(jax.random.PRNGKey(0), cfg)
    n0 = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    for strategy, kw in (("cprune", {}), ("uniform_l1", dict(ratio=0.25)),
                         ("fpgm", dict(ratio=0.25)),
                         ("netadapt", dict(max_iterations=1))):
        res = _session(cfg, params, max_iterations=2).prune(
            strategy=strategy, **kw)
        assert isinstance(res, PruneResult)
        assert res.strategy == strategy
        assert res.target == "tpu_v5e"
        n1 = sum(int(np.prod(np.asarray(x).shape))
                 for x in jax.tree.leaves(res.params))
        assert n1 < n0                           # something was pruned
        assert res.final_latency.total_s <= res.original_latency.total_s
        assert res.fps_increase >= 1.0


def test_custom_strategy_registration():
    @register_strategy("identity_test", overwrite=True)
    def _identity(session, **_):
        rep = session.latency_report()
        return PruneResult(
            strategy="identity_test", target=session.target.name,
            params=session.params, sites=session.sites, final_latency=rep,
            original_latency=rep, final_acc=1.0, candidates_evaluated=0)

    cfg = get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=2, d_model=64, vocab_size=128)
    res = _session(cfg, None).prune(strategy="identity_test")
    assert res.fps_increase == 1.0


# ---------------------------------------------------------------------------
# Session checkpointing
# ---------------------------------------------------------------------------

def test_session_save_resume_roundtrip(tmp_path):
    cfg = get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=2, d_model=64, d_ff=512, n_heads=8, n_kv_heads=2,
        head_dim=8, vocab_size=128)
    session = _session(cfg, None, max_iterations=2)
    res = session.prune(strategy="cprune")
    assert any(h.accepted for h in res.history)
    session.save(str(tmp_path / "ckpt"))

    resumed = PruningSession.resume(str(tmp_path / "ckpt"),
                                    hooks=_stub_hooks())
    assert resumed.cfg == cfg
    assert resumed.target.name == session.target.name
    assert resumed.workload == session.workload
    assert {s.site_id: s.dim for s in resumed.sites} \
        == {s.site_id: s.dim for s in session.sites}
    assert len(resumed.history) == len(session.history)
    for a, b in zip(jax.tree.leaves(jax.tree.map(np.asarray, session.params)),
                    jax.tree.leaves(resumed.params)):
        np.testing.assert_array_equal(a, b)
    # the prune loop can continue from the checkpoint
    res2 = resumed.prune(strategy="cprune")
    assert min(s.dim for s in res2.sites) \
        <= min(s.dim for s in session.sites)
    # unknown checkpoint versions are refused, not misread
    import json
    meta = json.loads((tmp_path / "ckpt" / "session.json").read_text())
    meta["version"] = 999
    (tmp_path / "ckpt" / "session.json").write_text(json.dumps(meta))
    with pytest.raises(ValueError, match="version"):
        PruningSession.resume(str(tmp_path / "ckpt"))


def test_resume_preserves_target_and_can_override(tmp_path):
    cfg = get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=2, d_model=64, vocab_size=128)
    session = PruningSession(cfg, target="edge",
                             workload=Workload(tokens_global=8192))
    session.save(str(tmp_path / "ckpt"))
    assert PruningSession.resume(str(tmp_path / "ckpt")).target.name == "edge"
    assert PruningSession.resume(str(tmp_path / "ckpt"),
                                 target="tpu_v4").target.name == "tpu_v4"
    # a custom (unregistered) spec round-trips through its saved fields
    custom = dataclasses.replace(get_target("edge"), name="my_chip",
                                 hbm_bw=123e9)
    PruningSession(cfg, target=custom,
                   workload=Workload(tokens_global=8192)
                   ).save(str(tmp_path / "ckpt2"))
    resumed = PruningSession.resume(str(tmp_path / "ckpt2"))
    assert resumed.target == custom
    # a customized spec that *shadows* a registry name must not be
    # silently replaced by the stock profile on resume
    shadow = dataclasses.replace(get_target("edge"), hbm_bw=999e9)
    PruningSession(cfg, target=shadow,
                   workload=Workload(tokens_global=8192)
                   ).save(str(tmp_path / "ckpt3"))
    assert PruningSession.resume(str(tmp_path / "ckpt3")).target == shadow


def test_prune_keeps_untouched_sites_in_session_state(tmp_path):
    """Strategies return only their filtered site subset; the session must
    merge it back so tune/latency_report/save still see every site."""
    cfg = get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=2, d_model=64, d_ff=512, n_heads=8, n_kv_heads=2,
        head_dim=8, vocab_size=128)
    session = PruningSession(
        cfg, workload=Workload(tokens_global=8192), hooks=_stub_hooks(),
        pcfg=_fast_pcfg(max_iterations=2, prunable_kinds=("ffn",)))
    kinds_before = sorted(s.kind for s in session.sites)
    assert "heads" in kinds_before
    res = session.prune(strategy="cprune")
    assert sorted(s.kind for s in res.sites) == ["ffn"]   # strategy subset
    assert sorted(s.kind for s in session.sites) == kinds_before
    ffn = next(s for s in session.sites if s.kind == "ffn")
    assert ffn.dim < cfg.d_ff                             # pruned site merged
    # save/resume agree with the live session, heads site included
    session.save(str(tmp_path / "ckpt"))
    resumed = PruningSession.resume(str(tmp_path / "ckpt"))
    assert {s.site_id: s.dim for s in resumed.sites} \
        == {s.site_id: s.dim for s in session.sites}


def test_prune_with_default_hooks_warns():
    cfg = get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=2, d_model=64, d_ff=512, n_heads=8, n_kv_heads=2,
        head_dim=8, vocab_size=128)
    session = PruningSession(cfg, workload=Workload(tokens_global=8192),
                             pcfg=_fast_pcfg(max_iterations=1))
    with pytest.warns(UserWarning, match="no-op"):
        session.prune(strategy="uniform_l1", ratio=0.25)


# ---------------------------------------------------------------------------
# Deprecation shims
# ---------------------------------------------------------------------------

def test_core_shims_forward_to_api():
    import repro.core as core
    with pytest.warns(DeprecationWarning):
        assert core.PruningSession is PruningSession
    with pytest.raises(AttributeError):
        core.definitely_not_a_symbol

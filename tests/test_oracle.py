"""Latency-oracle backends: analytic bit-identity, measured execution of
the repo's Pallas kernels, deterministic replay, and cross-backend cache
isolation."""
import dataclasses

import jax
import pytest

from repro.configs import get_reduced_config
from repro.core import (CPrune, CPruneConfig, TrainHooks, Workload,
                        clear_tuning_caches)
from repro.core import latency, oracle, tuner, tuning_cache
from repro.core.cost_model import Block
from repro.core.oracle import (AnalyticOracle, MeasuredOracle,
                               MeasurementConfig, MeasurementLog,
                               ReplayOracle)
from repro.core.tasks import local_gemm_dims
from repro.models.model import init_params, prune_sites

# fast measurement settings for CPU interpret mode: no warmup, two
# repeats, single-candidate shortlist, one measured grid step per dim
FAST = MeasurementConfig(warmup=0, repeats=2, trim=0, measure_top_k=1,
                         max_grid_steps=1)


def _tiny_setup(**over):
    base = dict(n_layers=2, d_model=64, d_ff=128, n_heads=4, n_kv_heads=2,
                head_dim=16, vocab_size=128)
    base.update(over)
    cfg = get_reduced_config("qwen3_1_7b").with_overrides(**base)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params, prune_sites(cfg)


def _fake_hooks():
    return TrainHooks(short_term_train=lambda p, s: p,
                      eval_acc=lambda p, s: 0.9)


# ---------------------------------------------------------------------------
# Analytic backend: bit-identical to the pre-oracle scoring path
# ---------------------------------------------------------------------------

def test_analytic_oracle_is_default_and_bit_identical():
    assert oracle.active_oracle().name == "analytic"
    p_default = tuner.tune_gemm(512, 256, 1024)
    p_explicit = tuner.tune_gemm(512, 256, 1024, oracle=AnalyticOracle())
    with tuner.engine_mode("reference"):
        p_reference = tuner.tune_gemm(512, 256, 1024)
    assert p_default == p_explicit == p_reference


def test_analytic_cprune_history_identical_with_and_without_oracle_arg():
    cfg, params, sites = _tiny_setup()
    wl = Workload(tokens_global=2048)
    pcfg = CPruneConfig(a_g=0.1, alpha=0.5, beta=0.99, max_iterations=3,
                        seq_len=32)
    clear_tuning_caches()
    res_plain = CPrune(cfg, sites, wl, _fake_hooks(), pcfg).run(params)
    clear_tuning_caches()
    res_oracle = CPrune(cfg, prune_sites(cfg), wl, _fake_hooks(), pcfg,
                        oracle=AnalyticOracle()).run(params)
    digest = lambda r: [(h.task_kind, h.prune_units, h.dim_before,
                         h.dim_after, h.l_m, h.accepted) for h in r.history]
    assert digest(res_plain) == digest(res_oracle)


def test_reference_engine_rejects_non_analytic_oracle():
    with tuner.engine_mode("reference"):
        with pytest.raises(RuntimeError, match="analytic"):
            tuner.tune_gemm(64, 128, 128, oracle=MeasuredOracle(FAST))


# ---------------------------------------------------------------------------
# Measured backend: times the repo's Pallas kernels
# ---------------------------------------------------------------------------

def test_measured_oracle_times_kernels_and_records():
    log = MeasurementLog(FAST)
    stats = tuner.TunerStats()
    prog = tuner.tune_gemm(64, 128, 128, stats=stats,
                           oracle=MeasuredOracle(FAST, record=log),
                           cache=tuning_cache.ProgramCache())
    assert prog.latency > 0.0
    assert stats.measured_programs == FAST.measure_top_k
    assert stats.measure_wall_s > 0.0
    assert len(log) == FAST.measure_top_k


def test_measured_oracle_times_grouped_gemm_for_batched_problems():
    log = MeasurementLog(FAST)
    prog = tuner.tune_gemm(32, 128, 128, batch=4,
                           oracle=MeasuredOracle(FAST, record=log),
                           cache=tuning_cache.ProgramCache())
    assert prog.latency > 0.0
    (key,) = log.entries
    assert key.startswith("gemm:32:128:128:4:")


def test_measurement_extrapolation_scales_by_grid_steps():
    mo = MeasuredOracle(FAST)
    m, k, n, b, scale = mo._clipped(512, 256, 256, 1, Block(128, 128, 128))
    assert (m, k, n, b) == (128, 128, 128, 1)
    assert scale == 4 * 2 * 2


# ---------------------------------------------------------------------------
# Replay backend: deterministic playback
# ---------------------------------------------------------------------------

def test_replay_log_round_trips_exactly(tmp_path):
    log = MeasurementLog(FAST)
    log.record(MeasurementLog.gemm_key(64, 128, 128, 1, 2, Block(64, 128, 128)),
               1.25e-4)
    log.record(MeasurementLog.gemm_key(64, 128, 256, 1, 2, Block(8, 128, 128)),
               3.5e-6)
    path = str(tmp_path / "replay.json")
    log.save(path)
    loaded = MeasurementLog.load(path)
    assert loaded.entries == log.entries
    assert loaded.config == log.config
    assert loaded.digest() == log.digest()


def test_replay_reproduces_measured_program_and_rejects_unknown(tmp_path):
    log = MeasurementLog(FAST)
    measured = tuner.tune_gemm(64, 128, 128,
                               oracle=MeasuredOracle(FAST, record=log),
                               cache=tuning_cache.ProgramCache())
    path = str(tmp_path / "replay.json")
    log.save(path)
    replayed = tuner.tune_gemm(64, 128, 128,
                               oracle=ReplayOracle.from_file(path),
                               cache=tuning_cache.ProgramCache())
    assert replayed == measured
    with pytest.raises(KeyError, match="replay log"):
        tuner.tune_gemm(64, 128, 384, oracle=ReplayOracle(log),
                        cache=tuning_cache.ProgramCache())


def test_measured_cprune_history_replays_identically(tmp_path):
    """The acceptance loop: a measured-execution CPrune run records a log,
    and a replay run over that log accepts the exact same history."""
    cfg, params, sites = _tiny_setup()
    wl = Workload(tokens_global=256)
    pcfg = CPruneConfig(a_g=0.1, alpha=0.5, beta=0.999, max_iterations=2,
                        seq_len=32)
    log = MeasurementLog(FAST)
    clear_tuning_caches()
    res_m = CPrune(cfg, sites, wl, _fake_hooks(), pcfg,
                   oracle=MeasuredOracle(FAST, record=log)).run(params)
    assert len(log) > 0 and res_m.tuner_stats.measured_programs > 0
    path = str(tmp_path / "replay.json")
    log.save(path)
    clear_tuning_caches()
    res_r = CPrune(cfg, prune_sites(cfg), wl, _fake_hooks(), pcfg,
                   oracle=ReplayOracle.from_file(path)).run(params)
    assert res_r.tuner_stats.replay_hits > 0
    assert res_r.tuner_stats.measured_programs == 0
    digest = lambda r: [(h.task_kind, h.prune_units, h.dim_before,
                         h.dim_after, h.l_m, h.accepted) for h in r.history]
    assert digest(res_r) == digest(res_m)
    assert res_r.final_latency.total_s == res_m.final_latency.total_s
    clear_tuning_caches()


# ---------------------------------------------------------------------------
# Cache isolation: winners never cross backends
# ---------------------------------------------------------------------------

def test_program_keys_and_table_fingerprints_differ_per_backend():
    k_analytic = tuning_cache.program_key(64, 128, 128)
    with oracle.use_oracle(MeasuredOracle(FAST)):
        k_measured = tuning_cache.program_key(64, 128, 128)
    log = MeasurementLog(FAST)
    with oracle.use_oracle(ReplayOracle(log)):
        k_replay = tuning_cache.program_key(64, 128, 128)
    assert len({k_analytic, k_measured, k_replay}) == 3
    # measurement config is part of the identity too
    other = dataclasses.replace(FAST, repeats=FAST.repeats + 1)
    with oracle.use_oracle(MeasuredOracle(other)):
        assert tuning_cache.program_key(64, 128, 128) != k_measured


def test_incremental_retune_refuses_cross_oracle_prev():
    cfg, params, sites = _tiny_setup()
    wl = Workload(tokens_global=2048)
    table = tuner.build_tuned_table(sites, wl)
    log = MeasurementLog(FAST)
    stats = tuner.TunerStats()
    tuner.build_tuned_table(sites, wl, stats=stats, prev=table,
                            oracle=MeasuredOracle(FAST, record=log))
    assert stats.tasks_reused == 0


# ---------------------------------------------------------------------------
# Session front door
# ---------------------------------------------------------------------------

def test_session_oracle_defaults_and_overrides():
    from repro.api import PruningSession, get_target
    cfg, params, sites = _tiny_setup()
    s = PruningSession(cfg, params=params)
    assert s.oracle.name == "analytic"
    assert get_target("tpu_v5e").default_oracle == "analytic"
    s2 = PruningSession(cfg, params=params, oracle="measured")
    assert isinstance(s2.oracle, MeasuredOracle)
    with pytest.raises(ValueError, match="replay"):
        PruningSession(cfg, params=params, oracle="replay")
    with pytest.raises(KeyError, match="unknown oracle"):
        PruningSession(cfg, params=params, oracle="psychic")


def test_recording_oracle_not_starved_by_warm_measured_caches(tmp_path):
    """A recorder is its own cache identity: warm ProgramCache/memo entries
    from an earlier (non-recording) measured run must not starve the log,
    or calibrate() would ship an incomplete replay artifact."""
    from repro.api import PruningSession
    assert MeasuredOracle(FAST, record=MeasurementLog(FAST)).fingerprint() \
        != MeasuredOracle(FAST, record=MeasurementLog(FAST)).fingerprint()
    assert MeasuredOracle(FAST).fingerprint() \
        == MeasuredOracle(FAST).fingerprint()
    cfg, params, sites = _tiny_setup()
    s = PruningSession(cfg, params=params, oracle=MeasuredOracle(FAST),
                       workload=Workload(tokens_global=256),
                       pcfg=CPruneConfig(a_g=0.0, seq_len=32))
    s.latency_report()                     # warms the caches, no recording
    log = s.calibrate(str(tmp_path / "calib.json"), config=FAST)
    assert len(log) > 0
    # the artifact really replays the whole report
    assert s.latency_report(oracle=ReplayOracle(log)).total_s > 0.0
    clear_tuning_caches()


def test_serve_predict_step_falls_back_when_replay_log_cannot_score():
    from repro.api import PruningSession
    cfg, params, sites = _tiny_setup()
    empty = ReplayOracle(MeasurementLog(FAST))
    s = PruningSession(cfg, params=params, oracle=empty,
                       workload=Workload(tokens_global=256),
                       pcfg=CPruneConfig(a_g=0.0, seq_len=32))
    engine = s.serve(max_batch=2, max_seq=16)   # must not raise KeyError
    assert engine.predicted_step_s is None
    clear_tuning_caches()


def test_session_calibrate_records_replayable_log(tmp_path):
    from repro.api import PruningSession
    cfg, params, sites = _tiny_setup()
    wl = Workload(tokens_global=256)
    s = PruningSession(cfg, params=params, workload=wl,
                       pcfg=CPruneConfig(a_g=0.0, seq_len=32))
    path = str(tmp_path / "calib.json")
    log = s.calibrate(path, config=FAST)
    assert len(log) > 0
    # the replayed latency report equals the measured one exactly
    clear_tuning_caches()
    rep_replay = s.latency_report(oracle=ReplayOracle.from_file(path))
    clear_tuning_caches()
    rep_measured = s.latency_report(
        oracle=MeasuredOracle(FAST, record=MeasurementLog.load(path)))
    assert rep_replay.total_s == rep_measured.total_s
    clear_tuning_caches()


# ---------------------------------------------------------------------------
# Satellites: router replication + bounded fixed-latency memo
# ---------------------------------------------------------------------------

def test_router_gemm_replicated_across_tp_shards():
    """The experts-site router GEMM runs replicated on every TP shard
    (prune_step already treats experts as unsharded); the moe_ffn expert
    GEMMs are TP-sharded as usual."""
    cfg, params, sites = _tiny_setup(d_ff=0, n_experts=8, top_k=2,
                                     moe_d_ff=128)
    experts = next(s for s in sites if s.kind == "experts")
    moe = next(s for s in sites if s.kind == "moe_ffn")
    wl1, wl4 = Workload(tokens_global=1024), Workload(tokens_global=1024,
                                                     tp=4)
    router = experts.gemms[0]
    assert local_gemm_dims(experts, router, wl4) \
        == local_gemm_dims(experts, router, wl1)
    assert local_gemm_dims(experts, router, wl4)[2] == cfg.n_experts
    up = next(g for g in moe.gemms if g.prunable == "n")
    assert local_gemm_dims(moe, up, wl4)[2] \
        == local_gemm_dims(moe, up, wl1)[2] // 4


def test_fixed_latency_cache_is_bounded_with_eviction_counter():
    latency.clear_fixed_latency_cache()
    old = latency.fixed_latency_cache_info()["max"]
    try:
        latency.set_fixed_latency_cache_limit(2)
        cfg, params, sites = _tiny_setup()
        for seq in (16, 32, 64, 128):
            latency.fixed_latency(cfg, sites, Workload(tokens_global=512),
                                  seq_len=seq)
        info = latency.fixed_latency_cache_info()
        assert info["size"] <= 2
        assert info["evictions"] == 2
        # clear_tuning_caches resets the memo and its counter
        from repro.core import clear_tuning_caches
        clear_tuning_caches()
        info = latency.fixed_latency_cache_info()
        assert info["size"] == 0 and info["evictions"] == 0
    finally:
        latency.set_fixed_latency_cache_limit(old)
    with pytest.raises(ValueError):
        latency.set_fixed_latency_cache_limit(0)


def test_measurement_log_bounded_lru_with_observation_windows():
    """Serve-time logs are bounded: LRU eviction with a counter (the
    same discipline as latency._FIXED_CACHE), and a per-key observation
    window so drift scoring sees recent behaviour, not one scalar."""
    log = MeasurementLog(max_entries=3, window_size=2)
    for i in range(3):
        log.record(f"k{i}", float(i))
    assert log.lookup("k0") == 0.0      # refreshes k0's recency
    log.record("k3", 3.0)               # evicts k1, the actual LRU
    assert len(log) == 3 and log.evicted == 1
    assert log.lookup("k1") is None
    assert log.lookup("k0") == 0.0
    # windows keep the newest window_size samples, newest last
    log.record("k3", 4.0)
    log.record("k3", 5.0)
    assert log.window("k3") == [4.0, 5.0]
    assert log.window("k1") == []       # evicted key's window went too
    # copy preserves bounds, windows, and the entries themselves
    dup = log.copy()
    assert dup.max_entries == 3 and dup.window("k3") == [4.0, 5.0]
    # an unbounded log never evicts
    unbounded = MeasurementLog()
    for i in range(64):
        unbounded.record(f"k{i}", 1.0)
    assert len(unbounded) == 64 and unbounded.evicted == 0


def test_score_drift_windowed_rel_error():
    log = MeasurementLog(window_size=4)
    key = MeasurementLog.step_key("m", 2, 24)
    # no evidence / not enough evidence / meaningless prediction -> None
    assert oracle.score_drift(log, key, 1.0) is None
    log.record(key, 2.0)
    assert oracle.score_drift(log, key, 1.0, min_window=2) is None
    log.record(key, 3.0)
    assert oracle.score_drift(log, key, 0.0, min_window=2) is None
    rep = oracle.score_drift(log, key, 1.0, min_window=2)
    assert rep is not None
    assert rep.window == 2 and rep.measured_s == 2.5
    # rel_error is signed: positive = slower than predicted
    assert rep.rel_error == pytest.approx(1.5)
    assert rep.magnitude == pytest.approx(1.5)
    fast = oracle.score_drift(log, key, 10.0, min_window=2)
    assert fast.rel_error == pytest.approx(-0.75)
    assert fast.magnitude == pytest.approx(0.75)

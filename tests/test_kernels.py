"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles.

Every Pallas kernel runs in interpret mode (CPU container; TPU is the
compile target) and must match ref.py within dtype-appropriate tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cost_model import Block
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.matmul import matmul
from repro.kernels.moe_gmm import moe_gmm
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.rwkv6_scan import rwkv6_scan

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (100, 200, 300),
                                   (8, 512, 128), (257, 129, 511)])
@pytest.mark.parametrize("block", [Block(32, 128, 128), Block(64, 256, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul(m, k, n, block, dtype):
    a = jax.random.normal(KEY, (m, k), jnp.float32).astype(dtype)
    b = jax.random.normal(jax.random.fold_in(KEY, 1), (k, n),
                          jnp.float32).astype(dtype)
    out = matmul(a, b, block=block, interpret=True)
    expect = ref.matmul_ref(a, b)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        rtol=_tol(dtype), atol=_tol(dtype) * np.abs(np.asarray(expect)).max())


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sq,sk,hq,hkv,causal,window", [
    (128, 128, 4, 2, True, 0),      # GQA causal
    (96, 96, 4, 1, True, 0),        # MQA, ragged seq
    (64, 64, 8, 8, False, 0),       # MHA bidirectional (encoder)
    (192, 192, 4, 2, True, 64),     # sliding window
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(sq, sk, hq, hkv, causal, window, dtype):
    B, D = 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, sq, hq, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, sk, hkv, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, sk, hkv, D), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          bq=32, bk=32, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=_tol(dtype), atol=_tol(dtype) * 3)


def test_flash_attention_matches_blockwise_model_path():
    """Kernel vs the model's XLA blockwise path (two independent impls)."""
    from repro.models.attention import blockwise_attention
    B, S, Hq, Hkv, D = 2, 128, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    a = flash_attention(q, k, v, causal=True, bq=32, bk=32, interpret=True)
    b = blockwise_attention(q, k, v, causal=True, q_block=32, k_block=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# paged decode attention
# ---------------------------------------------------------------------------

def _paged_case(B, n_cols, bs, hq, hkv, D, dtype, *, seed=0, ragged=True):
    """Random pools + a shuffled block table + ragged per-row lengths.

    Block ids are a permutation of the pool (plus a couple of shared ids
    when the pool is large enough) so the kernel's table indirection is
    actually exercised — an identity table would hide gather bugs."""
    ks = jax.random.split(jax.random.fold_in(KEY, seed), 4)
    n_blocks = B * n_cols + 2
    q = jax.random.normal(ks[0], (B, hq, D), jnp.float32).astype(dtype)
    kp = jax.random.normal(ks[1], (n_blocks, bs, hkv, D),
                           jnp.float32).astype(dtype)
    vp = jax.random.normal(ks[2], (n_blocks, bs, hkv, D),
                           jnp.float32).astype(dtype)
    rng = np.random.default_rng(seed)
    table = rng.permutation(n_blocks)[:B * n_cols] \
        .reshape(B, n_cols).astype(np.int32)
    if ragged:
        lens = rng.integers(1, n_cols * bs + 1, size=B).astype(np.int32)
    else:
        lens = np.full(B, n_cols * bs, np.int32)
    return q, kp, vp, jnp.asarray(table), jnp.asarray(lens)


@pytest.mark.parametrize("n_cols,bs", [(1, 8), (3, 8), (2, 16), (5, 4)])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (4, 1), (8, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_matrix(n_cols, bs, hq, hkv, dtype):
    from repro.kernels.paged_attention import paged_attention
    B, D = 3, 32
    q, kp, vp, table, lens = _paged_case(B, n_cols, bs, hq, hkv, D, dtype,
                                         seed=n_cols * 100 + bs)
    out = paged_attention(q, kp, vp, table, lens, interpret=True)
    expect = ref.paged_attention_ref(q, kp, vp, table, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=_tol(dtype), atol=_tol(dtype) * 3)


@pytest.mark.parametrize("D", [16, 32, 64, 128])
def test_paged_attention_head_dims(D):
    from repro.kernels.paged_attention import paged_attention
    q, kp, vp, table, lens = _paged_case(2, 3, 8, 4, 2, D, jnp.float32,
                                         seed=D)
    out = paged_attention(q, kp, vp, table, lens, interpret=True)
    expect = ref.paged_attention_ref(q, kp, vp, table, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


def test_paged_attention_len_one_and_full():
    """Boundary lengths: a single cached token and an exactly-full table."""
    from repro.kernels.paged_attention import paged_attention
    q, kp, vp, table, _ = _paged_case(2, 2, 8, 4, 2, 32, jnp.float32, seed=7)
    lens = jnp.asarray([1, 16], jnp.int32)
    out = paged_attention(q, kp, vp, table, lens, interpret=True)
    expect = ref.paged_attention_ref(q, kp, vp, table, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


def test_paged_attention_matches_dense_decode():
    """Paged kernel vs blockwise_attention over the *same* KV laid out
    contiguously — the two layouts must agree on the decode step."""
    from repro.models.attention import blockwise_attention
    from repro.kernels.paged_attention import paged_attention
    B, n_cols, bs, Hq, Hkv, D = 2, 4, 8, 4, 2, 32
    q, kp, vp, table, lens = _paged_case(B, n_cols, bs, Hq, Hkv, D,
                                         jnp.float32, seed=11)
    out = paged_attention(q, kp, vp, table, lens, interpret=True)
    for b in range(B):
        L = int(lens[b])
        kd = kp[table[b]].reshape(1, n_cols * bs, Hkv, D)[:, :L]
        vd = vp[table[b]].reshape(1, n_cols * bs, Hkv, D)[:, :L]
        dense = blockwise_attention(
            q[b][None, None], kd, vd, causal=True,
            q_positions=jnp.asarray([L - 1], jnp.int32))
        np.testing.assert_allclose(np.asarray(out[b]),
                                   np.asarray(dense[0, 0]),
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# rglru scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,w,bs,bw", [(64, 64, 32, 64), (100, 96, 32, 32),
                                       (33, 17, 16, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_scan(s, w, bs, bw, dtype):
    B = 2
    a = jax.nn.sigmoid(jax.random.normal(KEY, (B, s, w))).astype(dtype)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (B, s, w)).astype(dtype)
    y, s_last = rglru_scan(a, x, bs=bs, bw=bw, interpret=True)
    yr, sr = ref.rglru_scan_ref(a, x, jnp.zeros((B, w)))
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=_tol(dtype), atol=_tol(dtype) * 3)
    np.testing.assert_allclose(np.asarray(s_last, np.float32),
                               np.asarray(sr, np.float32),
                               rtol=_tol(dtype), atol=_tol(dtype) * 3)


# ---------------------------------------------------------------------------
# rwkv6 scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,h,d,bs", [(48, 2, 16, 16), (50, 3, 16, 16),
                                      (64, 1, 32, 32)])
def test_rwkv6_scan(s, h, d, bs):
    B = 2
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, s, h, d))
    k = jax.random.normal(ks[1], (B, s, h, d))
    v = jax.random.normal(ks[2], (B, s, h, d))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, s, h, d)))
    u = jax.random.normal(ks[4], (h, d)) * 0.1
    o, sl = rwkv6_scan(r, k, v, w, u, bs=bs, interpret=True)
    orf, slr = ref.rwkv6_scan_ref(r, k, v, w, u, jnp.zeros((B, h, d, d)))
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sl), np.asarray(slr),
                               rtol=1e-4, atol=1e-4)


def test_rwkv6_scan_matches_model_block():
    """Kernel output must equal the model's wkv_scan given same inputs."""
    from repro.models.rwkv6 import wkv_scan
    B, S, H, D = 1, 40, 2, 16
    ks = jax.random.split(KEY, 5)
    r, k, v = (jax.random.normal(ks[i], (B, S, H, D)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, D)))
    u = jax.random.normal(ks[4], (H, D)) * 0.1
    o1, s1 = rwkv6_scan(r, k, v, w, u, bs=8, interpret=True)
    o2, s2 = wkv_scan(r, k, v, w, u, jnp.zeros((B, H, D, D)))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# moe grouped GEMM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("e,c,k,n", [(4, 64, 64, 64), (8, 100, 64, 96),
                                     (2, 33, 200, 130)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gmm(e, c, k, n, dtype):
    x = jax.random.normal(KEY, (e, c, k), jnp.float32).astype(dtype)
    w = jax.random.normal(jax.random.fold_in(KEY, 2), (e, k, n),
                          jnp.float32).astype(dtype)
    out = moe_gmm(x, w, block=Block(32, 64, 64), interpret=True)
    expect = ref.moe_gmm_ref(x, w)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        rtol=_tol(dtype), atol=_tol(dtype) * np.abs(np.asarray(expect)).max())

"""plan(): the constraint front door (accuracy floor, latency budget).

Acceptance contract (ISSUE 4): plan() on tpu_v5e + edge with an accuracy
floor returns a frontier where every candidate's recomputed latency
matches its exported artifact's metadata, the best candidate satisfies
the floor, loading the exported artifact serves without constructing a
PruningSession, and an unsatisfiable floor raises a clear error.
"""
import jax
import numpy as np
import pytest

from repro.api import (CPruneConfig, DeploymentArtifact, PlanError,
                       TrainHooks, Workload, plan)
from repro.configs import get_reduced_config
from repro.core import clear_tuning_caches
from repro.models.model import init_params
from repro.serve.engine import Request, ServeEngine


@pytest.fixture(autouse=True)
def _cold_caches():
    clear_tuning_caches()
    yield
    clear_tuning_caches()


def _cfg():
    return get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=2, d_model=64, d_ff=512, n_heads=8, n_kv_heads=2,
        head_dim=8, vocab_size=128)


def _count(p):
    return sum(int(np.prod(np.asarray(x).shape)) for x in jax.tree.leaves(p))


def _setup():
    """Params + hooks whose accuracy is the remaining-parameter fraction:
    deterministic, and strategies that prune more score lower — so the
    accuracy/latency trade-off the planner ranks is real."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    n0 = _count(params)
    hooks = TrainHooks(short_term_train=lambda p, s: p,
                       eval_acc=lambda p, s: _count(p) / n0)
    pcfg = CPruneConfig(a_g=0.0, alpha=0.5, beta=0.9999, max_iterations=2,
                        seq_len=64)
    return cfg, params, hooks, pcfg


def _plan(cfg, params, hooks, pcfg, **kw):
    kw.setdefault("targets", ["tpu_v5e", "edge"])
    kw.setdefault("strategies", ["cprune", "uniform_l1"])
    kw.setdefault("workload", Workload(tokens_global=8192))
    kw.setdefault("strategy_kwargs", {"uniform_l1": {"ratio": 0.25}})
    return plan(cfg, params=params, hooks=hooks, pcfg=pcfg, **kw)


def test_plan_sweeps_strategy_x_target_with_pareto_frontier(tmp_path):
    cfg, params, hooks, pcfg = _setup()
    pl = _plan(cfg, params, hooks, pcfg, accuracy_floor=0.5)
    assert len(pl.candidates) == 4
    assert {(c.strategy, c.target) for c in pl.candidates} == {
        ("cprune", "tpu_v5e"), ("uniform_l1", "tpu_v5e"),
        ("cprune", "edge"), ("uniform_l1", "edge")}

    frontier = pl.frontier
    assert frontier
    # non-domination: no frontier member is beaten on both axes
    for c in frontier:
        assert not any(
            o.accuracy >= c.accuracy and o.latency_s <= c.latency_s
            and (o.accuracy > c.accuracy or o.latency_s < c.latency_s)
            for o in pl.candidates)

    best = pl.best
    assert best is not None and best.accuracy >= 0.5
    feasible = [c for c in pl.candidates if c.feasible]
    assert best.latency_s == min(c.latency_s for c in feasible)
    assert "best" in pl.summary()


def test_frontier_artifacts_reproduce_their_planned_latency(tmp_path):
    """The acceptance criterion: every frontier candidate's exported
    artifact, loaded cold, recomputes exactly the latency the plan ranked
    it by — and serves without a PruningSession."""
    cfg, params, hooks, pcfg = _setup()
    pl = _plan(cfg, params, hooks, pcfg, accuracy_floor=0.5)
    for i, cand in enumerate(pl.frontier):
        path = str(tmp_path / f"art{i}")
        art = cand.export(path, max_batch=2, max_seq=24)
        assert art.metadata["latency_total_s"] == cand.latency_s
        clear_tuning_caches()
        loaded = DeploymentArtifact.load(path)
        assert loaded.target.name == cand.target
        assert loaded.metadata["strategy"] == cand.strategy
        assert loaded.latency_report().total_s == cand.latency_s
        assert loaded.metadata["final_acc"] == cand.accuracy
    # serve the best one from disk alone
    best_path = str(tmp_path / "best")
    pl.export(best_path, max_batch=2, max_seq=24)
    clear_tuning_caches()
    engine = ServeEngine.from_artifact(best_path)
    rng = np.random.default_rng(0)
    engine.submit(Request(rid=0, prompt=rng.integers(
        0, cfg.vocab_size, 8).astype(np.int32), max_new_tokens=4))
    stats = engine.run()
    assert stats["total_new_tokens"] == 4


def test_unsatisfiable_floor_has_no_best_and_export_raises(tmp_path):
    cfg, params, hooks, pcfg = _setup()
    pl = _plan(cfg, params, hooks, pcfg, accuracy_floor=2.0,
               targets=["tpu_v5e"], strategies=["uniform_l1"])
    assert pl.best is None
    assert all(not c.meets_floor for c in pl.candidates)
    with pytest.raises(PlanError, match="accuracy_floor"):
        pl.export(str(tmp_path / "never"))


def test_latency_budget_filters_best(tmp_path):
    cfg, params, hooks, pcfg = _setup()
    # an impossible budget: floor is met but nothing is fast enough
    pl = _plan(cfg, params, hooks, pcfg, accuracy_floor=0.5,
               latency_budget_s=1e-12, targets=["tpu_v5e"],
               strategies=["uniform_l1"])
    assert all(c.meets_floor for c in pl.candidates)
    assert all(not c.meets_budget for c in pl.candidates)
    assert pl.best is None
    # a generous budget: same sweep, now feasible
    pl2 = _plan(cfg, params, hooks, pcfg, accuracy_floor=0.5,
                latency_budget_s=10.0, targets=["tpu_v5e"],
                strategies=["uniform_l1"])
    assert pl2.best is not None


def test_plan_threads_floor_into_the_cprune_accuracy_gate():
    """Without an explicit pcfg, the sessions run with a_g=accuracy_floor
    — the search stops at the requirement instead of pruning past it and
    failing the post-hoc check."""
    cfg, params, hooks, _ = _setup()
    pl = plan(cfg, accuracy_floor=0.9, targets=["tpu_v5e"],
              strategies=["cprune"], workload=Workload(tokens_global=8192),
              hooks=hooks, params=params)
    assert all(c.session.pcfg.a_g == 0.9 for c in pl.candidates)
    # every accepted step kept accuracy at/above the gate, so the arm
    # satisfies the floor by construction
    assert pl.best is not None and pl.best.accuracy >= 0.9
    # an explicit pcfg wins verbatim
    pl2 = plan(cfg, accuracy_floor=0.9, targets=["tpu_v5e"],
               strategies=["cprune"], workload=Workload(tokens_global=8192),
               hooks=hooks, params=params,
               pcfg=CPruneConfig(a_g=0.0, max_iterations=1, seq_len=64))
    assert all(c.session.pcfg.a_g == 0.0 for c in pl2.candidates)


def test_plan_candidates_share_the_program_cache_per_target():
    """The sweep must be cheap: the second strategy on a target rides the
    first one's ProgramCache entries instead of re-searching the grid."""
    cfg, params, hooks, pcfg = _setup()
    pl = _plan(cfg, params, hooks, pcfg, accuracy_floor=0.0,
               targets=["tpu_v5e"], strategies=["cprune", "uniform_l1"])
    first, second = pl.candidates[0].result, pl.candidates[1].result
    assert first.tuner_stats is not None
    # uniform_l1's PruneResult carries no stats; prove reuse by a fresh
    # tune() on the second session being served ~fully from cache
    from repro.core import tuner
    stats = tuner.TunerStats()
    pl.candidates[1].session.tune(stats=stats)
    assert stats.cache_hits > 0
    assert stats.cache_misses == 0
    assert second is not first

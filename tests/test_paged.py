"""Paged KV cache (ISSUE 8): block pool, table compaction, prefix
sharing, chunked prefill — and bit-identity against the contiguous path.
"""
import math

import jax
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models.model import init_params
from repro.models.paged_cache import (BlockAllocator, RESERVED_BLOCKS,
                                      SCRATCH_BLOCK, paged_compatible)
from repro.serve.engine import Request, ServeEngine
from repro.serve.scheduler import (PagedSlotGroup, SchedulerConfig,
                                   SlotGroup, _pow2_at_least)
from repro.util.faults import StragglerMonitor


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=2, d_model=64, vocab_size=128)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _mk(rng, cfg, rid, plen, n_new):
    return Request(rid=rid, prompt=rng.integers(
        0, cfg.vocab_size, size=plen).astype(np.int32),
        max_new_tokens=n_new)


def _drain(cfg, params, reqs, sched, **kw):
    eng = ServeEngine(cfg, params, scheduler=sched, **kw)
    for r in reqs:
        eng.submit(Request(rid=r.rid, prompt=r.prompt,
                           max_new_tokens=r.max_new_tokens))
    return eng, eng.run()


# ---------------------------------------------------------------------------
# paged vs contiguous bit-identity (the tentpole's correctness gate)
# ---------------------------------------------------------------------------

def test_paged_matches_contiguous_on_mixed_max_new(setup):
    """The [8, 2, 2, 2] mixed-budget cohort: compaction fires mid-decode,
    and the paged path must produce token-identical greedy outputs while
    physically copying zero cache rows (table row-select only)."""
    cfg, params = setup
    rng = np.random.default_rng(11)
    reqs = [_mk(rng, cfg, i, 8, n) for i, n in enumerate([8, 2, 2, 2])]

    contig, c_stats = _drain(
        cfg, params, reqs, SchedulerConfig(kv_layout="contiguous"),
        max_batch=4, max_seq=24)
    paged, p_stats = _drain(
        cfg, params, reqs, SchedulerConfig(kv_layout="paged", page_size=8),
        max_batch=4, max_seq=24)

    assert c_stats["kv_layout"] == "contiguous"
    assert p_stats["kv_layout"] == "paged"
    for rid in range(4):
        a = next(r for r in contig.done if r.rid == rid)
        b = next(r for r in paged.done if r.rid == rid)
        assert a.output == b.output
    # contiguous compaction gathers cache rows; paged rewrites the table
    assert c_stats["kv_row_copies"] > 0
    assert p_stats["kv_row_copies"] == 0
    # paged accounts peak KV by used blocks, strictly below the
    # contiguous full-depth reservation on this mixed-budget cohort
    assert p_stats["kv_blocks_peak"] > 0
    assert 0 < p_stats["peak_kv_bytes"] < c_stats["peak_kv_bytes"]
    # pool fully drains once every request retires
    assert p_stats["kv_blocks_in_use"] == 0


def test_paged_is_the_default_layout(setup):
    cfg, params = setup
    eng = ServeEngine(cfg, params, max_batch=2, max_seq=16)
    assert eng.kv_layout == "paged"


def test_wave_policy_serves_contiguous(setup):
    """wave *is* the legacy engine — it must silently stay contiguous."""
    cfg, params = setup
    eng = ServeEngine(cfg, params, max_batch=2, max_seq=16,
                      scheduler="wave")
    assert eng.kv_layout == "contiguous"


def test_incompatible_model_falls_back_to_contiguous():
    """Recurrent mixers / sliding windows have no paged path: the engine
    silently serves them contiguous and still decodes correctly."""
    cfg = get_reduced_config("recurrentgemma_9b").with_overrides(
        n_layers=3, d_model=64, vocab_size=128)
    assert not paged_compatible(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_batch=2, max_seq=16,
                      scheduler=SchedulerConfig(kv_layout="paged"))
    assert eng.kv_layout == "contiguous"
    rng = np.random.default_rng(5)
    eng.submit(_mk(rng, cfg, 0, 8, 3))
    stats = eng.run()
    assert stats["requests"] == 1 and stats["kv_layout"] == "contiguous"


def test_pool_exhaustion_raises(setup):
    cfg, params = setup
    eng = ServeEngine(cfg, params, max_batch=4, max_seq=24,
                      scheduler=SchedulerConfig(page_size=8),
                      kv_pool_blocks=RESERVED_BLOCKS + 1)
    rng = np.random.default_rng(6)
    for i in range(2):
        eng.submit(_mk(rng, cfg, i, 16, 4))
    with pytest.raises(RuntimeError, match="exhausted"):
        eng.run()


# ---------------------------------------------------------------------------
# prefix sharing (copy-on-write full-block reuse)
# ---------------------------------------------------------------------------

def test_prefix_sharing_reduces_prefill_work(setup):
    """Identical prompts in one cohort: with sharing on, the engine
    prefill-computes each unique prompt once and the duplicates incref
    the same full blocks — fewer prefill tokens, fewer peak blocks, and
    the *same* greedy outputs as the unshared run."""
    cfg, params = setup
    rng = np.random.default_rng(21)
    p = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
    reqs = [Request(rid=i, prompt=p.copy(), max_new_tokens=4)
            for i in range(4)]

    def run(share):
        return _drain(cfg, params, reqs,
                      SchedulerConfig(kv_layout="paged", page_size=8,
                                      share_prefix=share),
                      max_batch=4, max_seq=32)

    off, off_stats = run(False)
    on, on_stats = run(True)
    for rid in range(4):
        a = next(r for r in off.done if r.rid == rid)
        b = next(r for r in on.done if r.rid == rid)
        assert a.output == b.output
    assert on_stats["kv_shared_blocks"] > 0
    assert off_stats["kv_shared_blocks"] == 0
    # 4 identical prompts prefill once, not four times
    assert on_stats["prefill_tokens"] < off_stats["prefill_tokens"]
    assert on_stats["kv_blocks_peak"] < off_stats["kv_blocks_peak"]


def test_prefix_sharing_keeps_divergent_rows_independent(setup):
    """Shared-prefix rows must diverge freely after the first sampled
    token: compare each rid's output against its own solo run."""
    cfg, params = setup
    rng = np.random.default_rng(22)
    head = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
    prompts = [head.copy() for _ in range(2)] + \
        [np.concatenate([head[:-1], [int(head[-1]) ^ 1]]).astype(np.int32)]
    reqs = [Request(rid=i, prompt=pp, max_new_tokens=4)
            for i, pp in enumerate(prompts)]
    shared, _ = _drain(cfg, params, reqs,
                       SchedulerConfig(kv_layout="paged", page_size=8),
                       max_batch=4, max_seq=24)
    for i, pp in enumerate(prompts):
        solo = ServeEngine(cfg, params, max_batch=1, max_seq=24,
                           scheduler=SchedulerConfig(kv_layout="paged",
                                                     page_size=8))
        solo.submit(Request(rid=0, prompt=pp.copy(), max_new_tokens=4))
        solo.run()
        assert next(r for r in shared.done if r.rid == i).output == \
            solo.done[0].output


# ---------------------------------------------------------------------------
# chunked prefill
# ---------------------------------------------------------------------------

def test_chunked_prefill_matches_unchunked(setup):
    cfg, params = setup
    rng = np.random.default_rng(31)
    reqs = [_mk(rng, cfg, i, 32, 4) for i in range(2)]
    plain, plain_stats = _drain(
        cfg, params, reqs,
        SchedulerConfig(kv_layout="paged", page_size=8),
        max_batch=2, max_seq=48)
    chunked, chunked_stats = _drain(
        cfg, params, reqs,
        SchedulerConfig(kv_layout="paged", page_size=8, prefill_chunk=16),
        max_batch=2, max_seq=48)
    assert plain_stats["chunk_steps"] == 0
    # one cohort of width 2, 32-token prompts in 16-token chunks: a chunk
    # tick advances the whole cohort, so 2 ticks total
    assert chunked_stats["chunk_steps"] == 2
    for rid in range(2):
        a = next(r for r in plain.done if r.rid == rid)
        b = next(r for r in chunked.done if r.rid == rid)
        assert a.output == b.output


def test_chunked_prefill_interleaves_with_decode(setup):
    """A long prompt admitted mid-decode prefills one chunk per tick
    instead of stalling the live group behind a monolithic prefill."""
    cfg, params = setup
    rng = np.random.default_rng(32)
    eng = ServeEngine(cfg, params, max_batch=4, max_seq=48,
                      scheduler=SchedulerConfig(kv_layout="paged",
                                                page_size=8,
                                                prefill_chunk=16,
                                                compact="exact"))
    eng.submit(_mk(rng, cfg, 0, 8, 12))          # long decode
    eng.submit(_mk(rng, cfg, 1, 32, 2))          # long prompt, other bucket
    stats = eng.run()
    assert stats["requests"] == 2
    assert stats["chunk_steps"] == 2
    assert len(eng.done) == 2


# ---------------------------------------------------------------------------
# allocator + slot-group unit coverage
# ---------------------------------------------------------------------------

def test_block_allocator_refcounts_and_sharing():
    al = BlockAllocator(RESERVED_BLOCKS + 3)
    assert al.blocks_free == 3
    a = al.alloc()
    assert a >= RESERVED_BLOCKS and al.blocks_in_use == 1
    al.publish("k1", a)
    b = al.share("k1")
    assert b == a and al.refcount(a) == 2 and al.shared_hits == 1
    al.decref(a)
    assert al.refcount(a) == 1 and al.blocks_in_use == 1
    al.decref(a)                       # hits zero: freed + unpublished
    assert al.blocks_in_use == 0 and al.share("k1") is None
    with pytest.raises(RuntimeError):
        al.decref(a)
    for _ in range(3):
        al.alloc()
    with pytest.raises(RuntimeError, match="exhausted"):
        al.alloc()
    assert al.peak_blocks == 3
    al.reset_stats()
    assert al.peak_blocks == al.blocks_in_use == 3
    assert al.shared_hits == 0


def test_pow2_at_least_zero_is_zero():
    assert _pow2_at_least(0) == 0
    assert [_pow2_at_least(n) for n in (1, 2, 3, 4, 5)] == [1, 2, 4, 4, 8]


class _FakeReq:
    def __init__(self, n):
        self.max_new_tokens = n
        self.output = []


def test_zero_active_compact_releases_paged_group():
    al = BlockAllocator(RESERVED_BLOCKS + 8)
    reqs = [_FakeReq(0), _FakeReq(0)]          # both already done
    table = np.array([[al.alloc(), al.alloc()],
                      [al.alloc(), SCRATCH_BLOCK]], np.int32)
    g = PagedSlotGroup(reqs, table, cur=None, plen=4, allocator=al,
                       block_size=4, pos=4)
    assert al.blocks_in_use == 3
    assert g.compact("pow2") == 2              # whole group freed
    assert g.done and g.width == 0
    assert al.blocks_in_use == 0               # every real block decrefed
    g.release()                                # idempotent


def test_zero_active_compact_releases_contiguous_group():
    reqs = [_FakeReq(0)]
    g = SlotGroup(reqs, caches={"stack": {}, "tail": {}}, cur=None, plen=4)
    assert g.compact("pow2") == 1
    assert g.width == 0 and g.caches is None


def test_paged_compact_is_a_table_row_select():
    al = BlockAllocator(RESERVED_BLOCKS + 16)
    reqs = [_FakeReq(4), _FakeReq(0), _FakeReq(0), _FakeReq(4)]
    table = np.array([[al.alloc(), al.alloc()] for _ in range(4)], np.int32)
    kept = [tuple(table[0]), tuple(table[3])]
    import jax.numpy as jnp
    g = PagedSlotGroup(reqs, table, cur=jnp.arange(4), plen=4,
                       allocator=al, block_size=4, pos=4)
    g.copy_counter = counter = {"rows": 0}
    assert g.compact("pow2") == 2
    assert counter["rows"] == 0                # zero cache-row copies
    assert g.width == 2 and al.blocks_in_use == 4
    assert [tuple(r) for r in g.table] == kept


# ---------------------------------------------------------------------------
# straggler-monitor reset (satellite 3)
# ---------------------------------------------------------------------------

def test_straggler_monitor_reset_clears_window_not_warmup():
    mon = StragglerMonitor(factor=3.0, skip_first=2, min_samples=2)
    for t in (9.9, 9.9):                       # warmup: discarded
        mon.observe(t)
    for t in (0.01, 0.01, 0.01):
        mon.observe(t)
    assert mon.observe(1.0)                    # straggler vs 0.01 median
    assert mon.stragglers == 1 and mon.samples == 4
    mon.reset()
    assert mon.stragglers == 0 and mon.samples == 0
    # the warmup skip stays spent: the next sample enters the window
    mon.observe(0.5)
    assert mon.samples == 1


def test_engine_reset_stats_resets_straggler_window(setup):
    cfg, params = setup
    rng = np.random.default_rng(41)
    eng = ServeEngine(cfg, params, max_batch=2, max_seq=16,
                      straggler=StragglerMonitor())
    eng.submit(_mk(rng, cfg, 0, 8, 4))
    eng.run()
    assert eng.straggler.samples > 0
    eng.reset_stats()
    assert eng.straggler.samples == 0 and eng.straggler.stragglers == 0
    stats = eng.stats()
    assert stats["kv_row_copies"] == 0 and stats["prefill_tokens"] == 0


# ---------------------------------------------------------------------------
# oracle integration: the paged kernel as a measurable backend
# ---------------------------------------------------------------------------

def test_oracle_paged_attention_cost_backends():
    from repro.core.oracle import (AnalyticOracle, MeasuredOracle,
                                   MeasurementLog, ReplayOracle)
    an = AnalyticOracle()
    # analytically identical to a dense decode step: fingerprints (and
    # every tuning cache keyed on them) are unchanged by the layout
    assert an.paged_attention_cost(4, 40, 8, 64, n_kv_heads=2) == \
        an.attention_cost(4, 1, 40, 8, 64, window=0)

    log = MeasurementLog()
    mo = MeasuredOracle(record=log)
    t = mo.paged_attention_cost(2, 16, 4, 32, n_kv_heads=2, block_size=8)
    assert t > 0.0
    key = MeasurementLog.paged_attention_key(2, 16, 4, 32, 2, 8, 2)
    assert log.lookup(key) == t
    assert mo.paged_attention_cost(2, 16, 4, 32, n_kv_heads=2,
                                   block_size=8) == t   # memoized

    ro = ReplayOracle(log.copy())
    assert ro.paged_attention_cost(2, 16, 4, 32, n_kv_heads=2,
                                   block_size=8) == t
    # unknown shape: soft fallback to analytic, not a KeyError
    miss = ro.paged_attention_cost(1, 8, 4, 32, n_kv_heads=2, block_size=8)
    assert miss == an.paged_attention_cost(1, 8, 4, 32, n_kv_heads=2)


def test_fixed_latency_prices_paged_layout(setup):
    from repro.core import latency
    from repro.core.oracle import MeasuredOracle, MeasurementLog
    from repro.core.tasks import Workload
    cfg, _ = setup
    wl = Workload(tokens_global=4, dp=1, tp=1, dtype_bytes=2)
    a, _ = latency.fixed_latency(cfg, [], wl, seq_len=1, decode_kv_len=40)
    b, _ = latency.fixed_latency(cfg, [], wl, seq_len=1, decode_kv_len=40,
                                 kv_layout="paged")
    assert a == b                       # analytic backend: identical
    # a measuring backend times the real paged kernel for the paged layout
    log = MeasurementLog()
    mo = MeasuredOracle(record=log)
    p, _ = latency.fixed_latency(cfg, [], wl, seq_len=1, decode_kv_len=40,
                                 kv_layout="paged", oracle=mo,
                                 use_tuning=False)
    assert math.isfinite(p) and p > 0.0
    assert any(k.startswith("paged_attn:") for k in log.entries)

"""Hypothesis property tests on the system's invariants."""
import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.cost_model import Block, matmul_cost
from repro.core.program import Iterator, Program
from repro.core.prune_step import (iterator_step, lcm, lcm_prune_step,
                                   program_prune_step)
from repro.core.ranking import keep_indices

factors_st = st.lists(st.integers(1, 32), min_size=1, max_size=4)


@given(factors_st)
@settings(max_examples=200, deadline=None)
def test_iterator_step_is_min_decrement_bruteforce(factors):
    """iterator_step == min over mutable factors of prod/factor (brute)."""
    it = Iterator("x", tuple(factors), (True,) * len(factors))
    total = math.prod(factors)
    candidates = [total // f for f in factors if f > 1]
    expect = min(candidates) if candidates else total
    assert iterator_step(it) == expect


@given(factors_st, factors_st,
       st.integers(1, 8), st.integers(1, 16))
@settings(max_examples=200, deadline=None)
def test_lcm_step_divisibility(f1, f2, gran, shard):
    its = [Iterator("a", tuple(f1), (True,) * len(f1)),
           Iterator("b", tuple(f2), (True,) * len(f2))]
    step = lcm_prune_step(its, granularity=gran, shard_multiple=shard)
    assert step % gran == 0
    assert step % shard == 0
    assert step % iterator_step(its[0]) == 0
    assert step % iterator_step(its[1]) == 0


@given(st.integers(1, 64), st.integers(1, 64), st.integers(1, 64))
@settings(max_examples=100, deadline=None)
def test_lcm_properties(a, b, c):
    l = lcm(a, b, c)
    assert l % a == 0 and l % b == 0 and l % c == 0
    assert l <= a * b * c


@given(st.integers(1, 32).map(lambda x: x * 128),
       st.integers(1, 16).map(lambda x: x * 128),
       st.integers(1, 16).map(lambda x: x * 128))
@settings(max_examples=50, deadline=None)
def test_prune_step_keeps_lane_alignment(n, bn, bk):
    """TPU adaptation: prune steps over tuned programs are lane multiples."""
    prog_n = Program(m=512, k=512, n=n, block=Block(128, 128, min(bn, n)),
                     latency=1.0)
    prog_k = Program(m=512, k=n, n=512, block=Block(128, min(bk, n), 128),
                     latency=1.0)
    step = program_prune_step([(prog_n, "n"), (prog_k, "k")])
    assert step % 128 == 0 or step >= n


@given(st.integers(1, 6), st.integers(2, 6), st.integers(0, 4))
@settings(max_examples=100, deadline=None)
def test_keep_indices_grouped_uniform(per_group, groups, drop_per_group):
    dim = per_group * groups
    drop_per_group = min(drop_per_group, per_group - 1)
    rng = np.random.default_rng(0)
    scores = rng.random(dim)
    keep = keep_indices(scores, drop_per_group * groups, group=groups)
    assert len(keep) == dim - drop_per_group * groups
    # uniform count kept per contiguous group
    for g in range(groups):
        lo, hi = g * per_group, (g + 1) * per_group
        assert ((keep >= lo) & (keep < hi)).sum() == per_group - drop_per_group
    assert np.all(np.diff(keep) > 0)        # sorted, unique


@given(st.integers(1, 512), st.integers(1, 512), st.integers(1, 512))
@settings(max_examples=100, deadline=None)
def test_cost_model_monotone_in_dims(m, k, n):
    """Bigger GEMMs never cost less under a fixed program."""
    blk = Block(64, 128, 128)
    base = matmul_cost(m, k, n, blk)
    assert matmul_cost(m + 64, k, n, blk) >= base - 1e-12
    assert matmul_cost(m, k + 128, n, blk) >= base - 1e-12
    assert matmul_cost(m, k, n + 128, blk) >= base - 1e-12


@given(st.integers(1, 8), st.integers(1, 8))
@settings(max_examples=50, deadline=None)
def test_cost_model_step_function(i, j):
    """Latency is flat within a block tile and jumps only at boundaries —
    the paper's premise that makes structure-aware prune quanta matter."""
    blk = Block(64, 128, 128)
    n_lo = (i - 1) * 128 + 1
    n_hi = i * 128
    assert matmul_cost(256, 256, n_lo, blk) == matmul_cost(256, 256, n_hi, blk)
    assert matmul_cost(256, 256, n_hi, blk) < matmul_cost(
        256, 256, n_hi + 1, blk)


def test_vmem_budget_respected_by_candidates():
    from repro.core.cost_model import VMEM_BYTES
    from repro.core.tuner import candidate_blocks
    for blk in candidate_blocks(4096, 4096, 4096):
        assert blk.vmem_bytes(2) <= VMEM_BYTES

"""DeploymentArtifact: the export -> load -> serve exit of the pipeline.

Key contracts:
  * round-trip identity: export, cold-start every process cache, load,
    serve — decode outputs are bit-identical to the originating session's
    engine and the tuned fingerprint survives unchanged;
  * the artifact serves without a PruningSession (ServeEngine.from_artifact
    on a path alone);
  * validation on load: unknown schema versions, tampered params, a
    tampered target spec, and a tampered bundled replay log are all
    refused with a clear ArtifactError;
  * a recording measured session exports a replay artifact (its
    calibration log ships inside the directory);
  * session.save()/resume() round-trips a replay oracle through its log
    path, digest-checked.
"""
import json
import os

import jax
import numpy as np
import pytest

from repro.api import (ArtifactError, CPruneConfig, DeploymentArtifact,
                       MeasuredOracle, MeasurementConfig, MeasurementLog,
                       PruningSession, ReplayOracle, TrainHooks, Workload)
from repro.configs import get_reduced_config
from repro.core import clear_tuning_caches
from repro.serve.engine import Request, ServeEngine


@pytest.fixture(autouse=True)
def _cold_caches():
    clear_tuning_caches()
    yield
    clear_tuning_caches()


def _cfg():
    return get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=2, d_model=64, d_ff=512, n_heads=8, n_kv_heads=2,
        head_dim=8, vocab_size=128)


def _hooks(acc=0.9):
    return TrainHooks(short_term_train=lambda p, s: p,
                      eval_acc=lambda p, s: acc)


def _session(cfg, **kw):
    kw.setdefault("workload", Workload(tokens_global=8192))
    kw.setdefault("hooks", _hooks())
    kw.setdefault("pcfg", CPruneConfig(a_g=0.5, alpha=0.5, beta=0.9999,
                                       max_iterations=2, seq_len=64))
    return PruningSession(cfg, **kw)


def _decode(engine, cfg, n_req=2, n_new=4, seed=0):
    rng = np.random.default_rng(seed)
    for i in range(n_req):
        engine.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, 8).astype(np.int32), max_new_tokens=n_new))
    stats = engine.run()
    return [r.output for r in engine.done], stats


def _edit_json(path, mutate):
    fn = os.path.join(path, "artifact.json")
    blob = json.loads(open(fn).read())
    mutate(blob)
    with open(fn, "w") as f:
        json.dump(blob, f)


# ---------------------------------------------------------------------------
# Round-trip identity
# ---------------------------------------------------------------------------

def test_export_load_serve_round_trip_is_bit_identical(tmp_path):
    cfg = _cfg()
    session = _session(cfg)
    res = session.prune(strategy="cprune")
    assert any(h.accepted for h in res.history)

    session_fp = session.tune().tuned_fingerprint
    out_a, _ = _decode(session.serve(max_batch=2, max_seq=24), cfg)

    art = session.export(str(tmp_path / "art"), max_batch=2, max_seq=24)
    assert art.tuned_fingerprint == session_fp
    assert art.metadata["final_acc"] == res.final_acc
    assert art.metadata["strategy"] == "cprune"
    assert art.metadata["predicted_step_s"] is not None

    # a fresh interpreter state: every process-wide cache cold
    clear_tuning_caches()
    loaded = DeploymentArtifact.load(str(tmp_path / "art"))
    assert loaded.tuned_fingerprint == session_fp
    assert loaded.tuned_digest == art.tuned_digest

    engine = ServeEngine.from_artifact(loaded, max_batch=2, max_seq=24)
    assert engine.predicted_step_s == loaded.metadata["predicted_step_s"]
    out_b, stats = _decode(engine, cfg)
    assert out_b == out_a                       # bit-identical decode
    assert stats["requests"] == 2
    # pruned site dims survived the round trip
    assert {s.site_id: s.dim for s in loaded.sites} \
        == {s.site_id: s.dim for s in session.sites}


def test_artifact_serves_from_path_without_a_session(tmp_path):
    cfg = _cfg()
    session = _session(cfg)
    session.prune(strategy="uniform_l1", ratio=0.5)
    metadata_lat = session.export(
        str(tmp_path / "art")).metadata["latency_total_s"]
    clear_tuning_caches()
    # path in, engine out — no PruningSession anywhere in this flow
    engine = ServeEngine.from_artifact(str(tmp_path / "art"),
                                       max_batch=2, max_seq=24)
    outputs, stats = _decode(engine, cfg)
    assert stats["total_new_tokens"] == 8 and all(outputs)
    # and the embedded table recomputes to exactly the exported metadata
    clear_tuning_caches()
    loaded = DeploymentArtifact.load(str(tmp_path / "art"))
    assert loaded.latency_report().total_s == metadata_lat


def test_serve_defaults_and_prediction_recompute(tmp_path):
    cfg = _cfg()
    session = _session(cfg)
    art = session.export(str(tmp_path / "art"), max_batch=4, max_seq=32)
    loaded = DeploymentArtifact.load(str(tmp_path / "art"))
    # defaulted dims reuse the stored prediction
    engine = ServeEngine.from_artifact(loaded)
    assert engine.max_batch == 4 and engine.max_seq == 32
    assert engine.predicted_step_s == art.metadata["predicted_step_s"]
    # other dims re-derive a (different) prediction from the artifact
    engine2 = ServeEngine.from_artifact(loaded, max_batch=8, max_seq=64)
    assert engine2.predicted_step_s is not None
    assert engine2.predicted_step_s != engine.predicted_step_s


# ---------------------------------------------------------------------------
# Validation on load
# ---------------------------------------------------------------------------

def test_load_rejects_unknown_schema_version(tmp_path):
    session = _session(_cfg())
    session.export(str(tmp_path / "art"))

    _edit_json(str(tmp_path / "art"),
               lambda b: b.update(schema_version=999))
    with pytest.raises(ArtifactError, match="schema version"):
        DeploymentArtifact.load(str(tmp_path / "art"))
    with pytest.raises(ArtifactError, match="no deployment artifact"):
        DeploymentArtifact.load(str(tmp_path / "nowhere"))


def test_load_rejects_mismatched_target_fingerprint(tmp_path):
    session = _session(_cfg())
    session.export(str(tmp_path / "art"))

    def retarget(blob):
        blob["target_spec"]["hbm_bw"] = blob["target_spec"]["hbm_bw"] * 2

    _edit_json(str(tmp_path / "art"), retarget)
    with pytest.raises(ArtifactError, match="target"):
        DeploymentArtifact.load(str(tmp_path / "art"))

    # a consistent edit of spec + fingerprint still trips the tuned-table
    # check: the table was not tuned for that target
    session.export(str(tmp_path / "art2"))

    def retarget_consistent(blob):
        blob["target_spec"]["hbm_bw"] = blob["target_spec"]["hbm_bw"] * 2
        blob["fingerprints"]["target"][2] = blob["target_spec"]["hbm_bw"]

    _edit_json(str(tmp_path / "art2"), retarget_consistent)
    with pytest.raises(ArtifactError, match="different target/oracle"):
        DeploymentArtifact.load(str(tmp_path / "art2"))


def test_load_wraps_any_malformed_content_in_artifact_error(tmp_path):
    """The documented contract: missing/malformed/invalid all surface as
    ArtifactError, never raw FileNotFoundError/JSONDecodeError."""
    session = _session(_cfg())
    session.export(str(tmp_path / "a"))
    os.remove(str(tmp_path / "a" / "params.npz"))
    with pytest.raises(ArtifactError, match="malformed"):
        DeploymentArtifact.load(str(tmp_path / "a"))

    session.export(str(tmp_path / "b"))
    with open(str(tmp_path / "b" / "artifact.json"), "w") as f:
        f.write("{not json")
    with pytest.raises(ArtifactError, match="malformed"):
        DeploymentArtifact.load(str(tmp_path / "b"))

    session.export(str(tmp_path / "c"))
    _edit_json(str(tmp_path / "c"),
               lambda blob: blob["table"]["tasks"][0].update(task_id=99))
    with pytest.raises(ArtifactError, match="malformed"):
        DeploymentArtifact.load(str(tmp_path / "c"))


def test_from_artifact_derives_prediction_when_export_skipped_it(tmp_path):
    """predict_step=False at export must not pin serving to 'no
    prediction': from_artifact re-derives it from the artifact's own
    target + oracle."""
    session = _session(_cfg())
    DeploymentArtifact.from_session(session, max_batch=2, max_seq=24,
                                    predict_step=False).save(
        str(tmp_path / "art"))
    loaded = DeploymentArtifact.load(str(tmp_path / "art"))
    assert loaded.metadata["predicted_step_s"] is None
    engine = ServeEngine.from_artifact(loaded)       # default dims
    assert engine.predicted_step_s is not None
    assert engine.predicted_step_s \
        == loaded.predict_step_s(2, 24)


def test_load_rejects_tampered_params(tmp_path):
    session = _session(_cfg())
    art = session.export(str(tmp_path / "art"))
    flat = dict(np.load(os.path.join(str(tmp_path / "art"), "params.npz")))
    key = sorted(flat)[0]
    flat[key] = flat[key] + 1.0
    with open(os.path.join(str(tmp_path / "art"), "params.npz"), "wb") as f:
        np.savez(f, **flat)
    with pytest.raises(ArtifactError, match="params"):
        DeploymentArtifact.load(str(tmp_path / "art"))
    assert art is not None


# ---------------------------------------------------------------------------
# Measured/replay artifacts
# ---------------------------------------------------------------------------

_FAST = MeasurementConfig(warmup=0, repeats=1, trim=0, measure_top_k=1,
                          max_grid_steps=1)


def test_recording_measured_session_exports_replay_artifact(tmp_path):
    cfg = get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=2, d_model=64, d_ff=256, n_heads=4, n_kv_heads=2,
        head_dim=8, vocab_size=128)
    session = PruningSession(
        cfg, oracle=MeasuredOracle(_FAST, record=MeasurementLog(_FAST)),
        workload=Workload(tokens_global=256), hooks=_hooks(),
        pcfg=CPruneConfig(a_g=0.0, seq_len=32))
    art = session.export(str(tmp_path / "art"), max_batch=2, max_seq=16)
    # the calibration log ships inside the artifact; the table replays
    assert art.oracle.name == "replay"
    assert os.path.exists(str(tmp_path / "art" / "replay_log.json"))
    assert art.metadata["predicted_step_s"] is not None

    clear_tuning_caches()
    loaded = DeploymentArtifact.load(str(tmp_path / "art"))
    assert loaded.oracle.name == "replay"
    # deterministic replay: recomputed latency equals exported metadata
    assert loaded.latency_report().total_s \
        == art.metadata["latency_total_s"]
    _, stats = _decode(loaded.serve(max_batch=2, max_seq=16), cfg)
    assert stats["requests"] == 2

    # a tampered bundled log is refused
    log_fn = str(tmp_path / "art" / "replay_log.json")
    blob = json.loads(open(log_fn).read())
    k = sorted(blob["entries"])[0]
    blob["entries"][k] = blob["entries"][k] * 2
    with open(log_fn, "w") as f:
        json.dump(blob, f)
    with pytest.raises(ArtifactError, match="replay log"):
        DeploymentArtifact.load(str(tmp_path / "art"))


def test_in_memory_serving_snapshot_cannot_be_saved():
    session = _session(_cfg())
    art = DeploymentArtifact.from_session(session, include_table=False)
    assert art.table is None
    with pytest.raises(ArtifactError, match="serving snapshot"):
        art.save("/tmp/should_never_exist_artifact")


# ---------------------------------------------------------------------------
# Satellite: checkpoint round-trip of the replay oracle's log path
# ---------------------------------------------------------------------------

def test_session_save_resume_roundtrips_replay_log(tmp_path):
    cfg = get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=2, d_model=64, d_ff=256, n_heads=4, n_kv_heads=2,
        head_dim=8, vocab_size=128)
    base = PruningSession(cfg, workload=Workload(tokens_global=256),
                          pcfg=CPruneConfig(a_g=0.0, seq_len=32))
    log_path = str(tmp_path / "calib.json")
    base.calibrate(log_path, config=_FAST)

    session = PruningSession(cfg, oracle=ReplayOracle.from_file(log_path),
                             workload=Workload(tokens_global=256),
                             pcfg=CPruneConfig(a_g=0.0, seq_len=32))
    session.save(str(tmp_path / "ckpt"))
    meta = json.loads((tmp_path / "ckpt" / "session.json").read_text())
    assert meta["oracle"] == "replay"
    assert meta["oracle_log"] == os.path.abspath(log_path)

    resumed = PruningSession.resume(str(tmp_path / "ckpt"))
    assert resumed.oracle.name == "replay"
    assert resumed.oracle.log.digest() == session.oracle.log.digest()
    # the resumed session scores with the log, no re-pointing needed
    assert resumed.latency_report().total_s \
        == session.latency_report().total_s

    # a log edited after save is refused on resume
    blob = json.loads(open(log_path).read())
    k = sorted(blob["entries"])[0]
    blob["entries"][k] = blob["entries"][k] * 2
    with open(log_path, "w") as f:
        json.dump(blob, f)
    with pytest.raises(ValueError, match="changed since"):
        PruningSession.resume(str(tmp_path / "ckpt"))

    # a missing log falls back (with a warning), not a crash
    os.remove(log_path)
    with pytest.warns(UserWarning, match="missing"):
        resumed2 = PruningSession.resume(str(tmp_path / "ckpt"))
    assert resumed2.oracle.name == "analytic"

"""repro.analysis: golden diagnostics, paged-KV sanitizer, export stamp.

Key contracts:
  * golden diagnostics — a misaligned matmul block is ``K001``, an
    edge-target flash-attention config is ``K003 vmem-overflow``, a
    hand-built dangling block table is a sanitizer error, and a clean
    granite config is zero errors on all three passes;
  * the checker is pure — no global oracle/tuning-cache/target state
    survives a check run (``clear_tuning_caches()`` not required after);
  * a pool-exhausted paged admission releases every block it acquired
    (the cohort is re-queued against an intact pool);
  * ``save()`` stamps ``checks: {passed, codes}`` into artifact.json;
    ``load(strict_checks=True)`` refuses unstamped artifacts, the
    default warns and loads them.
"""
import json
import os

import jax
import numpy as np
import pytest

from repro.analysis import jaxpr_audit as ja
from repro.analysis import kernels as ak
from repro.analysis.diagnostics import (DIAGNOSTIC_CODES, AnalysisReport,
                                        Diagnostic)
from repro.analysis.kv_sanitizer import (check_allocator, check_cow,
                                         check_engine)
from repro.api import (ArtifactError, CPruneConfig, DeploymentArtifact,
                       PruningSession, TrainHooks, Workload)
from repro.api.targets import get_target
from repro.configs import get_config, get_reduced_config
from repro.core import clear_tuning_caches
from repro.core import oracle as oracle_mod
from repro.core import tuning_cache
from repro.core.cost_model import Block
from repro.models.model import init_params
from repro.models.paged_cache import RESERVED_BLOCKS, BlockAllocator
from repro.serve.engine import Request, ServeEngine
from repro.serve.scheduler import SchedulerConfig

GRANITE = "granite_moe_1b_a400m"


def _codes(diags):
    return {d.code for d in diags}


def _errors(diags):
    return [d for d in diags if d.severity == "error"]


# ---------------------------------------------------------------------------
# Diagnostic records
# ---------------------------------------------------------------------------

def test_diagnostic_rejects_unknown_code_and_severity():
    with pytest.raises(ValueError):
        Diagnostic("K999", "error", "x", "nope")
    with pytest.raises(ValueError):
        Diagnostic("K001", "fatal", "x", "nope")


def test_diagnostic_str_and_report_exit_semantics():
    d = Diagnostic("K003", "error", "layer.qkv", "needs 70MB of 64MB",
                   fix_hint="shrink the block")
    s = str(d)
    assert "K003" in s and "vmem-overflow" in s and "layer.qkv" in s
    rep = AnalysisReport().extend([d]).extend(
        [Diagnostic("J001", "warning", "y", "meh")])
    assert not rep.ok and len(rep.errors) == 1 and len(rep.warnings) == 1
    assert rep.codes == ["J001", "K003"]
    assert all(c in DIAGNOSTIC_CODES for c in rep.codes)


# ---------------------------------------------------------------------------
# Kernel static checker: golden diagnostics
# ---------------------------------------------------------------------------

def test_k001_misaligned_matmul_block():
    # bm=100 is neither the whole M dim nor sublane(8)-aligned
    call = ak.describe_matmul(1024, 1024, 1024, Block(100, 256, 256))
    diags = ak.check_call(call, get_target("tpu_v5e"))
    assert "K001" in _codes(_errors(diags))


def test_k003_flash_attention_overflows_edge():
    call = ak.describe_flash_attention(1, 2048, 2048, 8, 128,
                                       bq=1024, bk=1024)
    edge = _errors(ak.check_call(call, get_target("edge")))
    assert _codes(edge) == {"K003"}
    # the same blocks fit a v5e comfortably
    assert not _errors(ak.check_call(call, get_target("tpu_v5e")))


def test_k002_degenerate_grid():
    call = ak.describe_matmul(0, 256, 256, Block(8, 128, 128))
    assert "K002" in _codes(_errors(ak.check_call(call,
                                                  get_target("tpu_v5e"))))


def test_aligned_tuned_blocks_are_clean():
    # a tuner-shaped block: sublane/lane aligned, VMEM-sized
    call = ak.describe_matmul(512, 1024, 2048, Block(64, 256, 256))
    assert ak.check_call(call, get_target("tpu_v5e")) == []


# ---------------------------------------------------------------------------
# Jaxpr auditor: golden diagnostics
# ---------------------------------------------------------------------------

def test_j002_flags_host_transfer_inside_step():
    def step(x, w):
        return jax.device_put(x) @ w
    jaxpr = jax.make_jaxpr(step)(
        jax.ShapeDtypeStruct((8, 16), np.float32),
        jax.ShapeDtypeStruct((16, 32), np.float32))
    diags = ja.audit_jaxpr(jaxpr, site="t", expect_bf16=False)
    assert "J002" in _codes(_errors(diags))


def test_j001_flags_f32_gemm_in_bf16_step():
    jaxpr = jax.make_jaxpr(lambda x, w: x @ w)(
        jax.ShapeDtypeStruct((8, 16), np.float32),
        jax.ShapeDtypeStruct((16, 32), np.float32))
    diags = ja.audit_jaxpr(jaxpr, site="t", expect_bf16=True)
    assert _codes(diags) == {"J001"}
    assert not _errors(diags)            # advisory, not an error
    # the same trace in an f32-configured model is silent
    assert ja.audit_jaxpr(jaxpr, site="t", expect_bf16=False) == []


def test_j004_serve_shape_hazards():
    diags = ja.audit_serve_shapes(
        SchedulerConfig(compact="exact"), max_batch=6, max_seq=100)
    assert _codes(diags) == {"J004"}
    assert len(diags) == 3               # exact compaction, batch, seq
    assert ja.audit_serve_shapes(SchedulerConfig(),
                                 max_batch=8, max_seq=512) == []


# ---------------------------------------------------------------------------
# Paged-KV sanitizer: hand-built defects
# ---------------------------------------------------------------------------

def test_v003_dangling_table_entry():
    alloc = BlockAllocator(8)
    b = alloc.alloc()
    table = np.array([[b]], np.int32)
    alloc.decref(b)                      # freed while the row points at it
    assert "V003" in _codes(check_allocator(alloc, [table]))


def test_v001_leak_unreachable_block():
    alloc = BlockAllocator(8)
    alloc.alloc()                        # acquired, never tabled
    diags = check_allocator(alloc, [])
    assert "V001" in _codes(diags)


def test_v002_refcount_vs_occurrences():
    alloc = BlockAllocator(8)
    b = alloc.alloc()                    # refcount 1...
    table = np.array([[b, b]], np.int32)  # ...but two live entries
    assert "V002" in _codes(check_allocator(alloc, [table]))


def test_v005_free_list_corruption():
    alloc = BlockAllocator(8)
    b = alloc.alloc()
    alloc.decref(b)
    alloc._free.append(b)                # simulate a double-free
    assert "V005" in _codes(check_allocator(alloc, []))


def test_v004_cow_violation_on_shared_frontier():
    alloc = BlockAllocator(8)
    b = alloc.alloc()
    alloc.incref(b)                      # shared by two rows
    table = np.array([[b], [b]], np.int32)
    diags = check_cow(alloc, table, [True, True], pos=5, plen=4,
                      block_size=16)
    assert _codes(diags) == {"V004"}
    # no decode write yet -> nothing to check
    assert check_cow(alloc, table, [True, True], pos=4, plen=4,
                     block_size=16) == []


def test_sanitizer_clean_allocator():
    alloc = BlockAllocator(8)
    bids = [alloc.alloc() for _ in range(3)]
    table = np.array([bids], np.int32)
    assert check_allocator(alloc, [table]) == []


# ---------------------------------------------------------------------------
# The clean golden config: zero errors on all three passes
# ---------------------------------------------------------------------------

def test_clean_granite_zero_errors_on_all_three_passes():
    cfg = get_config(GRANITE)
    tgt = get_target("tpu_v5e")
    assert not _errors(ak.check_model_kernels(cfg, tgt))
    assert not _errors(ja.audit_model(cfg, max_batch=2, max_seq=64))

    rcfg = get_reduced_config(GRANITE)
    params = init_params(jax.random.PRNGKey(0), rcfg)
    eng = ServeEngine(rcfg, params, max_batch=2, max_seq=32,
                      scheduler=SchedulerConfig(debug_kv=True, page_size=8))
    rng = np.random.default_rng(0)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=rng.integers(
            1, rcfg.vocab_size, 9).astype(np.int32), max_new_tokens=4))
    stats = eng.serve_forever()
    assert stats["requests"] == 3
    assert stats["kv_debug_checks"] > 0
    assert stats["kv_debug_violations"] == 0
    assert ja.audit_engine_donation(eng) == []


# ---------------------------------------------------------------------------
# Satellite: the checker must not mutate global state
# ---------------------------------------------------------------------------

def test_check_run_leaves_global_state_untouched():
    clear_tuning_caches()
    fp_before = tuning_cache.target_fingerprint()
    oracle_before = oracle_mod.active_oracle()
    assert len(tuning_cache.global_cache()._store) == 0

    # a target different from the ambient one: restoration must be exact
    diags = ak.check_model_kernels(get_config(GRANITE),
                                   get_target("tpu_v4"))
    assert not _errors(diags)

    # no clear_tuning_caches() in between — everything is already clean
    assert tuning_cache.target_fingerprint() == fp_before
    assert oracle_mod.active_oracle() is oracle_before
    assert len(tuning_cache.global_cache()._store) == 0


# ---------------------------------------------------------------------------
# Satellite: pool-exhausted admission must not leak blocks
# ---------------------------------------------------------------------------

def test_admission_exhaustion_releases_every_block():
    cfg = get_reduced_config("qwen3_1_7b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    # 6 usable blocks; a width-2 cohort of 30-token prompts needs 8
    eng = ServeEngine(cfg, params, max_batch=4, max_seq=64,
                      scheduler=SchedulerConfig(page_size=8),
                      kv_pool_blocks=RESERVED_BLOCKS + 6)
    for i in range(2):
        eng.submit(Request(rid=i, prompt=rng.integers(
            1, 50, 30).astype(np.int32), max_new_tokens=4))
    with pytest.raises(RuntimeError):
        eng.step()
    # the failed cohort was re-queued and the pool is intact: no block
    # held, nothing leaked, the sanitizer agrees
    assert eng.kv_allocator.blocks_in_use == 0
    assert check_engine(eng) == []
    # chunked admission path, same exhaustion, same guarantee
    eng2 = ServeEngine(cfg, params, max_batch=4, max_seq=64,
                       scheduler=SchedulerConfig(page_size=8,
                                                 prefill_chunk=16),
                       kv_pool_blocks=RESERVED_BLOCKS + 3)
    eng2.submit(Request(rid=0, prompt=rng.integers(
        1, 50, 40).astype(np.int32), max_new_tokens=4))
    with pytest.raises(RuntimeError):
        eng2.step()
    assert eng2.kv_allocator.blocks_in_use == 0
    assert check_engine(eng2) == []


# ---------------------------------------------------------------------------
# Export stamp + strict load
# ---------------------------------------------------------------------------

def _stamped_artifact(tmp_path):
    cfg = get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=2, d_model=64, d_ff=512, n_heads=8, n_kv_heads=2,
        head_dim=8, vocab_size=128)
    session = PruningSession(
        cfg, workload=Workload(tokens_global=8192),
        hooks=TrainHooks(short_term_train=lambda p, s: p,
                         eval_acc=lambda p, s: 0.9),
        pcfg=CPruneConfig(a_g=0.5, alpha=0.5, beta=0.9999,
                          max_iterations=2, seq_len=64))
    session.prune(strategy="uniform_l1", ratio=0.5)
    path = str(tmp_path / "art")
    return session.export(path, max_batch=2, max_seq=24), path


def test_export_stamps_checks_and_strict_load_accepts(tmp_path):
    clear_tuning_caches()
    art, path = _stamped_artifact(tmp_path)
    with open(os.path.join(path, "artifact.json")) as f:
        blob = json.load(f)
    assert blob["checks"]["passed"] is True
    assert art.checks == blob["checks"]
    loaded = DeploymentArtifact.load(path, strict_checks=True)
    assert loaded.checks["passed"] is True


def test_unstamped_artifact_warns_by_default_and_strict_refuses(tmp_path):
    clear_tuning_caches()
    _, path = _stamped_artifact(tmp_path)
    fn = os.path.join(path, "artifact.json")
    with open(fn) as f:
        blob = json.load(f)
    del blob["checks"]                   # a pre-analysis export
    with open(fn, "w") as f:
        json.dump(blob, f)
    with pytest.warns(UserWarning, match="no static-analysis stamp"):
        DeploymentArtifact.load(path)
    with pytest.raises(ArtifactError, match="strict_checks"):
        DeploymentArtifact.load(path, strict_checks=True)
    # a stamp recording errors is refused outright, strict or not
    blob["checks"] = {"passed": False, "codes": ["K003"]}
    with open(fn, "w") as f:
        json.dump(blob, f)
    with pytest.raises(ArtifactError, match="K003"):
        DeploymentArtifact.load(path)

"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see 1 device; only launch/dryrun.py forces 512 host devices (and the
distributed integration tests spawn subprocesses with their own flags)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced_config


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=16, seed=0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    if cfg.frontend == "audio_frames":
        return {
            "frames": jax.random.normal(ks[0], (B, S, cfg.d_model),
                                        jnp.float32),
            "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
            "mask": jax.random.uniform(ks[2], (B, S)) < 0.4,
        }
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)}
    if cfg.frontend == "vision_patches":
        F = min(cfg.frontend_seq, S // 2)
        batch["patch_embeds"] = jax.random.normal(
            ks[1], (B, F, cfg.d_model), jnp.float32)
    return batch

"""Sharding rules: divisibility fallbacks, ZeRO-3 gather specs, cache specs.

These run on a 1-device fake mesh view (spec construction is pure); the
behavioural checks on real multi-device meshes live in test_distributed.py.
"""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config, get_reduced_config
from repro.launch import specs
from repro.models.model import Model
from repro.sharding import rules


@pytest.fixture(scope="module")
def mesh():
    # abstract mesh for spec construction (no computation launched)
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    m = Mesh(devs, ("data", "model"))
    # patch axis sizes to production values for divisibility logic
    return m


class FakeMesh:
    """Mesh stand-in with production axis sizes for spec math."""
    shape = {"data": 16, "model": 16}


def test_param_specs_shard_big_dims_and_replicate_norms():
    cfg = get_config("qwen3_1_7b")
    params = specs.param_specs(cfg)
    ps = rules.param_pspecs(params, FakeMesh())
    stack = ps["stack"]["pos0"]
    # FFN: (L, d, ff) -> (None, data, model)
    assert stack["ffn"]["w_up"] == P(None, "data", "model")
    assert stack["ffn"]["w_down"] == P(None, "model", "data")
    # attention q: heads 16 divisible by 16 -> sharded
    assert stack["mixer"]["wq"] == P(None, "data", "model", None)
    # kv heads 8 not divisible by 16 -> replicated on that dim
    assert stack["mixer"]["wk"] == P(None, "data", None, None)
    # norms replicated
    assert stack["norm1"]["scale"] == P()
    # embedding: vocab over model, d over data
    assert ps["embed"] == P("model", "data")


def test_param_specs_qwen2vl_heads_fallback():
    cfg = get_config("qwen2_vl_2b")          # 12 heads, not divisible by 16
    params = specs.param_specs(cfg)
    ps = rules.param_pspecs(params, FakeMesh())
    assert ps["stack"]["pos0"]["mixer"]["wq"] == P(None, "data", None, None)
    # but FFN still shards (8960 % 16 == 0)
    assert ps["stack"]["pos0"]["ffn"]["w_up"] == P(None, "data", "model")


def test_batch_specs_drop_unshardable_batch():
    b1 = {"tokens": jax.ShapeDtypeStruct((256, 128), np.int32)}
    b2 = {"tokens": jax.ShapeDtypeStruct((1, 128), np.int32)}  # long_500k
    assert rules.batch_pspecs(b1, FakeMesh())["tokens"] == P("data", None)
    assert rules.batch_pspecs(b2, FakeMesh())["tokens"] == P(None, None)


def test_cache_specs_shard_seq_over_model():
    cfg = get_reduced_config("qwen3_1_7b")
    model = Model(cfg)
    caches = jax.eval_shape(lambda: model.init_caches(32, 512))
    cs = rules.cache_pspecs(model, caches, FakeMesh())
    kv = cs["stack"]["pos0"]
    assert kv.k == P(None, "data", "model", None, None)
    assert kv.slot_pos == P(None, None)


def test_fit_spec_divisibility():
    assert rules.fit_spec(("data", "model"), (32, 32), FakeMesh()) == \
        P("data", "model")
    assert rules.fit_spec(("data", "model"), (7, 32), FakeMesh()) == \
        P(None, "model")
    assert rules.fit_spec((("pod", "data"),), (32,),
                          type("M", (), {"shape": {"pod": 2, "data": 16,
                                                   "model": 16}})()) == \
        P(("pod", "data"))
